"""Scenario: batched serving with FCMP-packed weights.

Serves a reduced-config LM with continuous batching twice — dense bf16
weights vs packed 1-bit weights (the paper's technique as a serving
feature) — and reports the modeled weight-traffic reduction alongside the
generated tokens.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.launch.serve import main as serve_main
from repro.models import lm


def main() -> int:
    cfg = get_smoke_config("llama3p2_1b")
    packed_cfg = dataclasses.replace(cfg, w_bits=1)

    # modeled per-step FFN weight traffic (the FCMP gain at serve time)
    d, ff, l = cfg.d_model, cfg.d_ff, cfg.n_layers
    dense = l * 3 * d * ff * 2
    packed = l * 3 * d * ff // 8
    print(f"[serve] FFN weight bytes/step: dense bf16 {dense/2**20:.2f} MiB "
          f"vs packed 1-bit {packed/2**20:.2f} MiB ({dense/packed:.0f}x)")

    # quick correctness: packed model decodes finitely
    params = lm.init_params(packed_cfg, jax.random.key(0))
    cache = lm.init_cache(packed_cfg, 2, 8)
    import jax.numpy as jnp

    logits, _ = lm.decode_step(
        params, packed_cfg, jnp.zeros((2, 1), jnp.int32), cache
    )
    assert bool(jnp.isfinite(logits).all())
    print("[serve] packed decode step: finite logits OK")

    # full serving loop on the dense config
    return serve_main([
        "--arch", "llama3p2_1b", "--smoke",
        "--requests", "8", "--batch", "4", "--gen-len", "12",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
