"""Scenario: continuous-batching serving with FCMP-packed weights.

Serves a reduced-config LM through the ``runtime.scheduler`` subsystem —
a shared block-granular KV pool with token-budget admission — comparing
dense bf16 weights vs packed 1-bit weights (the paper's technique as a
serving feature), and reports pool utilization, TTFT, and the modeled
weight-traffic reduction alongside the generated tokens.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.runtime.kv_pool import KVPool, choose_block_tokens
from repro.runtime.scheduler import Scheduler


def serve_once(cfg, *, requests=8, slots=4, prompt_len=16, gen_len=12):
    params = lm.init_params(cfg, jax.random.key(0))
    total = prompt_len + gen_len
    block_tokens = choose_block_tokens([total] * requests)
    max_len = total + block_tokens
    pool = KVPool.for_slots(
        cfg, slots=slots, max_len=max_len, block_tokens=block_tokens
    )

    def finite_greedy(lg):  # every prefill/decode logits must be finite
        assert np.isfinite(lg).all(), "non-finite logits"
        return np.argmax(lg, axis=-1)

    sched = Scheduler(
        cfg, params, pool, slots=slots, max_len=max_len, sample=finite_greedy
    )
    rng = np.random.default_rng(0)
    for _ in range(requests):
        sched.submit(
            rng.integers(0, cfg.vocab, size=(prompt_len,)).astype(np.int32),
            gen_len,
        )
    stats = sched.run()
    assert stats.completed == requests
    assert all(len(v) == gen_len for v in sched.outputs().values())
    return stats, block_tokens


def main() -> int:
    cfg = get_smoke_config("llama3p2_1b")
    packed_cfg = dataclasses.replace(cfg, w_bits=1)

    # modeled per-step FFN weight traffic (the FCMP gain at serve time)
    d, ff, l = cfg.d_model, cfg.d_ff, cfg.n_layers
    dense = l * 3 * d * ff * 2
    packed = l * 3 * d * ff // 8
    print(f"[serve] FFN weight bytes/step: dense bf16 {dense/2**20:.2f} MiB "
          f"vs packed 1-bit {packed/2**20:.2f} MiB ({dense/packed:.0f}x)")

    for label, c in (("dense", cfg), ("packed-1bit", packed_cfg)):
        stats, block_tokens = serve_once(c)
        print(
            f"[serve/{label}] {stats.completed} requests, "
            f"{stats.generated_tokens} tokens in {stats.prefill_steps} "
            f"prefill + {stats.decode_steps} decode steps "
            f"(block_tokens={block_tokens}, "
            f"pool utilization {stats.steady_state_utilization*100:.1f}%, "
            f"TTFT {stats.mean_ttft*1e3:.0f} ms)"
        )
    print("[serve] packed decode through the KV pool: finite outputs OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
