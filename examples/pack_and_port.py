"""Scenario: the paper's §V porting experiments, both directions.

(a) FPGA: ``launch.port``'s device sweep answers the §V question for the
    paper's own accelerators — CNV-W1A1 ports Zynq 7020 -> 7012S with
    zero throughput loss once FCMP-packed (the baseline no longer fits),
    and the binary ResNet-50 ports U250 -> U280 losing ~32% via FCMP vs
    ~51% via 2x folding.
(b) TPU adaptation: the same trade on the TPU tier ladder — the
    ``runtime.residency`` planner packs a model's FFN weight blocks into
    a VMEM budget (bin-packed into shared (8, 128) tile groups by the
    paper's solvers) and compares serving the FCMP-packed model vs dense
    weights per tier under a roofline decode model.
(c) Executable plan: compile a residency plan for a smoke config and
    show the budgeted weight set a ``--vmem-budget`` serve run executes
    (hot blocks pinned, cold layers streamed at the GALS R_F ring depth).

Run:  PYTHONPATH=src python examples/pack_and_port.py
"""

import dataclasses

from repro.configs import get_smoke_config
from repro.launch.port import accel_port_rows, lm_port_rows
from repro.runtime.residency import TrafficProfile, compile_residency_plan


def fpga_ports() -> None:
    print("== (a) FPGA ports: the launch.port device sweep ==")
    for arch, target in (("cnv_w1a1", "zynq7012s"), ("rn50_w2a2", "u280")):
        rows = {r["device"]: r for r in accel_port_rows(arch)}
        r = rows[target]
        print(f"  {arch} -> {target}: baseline {r['baseline_brams']} BRAM "
              f"({'fits' if r['baseline_fits'] else 'NO FIT'}), "
              f"packed {r['packed_brams']} BRAM "
              f"({'fits' if r['packed_fits'] else 'NO FIT'}, "
              f"+{r['packed_lut_overhead_k']}k LUT)")
        print(f"    delta_FPS: FCMP {r['fcmp_delta_fps_pct']}% vs "
              f"2x folding {r['fold2_delta_fps_pct']}% -> "
              f"recommended: {r['recommended']}")


def tpu_ladder() -> None:
    print("== (b) TPU tier ladder: packed vs dense serving (llama3.2-1b) ==")
    rows = lm_port_rows("llama3p2_1b", quant=1, lanes=8)
    for r in rows:
        extra = (
            f", {r['fcmp_vs_dense_speedup_pct']:+.0f}% vs dense"
            if "fcmp_vs_dense_speedup_pct" in r else ""
        )
        print(f"  {r['device']:4s} {r['variant']:12s} "
              f"resident {100*r['resident_fraction']:5.1f}%  "
              f"stream {r['streamed_mib_per_step']:8.2f} MiB/step  "
              f"{r['bound']:7s}-bound  {r['tokens_per_s']:9.1f} tok/s"
              f"{extra}")


def executable_plan() -> None:
    print("== (c) A compiled, executable residency plan (smoke config) ==")
    cfg = dataclasses.replace(get_smoke_config("smollm_360m"), w_bits=1)
    total = sum(
        b.padded_bytes()
        for b in compile_residency_plan(
            cfg, vmem_budget_bytes=0, traffic=TrafficProfile(lanes=2)
        ).blocks
    )
    plan = compile_residency_plan(
        cfg,
        vmem_budget_bytes=total // 2,
        traffic=TrafficProfile(lanes=2, prompt_len=16, gen_len=16),
    )
    s = plan.summary()
    mask = plan.layer_stream_mask(cfg)
    print(f"  {s['resident_blocks']}/{s['n_blocks']} blocks pinned in "
          f"{s['vmem_budget_mib']} MiB, HBM re-stream traffic cut "
          f"{100*s['hbm_traffic_reduction']:.0f}%")
    print(f"  layer stream mask {mask} at ring depth {s['stream_ahead']} "
          f"(R_F) — the set `serve --vmem-budget` decodes against, "
          "token-identical to the unbudgeted path")


if __name__ == "__main__":
    fpga_ports()
    tpu_ladder()
    executable_plan()
