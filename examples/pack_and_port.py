"""Scenario: the paper's §V porting experiments, both directions.

(a) Zynq: pack CNV-W1A1 with FCMP and port it from the 7020 to the
    smaller/cheaper 7012S with zero throughput loss (paper Table V).
(b) Alveo: compare the two ways of fitting the binary ResNet-50 into the
    smaller U280 — FCMP packing (32% slower) vs 2x folding (51% slower):
    FCMP wins by ~38%.
(c) TPU adaptation: the same trade on the v5e — FCMP-packed 1-bit weights
    cut the weight HBM-traffic roofline term 16x; plan the VMEM residency
    of the packed blocks (the BRAM-packing analogue).

Run:  PYTHONPATH=src python examples/pack_and_port.py
"""

import dataclasses

from repro.configs import get_accelerator, get_config
from repro.core.efficiency import baseline_report, device_utilization, report
from repro.core.gals import GalsOperatingPoint, folding_delta_fps
from repro.core.packing import PackItem, pack_genetic
from repro.core.resource_model import DEVICES, TPU_V5E
from repro.core.vmem_plan import WeightBlock, plan_vmem_residency


def zynq_port() -> None:
    print("== (a) CNV-W1A1: Zynq 7020 -> 7012S ==")
    acc = get_accelerator("cnv_w1a1")
    bufs = acc.buffers()
    base = baseline_report("base", bufs)
    packed = report(
        "P4", pack_genetic([PackItem(b) for b in bufs], acc.ga)
    )
    for dev_name in ("zynq7020", "zynq7012s"):
        dev = DEVICES[dev_name]
        fb = device_utilization(dev, base.brams, acc.folding.luts)
        fp = device_utilization(
            dev, packed.brams, acc.folding.luts + packed.lut_overhead
        )
        print(f"  {dev_name:10s} baseline {base.brams:4d} BRAM "
              f"({fb['bram_pct']:5.1f}%) {'fits' if fb['fits'] else 'NO'}"
              f"   P4 {packed.brams:4d} BRAM ({fp['bram_pct']:5.1f}%) "
              f"{'fits' if fp['fits'] else 'NO'}")
    op = GalsOperatingPoint(100.0, 200.0, 4, 100.0)
    print(f"  delta_FPS at R_F=2: {100*op.delta_fps:.0f}% "
          f"(throughput preserved: {op.throughput_preserved})")


def alveo_port() -> None:
    print("== (b) RN50-W1A2: U250 -> U280, FCMP vs folding ==")
    # FCMP path: paper's achieved clocks on U280
    fcmp = GalsOperatingPoint(138.0, 373.0, 4, 203.0)
    # folding path: 2x fold at ~baseline clock
    fold_loss = 1.0 - (1.0 - folding_delta_fps(2)) * 191.0 / 195.0
    print(f"  FCMP port:    delta_FPS = {100*fcmp.delta_fps:.0f}%")
    print(f"  2x-fold port: delta_FPS = {100*fold_loss:.0f}%")
    speedup = (1 - fcmp.delta_fps) / (1 - fold_loss) - 1
    print(f"  -> FCMP is {100*speedup:.0f}% faster than folding (paper: 38%)")


def tpu_adaptation() -> None:
    print("== (c) TPU v5e: packed weights + VMEM residency plan ==")
    cfg = get_config("olmoe_1b_7b")
    tp = 16
    # per-device expert FFN blocks (E/tp experts per device, 3 mats each)
    blocks = []
    for e in range(cfg.n_experts // tp):
        for mat, (k, n) in {
            "w1": (cfg.d_model, cfg.d_ff),
            "w3": (cfg.d_model, cfg.d_ff),
            "w2": (cfg.d_ff, cfg.d_model),
        }.items():
            blocks.append(WeightBlock(f"e{e}_{mat}", k, n, bits_per_weight=1))
    dense_bytes = sum(b.rows * b.cols * 2 for b in blocks)  # bf16
    packed_bytes = sum(b.padded_bytes(TPU_V5E) for b in blocks)
    print(f"  {len(blocks)} expert-FFN blocks/device: bf16 "
          f"{dense_bytes/2**20:.0f} MiB -> packed 1-bit "
          f"{packed_bytes/2**20:.1f} MiB ({dense_bytes/packed_bytes:.1f}x)")
    plan = plan_vmem_residency(blocks, TPU_V5E.vmem_bytes, reserve_frac=0.5)
    print(f"  VMEM residency: {sum(plan.resident)}/{len(blocks)} blocks "
          f"pinned ({plan.resident_bytes/2**20:.1f} MiB of "
          f"{TPU_V5E.vmem_bytes//2**21} MiB budget), HBM re-stream traffic "
          f"cut {100*plan.hbm_traffic_reduction:.0f}%")


if __name__ == "__main__":
    zynq_port()
    alveo_port()
    tpu_adaptation()
