"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the production code path (configs -> sharding policy -> train step ->
fault-tolerant loop with async checkpoints) on whatever devices exist.
The config is a width-reduced smollm (same family/recipe) sized to ~100M
params so it actually descends on this CPU container in minutes.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import main as train_main
from repro.models.config import ModelConfig


def make_100m() -> ModelConfig:
    """~100M params: smollm-360m recipe at reduced width/depth."""
    base = get_config("smollm_360m")
    return dataclasses.replace(
        base,
        name="smollm-100m",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv=4,
        head_dim=64,
        d_ff=1536,
        vocab=49_152,  # full vocab: embeddings dominate (~50M)
        dtype="float32",
    )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    cfg = make_100m()
    print(f"[example] training {cfg.name}: {cfg.n_params()/1e6:.0f}M params")

    # register the config so the generic launcher can find it
    import repro.configs as C

    mod_name = "examplelm_100m"
    import sys, types

    mod = types.ModuleType(f"repro.configs.{mod_name}")
    mod.CONFIG = cfg
    mod.SMOKE = cfg
    sys.modules[f"repro.configs.{mod_name}"] = mod

    return train_main([
        "--arch", mod_name,
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--ckpt", args.ckpt,
        "--ckpt-every", "100",
        "--ce-chunk", "64",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
