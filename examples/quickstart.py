"""Quickstart: the FCMP methodology end-to-end on the paper's own design.

1. Build the binary ResNet-50 accelerator model (layer set + folding).
2. Measure the baseline OCM mapping efficiency (paper Eq. 1).
3. Pack buffers into BRAMs with the genetic algorithm at bin height 4.
4. Frequency-compensate: check the memory clock needed to keep throughput
   (Eq. 2), and the delta_FPS if timing closure misses.
5. Port the design: does the packed accelerator now fit the smaller U280?

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

from repro.configs import get_accelerator
from repro.core.efficiency import baseline_report, device_utilization, report
from repro.core.gals import GalsOperatingPoint, required_rf
from repro.core.packing import PackItem, pack_genetic
from repro.core.resource_model import DEVICES


def main() -> None:
    acc = get_accelerator("rn50_w1a2")
    print(f"== {acc.name} on {acc.device.name} ==")
    model = acc.folding.model(195.0)
    print(f"throughput model: {model.fps:.0f} FPS, "
          f"{model.latency_s*1e3:.2f} ms latency, {model.tops:.1f} TOp/s")

    # 1-2: baseline memory subsystem
    bufs = acc.buffers()
    base = baseline_report("baseline", bufs)
    print(f"baseline:  {base.brams:5d} BRAM18, E = {100*base.efficiency:.1f}%")

    # 3: FCMP packing at H_B = 4
    items = [PackItem(b, r) for b, r in zip(bufs, acc.regions())]
    ga = dataclasses.replace(acc.ga, max_height=4)
    packed = pack_genetic(items, ga)
    rep = report("P4", packed)
    print(f"packed P4: {rep.brams:5d} BRAM18, E = {100*rep.efficiency:.1f}%, "
          f"+{rep.lut_overhead/1e3:.1f} kLUT streamers/CDC")

    # 4: frequency compensation (Eq. 2)
    rf = required_rf(4)
    print(f"H_B=4 needs R_F >= {rf} -> memory clock "
          f"{float(rf)*acc.f_compute_mhz:.0f} MHz over compute "
          f"{acc.f_compute_mhz:.0f} MHz")
    op = GalsOperatingPoint(183.0, 363.0, 4, 203.0)  # paper's achieved clocks
    print(f"at the paper's achieved clocks: delta_FPS = {100*op.delta_fps:.0f}%")

    # 5: port to the smaller Alveo U280. The weight memories are not the
    # only BRAM consumers: the paper's U250 build uses 3870 BRAM18 total
    # (Table II) vs ~2530 for weights -> ~1340 go to FIFOs/activations.
    # Multi-SLR placement realistically closes at <= ~85% BRAM.
    NON_WEIGHT_BRAMS = 1340
    PLACE_MARGIN = 0.85
    u280 = DEVICES["u280"]
    for label, brams in (("baseline", base.brams), ("packed P4", rep.brams)):
        pct = 100 * (brams + NON_WEIGHT_BRAMS) / u280.bram18
        fits = pct <= 100 * PLACE_MARGIN
        print(f"U280 port ({label}): BRAM {pct:.0f}% incl. FIFOs/activations "
              f"-> {'fits' if fits else 'DOES NOT FIT (needs packing or 2x folding)'}")


if __name__ == "__main__":
    main()
