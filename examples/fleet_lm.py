"""Scenario: scale the serving reproduction out to an engine fleet.

Walks the three fleet modes of ``runtime.cluster`` on one synthetic
trace — a single engine, a 2-engine least-loaded fleet, and a
4-engine disaggregated prefill/decode cluster whose role split comes
from the GALS Eq. 2 ratio (``provision_split``) — and checks the two
properties the subsystem guarantees:

  * every mode emits bit-identical token streams (temperature 0), and
  * scaling out actually moves the virtual-time SLO numbers.

Run:  PYTHONPATH=src python examples/fleet_lm.py
"""

import jax

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.runtime.cluster import (
    DisaggCluster,
    FleetCluster,
    SloPolicy,
    StepCostModel,
    TrafficSpec,
    measured_role_rates,
    synthesize,
)

SLOTS = 4


def main() -> int:
    cfg = get_smoke_config("llama3p2_1b")
    full = get_config("llama3p2_1b")
    params = lm.init_params(cfg, jax.random.key(0))
    cost = StepCostModel.for_config(full, slots=SLOTS)
    spec = TrafficSpec(n_requests=24, arrival_rate=1500.0, vocab=cfg.vocab)
    trace = synthesize(spec)
    slo = SloPolicy(ttft=0.05, tpot=0.005)
    common = dict(
        slots=SLOTS,
        max_len=spec.max_total_tokens + 8,
        block_tokens=8,
        cost=cost,
    )

    rates = measured_role_rates(cost, spec, slots=SLOTS)
    print(
        f"[fleet] measured rates: rho_p {rates.prefill_req_rate:.0f} req/s "
        f"rho_d {rates.decode_req_rate:.0f} req/s -> R_F {rates.r_f:.2f}"
    )

    runs = {}
    for name, cluster in (
        ("single", FleetCluster(cfg, params, n_engines=1, **common)),
        ("fleet-2", FleetCluster(cfg, params, n_engines=2, **common)),
        ("disagg-4", DisaggCluster(
            cfg, params, n_engines=4, spec=spec, **common
        )),
    ):
        result = cluster.run(trace)
        runs[name] = result
        r = result.report(slo).row()
        split = getattr(cluster, "split", None)
        extra = f" (split {split[0]}p:{split[1]}d)" if split else ""
        print(
            f"[fleet/{name}]{extra} {r['generated_tokens']} tokens in "
            f"{r['makespan']*1e3:.1f} virtual ms, TTFT p99 "
            f"{r['ttft_p99']*1e3:.1f} ms, goodput "
            f"{r['goodput_tokens_per_s']:.0f} tok/s"
        )

    base = runs["single"].outputs
    assert runs["fleet-2"].outputs == base, "fleet diverged"
    assert runs["disagg-4"].outputs == base, "disaggregation diverged"
    print("[fleet] all modes emitted identical token streams")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
