"""Fleet benchmark: single engine vs symmetric fleet vs disaggregated.

All modes serve the *same* seed-deterministic synthetic trace
(``runtime.cluster.traffic``) with the same smoke-config model, on the
virtual clock calibrated to the full-size arch — so every number here is
bit-reproducible on any host. Four modes:

  * ``single``       — 1 engine (the PR-2/3 scheduler, instrumented);
  * ``fleet2``       — 2 identical engines, least-loaded router;
  * ``disagg_gals``  — 4 engines split into prefill/decode roles by the
    GALS Eq. 2 provisioning (``required_rf`` over measured rates);
  * ``disagg_naive`` — the same 4 engines forced to a 1:1 role split.

Plus a packed (w_bits=1) single/disagg pair for the FCMP token-identity
gate. Band checks:

  1. every mode's token streams are identical to single-engine serving
     (temperature 0) — the disaggregation-correctness gate;
  2. goodput at 2 engines >= 1.8x the single engine on the saturating
     trace — the fleet actually scales;
  3. the GALS-provisioned split matches or beats the naive 1:1 split on
     TTFT p99 (and on goodput) — the paper's ratio algebra earns its
     keep as a fleet-sizing knob.

CLI::

    PYTHONPATH=src python benchmarks/fleet_bench.py --smoke \
        [--out fleet_bench.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

GOODPUT_FLOOR = 1.8  # fleet2 goodput vs single
TTFT_MARGIN = 1.001  # gals p99 must be <= naive p99 * margin

ARCH = "smollm_360m"
SLOTS = 4
SLO_TTFT = 0.03
SLO_TPOT = 0.002


def _spec(vocab: int, n_requests: int = 32):
    from repro.runtime.cluster import TrafficSpec

    return TrafficSpec(
        n_requests=n_requests,
        arrival_rate=2000.0,
        vocab=vocab,
        seed=1,
    )


def _run_mode(mode, cfg, full_cfg, params, spec, trace, split=None):
    from repro.runtime.cluster import (
        DisaggCluster,
        FleetCluster,
        SloPolicy,
        StepCostModel,
    )
    from repro.runtime.kv_pool import choose_block_tokens

    cost = StepCostModel.for_config(full_cfg, slots=SLOTS)
    common = dict(
        slots=SLOTS,
        max_len=spec.max_total_tokens + 8,
        block_tokens=choose_block_tokens([spec.max_total_tokens]),
        cost=cost,
    )
    if mode.startswith("disagg"):
        cluster = DisaggCluster(
            cfg, params, n_engines=4, spec=spec, split=split, **common
        )
    else:
        cluster = FleetCluster(
            cfg, params, n_engines=1 if mode == "single" else 2, **common
        )
    result = cluster.run(trace)
    report = result.report(SloPolicy(ttft=SLO_TTFT, tpot=SLO_TPOT))
    row = {
        "mode": mode,
        "engines": len(cluster.engines),
        "split": "x".join(map(str, getattr(cluster, "split", ()) or ())),
        "quant": cfg.w_bits,
        **report.row(),
    }
    return row, result.outputs


def run(n_requests: int = 32) -> list[dict]:
    import jax

    from repro.configs import get_config, get_smoke_config
    from repro.models import lm

    cfg = get_smoke_config(ARCH)
    full_cfg = get_config(ARCH)
    params = lm.init_params(cfg, jax.random.key(0))
    spec = _spec(cfg.vocab, n_requests)
    from repro.runtime.cluster import synthesize

    trace = synthesize(spec)

    rows = []
    reference = None
    for mode, split in (
        ("single", None),
        ("fleet2", None),
        ("disagg_gals", None),
        ("disagg_naive", (2, 2)),
    ):
        row, outputs = _run_mode(
            mode, cfg, full_cfg, params, spec, trace, split=split
        )
        if reference is None:
            reference = outputs
        row["token_identical"] = outputs == reference
        rows.append(row)

    # FCMP-packed variant: single vs GALS disagg, token identity only
    pcfg = dataclasses.replace(cfg, w_bits=1)
    pfull = dataclasses.replace(full_cfg, w_bits=1)
    pparams = lm.init_params(pcfg, jax.random.key(0))
    pref = None
    for mode, split in (("single", None), ("disagg_gals", None)):
        row, outputs = _run_mode(
            mode, pcfg, pfull, pparams, spec, trace, split=split
        )
        if pref is None:
            pref = outputs
        row["mode"] = f"packed_{mode}"
        row["token_identical"] = outputs == pref
        rows.append(row)
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    by = {r["mode"]: r for r in rows}
    needed = ("single", "fleet2", "disagg_gals", "disagg_naive",
              "packed_single", "packed_disagg_gals")
    missing = [m for m in needed if m not in by]
    if missing:
        return [f"missing mode rows: {missing}"]
    for r in rows:
        if not r["token_identical"]:
            errs.append(f"{r['mode']}: token streams diverged from single")
        if r["completed"] != r["n_requests"]:
            errs.append(
                f"{r['mode']}: {r['completed']}/{r['n_requests']} completed"
            )
    single, fleet2 = by["single"], by["fleet2"]
    ratio = fleet2["goodput_tokens_per_s"] / max(
        single["goodput_tokens_per_s"], 1e-9
    )
    if ratio < GOODPUT_FLOOR:
        errs.append(
            f"fleet2 goodput only {ratio:.2f}x single (< {GOODPUT_FLOOR}x)"
        )
    gals, naive = by["disagg_gals"], by["disagg_naive"]
    if gals["ttft_p99"] > naive["ttft_p99"] * TTFT_MARGIN:
        errs.append(
            f"GALS split TTFT p99 {gals['ttft_p99']:.4f}s worse than naive "
            f"1:1 {naive['ttft_p99']:.4f}s"
        )
    if gals["goodput_tokens_per_s"] < naive["goodput_tokens_per_s"]:
        errs.append("GALS split goodput below the naive 1:1 split")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CPU cell (the only cell this bench runs)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--out", default="fleet_bench.json")
    args = ap.parse_args(argv)
    if not args.smoke:
        print("[fleet_bench] only the reduced --smoke cell is implemented "
              "(full-size fleets need real accelerators); pass --smoke")
        return 2
    rows = run(args.requests)
    errs = check(rows)
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    for e in errs:
        print(f"  BAND-CHECK FAIL: {e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": errs}, f, indent=2)
        print(f"[fleet_bench] wrote {args.out}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
