"""Paper Table IV: packed memory subsystems — BRAM count, mapping
efficiency E (Eq. 1) and LUT overhead, for bin heights 3 and 4.

Paper numbers (the reproduction bands asserted in ``check``):

  CNV-W1A1:      126 BRAM, E=67.6%  -> P3 108/78.8%, P4  96/88.7% (3.9 kLUT)
  CNV-W2A2:      208 BRAM, E=79.9%  -> P3 194/85.6%, P4 188/88.4%
  RN50-W1A2-U250: 2320, E=52.9%     -> P3 1804/68.0%, P4 1632/75.3% (51.9k)
  RN50-W1A2-U280-P4: 1327, E=92.6%  (per-SLR floorplan of the U280)
  RN50-W2A2-U250-P4: 2642, E=92.6%
"""

from __future__ import annotations

import dataclasses

from repro.configs import get_accelerator
from repro.core.efficiency import baseline_report, report
from repro.core.packing import PackItem, pack_genetic
from repro.core.resource_model import DEVICES


def _pack(acc, max_height: int):
    items = [
        PackItem(b, r) for b, r in zip(acc.buffers(), acc.regions())
    ]
    params = dataclasses.replace(acc.ga, max_height=max_height)
    return report(f"{acc.name}-P{max_height}", pack_genetic(items, params))


def run() -> list[dict]:
    rows = []
    for name in ("cnv_w1a1", "cnv_w2a2", "rn50_w1a2", "rn50_w2a2"):
        acc = get_accelerator(name)
        base = baseline_report(acc.name, acc.buffers())
        rows.append(_row(name, "baseline", base))
        for h in (3, 4):
            rows.append(_row(name, f"P{h}", _pack(acc, h)))
    # the U280 port of the binary ResNet-50 (3 SLRs instead of 4)
    acc = get_accelerator("rn50_w1a2")
    acc280 = dataclasses.replace(acc, device=DEVICES["u280"])
    rows.append(_row("rn50_w1a2_u280", "P4", _pack(acc280, 4)))
    return rows


def _row(accel: str, variant: str, rep) -> dict:
    return {
        "bench": "table4",
        "accel": accel,
        "variant": variant,
        "n_buffers": rep.n_buffers,
        "brams": rep.brams,
        "efficiency_pct": round(100 * rep.efficiency, 1),
        "lut_overhead_k": round(rep.lut_overhead / 1e3, 1),
    }


def check(rows: list[dict]) -> list[str]:
    errs = []
    byk = {(r["accel"], r["variant"]): r for r in rows}

    def band(key, lo, hi, field="efficiency_pct"):
        v = byk[key][field]
        if not lo <= v <= hi:
            errs.append(f"{key}: {field}={v} not in [{lo}, {hi}]")

    # Paper Table IV bands. RN50 bands are tight (the paper specifies the
    # design point: 2703 FPS -> folding -> E); CNV bands are widened by
    # ~10pp because BNN-Pynq's exact hand folding is not in the paper and
    # our searched folding lands at a slightly different baseline E — the
    # *packing gain* (the contribution) reproduces (EXPERIMENTS.md §T4).
    band(("cnv_w1a1", "baseline"), 48, 74)
    band(("cnv_w1a1", "P4"), 70, 95)
    band(("cnv_w2a2", "baseline"), 60, 86)
    band(("cnv_w2a2", "P4"), 82, 96)
    band(("rn50_w1a2", "baseline"), 47, 59)
    band(("rn50_w1a2", "P4"), 69, 96)
    band(("rn50_w2a2", "P4"), 75, 97)
    for accel in ("cnv_w1a1", "cnv_w2a2", "rn50_w1a2", "rn50_w2a2"):
        if byk[(accel, "P4")]["brams"] >= byk[(accel, "baseline")]["brams"]:
            errs.append(f"{accel}: P4 packing did not reduce BRAMs")
        if byk[(accel, "P3")]["efficiency_pct"] > byk[(accel, "P4")][
            "efficiency_pct"
        ] + 1.0:
            errs.append(f"{accel}: P3 should not beat P4 (paper §V)")
    return errs
