"""Render EXPERIMENTS.md tables from dry-run artifacts (jsonl)."""

from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    with open(path) as fh:
        return [json.loads(l) for l in fh]


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b/2**30:.1f} GiB"
    if b >= 2**20:
        return f"{b/2**20:.1f} MiB"
    return f"{b/2**10:.0f} KiB"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile s | temp bytes/dev | args bytes/dev | collectives (per-dev bytes) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "OK":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | | | | |"
            )
            continue
        mem = r.get("memory", {})
        coll = r.get("coll_breakdown", {})
        cs = ", ".join(
            f"{k}:{fmt_bytes(v)}" for k, v in sorted(coll.items()) if v
        ) or "none"
        lines.append(
            "| {arch} | {shape} | {mesh} | OK | {tc:.0f} | {tmp} | {arg} | {cs} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                tc=r["t_compile_s"],
                tmp=fmt_bytes(mem.get("temp_size_in_bytes", 0)),
                arg=fmt_bytes(mem.get("argument_size_in_bytes", 0)),
                cs=cs,
            )
        )
    return "\n".join(lines)


MOVE_HINTS = {
    "compute": "cut redundant matmul flops (remat policy, causal block skip)",
    "memory": "shrink materialised intermediates (masks, f32 carriers) and fuse",
    "collective": "reshard to cut all-gathers; overlap psum with compute",
}


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    # decode cells compiled under --vmem-budget carry a budgeted memory
    # term (the residency plan's pinned weight blocks subtracted from the
    # per-step HBM traffic); quote it next to the unbudgeted one
    budgeted = any("t_memory_budgeted_ms" in r for r in recs)
    bcol = " T_mem budgeted ms |" if budgeted else ""
    lines = [
        "| arch | shape | T_compute ms | T_memory ms |" + bcol +
        " T_coll ms | bottleneck | MODEL_FLOPS/HLO | roofline % | to move the dominant term |",
        "|---|---|---|---|" + ("---|" if budgeted else "") + "---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "OK":
            dashes = "— | " * (1 if budgeted else 0)
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | {dashes}— | "
                f"{r['status']} | — | — | — |"
            )
            continue
        bcell = ""
        if budgeted:
            bv = r.get("t_memory_budgeted_ms")
            bcell = f" {bv:.2f} |" if bv is not None else " — |"
        lines.append(
            "| {arch} | {shape} | {tc:.2f} | {tm:.2f} |{bc} {tl:.2f} | {b} | {u:.3f} | {rf:.2f} | {hint} |".format(
                arch=r["arch"], shape=r["shape"],
                tc=r["t_compute_ms"], tm=r["t_memory_ms"], bc=bcell,
                tl=r["t_collective_ms"], b=r["bottleneck"],
                u=r["useful_flops_ratio"],
                rf=100 * r["roofline_fraction"],
                hint=MOVE_HINTS.get(r["bottleneck"], ""),
            )
        )
    return "\n".join(lines)


def fleet_table(rows: list[dict]) -> str:
    """Render ``benchmarks/fleet_bench.py`` rows (or ``launch.fleet``
    --json reports) with the TTFT/TPOT percentile fields."""
    lines = [
        "| mode | engines | split | TTFT p50/p95/p99 ms | TPOT p50/p99 ms | goodput tok/s | throughput tok/s | in-SLO | tokens exact |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            "| {mode} | {n} | {split} | {t50:.1f}/{t95:.1f}/{t99:.1f} | "
            "{p50:.2f}/{p99:.2f} | {good:.0f} | {thr:.0f} | {met}/{nr} | {tok} |".format(
                mode=r["mode"], n=r["engines"], split=r.get("split") or "—",
                t50=r["ttft_p50"] * 1e3, t95=r["ttft_p95"] * 1e3,
                t99=r["ttft_p99"] * 1e3,
                p50=r["tpot_p50"] * 1e3, p99=r["tpot_p99"] * 1e3,
                good=r["goodput_tokens_per_s"],
                thr=r["throughput_tokens_per_s"],
                met=r["slo_met"], nr=r["n_requests"],
                tok=(
                    ("yes" if r["token_identical"] else "NO")
                    if "token_identical" in r
                    else "—"  # driver reports don't run the identity A/B
                ),
            )
        )
    return "\n".join(lines)


def prefix_table(rows: list[dict]) -> str:
    """Render ``benchmarks/prefix_bench.py`` rows: prefill-token cuts and
    block-sharing telemetry of the radix prefix cache A/B."""
    lines = [
        "| arch | quant | mode | prefill tokens | hit rate | cut | shared blocks peak | cached | TTFT ms | utilization | tokens exact |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        # prefix rows self-identify; a merged jsonl may interleave
        # dry-run/fleet records, which lack the fields formatted below
        if r.get("bench") != "prefix":
            continue
        lines.append(
            "| {arch} | {q} | {mode} | {pt} | {hr:.1%} | {cut} | {sb} | "
            "{cb} | {ttft:.1f} | {util:.3f} | {tok} |".format(
                arch=r["arch"], q=r.get("quant", 0), mode=r["mode"],
                pt=r["prefill_tokens"], hr=r.get("hit_rate", 0.0),
                cut=(
                    f"{r['prefill_reduction']:.1%}"
                    if r.get("mode") == "cache"
                    and r.get("prefill_reduction") is not None
                    else "—"
                ),
                sb=r.get("shared_blocks_peak", 0),
                cb=r.get("cached_blocks", 0),
                ttft=r.get("mean_ttft_ms", 0.0),
                util=r.get("pool_utilization", 0.0),
                tok="yes" if r.get("token_identical") else "NO",
            )
        )
    return "\n".join(lines)


def spec_table(rows: list[dict]) -> str:
    """Render ``benchmarks/spec_bench.py`` rows: per speculative A/B
    cell, the acceptance rate (tokens per batched verify step), the
    p50 TPOT before/after and the cut, and the two exactness verdicts
    (byte-identical outputs, delta-counter replay)."""
    lines = [
        "| cell | arch | family | drafter | depth | sampling | accepted/step | draft tokens | verify steps | TPOT base ms | TPOT spec ms | cut | tokens exact | replay |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        # a merged jsonl interleaves other record shapes, and the spec
        # *trajectory* entries share the bench tag but carry no cell
        if r.get("bench") != "spec" or "cell" not in r:
            continue
        replay = r.get("replay_errors")
        lines.append(
            "| {cell} | {arch} | {fam} | {dr} | {d} | {samp} | "
            "{aps:.2f} | {dt} | {vs} | {tb:.3f} | {ts:.3f} | {cut:.1%} | "
            "{tok} | {rep} |".format(
                cell=r["cell"], arch=r["arch"], fam=r.get("family", "—"),
                dr=r["drafter"], d=r.get("depth", 0),
                samp=r.get("sampling", "—"),
                aps=r.get("accepted_per_step", 0.0),
                dt=r.get("draft_tokens", 0),
                vs=r.get("verify_steps", 0),
                tb=r.get("tpot_base_ms", 0.0),
                ts=r.get("tpot_spec_ms", 0.0),
                cut=r.get("tpot_spec_cut", 0.0),
                tok="yes" if r.get("identical") else "NO",
                rep=(
                    "—" if replay is None
                    else ("clean" if not replay else f"{len(replay)} ERRORS")
                ),
            )
        )
    return "\n".join(lines)


def soak_table(rows: list[dict]) -> str:
    """Render soak-trajectory entries (``BENCH_trajectory.json`` or a
    merged jsonl): one line per ``benchmarks/soak_bench.py`` run, so the
    file reads as the repo's endurance history across PRs."""
    lines = [
        "| run | virtual h | segs | reqs | done | drains | follow-ups | gen-reuse hits | handoffs | checks | TTFT p95 ms | TPOT p95 ms | wall s | ok |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("bench") not in (None, "soak"):
            continue  # merged jsonl may interleave other record shapes
        if "virtual_hours" not in r:
            continue
        lines.append(
            "| {idx} | {vh:.2f} | {seg} | {req} | {done} | {dr} | {fu} | "
            "{reuse} | {ho} | {chk} | {ttft:.1f} | {tpot:.1f} | {wall:.1f} | "
            "{ok} |".format(
                idx=r.get("run_index", "—"),
                vh=r["virtual_hours"], seg=r.get("segments", 0),
                req=r.get("requests", 0), done=r.get("completed", 0),
                dr=r.get("drains", 0), fu=r.get("followups", 0),
                reuse=r.get("gen_reuse_hits", 0),
                ho=r.get("handoffs", 0),
                chk=r.get("invariant_checks", 0),
                ttft=r.get("ttft_p95_s", 0.0) * 1e3,
                tpot=r.get("tpot_p95_s", 0.0) * 1e3,
                wall=r.get("wall_s", 0.0),
                ok="yes" if r.get("ok") else "NO",
            )
        )
    return "\n".join(lines)


def moe_table(recs: list[dict]) -> str:
    """Render expert-load telemetry from a serve tracker stream (jsonl of
    per-round metrics records): routed token-expert slots, normalized
    expert-load entropy, and the fraction of routed tokens that hit a
    residency-pinned ("hot") expert — the balance evidence behind the
    dropless serving claim. One line per engine in the stream."""
    from repro.runtime.tracker import replay_summary

    rows = [r for r in recs if r.get("kind", "metrics") == "metrics"]
    engines = sorted({r.get("engine") for r in rows}, key=lambda e: (e is None, e))
    lines = [
        "| engine | rounds | expert tokens | load entropy | hot-expert fraction |",
        "|---|---|---|---|---|",
    ]
    for eng in engines:
        s = replay_summary(rows, engine=eng)
        lines.append(
            "| {eng} | {rnd} | {et} | {ent} | {hot} |".format(
                eng="—" if eng is None else eng,
                rnd=s["rounds"], et=s["expert_tokens"],
                ent=(
                    f"{s['moe_expert_entropy']:.4f}"
                    if "moe_expert_entropy" in s else "—"
                ),
                hot=(
                    f"{s['moe_hot_expert_fraction']:.4f}"
                    if "moe_hot_expert_fraction" in s else "—"
                ),
            )
        )
    return "\n".join(lines)


def mem_table(recs: list[dict]) -> str:
    """Owner-attributed memory story from a tracker stream carrying the
    ``kind="mem"`` ledger records: per engine, the pool peak (and who
    held it — live requests vs prefix cache), the eviction and COW churn
    behind it, total allocation traffic, and the static VMEM
    reservations (pinned weight blocks, expert stream ring)."""
    from repro.runtime.memledger import summarize_ledger

    s = summarize_ledger(recs)
    if not s["engines"]:
        return "(no mem records in stream)"
    lines = [
        "| engine | peak occ | held@peak | cached@peak | evictable@peak | shared@peak | evicted | COW | alloc MiB | reserved |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for e in s["engines"]:
        res = e.get("reserved_bytes", {})
        res_cell = ", ".join(
            f"{k}:{fmt_bytes(v)}" for k, v in sorted(res.items())
        ) or "—"
        lines.append(
            "| {eng} | {occ:.1%} | {held}/{nb} | {cached} | {ev} | {sh} | "
            "{evd} | {cow} | {mib:.2f} | {res} |".format(
                eng="—" if e["engine"] is None else e["engine"],
                occ=e["peak_occupancy"], held=e["peak_held_blocks"],
                nb=e["n_blocks"], cached=e["peak_cached_blocks"],
                ev=e["peak_evictable_blocks"], sh=e["peak_shared_blocks"],
                evd=e["evicted_blocks"], cow=e["cow_copies"],
                mib=e["alloc_mib"], res=res_cell,
            )
        )
    return "\n".join(lines)


def spans_table(recs: list[dict]) -> str:
    """Critical-path attribution from a span stream (a JsonlTracker
    trace with ``--trace-spans``): requests bucketed by submit-relative
    TTFT percentile, each bucket naming the phase that dominates the
    pre-first-token time — the table answers "what do the slow requests
    wait on that the fast ones don't"."""
    from repro.runtime.spans import request_events, request_spans

    by_rid = request_spans(recs)
    events = request_events(recs)
    per = []  # (rid, ttft, {phase: pre-first seconds})
    for rid, ev in sorted(events.items()):
        spans = by_rid.get(rid)
        if not spans or "first" not in ev:
            continue
        t0 = spans[0]["t0"]
        shares: dict[str, float] = {}
        for s in spans:
            if s["t1"] <= ev["first"] + 1e-12:
                shares[s["phase"]] = (
                    shares.get(s["phase"], 0.0) + s["t1"] - s["t0"]
                )
        per.append((rid, ev["first"] - t0, shares))
    if not per:
        return "(no span records in stream)"
    per.sort(key=lambda x: x[1])
    n = len(per)
    buckets = [
        ("<=p50", 0.0, 0.5),
        ("p50-p90", 0.5, 0.9),
        ("p90-p99", 0.9, 0.99),
        (">p99", 0.99, 1.0),
    ]
    phases = ("queue", "prefix_lookup", "prefill", "handoff", "wait")
    lines = [
        "| TTFT bucket | reqs | TTFT ms (min-max) | dominant phase | "
        + " | ".join(f"{p} %" for p in phases)
        + " |",
        "|---|---|---|---|" + "---|" * len(phases),
    ]
    for name, lo, hi in buckets:
        grp = per[int(lo * n) : max(int(lo * n) + 1, round(hi * n))]
        if not grp:
            continue
        agg = {p: 0.0 for p in phases}
        for _, _, shares in grp:
            for p, v in shares.items():
                agg[p] = agg.get(p, 0.0) + v
        total = sum(agg.values()) or 1.0
        dom = max(agg, key=lambda p: agg[p])
        lines.append(
            "| {b} | {n} | {lo:.2f}-{hi:.2f} | {dom} ({ds:.0%}) | ".format(
                b=name, n=len(grp), lo=grp[0][1] * 1e3,
                hi=grp[-1][1] * 1e3, dom=dom, ds=agg[dom] / total,
            )
            + " | ".join(f"{100 * agg[p] / total:.1f}" for p in phases)
            + " |"
        )
    return "\n".join(lines)


def _load_rows(path: str) -> list[dict] | dict:
    """A single JSON document -> as parsed; a jsonl of flat records ->
    list (a jsonl's first line parses but leaves extra data, so the
    whole-document parse failing is the jsonl signal)."""
    with open(path) as fh:
        text = fh.read()
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        return [json.loads(l) for l in text.splitlines() if l.strip()]


def load_prefix(path: str) -> list[dict]:
    """Prefix rows from the bench JSON ({"rows": [...]}) or a merged
    jsonl of flat row records."""
    data = _load_rows(path)
    return data["rows"] if isinstance(data, dict) else data


def load_soak(path: str) -> list[dict]:
    """Soak rows from the trajectory file (a plain JSON list), a soak
    bench JSON ({"rows": [...]}), or a merged jsonl."""
    data = _load_rows(path)
    if isinstance(data, dict):
        return data.get("rows", [data])
    return data


def load_fleet(path: str) -> list[dict]:
    """Fleet rows from the bench JSON ({"rows": [...]}), a single
    ``launch.fleet --json`` report (percentiles nested under "report"),
    or a merged jsonl of flat row records."""
    data = _load_rows(path)
    if isinstance(data, list):
        return data
    if "rows" in data:
        return data["rows"]
    return [{
        "mode": data["mode"],
        "engines": data["engines"],
        "split": "x".join(map(str, data.get("split") or [])),
        **data["report"],
    }]


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_baseline.jsonl"
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if which == "fleet":
        print(fleet_table(load_fleet(path)))
    elif which == "prefix":
        print(prefix_table(load_prefix(path)))
    elif which == "soak":
        print(soak_table(load_soak(path)))
    elif which == "spec":
        print(spec_table(load_prefix(path)))  # same {"rows": ...} shape
    elif which == "moe":
        print(moe_table(load(path)))
    elif which == "spans":
        print(spans_table(load(path)))
    elif which == "mem":
        print(mem_table(load(path)))
    elif which == "roofline":
        print(roofline_table(load(path)))
    else:
        print(dryrun_table(load(path)))
