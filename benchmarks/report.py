"""Render EXPERIMENTS.md tables from dry-run artifacts (jsonl)."""

from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    with open(path) as fh:
        return [json.loads(l) for l in fh]


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b/2**30:.1f} GiB"
    if b >= 2**20:
        return f"{b/2**20:.1f} MiB"
    return f"{b/2**10:.0f} KiB"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile s | temp bytes/dev | args bytes/dev | collectives (per-dev bytes) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "OK":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | | | | |"
            )
            continue
        mem = r.get("memory", {})
        coll = r.get("coll_breakdown", {})
        cs = ", ".join(
            f"{k}:{fmt_bytes(v)}" for k, v in sorted(coll.items()) if v
        ) or "none"
        lines.append(
            "| {arch} | {shape} | {mesh} | OK | {tc:.0f} | {tmp} | {arg} | {cs} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                tc=r["t_compile_s"],
                tmp=fmt_bytes(mem.get("temp_size_in_bytes", 0)),
                arg=fmt_bytes(mem.get("argument_size_in_bytes", 0)),
                cs=cs,
            )
        )
    return "\n".join(lines)


MOVE_HINTS = {
    "compute": "cut redundant matmul flops (remat policy, causal block skip)",
    "memory": "shrink materialised intermediates (masks, f32 carriers) and fuse",
    "collective": "reshard to cut all-gathers; overlap psum with compute",
}


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | T_compute ms | T_memory ms | T_coll ms | bottleneck | MODEL_FLOPS/HLO | roofline % | to move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "OK":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']} | — | — | — |"
            )
            continue
        lines.append(
            "| {arch} | {shape} | {tc:.2f} | {tm:.2f} | {tl:.2f} | {b} | {u:.3f} | {rf:.2f} | {hint} |".format(
                arch=r["arch"], shape=r["shape"],
                tc=r["t_compute_ms"], tm=r["t_memory_ms"],
                tl=r["t_collective_ms"], b=r["bottleneck"],
                u=r["useful_flops_ratio"],
                rf=100 * r["roofline_fraction"],
                hint=MOVE_HINTS.get(r["bottleneck"], ""),
            )
        )
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else
                "experiments/dryrun_baseline.jsonl")
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if which == "roofline":
        print(roofline_table(recs))
    else:
        print(dryrun_table(recs))
