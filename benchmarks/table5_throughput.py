"""Paper Table V: packed vs folded accelerators — relative throughput loss
delta_FPS = 1 - min(F_c, F_m/2) / F_c_baseline.

Paper rows reproduced (achieved clocks are inputs — timing closure is a
hardware fact we take from the paper; the *model* turns clocks into
throughput):

  CNV-W1A1-7020-P4 / 7012S-P4: F_c 100 / F_m 200 -> delta_FPS 0%
  RN50-W1A2-U250-P4: clocks missed by 12% (183/363) -> delta_FPS 12%
  RN50-W1A2-U280-P4: compute clock 138 vs 203 baseline -> delta_FPS 32%
  RN50-W1A2-U280-F2: 2x folding at ~equal clock -> delta_FPS 51%
  => FCMP port is (1-0.32)/(1-0.51) - 1 = 38% faster than the folding port
"""

from __future__ import annotations

from repro.core.gals import GalsOperatingPoint, folding_delta_fps


# (name, F_c achieved, F_m achieved, H_B, F_c baseline)
OPERATING_POINTS = [
    ("cnv_w1a1_7020_p4", 100.0, 200.0, 4, 100.0),
    ("cnv_w1a1_7012s_p4", 100.0, 200.0, 4, 100.0),
    ("rn50_w1a2_u250_p4", 183.0, 363.0, 4, 203.0),
    ("rn50_w1a2_u280_p4", 138.0, 373.0, 4, 203.0),
]


def run() -> list[dict]:
    rows = []
    for name, fc, fm, hb, fbase in OPERATING_POINTS:
        op = GalsOperatingPoint(fc, fm, hb, fbase)
        rows.append(
            {
                "bench": "table5",
                "accel": name,
                "f_c": fc,
                "f_m": fm,
                "r_f": round(op.r_f, 2),
                "delta_fps_pct": round(100 * op.delta_fps, 1),
                "throughput_preserved": op.throughput_preserved,
            }
        )
    # the folding alternative (U280-F2): 2x fold at baseline-equal clock
    f2 = folding_delta_fps(2)
    # paper: F2 single-clock 191 vs 195-203 baseline -> ~51%
    d_f2 = 1.0 - (1.0 - f2) * 191.0 / 195.0
    rows.append(
        {
            "bench": "table5",
            "accel": "rn50_w1a2_u280_f2",
            "f_c": 191.0,
            "f_m": None,
            "r_f": None,
            "delta_fps_pct": round(100 * d_f2, 1),
            "throughput_preserved": False,
        }
    )
    p4 = next(r for r in rows if r["accel"] == "rn50_w1a2_u280_p4")
    speedup = (100 - p4["delta_fps_pct"]) / (100 - rows[-1]["delta_fps_pct"])
    rows.append(
        {
            "bench": "table5",
            "accel": "fcmp_vs_folding_u280",
            "delta_fps_pct": None,
            "f_c": None,
            "f_m": None,
            "r_f": None,
            "speedup_pct": round(100 * (speedup - 1.0), 1),
            "throughput_preserved": None,
        }
    )
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    byk = {r["accel"]: r for r in rows}
    if byk["cnv_w1a1_7020_p4"]["delta_fps_pct"] != 0.0:
        errs.append("CNV P4 should lose no throughput (paper: 0%)")
    if not 9 <= byk["rn50_w1a2_u250_p4"]["delta_fps_pct"] <= 15:
        errs.append("RN50-U250-P4 delta_FPS should be ~12%")
    if not 29 <= byk["rn50_w1a2_u280_p4"]["delta_fps_pct"] <= 35:
        errs.append("RN50-U280-P4 delta_FPS should be ~32%")
    if not 48 <= byk["rn50_w1a2_u280_f2"]["delta_fps_pct"] <= 54:
        errs.append("RN50-U280-F2 delta_FPS should be ~51%")
    if not 30 <= byk["fcmp_vs_folding_u280"]["speedup_pct"] <= 46:
        errs.append("FCMP should be ~38% faster than folding (paper §V)")
    return errs
