"""Paper Table II: the binary ResNet-50 accelerator on Alveo U250.

Paper claims for RN50-W1A2: 18.3 TOp/s of work per inference stream,
2703 FPS max, 1.9 ms min latency at F_max = 195 MHz. We reproduce these
from the dataflow pipeline model at the searched folding: FPS = F_c /
max II, latency = sum II / F_c, TOp/s = 2 * MACs * FPS.
"""

from __future__ import annotations

from repro.configs import get_accelerator


def run() -> list[dict]:
    rows = []
    for name, f_mhz in (("rn50_w1a2", 195.0), ("rn50_w2a2", 195.0)):
        acc = get_accelerator(name)
        model = acc.folding.model(f_mhz)
        rows.append(
            {
                "bench": "table2",
                "accel": name,
                "f_mhz": f_mhz,
                "fps": round(model.fps, 0),
                "latency_ms": round(model.latency_s * 1e3, 2),
                "tops": round(model.tops, 1),
                "total_gmacs": round(model.total_macs / 1e9, 2),
            }
        )
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    r = rows[0]  # rn50_w1a2
    # ResNet-50 v1.5 ~ 4.1 GMACs -> paper's 18.3 TOp/s at 2703 FPS checks
    # out as 2 * 4.1e9 * 2230 ~ 18e12; our folding search lands in band.
    if not 3.0 <= r["total_gmacs"] <= 5.0:
        errs.append(f"rn50 MACs {r['total_gmacs']}G out of ResNet-50 band")
    if not 1000 <= r["fps"] <= 6000:
        errs.append(f"rn50 FPS {r['fps']} out of paper band (2703 +- folding)")
    if not 0.5 <= r["latency_ms"] <= 6.0:
        errs.append(f"rn50 latency {r['latency_ms']}ms out of band (1.9)")
    return errs
