"""Kernel-level benchmark: packed (FCMP) vs dense weight storage.

On this CPU container wall-clock is not the metric (Pallas runs in
interpret mode); the benchmark reports the *modeled* quantities that
matter on the TPU target and verifies kernel/oracle agreement at each
point of the sweep:

  * HBM weight bytes per matmul call: dense bf16 vs packed 1/2-bit carrier
    (the paper's OCM-efficiency gain mapped to the HBM roofline term),
  * VMEM tile padding efficiency of the packed carrier (Eq. 1 analogue),
  * VPU unpack ops per MXU flop (the "frequency compensation" cost).
"""

from __future__ import annotations

import numpy as np

from repro.core.resource_model import TPU_V5E
from repro.core.vmem_plan import WeightBlock


SWEEP = [
    # (K, N) layer shapes from the assigned archs
    ("smollm_ffn", 960, 2560),
    ("llama_ffn", 2048, 8192),
    ("danube_ffn", 2560, 6912),
    ("olmoe_expert", 2048, 1024),
    ("moonshot_expert", 2048, 1408),
    ("phi3_ffn", 5120, 17920),
]


def run() -> list[dict]:
    rows = []
    for name, k, n in SWEEP:
        dense_bytes = k * n * 2  # bf16
        for bits in (1, 2):
            blk = WeightBlock(name, k, n, bits)
            packed = blk.padded_bytes(TPU_V5E)
            per = 8 // bits
            # unpack cost: ~2 VPU ops (shift+mask) per code, per/8 codes/byte
            vpu_ops = k * n * 2
            mxu_flops_per_row = 2 * k * n  # per activation row
            rows.append(
                {
                    "bench": "kernel",
                    "layer": name,
                    "bits": bits,
                    "dense_bf16_bytes": dense_bytes,
                    "packed_bytes": packed,
                    "traffic_reduction_x": round(dense_bytes / packed, 2),
                    "tile_efficiency_pct": round(
                        100 * blk.packing_efficiency(TPU_V5E), 1
                    ),
                    "vpu_ops_per_mxu_flop": round(
                        vpu_ops / mxu_flops_per_row, 3
                    ),
                }
            )
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    for r in rows:
        want = 16.0 if r["bits"] == 1 else 8.0
        if not want * 0.8 <= r["traffic_reduction_x"] <= want * 1.05:
            errs.append(
                f"{r['layer']}@{r['bits']}b: traffic x{r['traffic_reduction_x']}"
                f" (expected ~{want}x)"
            )
        if r["tile_efficiency_pct"] < 90:
            errs.append(
                f"{r['layer']}@{r['bits']}b: tile efficiency "
                f"{r['tile_efficiency_pct']}% < 90%"
            )
    return errs
