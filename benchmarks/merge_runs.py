"""Merge dry-run jsonl files: later records replace earlier ones with the
same (arch, shape, mesh, quant) key. Used to splice re-measured cells into
a sweep artifact after a targeted fix.

    python benchmarks/merge_runs.py out.jsonl base.jsonl patch1.jsonl ...
"""

import json
import sys


def merge(paths: list[str]) -> list[dict]:
    recs: dict[tuple, dict] = {}
    order: list[tuple] = []
    for p in paths:
        with open(p) as fh:
            for line in fh:
                r = json.loads(line)
                key = (r["arch"], r["shape"], r["mesh"], r.get("quant", 0))
                if key not in recs:
                    order.append(key)
                recs[key] = r
    return [recs[k] for k in order]


if __name__ == "__main__":
    out, *paths = sys.argv[1:]
    rows = merge(paths)
    with open(out, "w") as fh:
        for r in rows:
            fh.write(json.dumps(r) + "\n")
    print(f"merged {len(paths)} files -> {out} ({len(rows)} records)")
