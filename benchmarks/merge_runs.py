"""Merge benchmark jsonl files: later records replace earlier ones with
the same identity key. Used to splice re-measured cells into a sweep
artifact after a targeted fix.

Four record shapes are understood: dry-run cells, keyed
(arch, shape, mesh, quant, vmem budget); flat fleet rows as emitted in
``benchmarks/fleet_bench.py``'s "rows" list, keyed
(mode, engines, split, quant); ``benchmarks/prefix_bench.py`` rows
(self-identified via ``"bench": "prefix"``), keyed
(arch, quant, mode); ``benchmarks/soak_bench.py`` trajectory
entries (``"bench": "soak"``), keyed by configuration + run index so
successive soaks of the same shape replace each other; and
``benchmarks/spec_bench.py`` rows / trajectory entries
(``"bench": "spec"``), keyed by A/B cell + run index. (A
``launch.fleet --json`` report is one nested object, not jsonl —
flatten it via ``report.load_fleet`` first.)

    python benchmarks/merge_runs.py out.jsonl base.jsonl patch1.jsonl ...
"""

import json
import sys


def record_key(r: dict) -> tuple | None:
    # tracker-stream records (hparams/metrics/span lines from a
    # JsonlTracker trace) are an append-only log, not keyed cells:
    # they merge by concatenation (None = never collide). Without this
    # branch an hparams record ("arch" but no "shape") would crash the
    # dry-run key, and every span record would collapse into one fleet
    # key.
    if "kind" in r:
        return None
    if r.get("bench") == "prefix":  # a prefix-cache A/B row
        return (
            "prefix", r["arch"], r.get("quant", 0), r.get("mode"),
        )
    if r.get("bench") == "spec":
        # a speculative-decode A/B row ("cell" names the pairing) or a
        # spec trajectory entry (no "cell", keyed by run index instead)
        return ("spec", r.get("cell"), r.get("run_index", 0))
    if r.get("bench") == "soak":  # a soak-trajectory entry (no "arch")
        return (
            "soak", r.get("segments"), r.get("requests"),
            r.get("seed", 0), r.get("run_index", 0),
        )
    if "arch" in r:  # a dry-run cell
        return (
            "dryrun", r["arch"], r["shape"], r["mesh"],
            r.get("quant", 0), r.get("vmem_budget_mib", 0),
        )
    # a fleet row: TTFT/TPOT percentiles keyed by topology
    return (
        "fleet", r.get("mode"), r.get("engines"),
        r.get("split", ""), r.get("quant", 0),
    )


def merge(paths: list[str]) -> list[dict]:
    recs: dict[tuple, dict] = {}
    order: list[tuple] = []
    n_stream = 0
    for p in paths:
        with open(p) as fh:
            for line in fh:
                r = json.loads(line)
                key = record_key(r)
                if key is None:  # trace-stream record: unique, in order
                    key = ("trace", n_stream)
                    n_stream += 1
                if key not in recs:
                    order.append(key)
                recs[key] = r
    return [recs[k] for k in order]


if __name__ == "__main__":
    out, *paths = sys.argv[1:]
    rows = merge(paths)
    with open(out, "w") as fh:
        for r in rows:
            fh.write(json.dumps(r) + "\n")
    print(f"merged {len(paths)} files -> {out} ({len(rows)} records)")
