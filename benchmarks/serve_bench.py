"""Serving benchmark: pool-scheduled continuous batching vs fixed-batch.

Runs the same request trace (smollm_360m smoke config on CPU) through
both serving engines in ``repro.launch.serve``:

  * ``fixed`` — the legacy loop: per-slot ring caches, lockstep
    positions, prompts replayed token-by-token through the decode path;
  * ``pool``  — the ``runtime.scheduler`` subsystem: one shared
    block-granular KV pool, token-budget admission, single-step batched
    prefill, per-lane decode depths.

Rows report decode throughput as tokens/s (generated tokens / wall —
every generated token is a decode token, and the wall includes each
engine's own prefill strategy), per-decode-step latency (host
bookkeeping included, measured identically for both engines), mean
time-to-first-token, and steady-state KV-pool utilization (held tokens
/ held rows — the serving analog of paper Eq. 1). ``check`` enforces
the reproduction band: pool utilization >= 90% at steady state and pool
decode throughput no worse than the fixed-batch loop.

CLI::

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
        [--out serve_bench.json]
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

UTIL_FLOOR = 0.90
# throughput gate margin: the timed traces are ~0.1s on CPU, so a single
# scheduler stall on a shared CI runner can shave tens of percent off one
# engine's tokens/s; structurally the pool engine runs ~1.6x the fixed
# loop (55 vs 96 steps for the same tokens), so 0.8 catches real
# regressions without tripping on timer noise
SPEED_MARGIN = 0.8


def _serve_args(**overrides):
    from repro.launch.serve import build_parser

    args = build_parser().parse_args([])
    args.arch = "smollm_360m"
    args.smoke = True
    args.requests = 10
    args.batch = 4
    args.prompt_len = 16
    args.gen_len = 16
    args.max_len = 48
    for k, v in overrides.items():
        setattr(args, k, v)
    return args


def run(**overrides) -> list[dict]:
    from repro.configs import get_smoke_config
    from repro.launch.serve import run_fixed_engine, run_pool_engine
    from repro.models import lm

    args = _serve_args(**overrides)
    cfg = get_smoke_config(args.arch)
    params = lm.init_params(cfg, jax.random.key(args.seed))

    rows = []
    for name, engine in (("fixed", run_fixed_engine), ("pool", run_pool_engine)):
        # warmup run compiles the step functions so timed rows compare
        # steady-state step cost, not jit tracing
        warm = _serve_args(**overrides)
        warm.requests = min(4, args.requests)
        engine(cfg, params, warm)
        m = engine(cfg, params, args)
        m.pop("outputs")
        rows.append({k: round(v, 4) if isinstance(v, float) else v
                     for k, v in m.items()})
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    by = {r["engine"]: r for r in rows}
    pool, fixed = by.get("pool"), by.get("fixed")
    if pool is None or fixed is None:
        return ["missing engine row"]
    if pool["pool_utilization"] < UTIL_FLOOR:
        errs.append(
            f"steady-state pool utilization {pool['pool_utilization']:.3f} "
            f"< {UTIL_FLOOR}"
        )
    if pool["tokens_per_s"] < SPEED_MARGIN * fixed["tokens_per_s"]:
        errs.append(
            f"pool tokens/s {pool['tokens_per_s']:.2f} worse than "
            f"{SPEED_MARGIN} x fixed-batch {fixed['tokens_per_s']:.2f}"
        )
    if pool["generated_tokens"] != fixed["generated_tokens"]:
        errs.append("engines generated different token counts")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CPU cell (the only cell this bench runs)")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--out", default="serve_bench.json")
    args = ap.parse_args(argv)
    if not args.smoke:
        print("[serve_bench] only the reduced --smoke cell is implemented "
              "(full-size serving needs real accelerators); pass --smoke")
        return 2

    overrides = {}
    if args.requests:
        overrides["requests"] = args.requests
    rows = run(**overrides)
    errs = check(rows)

    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    for e in errs:
        print(f"  BAND-CHECK FAIL: {e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": errs}, f, indent=2)
        print(f"[serve_bench] wrote {args.out}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
