"""Prefix-cache benchmark: radix-cached serving vs the cold pool.

A shared-prefix trace (multi-turn sessions whose prompts nest: turn t's
prompt extends turn t-1's, the chat pattern prefix caching exists for)
runs twice through the continuous-batching scheduler — once with the
radix prefix cache attached to the KV pool, once without — for four
arch variants: dense (smollm_360m smoke), FCMP-packed (w_bits=1),
hybrid (zamba2 smoke, whose cache anchors carry the SSM lane state),
and moe (olmoe smoke, cacheable since dropless per-token routing made
a cached prefix's KV exactly what a cold prefill recomputes).

Reported per row: prefill tokens actually computed, prompt tokens served
from cached blocks (hit rate), steady-state pool utilization (Eq.-1
style, shared physical blocks counted once), peak count of blocks shared
between live requests, and wall TTFT (informational — wall clock on a CI
runner is noisy; the band checks are structural).

Band checks (the reproduction gate of ISSUE 5):

  1. cached serving is **exactly** token-identical to cold serving for
     every variant — greedy and seeded sampling alike share the
     scheduler's (seed, rid, position)-keyed sampler, so greedy identity
     here is the full gate;
  2. the cache cuts prefill tokens by >= 30% on the shared-prefix trace;
  3. blocks are genuinely shared while requests are co-resident
     (shared_blocks_peak > 0) and utilization never double-counts a
     shared block (<= 1.0 at every sampled step).

CLI::

    PYTHONPATH=src python benchmarks/prefix_bench.py --smoke \
        [--out prefix_bench.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

REDUCTION_FLOOR = 0.30  # prefill-token cut the cache must deliver

BLOCK = 4
SLOTS = 4
GEN = 6
SESSIONS = 3
TURNS = 4
TURN_TOKENS = 8  # each turn extends the session prompt by this many
MAX_LEN = TURNS * TURN_TOKENS + GEN + 2 * BLOCK


def _variants():
    from repro.configs import get_smoke_config

    dense = get_smoke_config("smollm_360m")
    return (
        ("smollm_360m", dense),
        ("smollm_360m", dataclasses.replace(dense, w_bits=1)),
        ("zamba2_2p7b", get_smoke_config("zamba2_2p7b")),
        ("olmoe_1b_7b", get_smoke_config("olmoe_1b_7b")),
    )


def _session_waves(vocab: int, seed: int = 0):
    """TURNS waves of 2 * SESSIONS prompts: per session, wave t carries
    the nested turn prompt (wave t-1's plus TURN_TOKENS fresh tokens)
    *and* a sibling branch sharing all but its last 3 tokens — the
    branched-turn / parallel-sampling pattern. Siblings admit right
    after their turn prompt commits, so live requests genuinely alias
    blocks; 3 is coprime to the block size, so siblings diverge
    *mid-block* and the dense match path exercises copy-on-write."""
    import numpy as np

    rng = np.random.default_rng(seed)
    fresh = lambda n: rng.integers(0, vocab, size=(n,)).astype(np.int32)
    prompts = [fresh(TURN_TOKENS) for _ in range(SESSIONS)]
    waves = []
    for t in range(TURNS):
        if t:
            prompts = [
                np.concatenate([p, fresh(TURN_TOKENS)]) for p in prompts
            ]
        wave = []
        for p in prompts:
            sibling = np.concatenate([p[:-3], fresh(3)])
            wave.extend([p, sibling])
        waves.append(wave)
    return waves


def _serve(cfg, params, waves, cached: bool) -> dict:
    import numpy as np

    from repro.runtime.kv_pool import KVPool
    from repro.runtime.prefix_cache import PrefixCache
    from repro.runtime.scheduler import Scheduler

    pool = KVPool.for_slots(
        cfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    cache = PrefixCache(pool) if cached else None
    sched = Scheduler(
        cfg, params, pool, slots=SLOTS, max_len=MAX_LEN, prefix_cache=cache
    )
    t0 = time.monotonic()
    util_ok = True
    for wave in waves:
        for p in wave:
            sched.submit(p, GEN)
        # drive rounds by hand so pool stats are sampled mid-flight
        while sched.queue or any(r is not None for r in sched.active):
            sched.round()
            util_ok &= sched.pool.stats().utilization <= 1.0 + 1e-9
    dt = time.monotonic() - t0
    pool.validate()
    st = sched.stats
    return {
        "outputs": sched.outputs(),
        "prefill_tokens": st.prefill_tokens,
        "prefix_hits": st.prefix_hits,
        "prefix_hit_tokens": st.prefix_hit_tokens,
        "hit_rate": round(st.prefix_hit_rate, 4),
        "mean_ttft_ms": round(st.mean_ttft * 1e3, 3),
        "pool_utilization": round(st.steady_state_utilization, 4),
        "shared_blocks_peak": st.shared_blocks_peak,
        "cached_blocks": pool.cached_blocks,
        "util_ok": util_ok,
        "wall_s": round(dt, 3),
        "completed": st.completed,
    }


def run() -> list[dict]:
    import jax

    from repro.models import lm

    rows = []
    for arch, cfg in _variants():
        params = lm.init_params(cfg, jax.random.key(0))
        waves = _session_waves(cfg.vocab, seed=3)
        cold = _serve(cfg, params, waves, cached=False)
        warm = _serve(cfg, params, waves, cached=True)
        identical = warm.pop("outputs") == cold.pop("outputs")
        reduction = 1.0 - warm["prefill_tokens"] / max(
            1, cold["prefill_tokens"]
        )
        for mode, m in (("nocache", cold), ("cache", warm)):
            rows.append(
                {
                    "bench": "prefix",
                    "arch": arch,
                    "family": cfg.family,
                    "quant": cfg.w_bits,
                    "mode": mode,
                    **m,
                    "prefill_reduction": (
                        round(reduction, 4) if mode == "cache" else 0.0
                    ),
                    "token_identical": identical,
                }
            )
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    cache_rows = [r for r in rows if r["mode"] == "cache"]
    if len(cache_rows) != 4:
        return [f"expected 4 cached variants, got {len(cache_rows)}"]
    for r in rows:
        tag = f"{r['arch']}/q{r['quant']}/{r['mode']}"
        if r["completed"] != 2 * SESSIONS * TURNS:
            errs.append(f"{tag}: {r['completed']} completed")
        if not r["util_ok"]:
            errs.append(f"{tag}: utilization exceeded 1.0 (double-counted "
                        "shared blocks)")
    for r in cache_rows:
        tag = f"{r['arch']}/q{r['quant']}"
        if not r["token_identical"]:
            errs.append(f"{tag}: cached tokens diverged from cold serving")
        if r["prefill_reduction"] < REDUCTION_FLOOR:
            errs.append(
                f"{tag}: prefill cut only {r['prefill_reduction']*100:.0f}% "
                f"(< {REDUCTION_FLOOR*100:.0f}%)"
            )
        if r["prefix_hits"] == 0 or r["prefix_hit_tokens"] == 0:
            errs.append(f"{tag}: the shared-prefix trace never hit the cache")
        if r["shared_blocks_peak"] == 0:
            errs.append(f"{tag}: no blocks were ever shared between requests")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CPU cell (the only cell this bench runs)")
    ap.add_argument("--out", default="prefix_bench.json")
    args = ap.parse_args(argv)
    if not args.smoke:
        print("[prefix_bench] only the reduced --smoke cell is implemented "
              "(full-size serving needs real accelerators); pass --smoke")
        return 2
    rows = run()
    errs = check(rows)
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    for e in errs:
        print(f"  BAND-CHECK FAIL: {e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": errs}, f, indent=2)
        print(f"[prefix_bench] wrote {args.out}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
