"""Speculative-decoding benchmark: spec vs non-spec A/B on the fleet.

Serves the same synthesized trace through a single-engine
``FleetCluster`` twice — plain decode vs speculate-and-verify — and
holds three reproduction bands:

  * **token identity**: the speculative run's output streams are
    byte-identical to plain decode, greedy AND seeded (the tentpole
    invariant — verification samples each position with the same
    (seed, rid, position) rng plain decode uses);
  * **acceptance**: accepted tokens per verify step on the dense +
    packed-drafter pair stays above the band (the drafter is earning
    its rollout);
  * **TPOT cut**: the virtual-clock p50 time-per-output-token drops by
    at least the band on the dense + packed-drafter pair — the drafter
    is charged at its own FCMP-discounted roofline
    (``StepCostModel.for_config`` on the w_bits=2 twin), so the cut is
    the honest roofline win, not a freebie.

Drafter pairing: random smoke weights have no trained drafter/target
correlation, so the dense target serves the *dequantized* FCMP params
(``speculative.dequantize_ffn_params``) and the drafter re-packs them —
a lossless twin, the smoke-scale stand-in for a trained dense model and
its packed checkpoint (arXiv:2011.07317's pairing). The moe row drives
the self-drafting ngram fallback instead (expert FFNs do not pack).

The twin row also replays its tracker stream: the new
``accepted_tokens`` / ``draft_tokens`` / ``verify_steps`` delta
counters must integrate back to the engine totals exactly, and the
span/ledger exactness contracts must hold with the new draft/verify
phases in the timeline.

CLI::

    PYTHONPATH=src python benchmarks/spec_bench.py --smoke \
        [--out spec_bench.json] [--no-trajectory]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import jax

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

DEPTH = 4
QUANT = 2
# bands (smoke cells, virtual clock — deterministic, so the margins are
# against design drift, not timer noise): the lossless twin accepts
# nearly the whole chain; ngram on random-weight moe still clears 1
# token/step structurally (the pending token always lands)
TWIN_ACCEPT_FLOOR = 3.0  # measured 3.5
NGRAM_ACCEPT_FLOOR = 1.5  # measured 2.8
TPOT_CUT_FLOOR = 0.30  # measured 0.457


def _serve(cfg, full_cfg, params, *, sampling, speculative, trace_out=None):
    from repro.runtime.cluster import (
        FleetCluster,
        SloPolicy,
        StepCostModel,
        TrafficSpec,
        synthesize,
    )

    spec = TrafficSpec(
        n_requests=8,
        arrival_rate=2000.0,
        session_reuse=0.0,
        vocab=cfg.vocab,
        seed=0,
    )
    trace = synthesize(spec)
    tracker = None
    if trace_out:
        from repro.runtime.tracker import JsonlTracker

        tracker = JsonlTracker(trace_out)
    cluster = FleetCluster(
        cfg,
        params,
        n_engines=1,
        slots=4,
        max_len=spec.max_total_tokens + 8,
        block_tokens=4,
        cost=StepCostModel.for_config(full_cfg, slots=4),
        sampling=sampling,
        prefix_cache=False,
        speculative=speculative,
        tracker=tracker,
    )
    result = cluster.run(trace)
    if tracker is not None:
        tracker.finish()
    outputs = {}
    for eng in cluster.engines:
        for rid, req in eng.scheduler.requests.items():
            outputs[rid] = list(req.output)
        eng.scheduler.pool.validate()
        assert not eng.scheduler.pool.draft_rids()
    row = result.report(SloPolicy(ttft=0.05, tpot=0.01)).row()
    return outputs, row, result.engine_summaries


def _replay_checks(trace_out, summaries) -> list[str]:
    """Span/ledger exactness + delta replay of the new counters."""
    from repro.runtime.memledger import validate_ledger
    from repro.runtime.spans import validate_trace
    from repro.runtime.tracker import read_jsonl, replay_summary

    recs = read_jsonl(trace_out)
    errs = [f"span: {e}" for e in validate_trace(recs)]
    errs += [f"ledger: {e}" for e in validate_ledger(recs)]
    replay = replay_summary(recs)
    for key in ("accepted_tokens", "draft_tokens", "verify_steps"):
        want = sum(s[key] for s in summaries)
        got = replay.get(key, 0)
        if got != want:
            errs.append(f"replay {key}: {got} != engine total {want}")
    return errs


def _cell(name, arch, drafter, *, sampling_kwargs, replay=False) -> dict:
    from repro import configs
    from repro.models import lm
    from repro.runtime.speculative import (
        SpecConfig,
        dequantize_ffn_params,
        resolve,
    )

    cfg = configs.get_smoke_config(arch)
    full_cfg = configs.get_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    if drafter != "ngram":
        # the lossless-twin pairing (module docstring): target = the
        # packed arch's dense execution, drafter = the re-packed twin
        params = dequantize_ffn_params(params, QUANT)
    sampling = lm.SamplingParams(**sampling_kwargs)
    speculative = resolve(
        cfg, SpecConfig(drafter=drafter, depth=DEPTH, quant=QUANT), smoke=True
    )

    base_out, base_row, _ = _serve(
        cfg, full_cfg, params, sampling=sampling, speculative=None
    )
    trace_out = None
    tmp = None
    if replay:
        tmp = tempfile.NamedTemporaryFile(
            suffix=".jsonl", delete=False
        )
        tmp.close()
        trace_out = tmp.name
    try:
        spec_out, spec_row, summaries = _serve(
            cfg,
            full_cfg,
            params,
            sampling=sampling,
            speculative=speculative,
            trace_out=trace_out,
        )
        replay_errs = (
            _replay_checks(trace_out, summaries) if replay else []
        )
    finally:
        if tmp is not None:
            os.unlink(tmp.name)

    accepted = sum(s["accepted_tokens"] for s in summaries)
    verify = sum(s["verify_steps"] for s in summaries)
    tpot_cut = (
        1.0 - spec_row["tpot_p50"] / base_row["tpot_p50"]
        if base_row["tpot_p50"]
        else 0.0
    )
    return {
        "bench": "spec",  # self-identify for merge_runs/report
        "cell": name,
        "arch": arch,
        "family": cfg.family,
        "drafter": drafter,
        "depth": DEPTH,
        "sampling": "greedy" if sampling.is_greedy else "seeded",
        "identical": base_out == spec_out,
        "accepted_tokens": accepted,
        "draft_tokens": sum(s["draft_tokens"] for s in summaries),
        "verify_steps": verify,
        "accepted_per_step": round(accepted / verify, 4) if verify else 0.0,
        "tpot_base_ms": round(base_row["tpot_p50"] * 1e3, 4),
        "tpot_spec_ms": round(spec_row["tpot_p50"] * 1e3, 4),
        "tpot_spec_cut": round(tpot_cut, 4),
        "replay_errors": replay_errs if replay else None,
    }


def run() -> list[dict]:
    return [
        _cell(
            "dense+twin/greedy",
            "smollm_360m",
            "smollm_360m",
            sampling_kwargs={},
            replay=True,
        ),
        _cell(
            "dense+twin/seeded",
            "smollm_360m",
            "smollm_360m",
            sampling_kwargs=dict(temperature=0.8, top_k=40, seed=5),
        ),
        _cell(
            "moe+ngram/greedy",
            "olmoe_1b_7b",
            "ngram",
            sampling_kwargs={},
        ),
    ]


def check(rows: list[dict]) -> list[str]:
    errs = []
    by = {r["cell"]: r for r in rows}
    for r in rows:
        if not r["identical"]:
            errs.append(
                f"{r['cell']}: speculative output diverged from "
                "non-speculative decode"
            )
        if r["replay_errors"]:
            errs.extend(f"{r['cell']}: {e}" for e in r["replay_errors"])
    twin = by.get("dense+twin/greedy")
    if twin is None:
        return errs + ["missing dense+twin/greedy cell"]
    if twin["accepted_per_step"] < TWIN_ACCEPT_FLOOR:
        errs.append(
            f"twin acceptance {twin['accepted_per_step']:.2f} tokens/verify "
            f"< {TWIN_ACCEPT_FLOOR}"
        )
    if twin["tpot_spec_cut"] < TPOT_CUT_FLOOR:
        errs.append(
            f"twin TPOT cut {twin['tpot_spec_cut']:.3f} < {TPOT_CUT_FLOOR}"
        )
    ngram = by.get("moe+ngram/greedy")
    if ngram and ngram["accepted_per_step"] < NGRAM_ACCEPT_FLOOR:
        errs.append(
            f"ngram acceptance {ngram['accepted_per_step']:.2f} "
            f"tokens/verify < {NGRAM_ACCEPT_FLOOR}"
        )
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CPU cell (the only cell this bench runs)")
    ap.add_argument("--out", default="spec_bench.json")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="do not append to BENCH_trajectory.json")
    args = ap.parse_args(argv)
    if not args.smoke:
        print("[spec_bench] only the reduced --smoke cell is implemented "
              "(full-size serving needs real accelerators); pass --smoke")
        return 2

    rows = run()
    errs = check(rows)
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    for e in errs:
        print(f"  BAND-CHECK FAIL: {e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": errs}, f, indent=2)
        print(f"[spec_bench] wrote {args.out}")
    if not args.no_trajectory:
        from benchmarks import trajectory

        twin = rows[0]
        entry = trajectory.append_run(
            {
                "ok": not errs,
                "accepted_per_step": twin["accepted_per_step"],
                "tpot_spec_cut": twin["tpot_spec_cut"],
                "drafter": twin["drafter"],
                "depth": twin["depth"],
            },
            bench="spec",
        )
        print(
            f"[spec_bench] trajectory run #{entry['run_index']} -> "
            f"{trajectory.TRAJECTORY_PATH}"
        )
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
