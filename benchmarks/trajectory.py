"""Persistent benchmark trajectory: one JSON file, one entry per run.

The soak harness (and any other bench that opts in) appends a compact
run summary to ``BENCH_trajectory.json`` at the repo root after every
run. The file is an append-only list, so the repo accumulates a
longitudinal record of soak results across sessions — regressions show
up as a break in the series, not as a lost stdout line.

Entries are whatever the caller passes plus bookkeeping (``bench``,
``run_index``, optional ``timestamp`` supplied by the caller); nothing
here interprets them beyond dedup-free appending. ``load_runs`` returns
the list for reporting (``benchmarks/report.py`` renders the tail).

CLI::

    PYTHONPATH=src python benchmarks/trajectory.py          # show tail
    PYTHONPATH=src python benchmarks/trajectory.py --bench soak -n 20
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

TRAJECTORY_PATH = Path(__file__).resolve().parent.parent / (
    "BENCH_trajectory.json"
)


def load_runs(path=None) -> list[dict]:
    p = Path(path) if path is not None else TRAJECTORY_PATH
    if not p.exists():
        return []
    with open(p) as fh:
        data = json.load(fh)
    if not isinstance(data, list):
        raise ValueError(f"{p}: expected a JSON list, got {type(data)}")
    return data


def append_run(summary: dict, *, bench: str, path=None) -> dict:
    """Append one run summary; returns the stored entry (with its
    ``run_index``). The write is whole-file (read, append, rewrite):
    the file stays a valid JSON list at every point."""
    p = Path(path) if path is not None else TRAJECTORY_PATH
    runs = load_runs(p)
    entry = {"bench": bench, "run_index": len(runs), **summary}
    runs.append(entry)
    tmp = p.with_suffix(".json.tmp")
    with open(tmp, "w") as fh:
        json.dump(runs, fh, indent=2)
        fh.write("\n")
    tmp.replace(p)
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=None, help="trajectory file "
                    f"(default {TRAJECTORY_PATH})")
    ap.add_argument("--bench", default=None, help="filter by bench name")
    ap.add_argument("-n", type=int, default=10, help="show the last N runs")
    args = ap.parse_args(argv)
    runs = load_runs(args.path)
    if args.bench:
        runs = [r for r in runs if r.get("bench") == args.bench]
    if not runs:
        print("(no recorded runs)")
        return 0
    for r in runs[-args.n :]:
        print(json.dumps(r))
    return 0


if __name__ == "__main__":
    sys.exit(main())
