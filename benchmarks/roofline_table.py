"""Beyond-paper benchmark: the TPU roofline table over all 40 assigned
(arch x shape) cells, read from the dry-run artifacts in
``experiments/*.jsonl`` (produced by ``repro.launch.dryrun``)."""

from __future__ import annotations

import json
import os

BASELINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments",
    "dryrun_baseline.jsonl",
)


def load(path: str = BASELINE) -> list[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as fh:
        for line in fh:
            out.append(json.loads(line))
    return out


def run() -> list[dict]:
    rows = []
    for r in load():
        if r.get("mesh") != "16x16":
            continue
        if r["status"] != "OK":
            rows.append(
                {
                    "bench": "roofline",
                    "cell": f"{r['arch']}/{r['shape']}",
                    "status": r["status"],
                }
            )
            continue
        rows.append(
            {
                "bench": "roofline",
                "cell": f"{r['arch']}/{r['shape']}",
                "status": "OK",
                "t_compute_ms": round(r["t_compute_ms"], 2),
                "t_memory_ms": round(r["t_memory_ms"], 2),
                "t_collective_ms": round(r["t_collective_ms"], 2),
                "bottleneck": r["bottleneck"],
                "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
                "roofline_pct": round(100 * r["roofline_fraction"], 2),
            }
        )
    return rows


def check(rows: list[dict]) -> list[str]:
    if not rows:
        return ["no dry-run artifacts: run `python -m repro.launch.dryrun`"]
    errs = []
    ok = [r for r in rows if r["status"] == "OK"]
    skip = [r for r in rows if r["status"].startswith("SKIP")]
    if len(ok) + len(skip) != 40:
        errs.append(f"expected 40 cells, got {len(ok)} OK + {len(skip)} skip")
    if any(not r["status"].startswith(("OK", "SKIP")) for r in rows):
        errs.append("dry-run failures present")
    return errs
