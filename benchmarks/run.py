"""Benchmark harness: one module per paper table + the TPU roofline table.

Each module exposes ``run() -> list[dict]`` (the rows) and
``check(rows) -> list[str]`` (reproduction-band assertions vs the paper's
published numbers). ``python -m benchmarks.run`` executes all of them,
prints the rows as CSV, and exits non-zero if any band check fails.
"""

from __future__ import annotations

import sys
import time

from benchmarks import (
    fig2_efficiency,
    fleet_bench,
    kernel_bench,
    prefix_bench,
    residency_bench,
    roofline_table,
    serve_bench,
    soak_bench,
    spec_bench,
    table1_bnn_pynq,
    table2_rn50,
    table4_packing,
    table5_throughput,
)

BENCHES = [
    ("table1_bnn_pynq (paper Table I)", table1_bnn_pynq),
    ("fig2_efficiency (paper Fig. 2)", fig2_efficiency),
    ("table2_rn50 (paper Table II)", table2_rn50),
    ("table4_packing (paper Table IV)", table4_packing),
    ("table5_throughput (paper Table V)", table5_throughput),
    ("kernel_bench (FCMP packed weights on TPU)", kernel_bench),
    ("roofline_table (40-cell dry-run)", roofline_table),
    ("serve_bench (KV-pool continuous batching vs fixed-batch)", serve_bench),
    ("residency_bench (budgeted weight residency + §V port)", residency_bench),
    ("fleet_bench (multi-engine fleet + disaggregated prefill/decode)",
     fleet_bench),
    ("prefix_bench (radix prefix cache vs cold KV pool)", prefix_bench),
    ("spec_bench (speculative decode vs plain paged decode)", spec_bench),
    ("soak_bench (virtual-hour churn soak + tracker replay)", soak_bench),
]


def _csv(rows: list[dict]) -> str:
    if not rows:
        return "(no rows)"
    keys: list[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(str(r.get(k, "")) for k in keys))
    return "\n".join(lines)


def main() -> int:
    failures: list[str] = []
    for title, mod in BENCHES:
        t0 = time.monotonic()
        rows = mod.run()
        dt = time.monotonic() - t0
        errs = mod.check(rows)
        print(f"\n=== {title} [{dt:.1f}s] ===")
        print(_csv(rows))
        for e in errs:
            print(f"  BAND-CHECK FAIL: {e}")
        failures.extend(f"{title}: {e}" for e in errs)
    print(f"\n{len(BENCHES)} benchmarks, {len(failures)} band-check failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
