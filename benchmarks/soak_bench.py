"""Long-horizon soak: multi-turn churn over the full serving stack.

The unit suites pin each seam in isolation; the soak replays the
*composition* for hours of virtual time and checks the conservation
invariants that only break under churn — leaked pool blocks after a
drain, a stale chunk cursor, a refcount that drifts across thousands of
adopt/release cycles, a tracker stream that stops adding up.

One soak run drives four phases over the same JSONL tracker stream:

  phase 1 (fleet): a 2-engine prefix-aware ``FleetCluster`` serves
  ``n_segments`` bursts of traffic spread over ``span_s`` virtual
  seconds each. Segments are *conversational*: half of each segment's
  arrivals extend a finished session (prior prompt + prior full
  response + fresh turn), which exercises ISSUE 6's generated-token
  re-indexing — the soak counts follow-ups whose cached match reaches
  past the parent's prompt into its generated tokens. Odd segments
  drain one engine mid-burst and restore it afterwards (requeue churn).

  phase 2 (disagg): a 3-engine ``DisaggCluster`` serves one more burst,
  so KV-handoff payload accounting rides the same invariant probe.

  phase 3 (moe): a 2-engine olmoe fleet under a small token budget
  serves a burst whose last prompt exceeds every engine's budget — the
  fleet-level chunked-admission regression (the router must place it,
  not bounce it) — and the ``expert_tokens`` seam counter must replay
  exactly from the stream.

  phase 4 (speculative): a 2-engine fleet decodes a burst through the
  packed-twin drafter with a mid-burst drain, so requeue churn rides
  the draft-and-verify path. Draft blocks are transient within one
  verify round — the probe asserts ``pool.draft_rids()`` is empty
  between rounds and after the burst (nothing leaked by rollback), and
  the ``accepted_tokens`` / ``draft_tokens`` / ``verify_steps``
  counters must replay exactly from the stream.

Invariants, probed every ``check_every`` engine rounds and at every
phase end:

  * ``KVPool.validate()`` — per-block refcount audit plus the lifetime
    conservation law ``alloc_blocks - freed_blocks == live blocks``;
  * no chunk-cursor or hybrid chunk-lane entry outside an active slot
    (the drain-leak regression of ISSUE 6);
  * every completed request produced exactly ``max_new_tokens`` tokens,
    and the engines' ``generated_tokens`` counters sum to exactly the
    tokens handed back (token conservation);
  * replaying the emitted JSONL stream (``tracker.replay_summary``)
    reproduces every engine's live summary counters exactly;
  * integrating the memory ledger's ``kind="mem"`` deltas over the
    *whole* stream (``memledger.validate_ledger``) reproduces every
    round's pool gauges byte-exactly — all four phases, the mid-burst
    drain/restore churn, and the engine-id reuse across phase
    boundaries included;
  * the lifecycle spans in the same stream decompose *exactly*
    (``spans.validate_trace``): every completed request's phase spans
    tile [submit, done] with zero gaps, and its admit/first stamps sit
    on span boundaries — probed per phase, since the four phases reuse
    request ids on one stream;
  * TTFT/TPOT percentiles stay inside a loose SLO band — measured
    submit-relative (arrival to first token), so queue wait counts
    against the band (the soak is a conservation test, not a latency
    benchmark).

The run summary is appended to ``BENCH_trajectory.json`` at the repo
root (see ``benchmarks/trajectory.py``) — the longitudinal record.

CLI (defaults to >= 1 virtual hour)::

    PYTHONPATH=src python benchmarks/soak_bench.py \
        [--virtual-hours 1.0] [--segments 4] [--requests 8] \
        [--trace-out soak_trace.jsonl] [--out soak_bench.json] \
        [--no-trajectory]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

# `python benchmarks/soak_bench.py` puts benchmarks/ (not the repo
# root) on sys.path; the trajectory import below needs the root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

BLOCK = 4
SLOTS = 2
MAX_LEN = 64
FRESH_TURN = 6  # tokens a follow-up appends after the prior response
SLO_TTFT_S = 600.0  # loose: the soak's bursts intentionally queue
SLO_TPOT_S = 60.0


# ---------------- conversational trace ----------------


def _segment_trace(rng, vocab, *, rid0, t0, span_s, n, history, engines):
    """One burst of arrivals. ``history`` maps session -> (prompt,
    output) of the session's last finished turn; half the arrivals
    extend one. Returns (requests, probes) where probes carry each
    follow-up's cached-match length *at build time* against its
    parent's prompt length (the generated-token reuse accounting)."""
    import numpy as np

    from repro.runtime.cluster.traffic import ClientRequest

    fresh = lambda k: rng.integers(0, vocab, size=(k,)).astype(np.int32)
    reqs, probes = [], []
    sessions = sorted(history)
    next_session = (max(sessions) + 1) if sessions else 0
    for i in range(n):
        # front-loaded burst: 60% arrive nearly at once (queues form, so
        # a mid-burst drain genuinely moves requests), the rest trickle
        if i < (6 * n) // 10:
            t = t0 + 0.001 * i
        else:
            t = t0 + span_s * (i + 1) / n  # last arrival paces the horizon
        rid = rid0 + i
        gen = int(rng.choice((4, 8)))
        parent = None
        if sessions and rng.random() < 0.5:
            s = sessions[int(rng.integers(len(sessions)))]
            pp, out = history[s]
            prompt = np.concatenate(
                [pp, np.asarray(out, np.int32), fresh(FRESH_TURN)]
            )
            if len(prompt) + gen <= MAX_LEN:
                parent = (s, len(pp))
            else:  # conversation outgrew the context: start a new one
                prompt = fresh(int(rng.integers(8, 17)))
        else:
            prompt = fresh(int(rng.integers(8, 17)))
        if parent is not None:
            session, plen = parent
            matched = max(e.prefix_match_tokens(prompt) for e in engines)
            probes.append(
                {"rid": rid, "parent_prompt_len": plen, "matched": matched}
            )
        else:
            session = next_session
            next_session += 1
        reqs.append(ClientRequest(rid, t, prompt, gen, session))
    return reqs, probes


# ---------------- invariant probe ----------------


class _Probe:
    """Periodic per-round invariant check (the ``round_hook``)."""

    def __init__(self, check_every: int):
        self.check_every = check_every
        self.checks = 0
        self.failures: list[str] = []

    def __call__(self, engine, rounds: int) -> None:
        if rounds % self.check_every:
            return
        self.checks += 1
        sch = engine.scheduler
        try:
            sch.pool.validate()
        except AssertionError as e:  # pragma: no cover - failure path
            self.failures.append(f"engine {engine.engine_id}: {e}")
        active = {rid for rid in sch.active if rid is not None}
        stale = set(sch._chunk_cursor) - active
        if stale:  # pragma: no cover - failure path
            self.failures.append(
                f"engine {engine.engine_id}: stale chunk cursors {stale}"
            )
        if set(sch._chunk_lane) - set(sch._chunk_cursor):
            self.failures.append(  # pragma: no cover - failure path
                f"engine {engine.engine_id}: leaked chunk lanes"
            )
        leaked = sch.pool.draft_rids()
        if leaked:  # pragma: no cover - failure path
            self.failures.append(
                f"engine {engine.engine_id}: draft blocks outlive "
                f"their verify round: {sorted(leaked)}"
            )


def _span_check(records, label: str) -> list[str]:
    """The span conservation law: each completed request's phase spans
    tile [submit, done] exactly. One phase's record slice at a time —
    request ids repeat across the soak's phases."""
    from repro.runtime.spans import validate_trace

    return [f"{label}: {e}" for e in validate_trace(records)]


def _handoff_transit_p95(records) -> float:
    """p95 handoff span duration (prefill-side KV transit) in seconds."""
    import numpy as np

    durs = [
        r["t1"] - r["t0"]
        for r in records
        if r.get("kind") == "span" and r.get("phase") == "handoff"
    ]
    return float(np.percentile(durs, 95)) if durs else 0.0


def _replay_check(records, engines) -> list[str]:
    """The tracker conservation law: stream replay == live summaries."""
    from repro.runtime.tracker import replay_summary

    errs = []
    for e in engines:
        rep = replay_summary(records, engine=e.engine_id)
        summ = e.summary()
        for k in (
            "completed", "handoffs", "prefill_steps", "prefill_tokens",
            "decode_steps", "generated_tokens", "prefix_hits",
            "prefix_hit_tokens", "expert_tokens", "accepted_tokens",
            "draft_tokens", "verify_steps",
        ):
            if rep[k] != summ[k]:
                errs.append(
                    f"engine {e.engine_id}: replayed {k}={rep[k]} != "
                    f"live {summ[k]}"
                )
    return errs


# ---------------- the soak ----------------


def run_soak(
    *,
    virtual_hours: float = 1.0,
    n_segments: int = 4,
    requests_per_segment: int = 8,
    seed: int = 0,
    check_every: int = 8,
    trace_out=None,
) -> dict:
    """Run all four phases; returns the summary dict (one trajectory entry)."""
    import math

    import jax
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.models import lm
    from repro.runtime.cluster import (
        DisaggCluster,
        FleetCluster,
        SloPolicy,
        StepCostModel,
        TrafficSpec,
    )
    from repro.runtime.cluster.traffic import slo_report
    from repro.runtime.tracker import JsonlTracker, NullTracker, read_jsonl

    t_wall = time.monotonic()
    rng = np.random.default_rng(seed)
    cfg = get_smoke_config("smollm_360m")
    params = lm.init_params(cfg, jax.random.key(0))
    cost = StepCostModel.for_config(get_config("smollm_360m"), slots=SLOTS)
    # arrivals pace the virtual clock: idle engines jump to the next
    # burst, so span_s per segment buys the horizon directly
    span_s = virtual_hours * 3600.0 / max(1, n_segments)
    tracker = JsonlTracker(trace_out) if trace_out else NullTracker()

    soak_slo = SloPolicy(ttft=SLO_TTFT_S, tpot=SLO_TPOT_S)
    cluster = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, policy="prefix-aware",
        prefix_cache=True, tracker=tracker, slo=soak_slo,
    )
    probe = _Probe(check_every)
    history: dict[int, tuple] = {}
    all_timings: dict = {}
    errors: list[str] = []
    rid0, drains, gen_reuse_hits, n_followups = 0, 0, 0, 0
    total_output_tokens = 0
    for seg in range(n_segments):
        t0 = seg * span_s
        trace, probes = _segment_trace(
            rng, cfg.vocab, rid0=rid0, t0=t0, span_s=span_s,
            n=requests_per_segment, history=history,
            engines=cluster.engines,
        )
        n_followups += len(probes)
        gen_reuse_hits += sum(
            p["matched"] > p["parent_prompt_len"] for p in probes
        )
        drain_at = None
        if seg % 2 == 1:  # churn: cycle one engine out mid-burst...
            drain_at = ((seg // 2) % len(cluster.engines), t0 + 0.0005)
        res = cluster.run(trace, drain_at=drain_at, round_hook=probe)
        if drain_at is not None:  # ...and back in for the next segment
            cluster.restore_engine(drain_at[0])
            drains += 1
        all_timings.update(res.timings)
        # engines accumulate requests for their lifetime; score only the
        # segment's own arrivals
        by_rid = {r.rid: r for r in trace}
        seg_done = 0
        for rid, out in res.outputs.items():
            creq = by_rid.get(rid)
            if creq is None:
                continue
            seg_done += 1
            if len(out) != creq.max_new_tokens:
                errors.append(
                    f"request {rid}: {len(out)} tokens, wanted "
                    f"{creq.max_new_tokens}"
                )
            total_output_tokens += len(out)
            history[creq.session] = (creq.prompt, out)
        if seg_done != len(trace):
            errors.append(
                f"segment {seg}: {seg_done}/{len(trace)} completed"
            )
        rid0 += len(trace)
    errors.extend(probe.failures)

    fleet_generated = sum(
        e.scheduler.stats.generated_tokens for e in cluster.engines
    )
    if fleet_generated != total_output_tokens:
        errors.append(
            f"token conservation: engines generated {fleet_generated}, "
            f"clients received {total_output_tokens}"
        )
    clock_h = max(e.clock for e in cluster.engines) / 3600.0
    if clock_h < virtual_hours * 0.95:
        errors.append(
            f"virtual horizon {clock_h:.2f}h < target {virtual_hours}h"
        )
    if n_followups and gen_reuse_hits == 0:
        errors.append("no follow-up ever matched into generated tokens")
    fleet_records = read_jsonl(trace_out) if trace_out else []
    n_fleet_lines = len(fleet_records)
    if trace_out:
        errors.extend(_replay_check(fleet_records, cluster.engines))
        errors.extend(_span_check(fleet_records, "fleet spans"))
    slo = slo_report(all_timings, soak_slo)
    if slo.completed and slo.slo_met < slo.completed * 0.9:
        errors.append(
            f"SLO band: only {slo.slo_met}/{slo.completed} met "
            f"(ttft<={SLO_TTFT_S}s, tpot<={SLO_TPOT_S}s)"
        )

    # phase 2: disaggregated prefill/decode on the same stream
    spec = TrafficSpec(
        vocab=cfg.vocab, n_requests=requests_per_segment,
        arrival_rate=2000.0,
        prompt_lens=((8, 0.5), (16, 0.5)), gen_lens=((4, 0.5), (8, 0.5)),
        seed=seed + 1,
    )
    disagg = DisaggCluster(
        cfg, params, n_engines=3, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, spec=spec, tracker=tracker,
        slo=soak_slo,
    )
    import dataclasses

    from repro.runtime.cluster.traffic import synthesize

    # phases share one tracker stream: keep rids globally unique so the
    # span/event timelines never collide (the per-phase validate_trace
    # slices don't need it, but report/export tooling reads whole files)
    dtrace = [
        dataclasses.replace(r, rid=r.rid + rid0) for r in synthesize(spec)
    ]
    dres = disagg.run(dtrace, round_hook=probe)
    handoffs = sum(
        e.scheduler.stats.handoffs for e in disagg.prefill_engines
    )
    if handoffs == 0:
        errors.append("disagg phase produced no KV handoffs")
    if len(dres.outputs) != spec.n_requests:
        errors.append(
            f"disagg: {len(dres.outputs)}/{spec.n_requests} completed"
        )
    if trace_out:
        disagg_records = read_jsonl(trace_out)[n_fleet_lines:]
        errors.extend(_replay_check(disagg_records, disagg.engines))
        errors.extend(_span_check(disagg_records, "disagg spans"))
    n_disagg_lines = n_fleet_lines + (
        len(disagg_records) if trace_out else 0
    )

    # phase 3: moe burst — dropless per-token serving and fleet-level
    # chunked admission ride the same stream. The burst includes one
    # prompt larger than every engine's token budget; the router must
    # place it (an idle chunkable engine streams it through
    # budget-sized chunks) instead of bouncing it at offer().
    from repro.runtime.cluster.traffic import ClientRequest

    mcfg = get_smoke_config("olmoe_1b_7b")
    mparams = lm.init_params(mcfg, jax.random.key(0))
    mcost = StepCostModel.for_config(
        get_config("olmoe_1b_7b"), slots=SLOTS
    )
    moe_budget = 24
    mfresh = lambda k: rng.integers(0, mcfg.vocab, size=(k,)).astype(
        np.int32
    )
    moe0 = rid0 + spec.n_requests
    moe_trace = [
        ClientRequest(moe0 + i, 0.001 * i, mfresh(int(rng.integers(8, 17))),
                      int(rng.choice((4, 8))), i)
        for i in range(requests_per_segment - 1)
    ]
    over = moe0 + requests_per_segment - 1
    moe_trace.append(  # over-budget: 32 + 4 > moe_budget on every engine
        ClientRequest(over, 0.001 * (over - moe0), mfresh(32), 4, over)
    )
    moe_cluster = FleetCluster(
        mcfg, mparams, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=mcost, policy="prefix-aware",
        prefix_cache=True, token_budget=moe_budget, tracker=tracker,
        slo=soak_slo,
    )
    mres = moe_cluster.run(moe_trace, round_hook=probe)
    if len(mres.outputs) != len(moe_trace):
        errors.append(
            f"moe burst: {len(mres.outputs)}/{len(moe_trace)} completed"
        )
    if len(mres.outputs.get(over, ())) != 4:
        errors.append(
            "moe burst: the over-budget prompt did not finish (fleet "
            "chunked admission regressed)"
        )
    moe_expert_tokens = sum(
        e.scheduler.stats.expert_tokens for e in moe_cluster.engines
    )
    if moe_expert_tokens == 0:
        errors.append("moe burst routed no token through the dispatch")
    if trace_out:
        moe_records = read_jsonl(trace_out)[n_disagg_lines:]
        errors.extend(_replay_check(moe_records, moe_cluster.engines))
        errors.extend(_span_check(moe_records, "moe spans"))
    n_moe_lines = n_disagg_lines + (len(moe_records) if trace_out else 0)

    # phase 4: speculative burst — the packed-twin drafter decodes over
    # the paged pool while a mid-burst drain requeues engine 0's queue.
    # Draft blocks are transient within one verify round; the probe and
    # the post-burst check assert rollback returned every one, and the
    # accepted/draft/verify counters must replay from the stream.
    from repro.runtime.speculative import SpecConfig, resolve

    spec4 = resolve(
        cfg, SpecConfig(drafter="smollm_360m", depth=4, quant=2),
        smoke=True,
    )
    sfresh = lambda k: rng.integers(0, cfg.vocab, size=(k,)).astype(
        np.int32
    )
    spec0 = over + 1
    spec_trace = [
        ClientRequest(spec0 + i, 0.001 * i,
                      sfresh(int(rng.integers(8, 17))),
                      int(rng.choice((4, 8))), spec0 + i)
        for i in range(requests_per_segment)
    ]
    spec_cluster = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, speculative=spec4,
        tracker=tracker, slo=soak_slo,
    )
    sres = spec_cluster.run(
        spec_trace, drain_at=(0, 0.004), round_hook=probe
    )
    drains += 1
    if len(sres.outputs) != len(spec_trace):
        errors.append(
            f"spec burst: {len(sres.outputs)}/{len(spec_trace)} completed"
        )
    spec_accepted = sum(
        e.scheduler.stats.accepted_tokens for e in spec_cluster.engines
    )
    spec_verify = sum(
        e.scheduler.stats.verify_steps for e in spec_cluster.engines
    )
    if spec_verify == 0 or spec_accepted == 0:
        errors.append("spec burst never verified a draft chain")
    for e in spec_cluster.engines:
        try:
            e.scheduler.pool.validate()
        except AssertionError as exc:  # pragma: no cover - failure path
            errors.append(f"spec burst engine {e.engine_id}: {exc}")
        leaked = e.scheduler.pool.draft_rids()
        if leaked:  # pragma: no cover - failure path
            errors.append(
                f"spec burst engine {e.engine_id}: leaked draft "
                f"blocks for rids {sorted(leaked)}"
            )
    if trace_out:
        spec_records = read_jsonl(trace_out)[n_moe_lines:]
        errors.extend(_replay_check(spec_records, spec_cluster.engines))
        errors.extend(_span_check(spec_records, "spec spans"))
    tracker.finish()

    # the memory-ledger conservation law, probed over the WHOLE stream:
    # integrating the kind="mem" deltas must land exactly on every
    # round's pool gauges, across all four phases, the mid-burst
    # drain/restore churn, and the engine-id reuse at phase boundaries
    # (each phase's attach records reset the integration)
    mem_records = 0
    kv_occupancy_p95 = cached_fraction_p50 = streamed_mib_per_vs = 0.0
    if trace_out:
        from repro.runtime.memledger import validate_ledger

        stream = read_jsonl(trace_out)
        mem_records = sum(1 for r in stream if r.get("kind") == "mem")
        errors.extend(f"mem ledger: {e}" for e in validate_ledger(stream))
        occ, cached = [], []
        n_blocks: dict = {}  # per engine, from the attach records
        streamed: dict = {}  # engine -> [first (t, cum), last (t, cum)]
        for r in stream:
            kind = r.get("kind", "metrics")
            if kind == "mem" and r.get("op") == "attach":
                n_blocks[r.get("engine")] = int(r["n_blocks"])
            if kind != "metrics":
                continue
            if "pool_occupancy" in r:
                occ.append(float(r["pool_occupancy"]))
            nb = n_blocks.get(r.get("engine"))
            if nb and "pool_cached_blocks" in r:
                cached.append(r["pool_cached_blocks"] / nb)
            if "residency_streamed_mib" in r and "clock_s" in r:
                pair = (
                    float(r["clock_s"]),
                    float(r["residency_streamed_mib"]),
                )
                streamed.setdefault(r.get("engine"), [pair, pair])[1] = pair
        if occ:
            kv_occupancy_p95 = round(float(np.percentile(occ, 95)), 4)
        if cached:
            cached_fraction_p50 = round(
                float(np.percentile(cached, 50)), 4
            )
        mib = dt = 0.0
        for (ta, ca), (tb, cb) in streamed.values():
            mib += cb - ca
            dt += tb - ta
        if dt > 0:
            streamed_mib_per_vs = round(mib / dt, 6)

    assert math.isfinite(clock_h)
    return {
        "virtual_hours": round(clock_h, 3),
        "segments": n_segments,
        "requests": rid0 + spec.n_requests + len(moe_trace)
        + len(spec_trace),
        "completed": slo.completed + len(dres.outputs) + len(mres.outputs)
        + len(sres.outputs),
        "drains": drains,
        "followups": n_followups,
        "gen_reuse_hits": gen_reuse_hits,
        "handoffs": handoffs,
        "moe_requests": len(moe_trace),
        "moe_expert_tokens": moe_expert_tokens,
        "spec_requests": len(spec_trace),
        "spec_accepted_tokens": spec_accepted,
        "spec_verify_steps": spec_verify,
        "generated_tokens": fleet_generated
        + sum(e.scheduler.stats.generated_tokens for e in disagg.engines)
        + sum(
            e.scheduler.stats.generated_tokens
            for e in moe_cluster.engines
        )
        + sum(
            e.scheduler.stats.generated_tokens
            for e in spec_cluster.engines
        ),
        "invariant_checks": probe.checks,
        "trace_records": (
            len(fleet_records) + len(disagg_records) + len(moe_records)
            + len(spec_records)
            if trace_out else 0
        ),
        "span_records": (
            sum(
                1
                for r in fleet_records + disagg_records + moe_records
                + spec_records
                if r.get("kind") == "span"
            )
            if trace_out else 0
        ),
        "mem_records": mem_records,
        "kv_occupancy_p95": kv_occupancy_p95,
        "cached_fraction_p50": cached_fraction_p50,
        "streamed_mib_per_vs": streamed_mib_per_vs,
        "ttft_p95_s": round(slo.ttft_p95, 3),
        "tpot_p95_s": round(slo.tpot_p95, 3),
        "queue_wait_p95_s": round(slo.queue_wait_p95, 6),
        "handoff_transit_p95_s": round(
            _handoff_transit_p95(disagg_records if trace_out else []), 9
        ),
        "wall_s": round(time.monotonic() - t_wall, 2),
        "errors": errors,
        "ok": not errors,
    }


# ---------------- benchmarks.run contract ----------------


def run() -> list[dict]:
    """Smoke cell for the bench suite / CI: still >= 1 virtual hour (the
    horizon is bought with arrival spacing, not wall clock)."""
    summary = run_soak(
        virtual_hours=1.0, n_segments=3, requests_per_segment=6,
        trace_out="soak_trace.jsonl",
    )
    from benchmarks import trajectory

    summary["timestamp"] = time.time()
    trajectory.append_run(
        {k: v for k, v in summary.items() if k != "errors"}, bench="soak"
    )
    return [{"bench": "soak", **summary, "errors": "; ".join(
        summary["errors"]) or ""}]


def check(rows: list[dict]) -> list[str]:
    errs = []
    for r in rows:
        if not r["ok"]:
            errs.append(f"soak invariants failed: {r['errors']}")
        if r["virtual_hours"] < 0.95:
            errs.append(f"soak horizon {r['virtual_hours']}h < 1h")
        if r["invariant_checks"] == 0:
            errs.append("the invariant probe never ran")
        if r["followups"] and r["gen_reuse_hits"] == 0:
            errs.append("no generated-token prefix reuse observed")
        if r.get("moe_requests") and r.get("moe_expert_tokens", 0) == 0:
            errs.append("moe burst recorded no expert-routed tokens")
        if r.get("spec_requests") and r.get("spec_accepted_tokens", 0) == 0:
            errs.append("spec burst accepted no speculative tokens")
        if r.get("trace_records") and r.get("mem_records", 0) == 0:
            errs.append("trace stream carries no kind='mem' records")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--virtual-hours", type=float, default=1.0)
    ap.add_argument("--segments", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8,
                    help="arrivals per segment")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-every", type=int, default=8,
                    help="probe invariants every K engine rounds")
    ap.add_argument("--trace-out", default="soak_trace.jsonl",
                    help="JSONL tracker stream ('' disables)")
    ap.add_argument("--out", default="soak_bench.json")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="do not append to BENCH_trajectory.json")
    args = ap.parse_args(argv)
    summary = run_soak(
        virtual_hours=args.virtual_hours,
        n_segments=args.segments,
        requests_per_segment=args.requests,
        seed=args.seed,
        check_every=args.check_every,
        trace_out=args.trace_out or None,
    )
    summary["timestamp"] = time.time()
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[soak_bench] wrote {args.out}")
    if not args.no_trajectory:
        from benchmarks import trajectory

        entry = trajectory.append_run(
            {k: v for k, v in summary.items() if k != "errors"},
            bench="soak",
        )
        print(
            f"[soak_bench] trajectory run #{entry['run_index']} -> "
            f"{trajectory.TRAJECTORY_PATH}"
        )
    for e in summary["errors"]:
        print(f"  SOAK FAIL: {e}")
    return 1 if summary["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
