"""Paper Fig. 2: OCM mapping efficiency decreases as compute parallelism
grows (same parameters, more/wider/shallower BRAMs)."""

from __future__ import annotations

from repro.core.buffers import Folding, LayerSpec, mvau_buffer


def run() -> list[dict]:
    # the paper's illustration: one conv layer at 1x / 2x / 4x parallelism
    layer = LayerSpec("conv", c_in=256, c_out=256, k=3, out_pixels=196)
    rows = []
    for label, pe, simd in (("1x", 4, 8), ("2x", 8, 8), ("4x", 8, 16),
                            ("8x", 16, 16), ("16x", 32, 16)):
        buf = mvau_buffer(layer, Folding(pe, simd))
        rows.append(
            {
                "bench": "fig2",
                "parallelism": label,
                "pe": pe,
                "simd": simd,
                "width_bits": buf.width_bits,
                "depth_words": buf.depth_words,
                "brams": buf.blocks(),
                "efficiency_pct": round(100 * buf.efficiency(), 1),
            }
        )
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    effs = [r["efficiency_pct"] for r in rows]
    if not all(a >= b - 1e-9 for a, b in zip(effs, effs[1:])):
        errs.append(f"efficiency should fall with parallelism: {effs}")
    if rows[0]["brams"] >= rows[-1]["brams"]:
        errs.append("BRAM count should grow with parallelism (Fig. 2)")
    return errs
