"""Paper Table I: resource utilization of FINN dataflow accelerators on
Zynq 7020 — BRAM is the bottleneck resource (the paper's motivation).

We reproduce the *structure* of the table from our resource model: for
CNV-W1A1/W2A2 at a throughput-maximising folding, BRAM% exceeds LUT% —
OCM is the binding constraint (paper reports 88-100% BRAM vs 49-92% LUT).
"""

from __future__ import annotations

from repro.configs import get_accelerator
from repro.core.efficiency import baseline_report, device_utilization


def run() -> list[dict]:
    rows = []
    for name in ("cnv_w1a1", "cnv_w2a2"):
        acc = get_accelerator(name)
        rep = baseline_report(name, acc.buffers())
        util = device_utilization(acc.device, rep.brams, acc.folding.luts)
        rows.append(
            {
                "bench": "table1",
                "accel": name,
                "device": acc.device.name,
                "bram_pct": round(util["bram_pct"], 1),
                "lut_pct": round(util["lut_pct"], 1),
                "bram_is_bottleneck": util["bram_pct"] > util["lut_pct"],
            }
        )
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    for r in rows:
        if not r["bram_is_bottleneck"]:
            errs.append(
                f"{r['accel']}: BRAM ({r['bram_pct']}%) should exceed "
                f"LUT ({r['lut_pct']}%) — paper Table I"
            )
        if not 50 <= r["bram_pct"] <= 110:
            errs.append(f"{r['accel']}: BRAM% {r['bram_pct']} out of band")
    return errs
