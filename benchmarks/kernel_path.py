"""Kernel-execution-path accounting for the attention hot-spot.

The 512-device dry-run lowers the *jnp* FA2 path: XLA materialises every
(G*qb, kb) score/probability tile at fusion boundaries, which sets a
floor on the measured memory term. The fused Pallas kernels
(`kernels/flash_attention.py`, validated vs the dense oracle incl.
gradients) keep those tiles in VMEM; this module recomputes the memory
roofline term for the kernel path:

    T_mem(kernel) = T_mem(HLO) - score_tile_traffic + kernel_hbm_traffic

where score-tile traffic is classified by shape (trailing dims matching
the cell's (G*qb, kb) / (G*qb, d) / (G*qb,) tiles) and the kernel's HBM
traffic is the analytic q/k/v/o block movement (KV re-read once per
visible q-block, FA2 bwd re-reads q/k/v/do once per visible pair).

Run only on demand (it compiles a cell): ``python -m benchmarks.kernel_path``.
"""

from __future__ import annotations

import math


def classify_and_correct(txt: str, cfg, shape, n_dev: int) -> dict:
    from collections import defaultdict

    from repro.perf.hlo_analysis import top_contributors, analyze
    from repro.perf.roofline import HW

    # block geometry exactly as models/flash.py picks it
    def pick(s, t):
        for d in range(min(t, s), 0, -1):
            if s % d == 0:
                return d
        return 1

    s = shape.seq_len
    qb, kb = pick(s, 512), pick(s, 1024)
    g = cfg.n_heads // cfg.n_kv
    gqb = g * qb
    d = cfg.hd

    cost = analyze(txt)
    rows = top_contributors(txt, "traffic", 10**9)
    tile_tails = {
        (gqb, kb), (kb, gqb), (gqb, d), (gqb,), (gqb, 32), (gqb, 64),
    }
    excluded = 0.0
    for v, _, _, _, sh, _ in rows:
        dims = []
        for part in sh.split("]"):
            if "[" in part:
                ds = part.split("[")[1]
                if ds:
                    dims = [int(x) for x in ds.split(",") if x]
        for tail_len in (1, 2):
            if len(dims) >= tail_len and tuple(dims[-tail_len:]) in tile_tails:
                excluded += v
                break

    # analytic kernel HBM traffic per device per step (fwd + bwd)
    dp = 16  # data shards on the single-pod mesh
    b_loc = max(1, shape.global_batch // n_dev)  # after batch resharding
    hq, hkv = cfg.n_heads, cfg.n_kv
    nq, nk = s // qb, s // kb
    visible_pairs = sum(
        min(nk, ((qi * qb + qb - 1) // kb) + 1) for qi in range(nq)
    )
    bytes_q = b_loc * s * hq * d * 2
    bytes_kv = 2 * b_loc * s * hkv * d * 2
    # fwd: q+o once, kv re-read per visible q-block row; bwd: ~2x fwd +
    # dq/dkv writes
    kv_block = b_loc * kb * hkv * d * 2 * 2
    fwd = 2 * bytes_q + visible_pairs * kv_block
    bwd = 2 * fwd + bytes_q + bytes_kv
    kernel_traffic = (fwd + bwd) * cfg.n_layers

    t_hlo = cost.traffic_bytes / HW.hbm_bw
    t_kernel = (cost.traffic_bytes - excluded + kernel_traffic) / HW.hbm_bw
    return {
        "bench": "kernel_path",
        "cell": f"{cfg.name}/{shape.name}",
        "t_mem_hlo_ms": round(t_hlo * 1e3, 1),
        "excluded_tile_gb": round(excluded / 1e9, 2),
        "kernel_attn_traffic_gb": round(kernel_traffic / 1e9, 2),
        "t_mem_kernel_path_ms": round(t_kernel * 1e3, 1),
    }


def main() -> None:
    import os

    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.configs import get_config
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES

    for arch, shape_name in (("smollm_360m", "train_4k"),):
        cfg = get_config(arch)
        mesh = make_production_mesh()
        lowered, _ = lower_cell(cfg, shape_name, mesh)
        compiled = lowered.compile()
        rec = classify_and_correct(
            compiled.as_text(), cfg, SHAPES[shape_name], mesh.size
        )
        print(rec)


if __name__ == "__main__":
    main()
