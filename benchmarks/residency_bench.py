"""Residency benchmark: budgeted serve equivalence + the §V port ordering.

Two claims are gated here (the paper's §V, executed end to end):

1. **Budgeted decode is exact.** Serving under a ``--vmem-budget``
   residency plan (hot FFN blocks pinned, cold blocks streamed
   HBM->VMEM per step) produces *token-identical* output to the
   unbudgeted path — checked on the dense LM family, on the FCMP-packed
   1-bit variant (the paper's CNN precision), and on the moe family
   (olmoe smoke), with the plan forced to stream at least one layer.
   The moe cell doubles as the dropless-serving gate: its budget is
   half the packed weight bytes, which no all-resident plan fits, so
   only per-(layer, expert) streaming makes olmoe serve at all — and it
   must do so token-identically.

2. **FCMP beats folding on the port target.** ``launch.port`` must
   reproduce the paper's ordering: porting RN50 to the smaller Alveo
   (U250 -> U280) loses less throughput via FCMP packing than via 2x
   folding, and CNV ports Zynq 7020 -> 7012S with zero loss while the
   unpacked baseline no longer fits.

CLI::

    PYTHONPATH=src python benchmarks/residency_bench.py --smoke \
        [--out residency_bench.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time


def _serve_cell(cfg, params, plan, prompts, gen_len, max_len, block_tokens):
    from repro.runtime.kv_pool import KVPool
    from repro.runtime.scheduler import Scheduler

    pool = KVPool.for_slots(
        cfg, slots=2, max_len=max_len, block_tokens=block_tokens
    )
    sched = Scheduler(
        cfg, params, pool, slots=2, max_len=max_len, residency=plan
    )
    for p in prompts:
        sched.submit(p, gen_len)
    t0 = time.monotonic()
    stats = sched.run()
    dt = time.monotonic() - t0
    return sched.outputs(), stats, dt


def _equivalence_rows(w_bits: int) -> list[dict]:
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.runtime.residency import TrafficProfile, compile_residency_plan

    cfg = get_smoke_config("smollm_360m")
    label = "dense_f32"
    if w_bits:
        cfg = dataclasses.replace(cfg, w_bits=w_bits)
        label = f"fcmp_w{w_bits}"  # the CNV/RN50 precision on the LM
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    prompts = [
        rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
        for _ in range(6)
    ]
    # budget = half the packed weight bytes: forces a mixed resident/
    # streamed layer split (all-resident would make the A/B vacuous)
    blocks_bytes = sum(
        b.padded_bytes() for b in compile_residency_plan(
            cfg, vmem_budget_bytes=0, traffic=TrafficProfile(lanes=2)
        ).blocks
    )
    plan = compile_residency_plan(
        cfg,
        vmem_budget_bytes=blocks_bytes // 2,
        traffic=TrafficProfile(lanes=2, prompt_len=8, gen_len=8),
    )
    mask = plan.layer_stream_mask(cfg)
    rows = []
    outs = {}
    for engine, p in (("full", None), ("budgeted", plan)):
        # warmup run so the timed row compares steady-state step cost
        _serve_cell(cfg, params, p, prompts[:2], 4, 32, 4)
        outputs, stats, dt = _serve_cell(cfg, params, p, prompts, 8, 32, 4)
        outs[engine] = outputs
        rows.append({
            "bench": "residency",
            "cell": label,
            "engine": engine,
            "streamed_layers": sum(mask) if engine == "budgeted" else 0,
            "n_layers": cfg.n_layers,
            "resident_fraction": (
                round(plan.resident_fraction, 3)
                if engine == "budgeted" else 1.0
            ),
            "stream_ahead": plan.stream_ahead if engine == "budgeted" else 0,
            "generated_tokens": stats.generated_tokens,
            "tokens_per_s": round(stats.generated_tokens / dt, 2),
        })
    for r in rows:
        r["token_identical"] = outs["full"] == outs["budgeted"]
    return rows


def _moe_rows() -> list[dict]:
    """The expert-streaming cell (the dropless-serving acceptance gate):
    olmoe under a VMEM budget that no all-resident plan fits — half the
    packed weight bytes — must still serve, by pinning hot (layer,
    expert) regions and streaming the cold experts through the weight
    ring, token-identical to the unbudgeted path."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.runtime.residency import TrafficProfile, compile_residency_plan

    cfg = get_smoke_config("olmoe_1b_7b")
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(9)
    prompts = [
        rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
        for _ in range(4)
    ]
    blocks_bytes = sum(
        b.padded_bytes() for b in compile_residency_plan(
            cfg, vmem_budget_bytes=0, traffic=TrafficProfile(lanes=2)
        ).blocks
    )
    budget = blocks_bytes // 2
    plan = compile_residency_plan(
        cfg,
        vmem_budget_bytes=budget,
        traffic=TrafficProfile(lanes=2, prompt_len=8, gen_len=8),
    )
    emask = np.asarray(plan.expert_stream_mask(cfg), bool)  # (L, E)
    rows = []
    outs = {}
    for engine, p in (("full", None), ("budgeted", plan)):
        _serve_cell(cfg, params, p, prompts[:2], 4, 32, 4)  # warmup
        outputs, stats, dt = _serve_cell(cfg, params, p, prompts, 8, 32, 4)
        outs[engine] = outputs
        rows.append({
            "bench": "residency",
            "cell": "moe_expert_stream",
            "engine": engine,
            "streamed_layers": (
                int(emask.any(axis=1).sum()) if engine == "budgeted" else 0
            ),
            "n_layers": cfg.n_layers,
            "streamed_experts": (
                int(emask.sum()) if engine == "budgeted" else 0
            ),
            "n_experts": cfg.n_layers * cfg.n_experts,
            # a plan with nothing streamed needs every block resident:
            # this budget cannot hold that, so dense residency is
            # infeasible and expert streaming is what makes it serve
            "fits_all_resident": budget >= blocks_bytes,
            "resident_fraction": (
                round(plan.resident_fraction, 3)
                if engine == "budgeted" else 1.0
            ),
            "stream_ahead": plan.stream_ahead if engine == "budgeted" else 0,
            "generated_tokens": stats.generated_tokens,
            "expert_tokens": stats.expert_tokens,
            "tokens_per_s": round(stats.generated_tokens / dt, 2),
        })
    for r in rows:
        r["token_identical"] = outs["full"] == outs["budgeted"]
    return rows


def _port_rows() -> list[dict]:
    from repro.launch.port import port_report

    rows = []
    for arch in ("cnv_w1a1", "rn50_w2a2"):
        rows.extend(port_report(arch))
    return rows


def run(**overrides) -> list[dict]:
    rows = []
    rows.extend(_equivalence_rows(w_bits=0))
    rows.extend(_equivalence_rows(w_bits=1))
    rows.extend(_moe_rows())
    rows.extend(_port_rows())
    return rows


def check(rows: list[dict]) -> list[str]:
    errs = []
    eq = [r for r in rows if r.get("bench") == "residency"]
    for cell in {r["cell"] for r in eq}:
        cr = [r for r in eq if r["cell"] == cell]
        budgeted = next(r for r in cr if r["engine"] == "budgeted")
        if not budgeted["token_identical"]:
            errs.append(f"{cell}: budgeted decode diverged from full decode")
        if budgeted["streamed_layers"] < 1:
            errs.append(f"{cell}: plan streamed no layer (A/B vacuous)")
    moe = next(
        (r for r in eq
         if r["cell"] == "moe_expert_stream" and r["engine"] == "budgeted"),
        None,
    )
    if moe is None:
        errs.append("missing moe_expert_stream budgeted row")
    else:
        if moe["fits_all_resident"]:
            errs.append(
                "moe cell budget fits all-resident: the expert-streaming "
                "infeasibility claim is vacuous"
            )
        if moe["streamed_experts"] < 1:
            errs.append("moe cell streamed no expert")
        if moe["streamed_experts"] >= moe["n_experts"]:
            errs.append("moe cell pinned no expert (knapsack ran dry)")
    port = {
        (r["arch"], r["device"]): r
        for r in rows
        if r.get("bench") == "port" and "fold2_delta_fps_pct" in r
    }
    rn = port.get(("rn50_w2a2", "u280"))
    if rn is None:
        errs.append("missing rn50_w2a2 u280 port row")
    else:
        if not rn["packed_fits"] or rn["baseline_fits"]:
            errs.append("rn50 u280: expected packed-fits / baseline-no-fit")
        if not rn["fcmp_delta_fps_pct"] < rn["fold2_delta_fps_pct"]:
            errs.append(
                "paper §V ordering violated: FCMP port should lose less "
                f"than 2x folding ({rn['fcmp_delta_fps_pct']}% vs "
                f"{rn['fold2_delta_fps_pct']}%)"
            )
    cnv = port.get(("cnv_w1a1", "zynq7012s"))
    if cnv is None:
        errs.append("missing cnv_w1a1 zynq7012s port row")
    elif not (
        cnv["packed_fits"]
        and not cnv["baseline_fits"]
        and cnv["fcmp_delta_fps_pct"] == 0.0
    ):
        errs.append(
            "cnv 7012S port should fit packed at zero throughput loss "
            "with the baseline not fitting (paper Table V)"
        )
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CPU cell (the only cell this bench runs)")
    ap.add_argument("--out", default="residency_bench.json")
    args = ap.parse_args(argv)
    if not args.smoke:
        print("[residency_bench] only the reduced --smoke cell is "
              "implemented (full-size serving needs real accelerators); "
              "pass --smoke")
        return 2
    rows = run()
    errs = check(rows)
    keys: list[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    for e in errs:
        print(f"  BAND-CHECK FAIL: {e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": errs}, f, indent=2)
        print(f"[residency_bench] wrote {args.out}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
