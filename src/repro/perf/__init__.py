"""Performance analysis: loop-aware HLO cost walk + roofline terms."""

from repro.perf.hlo_analysis import HloCost, analyze  # noqa: F401
from repro.perf.roofline import HW, RooflineReport, model_flops, roofline  # noqa: F401
