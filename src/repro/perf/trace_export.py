"""JSONL serve traces -> Chrome/Perfetto ``trace_event`` JSON.

``runtime.tracker.JsonlTracker`` streams interleave per-round metrics
records with per-request lifecycle spans (``runtime.spans``). This
module converts such a stream into the Trace Event Format that
https://ui.perfetto.dev and ``chrome://tracing`` open natively:

  * one *process* track per engine (pid = engine id, named with its
    role from the hparams records),
  * one *thread* row per request (tid = rid) carrying its phase spans
    as complete ("X") events,
  * flow arrows ("s"/"f") for cross-engine motion: a prefill->decode
    handoff connects the handoff span to the decode engine's first
    span, and a drain/requeue connects the aborted span to the
    request's next queue span on the new engine,
  * counter ("C") tracks per engine from the round records' gauges
    (pool utilization/occupancy, cached and shared blocks, queue depth,
    active lanes, per-round speculative accepted/draft tokens and
    verify steps — lining the acceptance rate up under the
    draft/verify spans, streamed HBM MiB/s from the cumulative
    residency gauge) and from the memory ledger's ``kind="mem"`` reserve records
    (VMEM-resident bytes: weights pinned by the residency plan plus the
    expert stream ring).

Timestamps are microseconds (the trace_event unit); the virtual clock's
nanosecond rounding survives exactly. ``validate_trace_events`` checks
the shape the viewers require — CI runs it against the soak trace so a
schema regression fails the build, not the human opening the file.

CLI::

    python -m repro.perf.trace_export soak_trace.jsonl \
        [-o soak_trace.perfetto.json] [--check]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Iterable

_US = 1e6  # seconds -> microseconds

# span attrs lifted into trace_event args (everything non-positional)
_SPAN_BASE = {"kind", "rid", "phase", "t0", "t1", "engine", "role"}


def _span_args(s: dict) -> dict:
    return {k: v for k, v in s.items() if k not in _SPAN_BASE}


def to_trace_events(records: Iterable[dict]) -> dict:
    """Convert a tracker record stream to a trace_event document."""
    records = list(records)
    events: list[dict] = []
    engines: dict[int, str] = {}
    for r in records:
        if r.get("kind") == "hparams" and r.get("surface") == "engine":
            engines[int(r["engine"])] = str(r.get("role", "both"))

    spans = [r for r in records if r.get("kind") == "span"]
    by_rid: dict[int, list[dict]] = {}
    for s in spans:
        by_rid.setdefault(int(s["rid"]), []).append(s)
    for ss in by_rid.values():
        ss.sort(key=lambda s: (s["t0"], s["t1"]))

    seen_pids: set[int] = set()
    for s in spans:
        pid = int(s.get("engine", 0))
        seen_pids.add(pid)
        events.append(
            {
                "ph": "X",
                "name": s["phase"],
                "cat": "span",
                "pid": pid,
                "tid": int(s["rid"]),
                "ts": s["t0"] * _US,
                "dur": (s["t1"] - s["t0"]) * _US,
                "args": _span_args(s),
            }
        )

    # process metadata: one named track per engine
    for pid in sorted(seen_pids | set(engines)):
        role = engines.get(pid, "both")
        events.append(
            {
                "ph": "M",
                "name": "process_name",
                "pid": pid,
                "args": {"name": f"engine {pid} ({role})"},
            }
        )

    # flow arrows: handoff transit and drain->requeue motion
    flow_id = 0
    for rid, ss in sorted(by_rid.items()):
        for i, s in enumerate(ss):
            nxt = next(
                (
                    n
                    for n in ss[i + 1 :]
                    if n.get("engine") != s.get("engine")
                ),
                None,
            )
            arrow = None
            if s["phase"] == "handoff" and nxt is not None:
                arrow = "handoff"
            elif s.get("aborted") and nxt is not None:
                arrow = "requeue"
            if arrow is None:
                continue
            flow_id += 1
            common = {"cat": arrow, "name": arrow, "id": flow_id}
            events.append(
                {
                    "ph": "s",
                    "pid": int(s.get("engine", 0)),
                    "tid": rid,
                    "ts": s["t1"] * _US,
                    **common,
                }
            )
            events.append(
                {
                    "ph": "f",
                    "bp": "e",
                    "pid": int(nxt.get("engine", 0)),
                    "tid": rid,
                    "ts": nxt["t0"] * _US,
                    **common,
                }
            )

    # engine gauges from the round records as counter tracks
    counter_keys = (
        "pool_utilization",
        "pool_occupancy",
        "pool_cached_blocks",
        "pool_shared_blocks",
        "queued",
        "active",
        # speculative decode: per-round delta counters; viewed next to
        # the draft/verify spans the first two read as acceptance rate
        "accepted_tokens",
        "draft_tokens",
        "verify_steps",
    )
    streamed_prev: dict[int, tuple[float, float]] = {}  # pid -> (t, cum)
    # standalone round records carry no clock_s; the ledger flushes its
    # mem records (monotonic-stamped) right before each one, so the last
    # mem timestamp per engine is the round's counter timestamp
    last_mem_t: dict[int, float] = {}
    for r in records:
        kind = r.get("kind", "metrics")
        if kind == "mem" and "t" in r:
            last_mem_t[int(r.get("engine") or 0)] = float(r["t"])
            continue
        if kind != "metrics":
            continue
        pid = int(r.get("engine", 0))
        t = r.get("clock_s", last_mem_t.get(pid))
        if t is None:
            continue
        t = float(t)
        ts = t * _US
        for key in counter_keys:
            if key in r:
                events.append(
                    {
                        "ph": "C",
                        "name": key,
                        "pid": pid,
                        "ts": ts,
                        "args": {key: r[key]},
                    }
                )
        # streamed HBM bandwidth: the gauge is cumulative MiB, so the
        # rate is its per-round difference over the virtual clock
        if "residency_streamed_mib" in r:
            cum = float(r["residency_streamed_mib"])
            prev = streamed_prev.get(pid)
            rate = 0.0
            if prev is not None and t > prev[0]:
                rate = max(0.0, (cum - prev[1]) / (t - prev[0]))
            streamed_prev[pid] = (t, cum)
            events.append(
                {
                    "ph": "C",
                    "name": "streamed_hbm_mib_per_s",
                    "pid": pid,
                    "ts": ts,
                    "args": {"streamed_hbm_mib_per_s": round(rate, 3)},
                }
            )

    # VMEM-resident bytes: integrate the ledger's static reservations
    # (weight-resident plan + expert stream ring) per engine
    vmem: dict[int, int] = {}
    for r in records:
        if r.get("kind") != "mem" or r.get("op") != "reserve":
            continue
        pid = int(r.get("engine") or 0)
        vmem[pid] = vmem.get(pid, 0) + int(r.get("nbytes", 0))
        events.append(
            {
                "ph": "C",
                "name": "vmem_resident_bytes",
                "pid": pid,
                "ts": float(r.get("t", 0.0)) * _US,
                "args": {"vmem_resident_bytes": vmem[pid]},
            }
        )

    events.sort(key=lambda e: (e.get("ts", 0.0), e.get("pid", 0)))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_trace_events(doc: dict) -> list[str]:
    """Shape checks against the trace_event format. Empty == loadable."""
    errors: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be an object with a 'traceEvents' list"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be a list"]
    flows: dict[object, list[str]] = {}
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "M", "C", "s", "f", "i", "b", "e"):
            errors.append(f"{where}: unknown ph {ph!r}")
            continue
        if "name" not in e:
            errors.append(f"{where}: missing name")
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            errors.append(f"{where}: ph={ph} needs a numeric ts")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X event needs dur >= 0, got {dur!r}")
        if ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"{where}: C event needs non-empty args")
            elif not all(isinstance(v, (int, float)) for v in args.values()):
                errors.append(f"{where}: C event args must be numeric")
        if ph in ("s", "f"):
            if "id" not in e:
                errors.append(f"{where}: flow event needs an id")
            else:
                flows.setdefault(e["id"], []).append(ph)
    for fid, phs in sorted(flows.items(), key=lambda kv: str(kv[0])):
        if sorted(phs) != ["f", "s"]:
            errors.append(f"flow id {fid!r}: unpaired steps {phs}")
    return errors


def main(argv=None) -> int:
    from repro.runtime.tracker import read_jsonl

    ap = argparse.ArgumentParser(
        description="Convert a JSONL serve trace to Perfetto trace_event "
        "JSON (open at https://ui.perfetto.dev)."
    )
    ap.add_argument("trace", help="JsonlTracker stream (one object/line)")
    ap.add_argument(
        "-o",
        "--out",
        default=None,
        help="output path (default: <trace>.perfetto.json)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="validate the exported document; non-zero exit on errors",
    )
    args = ap.parse_args(argv)

    records = read_jsonl(args.trace)
    doc = to_trace_events(records)
    out = Path(
        args.out
        if args.out is not None
        else str(Path(args.trace).with_suffix("")) + ".perfetto.json"
    )
    out.write_text(json.dumps(doc) + "\n")
    n_spans = sum(1 for e in doc["traceEvents"] if e["ph"] == "X")
    n_flows = sum(1 for e in doc["traceEvents"] if e["ph"] == "s")
    n_counters = sum(1 for e in doc["traceEvents"] if e["ph"] == "C")
    print(
        f"{out}: {len(doc['traceEvents'])} events "
        f"({n_spans} spans, {n_flows} flows, {n_counters} counters)"
    )
    if args.check:
        errors = validate_trace_events(doc)
        # a stream with timestampable round records must yield counter
        # tracks — a silent counter regression would strand the memory
        # telemetry (metrics records are timestamped by clock_s or by
        # the mem records flushed just before them)
        has_mem = any(r.get("kind") == "mem" for r in records)
        has_rounds = any(
            r.get("kind", "metrics") == "metrics"
            and ("clock_s" in r or has_mem)
            for r in records
        )
        if has_rounds and n_counters == 0:
            errors.append("metrics records present but no counter events")
        for err in errors:
            print(f"INVALID: {err}")
        if errors:
            return 1
        print("trace_event shape: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
