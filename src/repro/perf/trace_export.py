"""JSONL serve traces -> Chrome/Perfetto ``trace_event`` JSON.

``runtime.tracker.JsonlTracker`` streams interleave per-round metrics
records with per-request lifecycle spans (``runtime.spans``). This
module converts such a stream into the Trace Event Format that
https://ui.perfetto.dev and ``chrome://tracing`` open natively:

  * one *process* track per engine (pid = engine id, named with its
    role from the hparams records),
  * one *thread* row per request (tid = rid) carrying its phase spans
    as complete ("X") events,
  * flow arrows ("s"/"f") for cross-engine motion: a prefill->decode
    handoff connects the handoff span to the decode engine's first
    span, and a drain/requeue connects the aborted span to the
    request's next queue span on the new engine,
  * counter ("C") tracks per engine from the round records' gauges
    (pool utilization, queue depth, active lanes).

Timestamps are microseconds (the trace_event unit); the virtual clock's
nanosecond rounding survives exactly. ``validate_trace_events`` checks
the shape the viewers require — CI runs it against the soak trace so a
schema regression fails the build, not the human opening the file.

CLI::

    python -m repro.perf.trace_export soak_trace.jsonl \
        [-o soak_trace.perfetto.json] [--check]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Iterable

_US = 1e6  # seconds -> microseconds

# span attrs lifted into trace_event args (everything non-positional)
_SPAN_BASE = {"kind", "rid", "phase", "t0", "t1", "engine", "role"}


def _span_args(s: dict) -> dict:
    return {k: v for k, v in s.items() if k not in _SPAN_BASE}


def to_trace_events(records: Iterable[dict]) -> dict:
    """Convert a tracker record stream to a trace_event document."""
    records = list(records)
    events: list[dict] = []
    engines: dict[int, str] = {}
    for r in records:
        if r.get("kind") == "hparams" and r.get("surface") == "engine":
            engines[int(r["engine"])] = str(r.get("role", "both"))

    spans = [r for r in records if r.get("kind") == "span"]
    by_rid: dict[int, list[dict]] = {}
    for s in spans:
        by_rid.setdefault(int(s["rid"]), []).append(s)
    for ss in by_rid.values():
        ss.sort(key=lambda s: (s["t0"], s["t1"]))

    seen_pids: set[int] = set()
    for s in spans:
        pid = int(s.get("engine", 0))
        seen_pids.add(pid)
        events.append(
            {
                "ph": "X",
                "name": s["phase"],
                "cat": "span",
                "pid": pid,
                "tid": int(s["rid"]),
                "ts": s["t0"] * _US,
                "dur": (s["t1"] - s["t0"]) * _US,
                "args": _span_args(s),
            }
        )

    # process metadata: one named track per engine
    for pid in sorted(seen_pids | set(engines)):
        role = engines.get(pid, "both")
        events.append(
            {
                "ph": "M",
                "name": "process_name",
                "pid": pid,
                "args": {"name": f"engine {pid} ({role})"},
            }
        )

    # flow arrows: handoff transit and drain->requeue motion
    flow_id = 0
    for rid, ss in sorted(by_rid.items()):
        for i, s in enumerate(ss):
            nxt = next(
                (
                    n
                    for n in ss[i + 1 :]
                    if n.get("engine") != s.get("engine")
                ),
                None,
            )
            arrow = None
            if s["phase"] == "handoff" and nxt is not None:
                arrow = "handoff"
            elif s.get("aborted") and nxt is not None:
                arrow = "requeue"
            if arrow is None:
                continue
            flow_id += 1
            common = {"cat": arrow, "name": arrow, "id": flow_id}
            events.append(
                {
                    "ph": "s",
                    "pid": int(s.get("engine", 0)),
                    "tid": rid,
                    "ts": s["t1"] * _US,
                    **common,
                }
            )
            events.append(
                {
                    "ph": "f",
                    "bp": "e",
                    "pid": int(nxt.get("engine", 0)),
                    "tid": rid,
                    "ts": nxt["t0"] * _US,
                    **common,
                }
            )

    # engine gauges from the round records as counter tracks
    for r in records:
        if r.get("kind", "metrics") != "metrics" or "clock_s" not in r:
            continue
        pid = int(r.get("engine", 0))
        ts = r["clock_s"] * _US
        for key in ("pool_utilization", "queued", "active"):
            if key in r:
                events.append(
                    {
                        "ph": "C",
                        "name": key,
                        "pid": pid,
                        "ts": ts,
                        "args": {key: r[key]},
                    }
                )

    events.sort(key=lambda e: (e.get("ts", 0.0), e.get("pid", 0)))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_trace_events(doc: dict) -> list[str]:
    """Shape checks against the trace_event format. Empty == loadable."""
    errors: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be an object with a 'traceEvents' list"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be a list"]
    flows: dict[object, list[str]] = {}
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "M", "C", "s", "f", "i", "b", "e"):
            errors.append(f"{where}: unknown ph {ph!r}")
            continue
        if "name" not in e:
            errors.append(f"{where}: missing name")
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            errors.append(f"{where}: ph={ph} needs a numeric ts")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X event needs dur >= 0, got {dur!r}")
        if ph in ("s", "f"):
            if "id" not in e:
                errors.append(f"{where}: flow event needs an id")
            else:
                flows.setdefault(e["id"], []).append(ph)
    for fid, phs in sorted(flows.items(), key=lambda kv: str(kv[0])):
        if sorted(phs) != ["f", "s"]:
            errors.append(f"flow id {fid!r}: unpaired steps {phs}")
    return errors


def main(argv=None) -> int:
    from repro.runtime.tracker import read_jsonl

    ap = argparse.ArgumentParser(
        description="Convert a JSONL serve trace to Perfetto trace_event "
        "JSON (open at https://ui.perfetto.dev)."
    )
    ap.add_argument("trace", help="JsonlTracker stream (one object/line)")
    ap.add_argument(
        "-o",
        "--out",
        default=None,
        help="output path (default: <trace>.perfetto.json)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="validate the exported document; non-zero exit on errors",
    )
    args = ap.parse_args(argv)

    records = read_jsonl(args.trace)
    doc = to_trace_events(records)
    out = Path(
        args.out
        if args.out is not None
        else str(Path(args.trace).with_suffix("")) + ".perfetto.json"
    )
    out.write_text(json.dumps(doc) + "\n")
    n_spans = sum(1 for e in doc["traceEvents"] if e["ph"] == "X")
    n_flows = sum(1 for e in doc["traceEvents"] if e["ph"] == "s")
    print(
        f"{out}: {len(doc['traceEvents'])} events "
        f"({n_spans} spans, {n_flows} flows)"
    )
    if args.check:
        errors = validate_trace_events(doc)
        for err in errors:
            print(f"INVALID: {err}")
        if errors:
            return 1
        print("trace_event shape: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
