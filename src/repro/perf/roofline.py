"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
    memory term     = HLO_bytes / HBM_bw                 (per chip)
    collective term = collective_bytes / link_bw         (per chip)

``compiled.cost_analysis()`` is evaluated on the SPMD-partitioned module,
so its FLOPs/bytes are already *per device*; the collective bytes are
parsed from the post-partitioning HLO text by summing operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (also per device). Hardware constants are
TPU v5e (the adaptation target).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HwModel:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link


HW = HwModel()

@dataclasses.dataclass(frozen=True)
class RooflineReport:
    name: str
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device HLO bytes accessed
    coll_bytes: float  # per-device collective operand bytes
    coll_breakdown: dict
    model_flops: float  # 6*N*D (dense) / 6*N_active*D (MoE), total
    n_devices: int
    hw: HwModel = HW

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap model: step >= max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops (remat/redundancy waste)."""
        total_hlo = self.flops * self.n_devices
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the peak-bound step time."""
        useful_t = (self.model_flops / self.n_devices) / self.hw.peak_flops
        return useful_t / self.step_time if self.step_time else 0.0

    def row(self) -> str:
        return (
            f"{self.name:34s} {self.t_compute*1e3:9.2f} "
            f"{self.t_memory*1e3:9.2f} {self.t_collective*1e3:9.2f} "
            f"{self.bottleneck:10s} {self.useful_flops_ratio:6.2f} "
            f"{self.roofline_fraction*100:6.1f}%"
        )


def model_flops(cfg, shape) -> float:
    """6*N*D for training; 2*N*D for single forward (prefill); 2*N_active*B
    per decoded token."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


def roofline(
    name: str,
    compiled,
    cfg,
    shape,
    n_devices: int,
    hw: HwModel = HW,
) -> RooflineReport:
    """Loop-aware roofline from the compiled SPMD artifact.

    Uses ``perf.hlo_analysis`` rather than ``compiled.cost_analysis()``:
    XLA's cost analysis visits each instruction once, so a lax.scan over L
    layers under-counts FLOPs/bytes/collectives by ~L (13x measured on
    smollm train_4k). The loop-aware walk multiplies by known trip counts.
    """
    from repro.perf.hlo_analysis import analyze

    cost = analyze(compiled.as_text())
    return RooflineReport(
        name=name,
        flops=cost.dot_flops,
        hbm_bytes=cost.traffic_bytes,
        coll_bytes=cost.total_collective_bytes,
        coll_breakdown=dict(cost.collective_bytes),
        model_flops=model_flops(cfg, shape),
        n_devices=n_devices,
        hw=hw,
    )
