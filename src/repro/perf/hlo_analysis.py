"""Loop-aware cost analysis of post-optimization HLO text.

XLA's built-in ``HloCostAnalysis`` (what ``compiled.cost_analysis()``
reports) visits every instruction ONCE — a ``lax.scan`` over 32 layers
contributes its body a single time, so FLOPs/bytes/collectives are under-
counted by the trip count (13x on smollm train_4k). This module re-derives
the counts from ``compiled.as_text()`` with multipliers propagated through
the call graph:

  * ``while`` bodies/conditions x known_trip_count (XLA stamps
    ``backend_config={"known_trip_count":{"n":...}}`` on counted loops),
  * ``fusion`` / ``call`` / ``conditional`` / ``to_apply`` edges x 1,
  * a computation reachable from several sites accumulates the sum.

Counted metrics (all per-device — the module is the SPMD partition):
  * ``dot_flops``: 2 * prod(result dims) * prod(lhs contracting dims) for
    every dot; this is the MXU-relevant compute term.
  * ``traffic_bytes``: operand + result bytes of every materialising
    instruction outside fusion bodies (the HloCostAnalysis convention),
    i.e. an HBM-traffic proxy.
  * ``collective_bytes``: operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, by kind. These are
    the bytes *entering* the collective on one device (ring all-reduce
    moves ~2x this on the wire; the roofline term uses the operand-bytes
    convention from the assignment).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def xla_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalised across jax versions.

    Older jax returns a list with one properties-dict per partition; newer
    jax returns the dict directly. Callers always get a plain dict (empty
    when XLA reports nothing).
    """
    props = compiled.cost_analysis()
    if isinstance(props, (list, tuple)):
        props = props[0] if props else {}
    return dict(props)


def shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (tuples summed, layouts ignored)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str  # result shape string
    opcode: str
    operands: list[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]
    params: dict[str, str]  # param name -> shape string


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+)\s*\{\s*$")
_INSTR_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")


def _parse_instruction(line: str) -> Instruction | None:
    """Parse '  %name = <shape> opcode(<operands>), attrs' with balanced
    parens (operand lists contain nested parens; attrs follow the match)."""
    m = _INSTR_HEAD.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    # result shape: a tuple '(...)' or a run of shape tokens up to the
    # opcode word that precedes the operand '('.
    if rest.startswith("("):
        depth, i = 0, 0
        while i < len(rest):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    i += 1
                    break
            i += 1
        shape, rest = rest[:i], rest[i:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, rest = rest[:sp], rest[sp + 1:].lstrip()
    op_m = re.match(r"([\w\-]+)\(", rest)
    if not op_m:
        return None
    opcode = op_m.group(1)
    i, depth = op_m.end() - 1, 0
    start = i + 1
    while i < len(rest):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
        i += 1
    operands = rest[start:i]
    attrs = rest[i + 1:]
    return Instruction(
        name, shape.strip(), opcode, _split_top_level(operands), attrs, line
    )
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)"
)
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")


def _split_top_level(s: str) -> list[str]:
    """Split an operand list on top-level commas."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                params = {}
                # "a: f32[2], b: (f32[2], s32[])" — split top-level commas
                for p in _split_top_level(m.group(3)):
                    if ":" in p:
                        pname, pshape = p.split(":", 1)
                        params[pname.strip().lstrip("%")] = pshape.strip()
                cur = Computation(m.group(2), [], params)
            continue
        if line.strip() == "}" or line.strip().startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instruction(line)
        if ins is not None:
            cur.instructions.append(ins)
    return comps


def _call_edges(comp: Computation) -> list[tuple[str, float, str]]:
    """(callee, multiplier, kind) edges out of one computation."""
    edges = []
    for ins in comp.instructions:
        trip = 1.0
        if ins.opcode == "while":
            m = _TRIP.search(ins.attrs)
            trip = float(m.group(1)) if m else 1.0
        for cm in _CALL_ATTR.finditer(ins.attrs):
            kind = "fusion" if ins.opcode == "fusion" else ins.opcode
            edges.append((cm.group(1), trip, kind))
        bm = _BRANCHES.search(ins.attrs)
        if bm:
            for b in bm.group(1).split(","):
                edges.append((b.strip().lstrip("%"), 1.0, "conditional"))
    return edges


def computation_multipliers(
    comps: dict[str, Computation],
) -> tuple[dict[str, float], dict[str, str]]:
    """Execution-count multiplier for every computation + its call kind."""
    entry = None
    for name, c in comps.items():
        if name.startswith("main") or entry is None:
            pass
    # the ENTRY computation is the one never called by anyone
    called = set()
    edges_by_comp = {n: _call_edges(c) for n, c in comps.items()}
    for edges in edges_by_comp.values():
        for callee, _, _ in edges:
            called.add(callee)
    roots = [n for n in comps if n not in called]
    mult: dict[str, float] = defaultdict(float)
    kind: dict[str, str] = {}
    for r in roots:
        mult[r] = 1.0
        kind[r] = "entry"
    # propagate in topological order (HLO call graphs are acyclic);
    # iterate to fixpoint (small graphs, few dozen computations)
    for _ in range(len(comps) + 2):
        changed = False
        new = defaultdict(float)
        for r in roots:
            new[r] = 1.0
        for name, edges in edges_by_comp.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for callee, trip, k in edges:
                new[callee] += m * trip
                kind.setdefault(callee, k)
        for n, v in new.items():
            if abs(mult.get(n, 0.0) - v) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    return dict(mult), kind


_SKIP_TRAFFIC = {
    "tuple", "get-tuple-element", "parameter", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}


def _fusion_dus_bytes(comps: dict, ins: "Instruction"):
    """In-place dynamic-update-slice fusions: traffic is the update slice
    (read + written region + inputs), not the whole buffer.

    Matches fusions whose computation contains a DUS acting on a
    buffer-sized operand, with the fusion result the same (buffer) shape —
    XLA updates these in place inside while loops (possibly with trailing
    converts/bitcasts fused after the DUS). Returns bytes or None.
    """
    cm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
    if not cm or cm.group(1) not in comps:
        return None
    callee = comps[cm.group(1)]
    if not callee.instructions:
        return None
    fusion_dims = _shape_dims(ins.shape)
    defs = {i.name: i.shape for i in callee.instructions}

    def shape_of(operand):
        if "[" in operand and "%" in operand:
            return operand
        mm = _OPERAND_NAME.search(operand)
        if mm:
            nm = mm.group(1)
            return defs.get(nm, callee.params.get(nm, ""))
        return ""

    for inner in callee.instructions:
        if inner.opcode != "dynamic-update-slice" or len(inner.operands) < 2:
            continue
        if _shape_dims(inner.shape) != fusion_dims:
            continue  # the DUS doesn't produce the fusion-sized buffer
        return 3 * shape_bytes(shape_of(inner.operands[1]))
    return None


@dataclasses.dataclass
class HloCost:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: dict[str, float]
    transcendentals: float
    n_unknown_trip: int

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HloCost:
    comps = parse_module(text)
    mult, kind = computation_multipliers(comps)

    dot_flops = 0.0
    traffic = 0.0
    transcendental = 0.0
    coll: dict[str, float] = defaultdict(float)
    unknown_trip = 0

    def op_shape(comp: Computation, defs: dict[str, str], operand: str) -> str:
        # operand may carry an inline shape ("f32[8,16] %x.3") or be a bare
        # reference; fall back to defs / params.
        if "[" in operand and "%" in operand:
            return operand
        m = _OPERAND_NAME.search(operand)
        if m:
            nm = m.group(1)
            if nm in defs:
                return defs[nm]
            if nm in comp.params:
                return comp.params[nm]
        return ""

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = kind.get(cname, "") in ("fusion",)
        is_applied = kind.get(cname, "") in (
            "reduce", "all-reduce", "reduce-scatter", "scatter", "sort",
            "reduce-window", "select-and-scatter", "map",
        )
        defs = {i.name: i.shape for i in comp.instructions}
        for ins in comp.instructions:
            if ins.opcode == "while" and not _TRIP.search(ins.attrs):
                unknown_trip += 1
            # ---- dot flops (count inside fusions too) ----
            if ins.opcode == "dot" and not is_applied:
                res = 1
                for d in _shape_dims(ins.shape):
                    res *= d
                lhs_shape = op_shape(comp, defs, ins.operands[0]) if ins.operands else ""
                lhs_dims = _shape_dims(lhs_shape)
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
                contract = 1
                if cm and lhs_dims:
                    for idx in cm.group(1).split(","):
                        if idx:
                            contract *= lhs_dims[int(idx)]
                dot_flops += m * 2.0 * res * contract
            if ins.opcode in ("exponential", "log", "tanh", "rsqrt", "sqrt",
                              "power", "logistic"):
                res = 1
                for d in _shape_dims(ins.shape):
                    res *= d
                transcendental += m * res
            # ---- collectives ----
            base = None
            for c in COLLECTIVE_OPS:
                if ins.opcode in (c, f"{c}-start"):
                    base = c
                    break
            if base is not None:
                b = sum(
                    shape_bytes(op_shape(comp, defs, o)) for o in ins.operands
                )
                coll[base] += m * b
            # ---- traffic ----
            if in_fusion or is_applied:
                continue
            if ins.opcode in _SKIP_TRAFFIC or base is not None:
                continue
            if ins.opcode == "dynamic-update-slice":
                # XLA updates loop-carried buffers in place: traffic is the
                # update slice (read) + the written region, NOT the whole
                # buffer (HloCostAnalysis makes the same special case).
                upd = (
                    shape_bytes(op_shape(comp, defs, ins.operands[1]))
                    if len(ins.operands) > 1
                    else 0
                )
                traffic += m * 2 * upd
                continue
            if ins.opcode == "dynamic-slice":
                traffic += m * 2 * shape_bytes(ins.shape)
                continue
            if ins.opcode == "fusion":
                dus = _fusion_dus_bytes(comps, ins)
                if dus is not None:
                    traffic += m * dus
                    continue
            b = shape_bytes(ins.shape)
            for o in ins.operands:
                b += shape_bytes(op_shape(comp, defs, o))
            traffic += m * b
    return HloCost(
        dot_flops=dot_flops,
        traffic_bytes=traffic,
        collective_bytes=dict(coll),
        transcendentals=transcendental,
        n_unknown_trip=unknown_trip,
    )


def top_contributors(text: str, metric: str = "traffic", n: int = 20):
    """Debug/profiling: the n largest per-instruction contributors.

    metric: 'traffic' (operand+result bytes x multiplier), 'dot_flops',
    or 'collective'. Returns [(value, comp_name, instr_name, opcode,
    shape, op_name_metadata)].
    """
    comps = parse_module(text)
    mult, kind = computation_multipliers(comps)
    rows = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = kind.get(cname, "") in ("fusion",)
        is_applied = kind.get(cname, "") in (
            "reduce", "all-reduce", "reduce-scatter", "scatter", "sort",
            "reduce-window", "select-and-scatter", "map",
        )
        defs = {i.name: i.shape for i in comp.instructions}

        def shape_of(operand):
            if "[" in operand and "%" in operand:
                return operand
            mm = _OPERAND_NAME.search(operand)
            if mm:
                nm = mm.group(1)
                return defs.get(nm, comp.params.get(nm, ""))
            return ""

        for ins in comp.instructions:
            meta = ""
            mm = re.search(r'op_name="([^"]*)"', ins.attrs)
            if mm:
                meta = mm.group(1)
            if metric == "dot_flops":
                if ins.opcode != "dot" or is_applied:
                    continue
                res = 1
                for d in _shape_dims(ins.shape):
                    res *= d
                ld = _shape_dims(shape_of(ins.operands[0]) if ins.operands else "")
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
                contract = 1
                if cm and ld:
                    for idx in cm.group(1).split(","):
                        if idx:
                            contract *= ld[int(idx)]
                val = m * 2.0 * res * contract
            elif metric == "collective":
                if not any(
                    ins.opcode in (c, f"{c}-start") for c in COLLECTIVE_OPS
                ):
                    continue
                val = m * sum(shape_bytes(shape_of(o)) for o in ins.operands)
            else:  # traffic
                if in_fusion or is_applied or ins.opcode in _SKIP_TRAFFIC:
                    continue
                if any(ins.opcode in (c, f"{c}-start") for c in COLLECTIVE_OPS):
                    continue
                if ins.opcode == "dynamic-update-slice":
                    val = m * 2 * (
                        shape_bytes(shape_of(ins.operands[1]))
                        if len(ins.operands) > 1 else 0
                    )
                elif ins.opcode == "dynamic-slice":
                    val = m * 2 * shape_bytes(ins.shape)
                elif (
                    ins.opcode == "fusion"
                    and _fusion_dus_bytes(comps, ins) is not None
                ):
                    val = m * _fusion_dus_bytes(comps, ins)
                else:
                    b = shape_bytes(ins.shape)
                    for o in ins.operands:
                        b += shape_bytes(shape_of(o))
                    val = m * b
            rows.append((val, cname[:36], ins.name, ins.opcode, ins.shape[:44], meta[:70]))
    rows.sort(reverse=True)
    return rows[:n]
