"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape)`` returns abstract (no-allocation) stand-ins for
every model input of the step kind the shape dictates (train/prefill lower
the full-sequence step; decode shapes lower ``serve_step`` with a KV cache
/ SSM state of seq_len). ``cell_shardings`` pairs them with the policy
shardings for a mesh.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.dist import sharding as shd
from repro.models import lm
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamW


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Train/prefill batch stand-ins: {tokens, labels[, modality stub]}."""
    from repro.models.config import modality_batch_leaves

    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    for name, rest in modality_batch_leaves(cfg).items():
        out[name] = _sds((b,) + rest, jnp.dtype(cfg.dtype))
    return out


def abstract_params(cfg: ModelConfig):
    return lm.abstract_params(cfg)


def abstract_opt_state(cfg: ModelConfig, opt: AdamW | None = None):
    opt = opt or AdamW()
    return jax.eval_shape(opt.init, abstract_params(cfg))


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    """Decode-state stand-ins at the cell's (batch, seq_len)."""
    fn = functools.partial(
        lm.init_cache, cfg, shape.global_batch, shape.seq_len
    )
    cache = jax.eval_shape(fn)
    if cfg.family == "encdec":
        from repro.models.encdec import with_cross_caches

        cache = with_cross_caches(cache, cfg, shape.global_batch)
    return cache


def abstract_token(cfg: ModelConfig, shape: ShapeConfig):
    return _sds((shape.global_batch, 1), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """All abstract inputs for the cell's step kind."""
    if shape.kind in ("train", "prefill"):
        return {"batch": abstract_batch(cfg, shape)}
    return {
        "token": abstract_token(cfg, shape),
        "cache": abstract_cache(cfg, shape),
    }


# --------------------------------------------------------------------------
# Shardings
# --------------------------------------------------------------------------


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    specs = shd.batch_specs(cfg, mesh, shape.global_batch)
    b = abstract_batch(cfg, shape)
    return _named(mesh, {k: specs[k] for k in b})


def param_shardings(cfg: ModelConfig, mesh):
    return _named(mesh, shd.param_specs(cfg, mesh))


def opt_shardings(cfg: ModelConfig, mesh, opt: AdamW | None = None):
    from jax.sharding import PartitionSpec as P

    from repro.optim.adamw import OptState

    pspec = shd.param_specs(cfg, mesh)
    return _named(
        mesh, OptState(step=P(), mu=pspec, nu=pspec)
    )


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    cache = abstract_cache(cfg, shape)
    specs = shd.cache_specs(
        cfg, mesh, shape.global_batch, shape.seq_len, cache=cache
    )
    return _named(mesh, {k: specs[k] for k in cache})


def token_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    return NamedSharding(
        mesh, shd.token_spec(cfg, mesh, shape.global_batch)
    )
