"""Batched serving driver: continuous-batching decode loop.

Prefill + decode steps from ``runtime.steps``, a simple admission queue
with a fixed decode batch (requests join as slots free up), and per-slot
ring KV caches. On this container it serves a reduced config on CPU; the
same step functions lower at production scale in the dry-run.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
        --requests 12 --batch 4 --gen-len 16
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.runtime.steps import make_serve_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        print("[serve] encdec serving is exercised in tests; use an LM arch")
        return 0
    rng = np.random.default_rng(args.seed)
    params = lm.init_params(cfg, jax.random.key(args.seed))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    # request queue: each request is a prompt of prompt_len tokens
    queue = [
        rng.integers(0, cfg.vocab, size=(args.prompt_len,)).astype(np.int32)
        for _ in range(args.requests)
    ]
    b = args.batch
    cache = lm.init_cache(cfg, b, args.max_len)
    active = [None] * b  # request id per slot
    to_go = np.zeros(b, np.int32)
    fed = np.zeros((b,), np.int32)  # next token to feed per slot
    prompts: list[np.ndarray | None] = [None] * b
    outputs: dict[int, list[int]] = {}
    next_req = 0
    done = 0
    steps = 0
    t0 = time.monotonic()

    # NOTE: single shared cache["len"] means slots advance in lockstep;
    # a slot joining mid-stream replays its prompt through the decode path
    # (teacher forcing) — simple continuous batching without per-slot
    # position bookkeeping. Positions are per-cache-global, which is fine
    # for RoPE at these lengths.
    token = np.zeros((b, 1), np.int32)
    while done < args.requests:
        # admit requests into free slots
        for i in range(b):
            if active[i] is None and next_req < len(queue):
                active[i] = next_req
                prompts[i] = queue[next_req]
                fed[i] = 0
                to_go[i] = args.gen_len
                outputs[next_req] = []
                next_req += 1
        # build the next token per slot (prompt replay or generated token)
        logits, cache = serve(params, jnp.asarray(token), cache)
        steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        for i in range(b):
            if active[i] is None:
                continue
            if fed[i] < len(prompts[i]):  # still feeding the prompt
                token[i, 0] = prompts[i][fed[i]]
                fed[i] += 1
            else:
                outputs[active[i]].append(int(nxt[i]))
                token[i, 0] = nxt[i]
                to_go[i] -= 1
                if to_go[i] <= 0:
                    done += 1
                    active[i] = None
        if steps > args.requests * (args.prompt_len + args.gen_len) + 64:
            raise RuntimeError("serving loop failed to drain the queue")
    dt = time.monotonic() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    print(
        f"[serve] {args.requests} requests, {total_tokens} generated tokens "
        f"in {steps} steps, {dt:.1f}s ({total_tokens/dt:.1f} tok/s)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
