"""Serving driver: continuous batching over a shared KV pool.

The default engine is the ``runtime.scheduler`` subsystem: one physical
KV pool (``runtime.kv_pool``, block-granular, allocated/freed per
request), token-budget admission, single-step batched prefill, and
paged decode lanes that each run at their own depth. The legacy
fixed-batch loop (per-slot ring caches, lockstep positions, prompt
replayed token-by-token through the decode path) is kept as
``--engine fixed`` — it is the A/B baseline for ``benchmarks/serve_bench``
and the fallback for the SSM/hybrid families, whose decode state is
fixed-size per slot and needs no pool.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
        --requests 12 --batch 4 --gen-len 16
"""

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.models.config import (
    PACKING_FAMILIES,
    PAGED_FAMILIES,
    PREFIX_CACHE_FAMILIES,
)
from repro.runtime.kv_pool import KVPool, choose_block_tokens
from repro.runtime.prefix_cache import PrefixCache
from repro.runtime.scheduler import Scheduler
from repro.runtime.steps import make_serve_step


def make_requests(args, vocab: int) -> list[np.ndarray]:
    rng = np.random.default_rng(args.seed)
    return [
        rng.integers(0, vocab, size=(args.prompt_len,)).astype(np.int32)
        for _ in range(args.requests)
    ]


def build_residency_plan(cfg, args):
    """Compile the ``--vmem-budget`` residency plan (None when unbudgeted)."""
    if not args.vmem_budget:
        return None
    from repro.runtime.residency import TrafficProfile, compile_residency_plan
    from repro.runtime.residency.executor import supports_budgeted_decode

    if not supports_budgeted_decode(cfg):
        raise ValueError(
            f"--vmem-budget needs a streamable-FFN attention family; "
            f"{cfg.name} is {cfg.family!r}"
        )
    traffic = TrafficProfile(
        lanes=args.batch, prompt_len=args.prompt_len, gen_len=args.gen_len
    )
    return compile_residency_plan(
        cfg,
        vmem_budget_bytes=int(args.vmem_budget * 2**20),
        traffic=traffic,
    )


def build_pool_engine(cfg, params, args) -> Scheduler:
    total = args.prompt_len + args.gen_len
    block_tokens = args.block_tokens or choose_block_tokens(
        [total] * args.requests
    )
    pool = KVPool.for_slots(
        cfg, slots=args.batch, max_len=args.max_len, block_tokens=block_tokens
    )
    prefix_cache = None
    if args.prefix_cache and cfg.family in PREFIX_CACHE_FAMILIES:
        prefix_cache = PrefixCache(pool)
    tracker = None
    spans = None
    if getattr(args, "trace_out", None):
        from repro.runtime.tracker import JsonlTracker

        tracker = JsonlTracker(args.trace_out)
        if getattr(args, "trace_spans", True):
            # standalone serving has no virtual clock: spans are stamped
            # on the host monotonic clock instead (same record schema,
            # same Perfetto export; decomposition exactness is a
            # virtual-clock property and not asserted here)
            from repro.runtime.spans import SpanRecorder

            spans = SpanRecorder(time.monotonic, tracker=tracker)
    from repro.runtime.memledger import MemLedger, MemPressureMonitor

    # no engine stamp: standalone round records carry none either, and
    # the ledger/metrics engine keys must agree for validate_ledger
    ledger = MemLedger(time.monotonic, tracker=tracker)
    mem_monitor = MemPressureMonitor()
    speculator = None
    if getattr(args, "speculate", ""):
        from repro.runtime.speculative import SpecConfig, build_speculator

        speculator = build_speculator(
            cfg,
            params,
            SpecConfig(
                drafter=args.speculate,
                depth=args.spec_depth,
                quant=args.spec_quant,
            ),
            slots=args.batch,
            max_len=args.max_len,
            smoke=args.smoke,
        )
    return Scheduler(
        cfg,
        params,
        pool,
        slots=args.batch,
        max_len=args.max_len,
        token_budget=args.token_budget or None,
        decode_per_round=args.rf or None,
        sampling=lm.SamplingParams(
            temperature=args.temperature,
            top_k=args.top_k,
            top_p=args.top_p,
            seed=args.seed,
        ),
        prefill_chunk=args.prefill_chunk or None,
        residency=build_residency_plan(cfg, args),
        prefix_cache=prefix_cache,
        speculative=speculator,
        tracker=tracker,
        spans=spans,
        ledger=ledger,
        mem_monitor=mem_monitor,
    )


def run_pool_engine(cfg, params, args) -> dict:
    sched = build_pool_engine(cfg, params, args)
    for prompt in make_requests(args, cfg.vocab):
        sched.submit(prompt, args.gen_len)
    t0 = time.monotonic()
    stats = sched.run()
    dt = time.monotonic() - t0
    if sched.tracker is not None:
        sched.tracker.finish()
    outputs = sched.outputs()
    assert stats.completed == args.requests, (stats.completed, args.requests)
    assert all(len(v) == args.gen_len for v in outputs.values())
    return {
        "engine": "pool",
        "requests": args.requests,
        "generated_tokens": stats.generated_tokens,
        "steps": stats.prefill_steps + stats.decode_steps,
        "prefill_steps": stats.prefill_steps,
        "decode_steps": stats.decode_steps,
        "wall_s": dt,
        "tokens_per_s": stats.generated_tokens / dt if dt > 0 else 0.0,
        "decode_step_ms": (
            stats.decode_time / stats.decode_steps * 1e3
            if stats.decode_steps
            else 0.0
        ),
        "mean_ttft_s": stats.mean_ttft,
        "pool_utilization": stats.steady_state_utilization,
        "block_tokens": sched.pool.block_tokens,
        "prefix_cache": sched.prefix_cache is not None,
        "prefix_hits": stats.prefix_hits,
        "prefix_hit_tokens": stats.prefix_hit_tokens,
        "prefix_hit_rate": stats.prefix_hit_rate,
        "shared_blocks_peak": stats.shared_blocks_peak,
        "cached_blocks": sched.pool.cached_blocks,
        "speculate": (
            sched.speculative.name if sched.speculative is not None else ""
        ),
        "spec_depth": (
            sched.speculative.depth if sched.speculative is not None else 0
        ),
        "accepted_tokens": stats.accepted_tokens,
        "draft_tokens": stats.draft_tokens,
        "verify_steps": stats.verify_steps,
        "accepted_per_step": stats.accepted_per_step,
        "residency": (
            sched.residency.summary() if sched.residency is not None else None
        ),
        "span_records": sched.spans.n_spans if sched.spans else 0,
        "mem": sched.mem_monitor.summary(now=time.monotonic()),
        "mem_records": sched.ledger.n_records,
        "fragmentation": sched.pool.fragmentation_report(),
        "outputs": outputs,
    }


@functools.lru_cache(maxsize=None)
def _jitted_fixed_step(cfg):
    return jax.jit(make_serve_step(cfg), donate_argnums=(2,))


def run_fixed_engine(cfg, params, args) -> dict:
    """The legacy fixed-batch loop: per-slot ring caches, lockstep
    positions, prompts replayed through the decode path. Drains the queue
    to empty (requests % batch != 0 included)."""
    if args.prompt_len + args.gen_len > args.max_len:
        # the ring cache holds max_len rows; past that, rows clobber
        # (caught in main -> exit 2, matching the pool engine's check)
        raise ValueError(
            f"request needs {args.prompt_len + args.gen_len} tokens "
            f"> max_len {args.max_len}"
        )
    serve = _jitted_fixed_step(cfg)
    queue = make_requests(args, cfg.vocab)
    b = args.batch
    cache = None  # allocated at each wave boundary below
    active = [None] * b
    to_go = np.zeros(b, np.int32)
    fed = np.zeros((b,), np.int32)
    prompts: list[np.ndarray | None] = [None] * b
    outputs: dict[int, list[int]] = {}
    ttft: dict[int, float] = {}
    next_req = 0
    done = 0
    steps = 0
    t0 = time.monotonic()
    token = np.zeros((b, 1), np.int32)
    decode_time = 0.0
    gen_steps = 0
    while done < args.requests:
        if next_req < len(queue) and all(a is None for a in active):
            # wave boundary (lockstep lengths drain all slots at once):
            # fresh ring + len=0 so a long trace can't overflow max_len
            # rows and clobber the new wave's KV history
            cache = lm.init_cache(cfg, b, args.max_len)
            token[:] = 0
        for i in range(b):
            if active[i] is None and next_req < len(queue):
                active[i] = next_req
                prompts[i] = queue[next_req]
                fed[i] = 0
                to_go[i] = args.gen_len
                outputs[next_req] = []
                next_req += 1
        ts = time.monotonic()
        logits, cache = serve(params, jnp.asarray(token), cache)
        steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        generated_this_step = 0
        for i in range(b):
            if active[i] is None:
                continue
            if fed[i] < len(prompts[i]):  # still feeding the prompt
                token[i, 0] = prompts[i][fed[i]]
                fed[i] += 1
            else:
                if not outputs[active[i]]:
                    ttft[active[i]] = time.monotonic() - t0
                generated_this_step += 1
                outputs[active[i]].append(int(nxt[i]))
                token[i, 0] = nxt[i]
                to_go[i] -= 1
                if to_go[i] <= 0:
                    done += 1
                    active[i] = None
        if generated_this_step:
            # a decoding step, counted once per step, host bookkeeping
            # included (the pool engine's decode_time is measured the same
            # way around its round loop)
            decode_time += time.monotonic() - ts
            gen_steps += 1
        if steps > args.requests * (args.prompt_len + args.gen_len) + 64:
            raise RuntimeError("serving loop failed to drain the queue")
    dt = time.monotonic() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    return {
        "engine": "fixed",
        "requests": args.requests,
        "generated_tokens": total_tokens,
        "steps": steps,
        "prefill_steps": 0,
        "decode_steps": steps,
        "wall_s": dt,
        "tokens_per_s": total_tokens / dt if dt > 0 else 0.0,
        "decode_step_ms": decode_time / gen_steps * 1e3 if gen_steps else 0.0,
        "mean_ttft_s": sum(ttft.values()) / len(ttft) if ttft else 0.0,
        "pool_utilization": 0.0,
        "block_tokens": 0,
        "prefix_cache": False,
        "prefix_hits": 0,
        "prefix_hit_tokens": 0,
        "prefix_hit_rate": 0.0,
        "shared_blocks_peak": 0,
        "cached_blocks": 0,
        "outputs": outputs,
    }


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=["pool", "fixed"], default="pool")
    ap.add_argument("--block-tokens", type=int, default=0,
                    help="KV-pool block size; 0 = bin-cost sweep")
    ap.add_argument("--rf", type=int, default=0,
                    help="decode steps per admission round; 0 = Eq. 2 default")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="admission token budget; 0 = pool capacity")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill chunk size for long prompts; "
                         "0 = the admission token budget")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="radix prefix cache over the KV pool: requests "
                         "adopt their longest cached prefix's blocks and "
                         "prefill only the unmatched suffix "
                         "(--no-prefix-cache disables)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the top-k logits; 0 = off")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass; 1.0 = off")
    ap.add_argument("--speculate", default="",
                    help="speculative decoding drafter: 'ngram' (self-"
                         "drafting suffix match) or a canonical arch id "
                         "whose packed twin drafts for the target "
                         "(pool engine, dense/vlm/moe families)")
    ap.add_argument("--spec-depth", type=int, default=4,
                    help="draft chain depth k: each verify step scores "
                         "the pending token plus k-1 proposals")
    ap.add_argument("--spec-quant", type=int, default=2, choices=[1, 2],
                    help="packed-carrier width of a model drafter's FFN "
                         "(the twin's w_bits)")
    ap.add_argument("--quant", type=int, default=0, choices=[0, 1, 2],
                    help="serve with FCMP-packed 1/2-bit FFN weights "
                         "(inference-only carriers)")
    ap.add_argument("--vmem-budget", type=float, default=0.0,
                    help="MiB of VMEM for pinned weight blocks; decode "
                         "runs against the budgeted set, cold blocks "
                         "stream HBM->VMEM (0 = unbudgeted)")
    ap.add_argument("--trace-out", default="",
                    help="append one JSONL record per scheduler round "
                         "(runtime.tracker stream; pool engine only)")
    ap.add_argument("--trace-spans", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="emit per-request lifecycle span records into "
                         "--trace-out (wall-clock stamps; export with "
                         "perf.trace_export; --no-trace-spans for "
                         "rounds-only streams)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    except ValueError as e:
        print(f"[serve] {e}")
        return 2
    if cfg.family == "encdec":
        print("[serve] encdec serving is exercised in tests; use an LM arch")
        return 0
    if args.quant:
        if cfg.family not in PACKING_FAMILIES:
            print(f"[serve] note: --quant has no effect on family "
                  f"{cfg.family!r} (no dense FFN to pack)")
        else:
            cfg = dataclasses.replace(cfg, w_bits=args.quant)
    engine = args.engine
    if engine == "pool" and cfg.family not in PAGED_FAMILIES:
        print(f"[serve] family {cfg.family!r} keeps fixed-size per-slot "
              "decode state and holds no KV rows; using the fixed-batch "
              "engine")
        engine = "fixed"
    if args.vmem_budget and engine == "fixed":
        # the fixed loop has no budgeted decode path; failing loudly beats
        # reporting numbers the user would read as budgeted
        print(f"[serve] --vmem-budget needs the pool engine's paged decode; "
              f"family {cfg.family!r} / --engine fixed cannot run budgeted")
        return 2
    if args.speculate and engine == "fixed":
        print(f"[serve] --speculate needs the pool engine's paged verify; "
              f"family {cfg.family!r} / --engine fixed cannot speculate")
        return 2

    params = lm.init_params(cfg, jax.random.key(args.seed))
    run = run_pool_engine if engine == "pool" else run_fixed_engine
    try:
        m = run(cfg, params, args)
    except ValueError as e:
        # bad request/budget geometry (e.g. prompt+gen > --max-len)
        print(f"[serve] {e}")
        return 2
    line = (
        f"[serve/{m['engine']}] {m['requests']} requests, "
        f"{m['generated_tokens']} generated tokens in {m['steps']} steps "
        f"({m['prefill_steps']} prefill + {m['decode_steps']} decode), "
        f"{m['wall_s']:.1f}s ({m['tokens_per_s']:.1f} tok/s, "
        f"TTFT {m['mean_ttft_s']*1e3:.0f} ms)"
    )
    if m["engine"] == "pool":
        line += f", pool utilization {m['pool_utilization']*100:.1f}%"
    print(line)
    if m.get("speculate"):
        print(
            f"[serve/spec] drafter {m['speculate']} depth {m['spec_depth']}: "
            f"{m['accepted_tokens']} tokens from {m['verify_steps']} verify "
            f"steps ({m['accepted_per_step']:.2f} accepted/step, "
            f"{m['draft_tokens']} drafted)"
        )
    if m.get("prefix_cache"):
        print(
            f"[serve/prefix] {m['prefix_hits']} prefix hits, "
            f"{m['prefix_hit_tokens']} prompt tokens served from cache "
            f"(hit rate {m['prefix_hit_rate']*100:.1f}%), "
            f"{m['shared_blocks_peak']} shared blocks at peak, "
            f"{m['cached_blocks']} blocks cached at drain"
        )
    if m.get("residency"):
        r = m["residency"]
        print(
            f"[serve/residency] {r['resident_blocks']}/{r['n_blocks']} "
            f"weight blocks pinned ({r['resident_mib']:.2f} MiB of "
            f"{r['vmem_budget_mib']:.2f} MiB budget), HBM re-stream "
            f"traffic cut {r['hbm_traffic_reduction']*100:.0f}%, "
            f"stream-ahead depth {r['stream_ahead']} (R_F)"
        )
    if m.get("mem"):
        mm = m["mem"]
        frag = mm.get("frag_at_peak") or {}  # drain-time report is empty
        line = (
            f"[serve/mem] signal {mm['signal']}, peak occupancy "
            f"{mm['peak_occupancy']*100:.1f}% "
            f"({mm['peak_held_blocks']} blocks, headroom "
            f"{mm['headroom_blocks']}), {mm['evicted_blocks']} blocks "
            f"evicted, {m['mem_records']} ledger records"
        )
        if frag:
            line += (
                f", packing at peak "
                f"{frag.get('baseline_efficiency', 1.0)*100:.1f}% "
                f"(FFD bound {frag.get('ffd_efficiency', 1.0)*100:.1f}%)"
            )
        print(line)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
