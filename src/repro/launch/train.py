"""End-to-end training driver.

Runs on whatever devices exist: a (1, 1) mesh on this CPU container (the
examples train a ~100M-param model for a few hundred steps), the 16x16 /
2x16x16 production meshes on real pods. Fault tolerance comes from
``runtime.train.TrainLoop`` (atomic async checkpoints, deterministic
resume, straggler monitor).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
        --smoke --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt
"""

import argparse

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import lm
from repro.optim.adamw import AdamW
from repro.runtime.steps import make_train_step
from repro.runtime.train import TrainLoop, TrainLoopConfig


def fit_mesh():
    """Largest (data, model) mesh the available devices support."""
    n = len(jax.devices())
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--ce-chunk", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--quant", type=int, default=0, choices=[0, 1, 2])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    try:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    except ValueError as e:
        print(f"[train] {e}")
        return 2
    if args.quant:
        # Families with a dense FFN store 1/2-bit weights as packed uint8
        # carriers (repro.models.lm), which are inference-only: no
        # gradients, no optimizer moments (optim.adamw._is_frozen).
        from repro.models.config import PACKING_FAMILIES

        if cfg.family in PACKING_FAMILIES:
            print(
                f"[train] --quant {args.quant} is not trainable: "
                f"{cfg.family!r} archs pack FFN weights into inference-only "
                "uint8 carriers. Train dense (no --quant), then quantize the "
                "checkpoint for serving (examples/pack_and_port.py, "
                "launch/serve.py)."
            )
            return 2
        # non-packing families: leave cfg untouched so the message stays
        # true downstream (ckpt metadata, traffic modeling keyed on w_bits)
        print(f"[train] note: --quant has no effect on family "
              f"{cfg.family!r} (no dense FFN to pack); ignoring")
    mesh = (
        make_production_mesh() if args.production_mesh else fit_mesh()
    )
    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}")

    opt = AdamW(lr=args.lr)
    step_fn = make_train_step(
        cfg, opt, remat=args.remat, ce_chunk=args.ce_chunk
    )
    p_sh = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        shd.param_specs(cfg, mesh),
    )
    with mesh:
        params = jax.jit(
            lambda k: lm.init_params(cfg, k), out_shardings=p_sh
        )(jax.random.key(args.seed))
        opt_state = opt.init(params)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        pipeline = TokenPipeline(
            vocab=cfg.vocab, batch=args.batch, seq_len=args.seq,
            seed=args.seed,
        )
        ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
        loop = TrainLoop(
            step_fn=jitted,
            pipeline=pipeline,
            ckpt=ckpt,
            config=TrainLoopConfig(
                n_steps=args.steps, ckpt_every=args.ckpt_every,
                log_every=10,
            ),
        )
        params, opt_state, start = loop.restore_or_init(params, opt_state)
        if start:
            print(f"[train] resumed from step {start}")
        params, opt_state, log = loop.run(params, opt_state, start)

    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"[train] steps {start}..{len(log)+start}: "
          f"loss {first:.4f} -> {last:.4f}")
    for e in log[:: max(1, len(log) // 10)]:
        print(f"  step {e['step']:5d} loss {e['loss']:.4f} "
              f"{e['time_s']*1e3:7.1f} ms")
    if not np.isfinite(last):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
