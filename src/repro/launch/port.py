"""Device-port planner: which tier fits this model + traffic, at what loss?

The paper's §V question, answered for both sides of the repo:

* **FPGA accelerator configs** (``cnv_w1a1`` ... ``rn50_w2a2``): sweep the
  ``core.resource_model.DEVICES`` catalog. Per tier, report the baseline
  (one buffer per BRAM structure) vs FCMP-packed memory subsystem — does
  it fit, at what BRAM/LUT utilization, and at what throughput loss
  (``core.gals`` operating points; achieved clocks for the paper's own
  design points are taken from Table V — timing closure is a hardware
  fact, the model turns clocks into throughput). The alternative port,
  2x folding, is evaluated by re-folding the design (halving the slowest
  dimension of each layer's parallelism) — it fits by shrinking *compute*
  and pays ~half the throughput, the paper's Table V F2 row.

* **LM archs** (``smollm_360m`` ...): walk the ``TPU_TIERS`` ladder with
  the ``runtime.residency`` planner. Per tier, compile a residency plan
  for the packed model (``--quant``) and for the dense model at the same
  VMEM budget, then compare decode throughput under a roofline step
  model: FCMP packing cuts the streamed weight bytes 8-16x, so the port
  to a bandwidth-poorer tier loses less throughput than serving dense
  weights — the §V ordering, one level up the memory hierarchy.

Usage::

    PYTHONPATH=src python -m repro.launch.port --arch rn50_w2a2
    PYTHONPATH=src python -m repro.launch.port --arch smollm_360m --quant 1 \
        --out port_report.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json

from repro.configs import ACCEL_IDS, canonical, get_accelerator, get_config
from repro.core.buffers import Folding, buffer_set
from repro.core.efficiency import baseline_report, device_utilization, report
from repro.core.folding import mvau_luts
from repro.core.gals import GalsOperatingPoint, folding_delta_fps
from repro.core.packing import PackItem, pack_ffd, pack_genetic
from repro.core.resource_model import DEVICES, TPU_TIERS

# Achieved clocks per (kind, device) — paper Table V hardware facts
# (f_compute, f_memory, f_compute_baseline). The w2a2 variants reuse the
# w1a2 closure numbers: the GALS memory subsystem, not the datapath
# precision, is what sets these clocks.
ACHIEVED_CLOCKS = {
    ("cnv", "zynq7020"): (100.0, 200.0, 100.0),
    ("cnv", "zynq7012s"): (100.0, 200.0, 100.0),
    ("rn50", "u250"): (183.0, 363.0, 203.0),
    ("rn50", "u280"): (138.0, 373.0, 203.0),
}
# F2 folding achieved clock vs its baseline (paper: 191 vs 195 MHz on U280)
FOLD2_CLOCKS = {("rn50", "u280"): (191.0, 195.0)}


def _clocks(kind: str, dev) -> tuple[float, float, float]:
    if (kind, dev.name) in ACHIEVED_CLOCKS:
        return ACHIEVED_CLOCKS[(kind, dev.name)]
    f_c = dev.f_compute_typ_mhz
    return f_c, min(2 * f_c, dev.f_mem_max_mhz), f_c


def _fold2(acc):
    """Re-fold the accelerator 2x: halve each layer's parallelism along
    its largest legal dimension (the paper's F2 alternative port)."""
    foldings = []
    for layer, f in zip(acc.layers, acc.folding.foldings):
        if f.pe > 1:
            foldings.append(Folding(f.pe // 2, f.simd))
        elif f.simd > 1:
            foldings.append(Folding(f.pe, f.simd // 2))
        else:
            foldings.append(f)
    bufs = buffer_set(acc.layers, foldings)
    luts = sum(mvau_luts(l, f) for l, f in zip(acc.layers, foldings))
    return bufs, luts


def accel_port_rows(name: str, solver: str = "ffd") -> list[dict]:
    # The design is folded ONCE for its native device and then ported
    # as-is — the paper's §V framing (same accelerator, smaller part).
    # Re-folding for the target is exactly the "folding" alternative the
    # comparison is against.
    acc = get_accelerator(name)
    bufs = acc.buffers()
    regions = acc.regions()
    items = [PackItem(b, region=r) for b, r in zip(bufs, regions)]
    base = baseline_report("base", bufs)
    if solver == "ga":
        packing = pack_genetic(items, acc.ga)
    else:
        packing = pack_ffd(items, acc.ga.max_height)
    packed = report(f"P{acc.ga.max_height}", packing)
    compute_luts = acc.folding.luts
    fold_bufs, fold_luts = _fold2(acc)
    fold_brams = sum(b.blocks() for b in fold_bufs)
    rows = []
    for dev_name, dev in DEVICES.items():
        fit_b = device_utilization(dev, base.brams, compute_luts)
        fit_p = device_utilization(
            dev, packed.brams, compute_luts + packed.lut_overhead
        )
        f_c, f_m, f_base = _clocks(acc.kind, dev)
        op = GalsOperatingPoint(f_c, f_m, acc.ga.max_height, f_base)
        ff, ffb = FOLD2_CLOCKS.get((acc.kind, dev.name), (f_base, f_base))
        fit_f = device_utilization(dev, fold_brams, fold_luts)
        fold_delta = 1.0 - (1.0 - folding_delta_fps(2)) * ff / ffb
        rows.append({
            "bench": "port",
            "arch": name,
            "device": dev_name,
            "baseline_brams": base.brams,
            "baseline_fits": bool(fit_b["fits"]),
            "packed_brams": packed.brams,
            "packed_lut_overhead_k": round(packed.lut_overhead / 1000, 1),
            "packed_fits": bool(fit_p["fits"]),
            "packed_bram_pct": round(fit_p["bram_pct"], 1),
            "fcmp_delta_fps_pct": round(100 * op.delta_fps, 1),
            "fold2_brams": fold_brams,
            "fold2_fits": bool(fit_f["fits"]),
            "fold2_delta_fps_pct": round(100 * fold_delta, 1),
            "recommended": (
                "baseline" if fit_b["fits"]
                else "fcmp" if fit_p["fits"]
                and (not fit_f["fits"] or op.delta_fps <= fold_delta)
                else "fold2" if fit_f["fits"]
                else "none"
            ),
        })
    return rows


def _lm_step_model(cfg, chip, plan, traffic) -> dict:
    """Roofline decode-step model: compute vs HBM, per tier."""
    from repro.runtime.residency.plan import fixed_hbm_bytes

    flop_t = 2.0 * cfg.active_params() * traffic.lanes / chip.peak_bf16_flops
    hbm_bytes = plan.streamed_bytes_per_step + fixed_hbm_bytes(cfg, traffic)
    hbm_t = hbm_bytes / chip.hbm_bw
    step = max(flop_t, hbm_t)
    return {
        "step_us": step * 1e6,
        "tokens_per_s": traffic.lanes / step,
        "bound": "hbm" if hbm_t > flop_t else "compute",
    }


def lm_port_rows(
    name: str,
    quant: int = 1,
    lanes: int = 8,
    prompt_len: int = 512,
    gen_len: int = 128,
    reserve_frac: float = 0.5,
    solver: str = "ffd",
) -> list[dict]:
    from repro.runtime.residency import TrafficProfile, compile_residency_plan

    cfg = get_config(name)
    traffic = TrafficProfile(
        lanes=lanes, prompt_len=prompt_len, gen_len=gen_len
    )
    variants = {"dense": cfg}
    if quant and cfg.family in ("dense", "vlm", "encdec", "hybrid"):
        variants = {
            "fcmp_packed": dataclasses.replace(cfg, w_bits=quant),
            "dense": cfg,
        }
    rows = []
    best_tput: dict[str, float] = {}
    for tier, chip in TPU_TIERS.items():
        budget = int(chip.vmem_bytes * (1.0 - reserve_frac))
        for variant, vcfg in variants.items():
            plan = compile_residency_plan(
                vcfg,
                vmem_budget_bytes=budget,
                traffic=traffic,
                chip=chip,
                solver=solver,
            )
            perf = _lm_step_model(vcfg, chip, plan, traffic)
            param_bytes = sum(b.padded_bytes(chip) for b in plan.blocks)
            rows.append({
                "bench": "port",
                "arch": name,
                "device": tier,
                "variant": variant,
                "fits_hbm": bool(param_bytes < chip.hbm_bytes),
                "vmem_budget_mib": round(budget / 2**20, 1),
                "resident_fraction": round(plan.resident_fraction, 3),
                "streamed_mib_per_step": round(
                    plan.streamed_bytes_per_step / 2**20, 2
                ),
                "stream_ahead": plan.stream_ahead,
                "bound": perf["bound"],
                "tokens_per_s": round(perf["tokens_per_s"], 1),
            })
            best_tput[variant] = max(
                best_tput.get(variant, 0.0), perf["tokens_per_s"]
            )
    dense_tput = {
        r["device"]: r["tokens_per_s"]
        for r in rows
        if r["variant"] == "dense"
    }
    for r in rows:
        ref = best_tput[r["variant"]]
        r["delta_fps_pct"] = round(
            100 * (1.0 - r["tokens_per_s"] / ref), 1
        ) if ref else 0.0
        # the §V cross-check per tier: packing vs serving dense weights
        if r["variant"] == "fcmp_packed" and dense_tput.get(r["device"]):
            r["fcmp_vs_dense_speedup_pct"] = round(
                100 * (r["tokens_per_s"] / dense_tput[r["device"]] - 1.0), 1
            )
    return rows


def port_report(arch: str, **kw) -> list[dict]:
    """Rows for one arch — the entry point ``benchmarks.residency_bench``
    consumes."""
    cand = canonical(arch)
    if cand in ACCEL_IDS:
        return accel_port_rows(cand, solver=kw.get("solver", "ffd"))
    return lm_port_rows(
        cand,
        quant=kw.get("quant", 1),
        lanes=kw.get("lanes", 8),
        prompt_len=kw.get("prompt_len", 512),
        gen_len=kw.get("gen_len", 128),
        reserve_frac=kw.get("reserve_frac", 0.5),
        solver=kw.get("solver", "ffd"),
    )


def _print_rows(rows: list[dict]) -> None:
    keys: list[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="accelerator (cnv_w1a1 ...) or LM arch")
    ap.add_argument("--quant", type=int, default=1, choices=[0, 1, 2],
                    help="packed precision for the LM FCMP variant")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--gen-len", type=int, default=128)
    ap.add_argument("--reserve-frac", type=float, default=0.5,
                    help="VMEM fraction reserved for activations")
    ap.add_argument("--solver", choices=["ffd", "ga"], default="ffd",
                    help="packing solver for the accelerator sweep")
    ap.add_argument("--out", default="",
                    help="write the report rows as JSON")
    args = ap.parse_args(argv)
    try:
        rows = port_report(
            args.arch,
            quant=args.quant,
            lanes=args.lanes,
            prompt_len=args.prompt_len,
            gen_len=args.gen_len,
            reserve_frac=args.reserve_frac,
            solver=args.solver,
        )
    except ValueError as e:
        print(f"[port] {e}")
        return 2
    _print_rows(rows)
    # the §V headline, where the row set exposes it: on a port target the
    # FCMP memory subsystem loses less throughput than 2x folding
    for r in rows:
        if "fold2_delta_fps_pct" in r and r["packed_fits"]:
            if not r["baseline_fits"]:
                better = r["fcmp_delta_fps_pct"] < r["fold2_delta_fps_pct"]
                print(
                    f"[port] {r['arch']} -> {r['device']}: FCMP loses "
                    f"{r['fcmp_delta_fps_pct']}% vs folding "
                    f"{r['fold2_delta_fps_pct']}% -> "
                    f"{'FCMP wins (paper §V)' if better else 'folding wins'}"
                )
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"arch": args.arch, "rows": rows}, f, indent=2)
        print(f"[port] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
