import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods x 256 chips,
``jax.jit(step).lower(**input_specs).compile()`` must succeed for every
cell, and the compiled artifact yields the memory analysis, cost analysis
and collective schedule consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe_1b_7b \
        --shape train_4k --multi-pod --json out.jsonl
"""

import argparse
import dataclasses
import json
import sys
import time

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, ModelConfig, shape_applicable
from repro.optim.adamw import AdamW
from repro.perf.roofline import model_flops, roofline
from repro.runtime.steps import make_prefill_step, make_serve_step, make_train_step


def lower_cell(
    cfg: ModelConfig,
    shape_name: str,
    mesh,
    *,
    remat: str = "full",
    ce_chunk: int = 512,
    donate: bool = True,
    constraints: bool = True,
):
    """Lower one (arch, shape) cell on ``mesh``. Returns (lowered, meta)."""
    from jax.sharding import PartitionSpec as P

    from repro.models.lm import set_attn_batch_sharding

    shape = SHAPES[shape_name]
    opt = AdamW()
    # §Perf iteration 5: when heads don't divide TP, GSPMD replicates the
    # attention math across the model axis; reshard it batch-wise over the
    # full mesh instead (only when the batch divides the mesh).
    tp = mesh.shape.get("model", 1)
    set_attn_batch_sharding(None)
    if (
        constraints
        and cfg.n_heads % tp != 0
        and shape.kind in ("train", "prefill")
    ):
        # largest axis combination the batch divides: on the 2-pod mesh a
        # 256-batch reshards over (data, model) and stays replicated over
        # 'pod' (plain DP) — without the fallback the multi-pod cells
        # regress to 16x-replicated attention.
        for axes in (
            tuple(mesh.axis_names),
            ("data", "model"),
            ("data",),
        ):
            axes = tuple(a for a in axes if a in mesh.axis_names)
            sz = 1
            for a in axes:
                sz *= mesh.shape[a]
            if "model" in axes and shape.global_batch % sz == 0:
                set_attn_batch_sharding(P(axes))
                break
    # §Perf iteration 8: sequence-sharded prefill attention when the batch
    # reshard above was not applicable (e.g. prefill batch 32 on 256 dev).
    from repro.models.lm import _ATTN_BATCH_SHARD, set_attn_seq_sharding

    set_attn_seq_sharding(None)
    if (
        constraints
        and cfg.n_heads % tp != 0
        and shape.kind == "prefill"
        and _ATTN_BATCH_SHARD["spec"] is None
        and shape.seq_len % tp == 0
    ):
        set_attn_seq_sharding(mesh)
    # §Perf iteration 6: pin MoE dispatch tensors to the expert axis
    from repro.models.moe import set_moe_ep_axis

    set_moe_ep_axis(
        "model"
        if constraints and cfg.family == "moe" and cfg.n_experts % tp == 0
        else None
    )
    # §Perf iteration 7: split-d decode attention keeps the cache resident
    # in its head_dim-sharded layout when KV heads don't divide TP.
    from repro.models.lm import set_decode_split_d

    set_decode_split_d(None)
    if (
        constraints
        and shape.kind == "decode"
        and cfg.n_kv % tp != 0
        and cfg.hd % tp == 0
        and shape.global_batch % (mesh.size // tp) == 0
    ):
        set_decode_split_d(mesh)
    if shape.kind == "train":
        step = make_train_step(cfg, opt, remat=remat, ce_chunk=ce_chunk)
        p_sh = S.param_shardings(cfg, mesh)
        o_sh = S.opt_shardings(cfg, mesh, opt)
        b_sh = S.batch_shardings(cfg, shape, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1) if donate else (),
        )
        args = (
            S.abstract_params(cfg),
            S.abstract_opt_state(cfg, opt),
            S.abstract_batch(cfg, shape),
        )
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(
                S.param_shardings(cfg, mesh),
                S.batch_shardings(cfg, shape, mesh),
            ),
        )
        args = (S.abstract_params(cfg), S.abstract_batch(cfg, shape))
    else:  # decode
        step = make_serve_step(cfg)
        c_sh = S.cache_shardings(cfg, shape, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(
                S.param_shardings(cfg, mesh),
                S.token_shardings(cfg, shape, mesh),
                c_sh,
            ),
            out_shardings=(None, c_sh),
            donate_argnums=(2,) if donate else (),
        )
        args = (
            S.abstract_params(cfg),
            S.abstract_token(cfg, shape),
            S.abstract_cache(cfg, shape),
        )
    with mesh:
        lowered = jitted.lower(*args)
    return lowered, {"kind": shape.kind}


def fold_residency(
    rec: dict, cfg: ModelConfig, shape, vmem_budget_mib: float
) -> dict:
    """Fold a ``runtime.residency`` plan into a decode roofline record.

    The residency planner pins the highest-traffic FFN weight regions
    into a VMEM budget; whatever is pinned stops moving over HBM every
    decode step. This re-quotes the record's memory term with those
    bytes subtracted (weights are sharded, so the per-replica saving is
    divided across devices), plus the budgeted bottleneck — the dry-run
    analogue of serving with ``--vmem-budget``.
    """
    from repro.perf.roofline import HW
    from repro.runtime.residency import TrafficProfile, compile_residency_plan
    from repro.runtime.residency.executor import supports_budgeted_decode

    rec = dict(rec)
    rec["vmem_budget_mib"] = vmem_budget_mib
    if shape.kind != "decode" or not supports_budgeted_decode(cfg):
        rec["residency"] = None  # budget has nothing to pin in this cell
        return rec
    n_dev = 512 if rec["mesh"] == "2x16x16" else 256
    plan = compile_residency_plan(
        cfg,
        vmem_budget_bytes=int(vmem_budget_mib * 2**20),
        traffic=TrafficProfile(
            lanes=shape.global_batch, prompt_len=shape.seq_len
        ),
    )
    saved_per_dev = (
        plan.streamable_bytes_per_step - plan.streamed_bytes_per_step
    ) / n_dev
    hbm_budgeted = max(0.0, rec["hbm_bytes_per_dev"] - saved_per_dev)
    t_mem = hbm_budgeted / HW.hbm_bw
    rec["residency"] = plan.summary()
    rec["hbm_bytes_per_dev_budgeted"] = hbm_budgeted
    rec["t_memory_budgeted_ms"] = t_mem * 1e3
    terms = {
        "compute": rec["t_compute_ms"],
        "memory": t_mem * 1e3,
        "collective": rec["t_collective_ms"],
    }
    rec["bottleneck_budgeted"] = max(terms, key=terms.get)
    return rec


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    remat: str = "full",
    ce_chunk: int = 512,
    quant: int = 0,
    constraints: bool = True,
    vmem_budget_mib: float = 0.0,
    verbose: bool = True,
) -> dict:
    """Lower + compile one cell; return the §Dry-run / §Roofline record."""
    cfg = get_config(arch)
    if quant:
        cfg = dataclasses.replace(cfg, w_bits=quant)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": why,
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.monotonic()
    lowered, meta = lower_cell(
        cfg, shape_name, mesh, remat=remat, ce_chunk=ce_chunk,
        constraints=constraints,
    )
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    mem_rec = {}
    for f in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, f, None)
        if v is not None:
            mem_rec[f] = int(v)

    rl = roofline(
        f"{arch}/{shape_name}", compiled, cfg, shape, n_dev
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "OK",
        "kind": meta["kind"],
        "quant": quant,
        "remat": remat,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "memory": mem_rec,
        "flops_per_dev": rl.flops,
        "hbm_bytes_per_dev": rl.hbm_bytes,
        "coll_bytes_per_dev": rl.coll_bytes,
        "coll_breakdown": rl.coll_breakdown,
        "model_flops": rl.model_flops,
        "t_compute_ms": rl.t_compute * 1e3,
        "t_memory_ms": rl.t_memory * 1e3,
        "t_collective_ms": rl.t_collective * 1e3,
        "bottleneck": rl.bottleneck,
        "useful_flops_ratio": rl.useful_flops_ratio,
        "roofline_fraction": rl.roofline_fraction,
    }
    if vmem_budget_mib:
        rec = fold_residency(rec, cfg, shape, vmem_budget_mib)
    if verbose:
        print(
            f"[dryrun] {arch:22s} {shape_name:12s} {rec['mesh']:8s} OK  "
            f"lower {t_lower:5.1f}s compile {t_compile:6.1f}s  "
            f"Tc {rec['t_compute_ms']:8.2f}ms Tm {rec['t_memory_ms']:8.2f}ms "
            f"Tcoll {rec['t_collective_ms']:8.2f}ms -> {rec['bottleneck']}",
            flush=True,
        )
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--ce-chunk", type=int, default=512)
    ap.add_argument("--quant", type=int, default=0, choices=[0, 1, 2])
    ap.add_argument("--attn-impl", default="fa2", choices=["fa2", "scan"])
    ap.add_argument(
        "--no-constraints", action="store_true",
        help="disable the Perf-iteration sharding hooks (paper-faithful "
        "baseline measurements)",
    )
    ap.add_argument(
        "--vmem-budget", type=float, default=0.0,
        help="MiB of VMEM for pinned weight blocks: decode cells on "
        "budget-supporting families additionally quote the *budgeted* "
        "HBM traffic / memory term from the residency plan",
    )
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)

    from repro.models.attention import set_attn_impl

    set_attn_impl(args.attn_impl)

    if args.arch != "all":
        try:
            from repro.configs import canonical_arch

            canonical_arch(args.arch)
        except ValueError as e:
            print(f"[dryrun] {e}")
            return 2
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(
                        arch, shape, multi_pod=mp, remat=args.remat,
                        ce_chunk=args.ce_chunk, quant=args.quant,
                        constraints=not args.no_constraints,
                        vmem_budget_mib=args.vmem_budget,
                    )
                except Exception as e:  # noqa: BLE001 — report all failures
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": f"FAIL: {type(e).__name__}: {e}",
                    }
                    failures.append(rec)
                    print(f"[dryrun] {arch} {shape} FAILED: {e}", flush=True)
                records.append(rec)

    if args.json:
        with open(args.json, "a") as fh:
            for r in records:
                fh.write(json.dumps(r) + "\n")
    n_ok = sum(1 for r in records if r["status"] == "OK")
    n_skip = sum(1 for r in records if r["status"].startswith("SKIP"))
    print(f"[dryrun] {n_ok} OK, {n_skip} skipped, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
