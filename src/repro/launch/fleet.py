"""Fleet-serving driver: N engines behind a router, on a virtual clock.

Modes::

    single  one engine (the PR-2/3 scheduler, fleet-instrumented)
    fleet   N identical engines behind the router (least-loaded or
            session-affinity dispatch, token-budget-aware admission)
    disagg  prefill and decode engine roles with KV-block handoff; the
            role split is provisioned from ``core.gals.required_rf``
            applied to the measured prefill/decode rates (override with
            --split P,D)

Engines run the real model (token streams are identical across modes at
temperature 0 — the fleet acceptance gate), while time is charged on a
roofline-derived virtual clock calibrated to the *full-size* arch, so
TTFT/TPOT/goodput are deterministic and meaningful on a CPU host.

Usage::

    PYTHONPATH=src python -m repro.launch.fleet --arch smollm_360m \
        --smoke --mode disagg --engines 4
"""

import argparse
import dataclasses
import json
import sys

import jax

from repro.configs import get_config, get_smoke_config
from repro.dist.mesh_axes import MeshView
from repro.dist.placement import plan_engine_placement
from repro.models import lm
from repro.models.config import PAGED_FAMILIES, PREFIX_CACHE_FAMILIES
from repro.runtime.cluster import (
    DisaggCluster,
    FleetCluster,
    SloPolicy,
    StepCostModel,
    TrafficSpec,
    measured_role_rates,
    synthesize,
)
from repro.runtime.kv_pool import choose_block_tokens


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true",
                    help="serve the reduced config (costs still calibrate "
                         "to the full-size arch)")
    ap.add_argument("--mode", choices=["single", "fleet", "disagg"],
                    default="fleet")
    ap.add_argument("--engines", type=int, default=2)
    ap.add_argument("--policy",
                    choices=["least-loaded", "affinity", "prefix-aware"],
                    default="least-loaded")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="per-engine radix prefix caches over the KV pools "
                         "(--no-prefix-cache disables)")
    ap.add_argument("--split", default="",
                    help="disagg role split 'P,D'; empty = GALS-ratio "
                         "provisioning from measured rates")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--arrival-rate", type=float, default=2000.0,
                    help="Poisson arrivals per virtual second")
    ap.add_argument("--session-reuse", type=float, default=0.3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=0,
                    help="0 = sized from the trace's longest request")
    ap.add_argument("--block-tokens", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--slo-ttft", type=float, default=0.03,
                    help="TTFT SLO in virtual seconds")
    ap.add_argument("--slo-tpot", type=float, default=0.002,
                    help="per-token SLO in virtual seconds")
    ap.add_argument("--speculate", default="",
                    help="speculative decoding drafter per engine: 'ngram' "
                         "or a canonical arch id whose packed twin drafts "
                         "(dense/vlm/moe families)")
    ap.add_argument("--spec-depth", type=int, default=4,
                    help="draft chain depth k")
    ap.add_argument("--spec-quant", type=int, default=2, choices=[1, 2],
                    help="packed-carrier width of a model drafter's FFN")
    ap.add_argument("--quant", type=int, default=0, choices=[0, 1, 2])
    ap.add_argument("--json", default="", help="write the SLO report here")
    ap.add_argument("--trace-out", default="",
                    help="append one JSONL record per engine round "
                         "(runtime.tracker stream, all engines interleaved; "
                         "replay with runtime.tracker.replay_summary)")
    ap.add_argument("--trace-spans", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="emit per-request lifecycle span records into "
                         "--trace-out (runtime.spans; export with "
                         "perf.trace_export; --no-trace-spans for "
                         "rounds-only streams)")
    return ap


def build_cluster(cfg, full_cfg, params, args, spec):
    cost = StepCostModel.for_config(full_cfg, slots=args.slots)
    max_len = args.max_len or spec.max_total_tokens + 8
    block_tokens = args.block_tokens or choose_block_tokens(
        [spec.max_total_tokens] * spec.n_requests
    )
    sampling = lm.SamplingParams(
        temperature=args.temperature, seed=args.seed
    )
    tracker = None
    if getattr(args, "trace_out", ""):
        from repro.runtime.tracker import JsonlTracker

        tracker = JsonlTracker(args.trace_out)
    speculative = None
    if getattr(args, "speculate", ""):
        from repro.runtime.speculative import SpecConfig, resolve

        # resolved once (validation + cost config); each engine builds
        # its own drafter instance from it
        speculative = resolve(
            cfg,
            SpecConfig(
                drafter=args.speculate,
                depth=args.spec_depth,
                quant=args.spec_quant,
            ),
            smoke=args.smoke,
        )
    common = dict(
        slots=args.slots,
        max_len=max_len,
        block_tokens=block_tokens,
        cost=cost,
        sampling=sampling,
        prefix_cache=args.prefix_cache
        and cfg.family in PREFIX_CACHE_FAMILIES,
        speculative=speculative,
        tracker=tracker,
        trace_spans=getattr(args, "trace_spans", True),
        slo=SloPolicy(ttft=args.slo_ttft, tpot=args.slo_tpot),
    )
    n = 1 if args.mode == "single" else args.engines
    if args.mode == "disagg":
        split = None
        if args.split:
            p, d = args.split.split(",")
            split = (int(p), int(d))
        return DisaggCluster(
            cfg, params, n_engines=n, spec=spec, split=split,
            policy=args.policy, **common,
        )
    return FleetCluster(
        cfg, params, n_engines=n, policy=args.policy, **common
    )


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
        full_cfg = get_config(args.arch)
    except ValueError as e:
        print(f"[fleet] {e}")
        return 2
    if cfg.family not in PAGED_FAMILIES:
        print(f"[fleet] family {cfg.family!r} has no paged serving path; "
              "use an attention-KV or hybrid arch")
        return 2
    # every paged family disaggregates: hybrid handoffs carry the SSM
    # lane-state snapshot next to the KV-block rows
    if args.prefix_cache and cfg.family not in PREFIX_CACHE_FAMILIES:
        print(f"[fleet] note: family {cfg.family!r} cannot prefix-cache; "
              "serving uncached")
    if args.quant:
        cfg = dataclasses.replace(cfg, w_bits=args.quant)
        full_cfg = dataclasses.replace(full_cfg, w_bits=args.quant)

    use_prefix = args.prefix_cache and cfg.family in PREFIX_CACHE_FAMILIES
    spec = TrafficSpec(
        n_requests=args.requests,
        arrival_rate=args.arrival_rate,
        session_reuse=args.session_reuse,
        vocab=cfg.vocab,
        seed=args.seed,
    )
    trace = synthesize(spec)
    params = lm.init_params(cfg, jax.random.key(args.seed))
    try:
        cluster = build_cluster(cfg, full_cfg, params, args, spec)
    except ValueError as e:
        print(f"[fleet] {e}")
        return 2

    n = len(cluster.engines)
    if args.mode == "disagg":
        rates = measured_role_rates(
            StepCostModel.for_config(full_cfg, slots=args.slots), spec,
            slots=args.slots,
        )
        print(
            f"[fleet] GALS rates: rho_p {rates.prefill_req_rate:.0f} req/s, "
            f"rho_d {rates.decode_req_rate:.0f} req/s, R_F {rates.r_f:.2f} "
            f"-> split {cluster.split[0]} prefill : {cluster.split[1]} decode"
            + (" (forced)" if args.split else " (Eq. 2 provisioned)")
        )
    # production placement of the engines over the single-pod mesh view
    view = MeshView(("data", "model"), (16, 16))
    try:
        for pl in plan_engine_placement(view, n):
            print(f"[fleet] {pl.describe()}")
    except ValueError as e:
        print(f"[fleet] placement: {e}")

    result = cluster.run(trace)
    if cluster.tracker is not None:
        cluster.tracker.finish()
        print(f"[fleet] wrote round-level tracker stream {args.trace_out}")
    report = result.report(
        SloPolicy(ttft=args.slo_ttft, tpot=args.slo_tpot)
    )
    r = report.row()
    print(
        f"[fleet/{args.mode}] {n} engines, {r['completed']}/"
        f"{r['n_requests']} requests, {r['generated_tokens']} tokens in "
        f"{r['makespan']*1e3:.1f} virtual ms "
        f"({r['throughput_tokens_per_s']:.0f} tok/s, goodput "
        f"{r['goodput_tokens_per_s']:.0f} tok/s, {r['slo_met']} in-SLO)"
    )
    print(
        f"[fleet/{args.mode}] TTFT p50/p95/p99 {r['ttft_p50']*1e3:.1f}/"
        f"{r['ttft_p95']*1e3:.1f}/{r['ttft_p99']*1e3:.1f} ms, "
        f"TPOT p50/p99 {r['tpot_p50']*1e3:.2f}/{r['tpot_p99']*1e3:.2f} ms"
    )
    print(
        f"[fleet/{args.mode}] queue wait p50/p95 "
        f"{r['queue_wait_p50']*1e3:.2f}/{r['queue_wait_p95']*1e3:.2f} ms, "
        f"TTFT-from-admit p95 {r['ttft_admit_p95']*1e3:.1f} ms "
        "(spread from TTFT p95 is the queue)"
    )
    ss = result.slo_summary
    if ss:
        burns = ", ".join(
            f"{k[5:]}={ss[k]:.2f}" for k in sorted(ss) if k.startswith("burn_")
        )
        print(
            f"[fleet/{args.mode}] SLO monitor: {ss.get('observed', 0)} "
            f"observed, {ss.get('violations', 0)} violations"
            + (f", burn rates [{burns}]" if burns else "")
        )
    ms = result.mem_summary
    if ms:
        print(
            f"[fleet/mem] signal {ms['signal']}, peak occupancy "
            f"{ms['peak_occupancy']*100:.1f}%, min headroom "
            f"{ms['headroom_blocks']} blocks, {ms['evicted_blocks']} "
            f"blocks evicted fleet-wide"
            + (
                f", pressure on engines {ms['pressure_engines']}"
                if ms.get("pressure_engines")
                else ""
            )
        )
    for s in result.engine_summaries:
        line = (
            f"[fleet]   engine {s['engine']} ({s['role']}): "
            f"{s['completed']} done, {s['handoffs']} handoffs, "
            f"{s['prefill_tokens']} prefill tokens, "
            f"{s['decode_steps']} decode steps, clock {s['clock_s']*1e3:.1f} ms"
        )
        if use_prefix:
            line += (
                f", prefix hit rate {s['prefix_hit_rate']*100:.1f}% "
                f"({s['prefix_hit_tokens']} tokens, "
                f"{s['shared_blocks_peak']} shared blocks peak, "
                f"{s['cached_blocks']} cached)"
            )
        if args.speculate and s.get("verify_steps"):
            line += (
                f", spec {s['accepted_per_step']:.2f} accepted/verify "
                f"({s['accepted_tokens']} tokens / {s['verify_steps']} "
                "steps)"
            )
        mem = s.get("mem") or {}
        if mem:
            # peak snapshot: the drain-time report sees an empty pool
            frag = mem.get("frag_at_peak") or s.get("fragmentation") or {}
            line += (
                f", mem peak {mem['peak_occupancy']*100:.0f}% occ "
                f"({mem['evicted_blocks']} evicted, packing "
                f"{frag.get('baseline_efficiency', 1.0)*100:.0f}%)"
            )
        print(line)
    if args.json:
        payload = {
            "mode": args.mode,
            "engines": n,
            "policy": args.policy,
            "speculate": args.speculate,
            "spec_depth": args.spec_depth if args.speculate else 0,
            "split": list(getattr(cluster, "split", ()) or ()),
            "report": r,
            "engine_summaries": result.engine_summaries,
            "slo_summary": result.slo_summary,
            "mem_summary": result.mem_summary,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"[fleet] wrote {args.json}")
    ok = report.completed == spec.n_requests
    if not ok:
        print(f"[fleet] INCOMPLETE: {report.completed}/{spec.n_requests}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
