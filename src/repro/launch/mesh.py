"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count locks on first jax init, and smoke
tests must see 1 CPU device while the dry-run sees 512 placeholders).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: 'data' carries DP (+ sequence parallelism for the batch=1 long-
    context cells), 'model' carries TP/EP, 'pod' is the outer DP axis whose
    collectives cross the inter-pod DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small shapes on forced host devices)."""
    return jax.make_mesh(shape, axes)
