"""Dataflow-pipeline performance model (FPS, latency, TOp/s).

A custom-dataflow accelerator is a pipeline of per-layer compute units; the
steady-state throughput is set by the slowest stage's initiation interval
(II, cycles per inference) and the clock:

    FPS     = F_c / max_i II_i
    latency = sum_i II_i / F_c        (first-inference pipeline fill)
    TOp/s   = 2 * total MACs * FPS
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.buffers import Folding, LayerSpec, mvau_cycles


@dataclasses.dataclass(frozen=True)
class PipelineModel:
    layers: tuple[LayerSpec, ...]
    foldings: tuple[Folding, ...]
    f_compute_mhz: float

    def cycles(self) -> list[int]:
        return [mvau_cycles(l, f) for l, f in zip(self.layers, self.foldings)]

    @property
    def max_ii(self) -> int:
        return max(self.cycles())

    @property
    def fps(self) -> float:
        return self.f_compute_mhz * 1e6 / self.max_ii

    @property
    def latency_s(self) -> float:
        """First-inference latency = pipeline fill.

        In streaming dataflow a layer emits its first outputs after seeing
        only ~K rows of its input, so its fill contribution is
        II * min(1, K / sqrt(out_pixels)) — full II only for FC layers
        (out_pixels = 1). This reproduces the paper's 1.9 ms for RN50 at
        370 us steady-state II; the naive sum-of-II bound would give 19 ms.
        """
        import math

        total = 0.0
        for l, c in zip(self.layers, self.cycles()):
            frac = min(1.0, l.k / math.sqrt(max(1, l.out_pixels)))
            total += c * frac
        return total / (self.f_compute_mhz * 1e6)

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def tops(self) -> float:
        """Effective tera-ops/s (2 ops per MAC) at steady state."""
        return 2.0 * self.total_macs * self.fps / 1e12

    def scaled_clock(self, f_compute_mhz: float) -> "PipelineModel":
        return dataclasses.replace(self, f_compute_mhz=f_compute_mhz)

    def folded(self, factor: int) -> "PipelineModel":
        """Uniformly reduce parallelism by ``factor`` (the paper's F2
        alternative): every II grows by ~factor, FPS drops by ~factor."""
        new = []
        for l, f in zip(self.layers, self.foldings):
            pe, simd = f.pe, f.simd
            rem = factor
            while rem > 1 and pe > 1 and (pe % 2 == 0):
                pe //= 2
                rem //= 2
            while rem > 1 and simd > 1 and (simd % 2 == 0):
                simd //= 2
                rem //= 2
            new.append(Folding(pe, simd))
        return dataclasses.replace(self, foldings=tuple(new))


def balance_report(model: PipelineModel) -> str:
    cyc = model.cycles()
    lines = [f"{'layer':24s} {'II':>10s} {'PE':>4s} {'SIMD':>5s}"]
    for l, f, c in zip(model.layers, model.foldings, cyc):
        lines.append(f"{l.name:24s} {c:10d} {f.pe:4d} {f.simd:5d}")
    lines.append(
        f"max II {model.max_ii}  FPS {model.fps:.0f}  "
        f"latency {model.latency_s*1e3:.2f} ms  {model.tops:.1f} TOp/s"
    )
    return "\n".join(lines)
