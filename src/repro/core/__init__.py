"""FCMP core: the paper's contribution as a composable library.

- ``resource_model``: BRAM/URAM/device geometry, LUT-overhead model
- ``buffers``: FINN MVAU weight-buffer shapes from (layer, folding)
- ``packing``: FFD / annealing / genetic buffer-to-BRAM packing
- ``gals``: frequency-compensation (R_F, H_B, delta_FPS) model
- ``efficiency``: Eq. 1 reports
- ``folding``: folding-solution search
- ``dataflow``: pipeline FPS/latency/TOp/s model
- ``topologies``: CNV + ResNet-50 layer sets
- ``vmem_plan``: TPU adaptation (VMEM residency packing)
"""

from repro.core.buffers import (  # noqa: F401
    Folding,
    LayerSpec,
    WeightBuffer,
    buffer_set,
    mvau_buffer,
    mvau_cycles,
)
from repro.core.dataflow import PipelineModel, balance_report  # noqa: F401
from repro.core.efficiency import (  # noqa: F401
    MemSubsystemReport,
    baseline_report,
    device_utilization,
    report,
)
from repro.core.folding import FoldingSolution, search_folding  # noqa: F401
from repro.core.gals import (  # noqa: F401
    GalsOperatingPoint,
    folding_delta_fps,
    max_bin_height,
    needs_odd_even_split,
    required_rf,
    virtual_ports,
)
from repro.core.packing import (  # noqa: F401
    GA_PARAMS_CNV,
    GA_PARAMS_RN50,
    GaParams,
    PackItem,
    Packing,
    baseline_packing,
    bin_cost,
    pack_anneal,
    pack_ffd,
    pack_genetic,
)
from repro.core.resource_model import (  # noqa: F401
    BRAM18,
    DEVICES,
    TPU_V5E,
    FpgaDevice,
    RamPrimitive,
    TpuChip,
    URAM,
    fcmp_lut_overhead,
)
from repro.core.topologies import (  # noqa: F401
    cnv_layers,
    resblock_slr_map,
    resnet50_layers,
)
from repro.core.vmem_plan import (  # noqa: F401
    ResidencyPlan,
    WeightBlock,
    plan_vmem_residency,
)
