"""Frequency-compensation model (paper §IV, Eq. 2; Fig. 7).

The GALS transformation splits each MVAU into a weight-storage block (memory
clock domain, ``F_m``) and a compute block (``F_c``), connected by async
FIFOs. With frequency ratio ``R_F = F_m / F_c`` a 2-port BRAM exposes
``2*R_F`` virtual ports per compute cycle, so a bin of height ``H_B``
sustains full readback iff

    H_B <= N_ports * R_F            (Eq. 2)

Integer ratios serve even bin heights with simple round-robin port schedules
(Fig. 7a). Fractional ratios ``R_F = N_b/2`` serve odd heights by splitting
one buffer into odd/even-address halves on different ports (Fig. 7b); the
split buffer momentarily gets *more* than its required throughput
(``2*N_b/(N_b+1)`` reads/compute-cycle), the surplus is returned to the other
streams by backpressure-driven adaptive slot allocation.
"""

from __future__ import annotations

import dataclasses
import math
from fractions import Fraction


N_PORTS = 2  # dual-port BRAM


def virtual_ports(r_f: float, n_ports: int = N_PORTS) -> int:
    """Virtual ports exposed to the compute domain."""
    return math.floor(n_ports * r_f + 1e-9)


def max_bin_height(r_f: float, n_ports: int = N_PORTS) -> int:
    """Largest bin height sustainable without throughput loss (Eq. 2)."""
    return virtual_ports(r_f, n_ports)


def required_rf(h_b: int, n_ports: int = N_PORTS) -> Fraction:
    """Minimum frequency ratio for bin height ``h_b`` (Eq. 2 inverted).

    h_b=4 -> 2 (paper's P4 experiments); h_b=3 -> 3/2 (P3, fractional).
    """
    if h_b < 1:
        raise ValueError("bin height must be >= 1")
    return Fraction(h_b, n_ports)


def needs_odd_even_split(h_b: int, n_ports: int = N_PORTS) -> bool:
    """Odd heights > 1 need the Fig. 7b odd/even address split + DWCs."""
    return h_b > 1 and (h_b % n_ports) != 0


def reads_per_compute_cycle(h_b: int, r_f: float, n_ports: int = N_PORTS) -> float:
    """Per-buffer readback rate seen by compute, w/o backpressure (Fig. 7)."""
    if h_b <= 0:
        raise ValueError("empty bin")
    return n_ports * r_f / h_b


def split_buffer_rate(n_b: int) -> Fraction:
    """Rate of the odd/even-split buffer at R_F = N_b/2 (Fig. 7b): the split
    buffer is read on both ports, 2*N_b/(N_b+1) reads per compute cycle."""
    return Fraction(2 * n_b, n_b + 1)


@dataclasses.dataclass(frozen=True)
class GalsOperatingPoint:
    """An implemented design point (Table V row)."""

    f_compute_mhz: float  # achieved compute clock
    f_memory_mhz: float  # achieved memory clock
    h_b: int  # max bin height in the packing
    f_compute_baseline_mhz: float  # non-packed baseline compute clock

    @property
    def r_f(self) -> float:
        return self.f_memory_mhz / self.f_compute_mhz

    @property
    def effective_rate_mhz(self) -> float:
        """Pipeline rate: compute is throttled to the slower of its own clock
        and the packed memory's per-buffer delivery rate (paper Table V:
        min(F_c, F_m/2) for H_B=4)."""
        delivery = N_PORTS * self.f_memory_mhz / self.h_b
        return min(self.f_compute_mhz, delivery)

    @property
    def delta_fps(self) -> float:
        """Relative throughput reduction vs the non-packed baseline."""
        return 1.0 - self.effective_rate_mhz / self.f_compute_baseline_mhz

    @property
    def throughput_preserved(self) -> bool:
        return self.r_f + 1e-9 >= self.h_b / N_PORTS


def folding_delta_fps(fold_factor: int) -> float:
    """The alternative the paper compares against: F2 folding halves
    per-cycle parallelism -> ~(1 - 1/fold) throughput loss at equal clocks."""
    return 1.0 - 1.0 / fold_factor
