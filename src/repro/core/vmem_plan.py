"""TPU adaptation of FCMP: bank-packing packed-weight blocks into VMEM.

On TPU the "fixed-geometry memory" is the (8, 128)-tiled VMEM allocation: a
weight block of logical shape (r, c) at b bits/weight occupies
ceil(r/8)*ceil(c/128) tiles regardless of how oddly it is shaped — exactly
the BRAM aspect-ratio mismatch of the paper, one level down the hierarchy.

``plan_vmem_residency`` packs the per-layer packed weight blocks of a model
into a VMEM budget, producing a *residency schedule*: which blocks co-reside
per pipeline step (the analogue of buffers co-located in one BRAM), and what
fraction of weight traffic is served from VMEM vs re-streamed from HBM. The
"frequency compensation" term is the HBM->VMEM bandwidth surplus left by
bit-packing (1/2-bit weights move 8-16x fewer bytes than bf16).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.packing import PackItem, Packing, pack_ffd
from repro.core.buffers import WeightBuffer
from repro.core.resource_model import TPU_V5E, TpuChip, RamPrimitive


@dataclasses.dataclass(frozen=True)
class WeightBlock:
    """One layer's packed weight tensor on a single device."""

    name: str
    rows: int  # reduction dim (already sharded)
    cols: int  # output dim (already sharded)
    bits_per_weight: int

    @property
    def logical_bytes(self) -> int:
        return self.rows * self.cols * self.bits_per_weight // 8

    def padded_bytes(self, chip: TpuChip = TPU_V5E) -> int:
        """Bytes after (8,128) tile padding of the *packed* int8 carrier.

        Packing along rows: 8/bits weights per int8 byte along the reduction
        dim, so the carrier is (rows*bits/8, cols) int8.
        """
        carrier_rows = math.ceil(self.rows * self.bits_per_weight / 8)
        return chip.tile_blocks_for(carrier_rows, self.cols) * chip.sublane * chip.lane

    def packing_efficiency(self, chip: TpuChip = TPU_V5E) -> float:
        return self.logical_bytes / max(1, self.padded_bytes(chip))


@dataclasses.dataclass(frozen=True)
class ResidencyPlan:
    blocks: tuple[WeightBlock, ...]
    resident: tuple[bool, ...]  # True = pinned in VMEM for the whole step
    vmem_budget_bytes: int

    @property
    def resident_bytes(self) -> int:
        return sum(
            b.padded_bytes() for b, r in zip(self.blocks, self.resident) if r
        )

    @property
    def streamed_bytes(self) -> int:
        """HBM bytes re-read per step for non-resident blocks."""
        return sum(
            b.padded_bytes() for b, r in zip(self.blocks, self.resident) if not r
        )

    @property
    def hbm_traffic_reduction(self) -> float:
        total = sum(b.padded_bytes() for b in self.blocks)
        return 1.0 - self.streamed_bytes / max(1, total)


def plan_vmem_residency(
    blocks: Sequence[WeightBlock],
    vmem_budget_bytes: int,
    reserve_frac: float = 0.5,
) -> ResidencyPlan:
    """Greedy knapsack by (bytes saved / VMEM used) = 1, i.e. by reuse value:
    smaller blocks with worse tile-padding efficiency benefit most from
    pinning (they're the 'oddly shaped buffers' of the paper)."""
    budget = int(vmem_budget_bytes * (1.0 - reserve_frac))
    # value: HBM bytes avoided per VMEM byte spent is 1 for all; prefer
    # blocks with the worst per-byte padding efficiency first (they pay the
    # padding once in VMEM instead of on every HBM stream), then smallest.
    order = sorted(
        range(len(blocks)),
        key=lambda i: (blocks[i].packing_efficiency(), blocks[i].padded_bytes()),
    )
    resident = [False] * len(blocks)
    used = 0
    for i in order:
        b = blocks[i].padded_bytes()
        if used + b <= budget:
            resident[i] = True
            used += b
    return ResidencyPlan(tuple(blocks), tuple(resident), vmem_budget_bytes)


def blocks_from_buffers(
    buffers: Sequence[WeightBuffer], rows_of: dict[str, tuple[int, int]]
) -> list[WeightBlock]:
    return [
        WeightBlock(b.name, *rows_of[b.name], bits_per_weight=b.w_bits)
        for b in buffers
    ]


# --------------------------------------------------------------------------
# core.packing bridge: VMEM tiles as a RamPrimitive
# --------------------------------------------------------------------------


def vmem_tile_ram(chip: TpuChip = TPU_V5E) -> RamPrimitive:
    """One (sublane, lane) VMEM tile of the int8 carrier as a RAM primitive.

    A carrier column is ``lane`` bytes wide (8 bits each) and a tile holds
    ``sublane`` carrier rows, so ``blocks_for(cols*8, carrier_rows)`` equals
    ``chip.tile_blocks_for(carrier_rows, cols)`` exactly — the bridge that
    lets the paper's bin-packing solvers run over TPU weight blocks.
    """
    return RamPrimitive(
        name=f"VMEM_TILE_{chip.name}",
        capacity_bits=chip.sublane * chip.lane * 8,
        n_ports=2,
        configs=((chip.lane * 8, chip.sublane),),
    )


def block_item(
    block: WeightBlock, chip: TpuChip = TPU_V5E, region: str = ""
) -> PackItem:
    """A WeightBlock's packed int8 carrier as a packable buffer.

    width = cols * 8 bits (one carrier byte per output channel),
    depth = carrier rows (= ceil(rows * bits / 8)).
    """
    carrier_rows = math.ceil(block.rows * block.bits_per_weight / 8)
    buf = WeightBuffer(
        block.name,
        width_bits=block.cols * 8,
        depth_words=carrier_rows,
        w_bits=block.bits_per_weight,
    )
    return PackItem(buf, region=region)


def pack_blocks(
    blocks: Sequence[WeightBlock],
    *,
    chip: TpuChip = TPU_V5E,
    max_height: int = 4,
    solver: str = "ffd",
    regions: Sequence[str] | None = None,
) -> Packing:
    """Bin-pack weight-block carriers into shared VMEM tile groups.

    Co-locating oddly-shaped blocks in one tile bin recovers the (8, 128)
    padding waste the same way FCMP recovers BRAM aspect-ratio waste —
    ``Packing.total_blocks`` is the tile count of the packed layout, and
    ``Packing.efficiency`` is paper Eq. 1 over VMEM tiles.
    """
    from repro.core.packing import SOLVERS

    items = [
        block_item(b, chip, region=(regions[i] if regions else ""))
        for i, b in enumerate(blocks)
    ]
    ram = vmem_tile_ram(chip)
    return SOLVERS[solver](items, max_height, ram)
