"""Folding-solution search (paper §III-B "modelling exercise").

Chooses per-layer (PE, SIMD) to maximise pipeline throughput subject to a
device's LUT/BRAM budget: iteratively doubles the parallelism of the
slowest stage (largest II) while resources allow — the standard FINN
balancing strategy.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.buffers import Folding, LayerSpec, mvau_buffer, mvau_cycles
from repro.core.dataflow import PipelineModel
from repro.core.resource_model import BRAM18, FpgaDevice

# Calibrated MVAU compute cost: LUTs per (PE x SIMD) lane for low-precision
# (XNOR-popcount style) arithmetic, incl. accumulators + thresholding.
LUT_PER_LANE_W1 = 5.5
LUT_PER_LANE_W2 = 9.0


def mvau_luts(layer: LayerSpec, f: Folding) -> float:
    per_lane = LUT_PER_LANE_W1 if layer.w_bits == 1 else LUT_PER_LANE_W2
    return per_lane * f.pe * f.simd + 120.0  # fixed control overhead


@dataclasses.dataclass
class FoldingSolution:
    layers: list[LayerSpec]
    foldings: list[Folding]

    def model(self, f_mhz: float) -> PipelineModel:
        return PipelineModel(tuple(self.layers), tuple(self.foldings), f_mhz)

    @property
    def luts(self) -> float:
        return sum(mvau_luts(l, f) for l, f in zip(self.layers, self.foldings))

    @property
    def brams(self) -> int:
        return sum(
            mvau_buffer(l, f).blocks(BRAM18)
            for l, f in zip(self.layers, self.foldings)
        )


def _grow_options(layer: LayerSpec, f: Folding) -> list[Folding]:
    """Legal parallelism-doubling moves for one layer."""
    opts = []
    if (layer.c_out // f.pe) % 2 == 0:
        opts.append(Folding(f.pe * 2, f.simd))
    fold_in = layer.k * layer.k * layer.c_in
    if (fold_in // f.simd) % 2 == 0:
        opts.append(Folding(f.pe, f.simd * 2))
    return opts


def search_folding(
    layers: Sequence[LayerSpec],
    device: FpgaDevice,
    lut_budget_frac: float = 0.7,
    bram_budget_frac: float = 0.9,
    target_ii: int | None = None,
) -> FoldingSolution:
    """Greedy throughput-balancing folding search.

    Repeatedly doubles parallelism of the current bottleneck layer while the
    design fits ``lut_budget_frac`` of LUTs and ``bram_budget_frac`` of
    BRAM18s (OCM is the expected bottleneck, paper Table I).
    """
    sol = FoldingSolution(list(layers), [Folding(1, 1) for _ in layers])
    lut_budget = device.luts * lut_budget_frac
    bram_budget = device.bram18 * bram_budget_frac
    while True:
        cycles = [mvau_cycles(l, f) for l, f in zip(sol.layers, sol.foldings)]
        worst = max(range(len(cycles)), key=lambda i: cycles[i])
        if target_ii is not None and cycles[worst] <= target_ii:
            return sol
        layer, f = sol.layers[worst], sol.foldings[worst]
        grown = False
        for cand in _grow_options(layer, f):
            old = sol.foldings[worst]
            sol.foldings[worst] = cand
            if sol.luts <= lut_budget and sol.brams <= bram_budget:
                grown = True
                break
            sol.foldings[worst] = old
        if not grown:
            # bottleneck layer cannot grow: try the next-worst layers once,
            # else stop — pipeline is resource-bound.
            order = sorted(range(len(cycles)), key=lambda i: -cycles[i])
            for i in order[1:]:
                for cand in _grow_options(sol.layers[i], sol.foldings[i]):
                    old = sol.foldings[i]
                    sol.foldings[i] = cand
                    if (
                        sol.luts <= lut_budget
                        and sol.brams <= bram_budget
                        and mvau_cycles(sol.layers[i], cand) >= cycles[worst] // 4
                    ):
                        grown = True
                        break
                    sol.foldings[i] = old
                if grown:
                    break
            if not grown:
                return sol
