"""Layer descriptions of the paper's accelerators: CNV (BNN-Pynq) and
quantized ResNet-50 v1.5 — expressed as FINN MVAU layer sets for the
resource/packing/performance models.

CNV (FINN / BNN-Pynq): 6 valid 3x3 convs (64,64,128,128,256,256) with two
2x2 maxpools, then FC 256->512->512->10. Input 32x32 CIFAR-10.
Spatial trace: 32-30-28 |pool| 14-12-10 |pool| 5-3-1.

ResNet-50 v1.5: 7x7/64 stem; 4 stages of [3,4,6,3] bottleneck ResBlocks
(1x1 -> 3x3 -> 1x1 with 4x expansion; 1x1 downsample on the first block of
each stage); 16 ResBlocks total, matching the paper's description (§III).
Weights inside ResBlocks are W (1 or 2) bits; first/last layers 8 bit.
"""

from __future__ import annotations

from repro.core.buffers import LayerSpec


def cnv_layers(w_bits: int = 1) -> list[LayerSpec]:
    spec = [
        # name,            c_in, c_out, k, out_hw
        ("conv0", 3, 64, 3, 30),
        ("conv1", 64, 64, 3, 28),
        ("conv2", 64, 128, 3, 12),
        ("conv3", 128, 128, 3, 10),
        ("conv4", 128, 256, 3, 3),
        ("conv5", 256, 256, 3, 1),
        ("fc0", 256, 512, 1, 1),
        ("fc1", 512, 512, 1, 1),
        ("fc2", 512, 10, 1, 1),
    ]
    # first layer inputs are 8-bit images but weights follow the W1/W2 scheme
    # in BNN-Pynq (all layers binarized/ternarized).
    return [
        LayerSpec(n, ci, co, k, hw * hw, w_bits) for n, ci, co, k, hw in spec
    ]


def resnet50_layers(w_bits: int = 1, include_top_bottom: bool = False) -> list[LayerSpec]:
    """ResBlock convolutions of ResNet-50 v1.5 (paper packs only these;
    stem + final FC are excluded from packing, §V)."""
    layers: list[LayerSpec] = []
    if include_top_bottom:
        layers.append(LayerSpec("stem_conv7x7", 3, 64, 7, 112 * 112, 8))
    stages = [
        # (n_blocks, c_mid, c_out, spatial_out)
        (3, 64, 256, 56),
        (4, 128, 512, 28),
        (6, 256, 1024, 14),
        (3, 512, 2048, 7),
    ]
    c_in = 64
    for s, (n_blocks, c_mid, c_out, hw) in enumerate(stages):
        for b in range(n_blocks):
            px = hw * hw
            pfx = f"s{s}b{b}"
            layers.append(LayerSpec(f"{pfx}_c1x1a", c_in, c_mid, 1, px, w_bits))
            layers.append(LayerSpec(f"{pfx}_c3x3", c_mid, c_mid, 3, px, w_bits))
            layers.append(LayerSpec(f"{pfx}_c1x1b", c_mid, c_out, 1, px, w_bits))
            if b == 0:
                layers.append(
                    LayerSpec(f"{pfx}_c1x1ds", c_in, c_out, 1, px, w_bits)
                )
            c_in = c_out
    if include_top_bottom:
        layers.append(LayerSpec("fc", 2048, 1000, 1, 1, 8))
    return layers


def resblock_slr_map(layers: list[LayerSpec], n_slr: int) -> list[str]:
    """Assign ResBlock layers to SLRs by contiguous pipeline order with
    per-SLR parameter-bit balancing — mirrors the paper's Alveo floorplan
    (Fig. 5), where packing may only group buffers within one SLR."""
    total_bits = sum(l.param_bits for l in layers)
    target = total_bits / n_slr
    regions, acc, slr = [], 0, 0
    for l in layers:
        regions.append(f"slr{slr}")
        acc += l.param_bits
        if acc > target * (slr + 1) and slr < n_slr - 1:
            slr += 1
    return regions
