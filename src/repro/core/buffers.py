"""Logical weight-buffer shape derivation for FINN-style dataflow layers.

In a FINN Matrix-Vector-Activation Unit (MVAU) the weight memory shape is a
*function of the folding*, not only of the parameter count (paper §II-B):

    width_bits  = PE * SIMD * W
    depth_words = (K^2 * C / SIMD) * (F / PE)

so doubling compute parallelism halves depth and doubles width, which maps
progressively worse onto fixed 1024x18 BRAMs (paper Fig. 2). This module
derives the logical buffer set of an accelerator from its topology + folding.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.resource_model import BRAM18, RamPrimitive


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One dataflow compute layer (conv expressed as matrix-vector).

    For a conv layer: ``c_in`` input channels, ``c_out`` filters, ``k`` kernel
    dim, ``out_pixels`` output spatial positions (H_out*W_out). An FC layer is
    k=1, out_pixels=1.
    """

    name: str
    c_in: int
    c_out: int
    k: int = 1
    out_pixels: int = 1
    w_bits: int = 1  # weight precision

    @property
    def n_params(self) -> int:
        return self.k * self.k * self.c_in * self.c_out

    @property
    def param_bits(self) -> int:
        return self.n_params * self.w_bits

    @property
    def macs(self) -> int:
        """MACs per inference for this layer."""
        return self.n_params * self.out_pixels


@dataclasses.dataclass(frozen=True)
class Folding:
    """FINN folding solution for one layer: PE filters x SIMD inputs / cycle."""

    pe: int
    simd: int

    def validate(self, layer: LayerSpec) -> None:
        if layer.c_out % self.pe != 0:
            raise ValueError(
                f"{layer.name}: PE={self.pe} must divide c_out={layer.c_out}"
            )
        fold_in = layer.k * layer.k * layer.c_in
        if fold_in % self.simd != 0:
            raise ValueError(
                f"{layer.name}: SIMD={self.simd} must divide K^2*C={fold_in}"
            )


@dataclasses.dataclass(frozen=True)
class WeightBuffer:
    """A logical weight memory: what packing operates on."""

    name: str
    width_bits: int
    depth_words: int
    w_bits: int  # precision of the packed weights (for efficiency accounting)

    @property
    def bits(self) -> int:
        return self.width_bits * self.depth_words

    def blocks(self, ram: RamPrimitive = BRAM18) -> int:
        return ram.blocks_for(self.width_bits, self.depth_words)

    def efficiency(self, ram: RamPrimitive = BRAM18) -> float:
        return ram.efficiency_for(self.width_bits, self.depth_words)


def mvau_buffer(layer: LayerSpec, folding: Folding) -> WeightBuffer:
    """Weight buffer of an MVAU at the given folding (paper §II-B(a))."""
    folding.validate(layer)
    width = folding.pe * folding.simd * layer.w_bits
    depth = (layer.k * layer.k * layer.c_in // folding.simd) * (
        layer.c_out // folding.pe
    )
    return WeightBuffer(layer.name, width, depth, layer.w_bits)


def mvau_cycles(layer: LayerSpec, folding: Folding) -> int:
    """Initiation interval (cycles per inference) of an MVAU."""
    folds = (layer.k * layer.k * layer.c_in // folding.simd) * (
        layer.c_out // folding.pe
    )
    return folds * layer.out_pixels


def buffer_set(
    layers: Iterable[LayerSpec], foldings: Iterable[Folding]
) -> list[WeightBuffer]:
    return [mvau_buffer(l, f) for l, f in zip(layers, foldings, strict=True)]


def kernel_efficiency_bound(k: int) -> float:
    """Paper §II-B(b): best-case efficiency from odd kernel sizes alone.

    Buffer depths are multiples of K^2; with power-of-two RAM depths the
    ceiling is K^2 / 2^ceil(log2(K^2)).
    """
    k2 = k * k
    return k2 / (2 ** math.ceil(math.log2(k2)))
