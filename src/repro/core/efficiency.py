"""OCM mapping-efficiency reports (paper Eq. 1, Tables I/IV)."""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.buffers import WeightBuffer
from repro.core.packing import PackItem, Packing, baseline_packing
from repro.core.resource_model import BRAM18, FpgaDevice, RamPrimitive, fcmp_lut_overhead


@dataclasses.dataclass(frozen=True)
class MemSubsystemReport:
    """One row of Table IV."""

    name: str
    n_buffers: int
    brams: int
    efficiency: float  # E, Eq. 1
    lut_overhead: float
    max_height: int
    odd_height_bins: int

    def row(self) -> str:
        return (
            f"{self.name:28s} {self.n_buffers:5d} {self.brams:6d} "
            f"{100*self.efficiency:6.1f}% {self.lut_overhead/1000:7.1f}k "
            f"H_B={self.max_height}"
        )


def report(name: str, packing: Packing, ram: RamPrimitive = BRAM18) -> MemSubsystemReport:
    heights = packing.heights
    max_h = max(heights) if heights else 0
    odd = packing.odd_height_bins
    # the odd/even split applies to one buffer per odd bin; its stream width
    # bounds the DWC cost
    widths = packing.bin_widths_bits()
    odd_w = max(
        (w for w, b in zip(widths, packing.bins) if len(b) > 1 and len(b) % 2 == 1),
        default=0,
    )
    lut = fcmp_lut_overhead(widths, heights, odd, odd_w)
    return MemSubsystemReport(
        name=name,
        n_buffers=len(packing.items),
        brams=packing.total_blocks,
        efficiency=packing.efficiency,
        lut_overhead=lut,
        max_height=max_h,
        odd_height_bins=odd,
    )


def baseline_report(
    name: str, buffers: Sequence[WeightBuffer], ram: RamPrimitive = BRAM18
) -> MemSubsystemReport:
    items = [PackItem(b) for b in buffers]
    return report(name, baseline_packing(items, ram), ram)


def device_utilization(
    dev: FpgaDevice, brams: int, luts: float
) -> dict[str, float]:
    return {
        "bram_pct": 100.0 * brams / dev.bram18,
        "lut_pct": 100.0 * luts / dev.luts,
        "fits": brams <= dev.bram18 and luts <= dev.luts,
    }
