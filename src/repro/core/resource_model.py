"""FPGA / TPU memory-resource models for FCMP.

This module reproduces the *physical memory geometry* side of the paper:

- Xilinx Block RAM (BRAM18): an 18 Kib dual-port SRAM primitive whose legal
  aspect-ratio configurations are fixed by the fabric (1x16384 ... 36x512).
  Mapping an arbitrarily shaped logical buffer (width_bits x depth_words) onto
  these fixed shapes is what wastes OCM (paper Eq. 1, Fig. 2).
- UltraRAM (URAM): 288 Kib, fixed 72x4096, used by the paper for activations
  and the final FC layer.
- A device catalog (Zynq 7020 / 7012S, Alveo U250 / U280) with the resource
  counts used in the paper's porting experiments, plus TPU v5e as the
  adaptation target (HBM/VMEM geometry for the packed-weight analogue).
- A calibrated LUT-overhead model for the GALS weight streamers, data-width
  converters and clock-domain-crossing FIFOs introduced by FCMP (Table IV).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence


# --------------------------------------------------------------------------
# RAM primitives
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RamPrimitive:
    """A fixed-geometry on-chip RAM block.

    ``configs`` is the set of legal (width_bits, depth_words) aspect ratios
    the primitive supports; ``capacity_bits`` is identical across configs.
    """

    name: str
    capacity_bits: int
    n_ports: int
    configs: tuple[tuple[int, int], ...]

    def blocks_for(self, width_bits: int, depth_words: int) -> int:
        """Physical blocks needed for one logical buffer, best legal config.

        Mirrors how synthesis tools map a logical memory: pick the aspect
        ratio minimising ceil(w/W) * ceil(d/D).
        """
        if width_bits <= 0 or depth_words <= 0:
            return 0
        best = None
        for w_cfg, d_cfg in self.configs:
            n = math.ceil(width_bits / w_cfg) * math.ceil(depth_words / d_cfg)
            best = n if best is None else min(best, n)
        assert best is not None
        return best

    def efficiency_for(self, width_bits: int, depth_words: int) -> float:
        """Mapping efficiency of a single buffer (paper Eq. 1, one buffer)."""
        n = self.blocks_for(width_bits, depth_words)
        if n == 0:
            return 1.0
        return (width_bits * depth_words) / (n * self.capacity_bits)


# Xilinx 18 Kib BRAM: true-dual-port widths up to 18; the 36-wide config is
# the simple-dual-port mode (one R + one W port). For weight memories
# (read-only at inference) SDP is legal, so 36x512 is included.
BRAM18 = RamPrimitive(
    name="BRAM18",
    capacity_bits=18 * 1024,
    n_ports=2,
    configs=((1, 16384), (2, 8192), (4, 4096), (9, 2048), (18, 1024), (36, 512)),
)

# UltraRAM: fixed 72x4096, 2 ports.
URAM = RamPrimitive(
    name="URAM",
    capacity_bits=288 * 1024,
    n_ports=2,
    configs=((72, 4096),),
)


# --------------------------------------------------------------------------
# Devices
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FpgaDevice:
    name: str
    luts: int
    bram18: int
    uram: int
    dsp: int
    slrs: int = 1
    # Nominal achievable clock for BRAM primitives vs compiled dataflow
    # compute logic (paper section IV: memory primitives are specified for
    # >600 MHz while HLS compute closes at 100-300 MHz).
    f_mem_max_mhz: float = 600.0
    f_compute_typ_mhz: float = 200.0

    @property
    def ocm_bits(self) -> int:
        return self.bram18 * BRAM18.capacity_bits + self.uram * URAM.capacity_bits


# Resource counts per Xilinx data sheets (DS190, DS962, U250/U280 product
# briefs). BRAM is counted in 18 Kib units (1 BRAM36 = 2 BRAM18).
DEVICES: dict[str, FpgaDevice] = {
    "zynq7020": FpgaDevice("zynq7020", luts=53_200, bram18=280, uram=0, dsp=220),
    "zynq7012s": FpgaDevice("zynq7012s", luts=34_400, bram18=144, uram=0, dsp=120),
    "u250": FpgaDevice(
        "u250", luts=1_728_000, bram18=5376, uram=1280, dsp=12_288, slrs=4
    ),
    "u280": FpgaDevice(
        "u280", luts=1_304_000, bram18=4032, uram=960, dsp=9024, slrs=3
    ),
}


@dataclasses.dataclass(frozen=True)
class TpuChip:
    """TPU v5e — the adaptation target for the packed-weight analogue."""

    name: str = "tpu_v5e"
    peak_bf16_flops: float = 197e12
    hbm_bytes: int = 16 * 1024**3
    hbm_bw: float = 819e9
    vmem_bytes: int = 128 * 1024**2
    ici_bw_per_link: float = 50e9
    ici_links: int = 4
    # MXU/VPU native tile granularity: packed weight blocks are padded to
    # (sublane, lane) = (8, 128) multiples, the TPU's "fixed geometry" that
    # plays the role BRAM aspect ratios play on FPGA.
    sublane: int = 8
    lane: int = 128

    def tile_blocks_for(self, rows: int, cols: int) -> int:
        return math.ceil(rows / self.sublane) * math.ceil(cols / self.lane)


TPU_V5E = TpuChip()

# The TPU porting ladder (the paper's §V question, one level up the
# hierarchy): can a model + traffic profile be ported from a bigger chip
# to a smaller/cheaper tier, and at what throughput loss? VMEM is the
# fixed-size "OCM" every tier shares; the tiers differ in HBM bandwidth
# and peak compute, so a port that streams more weight bytes per step
# (smaller resident set) degrades exactly where hbm_bw is scarce.
TPU_V4 = TpuChip(
    name="tpu_v4",
    peak_bf16_flops=275e12,
    hbm_bytes=32 * 1024**3,
    hbm_bw=1228e9,
    vmem_bytes=128 * 1024**2,
    ici_bw_per_link=50e9,
    ici_links=6,
)
TPU_V5P = TpuChip(
    name="tpu_v5p",
    peak_bf16_flops=459e12,
    hbm_bytes=95 * 1024**3,
    hbm_bw=2765e9,
    vmem_bytes=128 * 1024**2,
    ici_bw_per_link=90e9,
    ici_links=6,
)
# Ordered small -> large by (hbm_bw, flops): the porting sweep walks this
# ladder the way the paper walks 7020 -> 7012S / U250 -> U280.
TPU_TIERS: dict[str, TpuChip] = {
    "v5e": TPU_V5E,
    "v4": TPU_V4,
    "v5p": TPU_V5P,
}


# --------------------------------------------------------------------------
# FCMP LUT-overhead model
# --------------------------------------------------------------------------

# The GALS transformation (paper Fig. 6) adds, per packed memory bin:
#   * a weight streamer: address generator + round-robin port scheduler,
#   * one AXI-stream CDC FIFO per logical buffer (width-proportional),
#   * for odd bin heights, data-width converters (DWC) on the split buffer.
# The constants below are calibrated against Table IV:
#   CNV-W1A1-P4:  96 bins  -> 3.9 kLUT      CNV-W2A2-P4: 188 bins -> 1.8 kLUT*
#   RN50-U250-P4: 1632 bins -> 51.9 kLUT    RN50-U250-P3: 1804 -> 64.9 kLUT
# (*packed CNV-W2A2 shares streamers across nearly-full bins; the paper's
# numbers bound our model from below/above; we target the RN50-scale fit,
# which dominates any real design decision.)

LUT_PER_STREAMER = 18.0  # address gen + scheduler per occupied bin
LUT_PER_BUFFER = 9.0  # stream decoupling / tagging per logical buffer
LUT_PER_FIFO_BIT = 0.45  # CDC FIFO cost per bit of stream width
LUT_PER_DWC_BIT = 1.1  # data width converter per bit (odd heights only)


def fcmp_lut_overhead(
    bin_widths_bits: Sequence[int],
    buffers_per_bin: Sequence[int],
    odd_height_bins: int = 0,
    odd_split_width_bits: int = 0,
) -> float:
    """Estimate LUT overhead of the packed memory subsystem (Table IV)."""
    assert len(bin_widths_bits) == len(buffers_per_bin)
    luts = 0.0
    for w, nb in zip(bin_widths_bits, buffers_per_bin):
        if nb <= 1:
            # A lone buffer keeps the plain (non-GALS) streamer: no overhead.
            continue
        luts += LUT_PER_STREAMER
        luts += LUT_PER_BUFFER * nb
        luts += LUT_PER_FIFO_BIT * w * nb
    luts += LUT_PER_DWC_BIT * odd_split_width_bits * odd_height_bins
    return luts
