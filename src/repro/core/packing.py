"""Buffer-to-BRAM bin packing (paper §II-C, §IV; GA of Kroes et al. [18]).

A *bin* is a packed physical memory structure holding up to ``H_B`` logical
buffers, all streamed through the structure's two physical ports. FCMP makes
``H_B > 2`` legal by overclocking the memory domain (see ``gals.py``); this
module finds the assignment of buffers to bins that minimises physical BRAM
count, i.e. maximises paper Eq. 1 efficiency.

Three solvers are provided, in increasing quality order:
  * ``pack_ffd``      — first-fit-decreasing baseline,
  * ``pack_anneal``   — simulated annealing (MPack [20] style),
  * ``pack_genetic``  — tournament GA with the paper's Table III
                        hyperparameters (population 50/75, tournament 5,
                        admission/mutation probabilities).

Buffers may carry a ``region`` tag (SLR on Alveo, or TPU core); bins never mix
regions — matching the paper's floorplan-constrained inter-layer packing.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Sequence

from repro.core.buffers import WeightBuffer
from repro.core.resource_model import BRAM18, RamPrimitive


@dataclasses.dataclass(frozen=True)
class PackItem:
    """A buffer plus packing metadata."""

    buffer: WeightBuffer
    region: str = ""

    @property
    def width(self) -> int:
        return self.buffer.width_bits

    @property
    def depth(self) -> int:
        return self.buffer.depth_words


def bin_cost(
    items: Sequence[PackItem], ram: RamPrimitive = BRAM18
) -> tuple[int, str]:
    """Physical blocks for one bin and the chosen layout.

    Horizontal co-location stacks buffers along the address space
    (width = max, depth = sum); vertical concatenates words
    (width = sum, depth = max). Synthesis picks whichever is cheaper.
    """
    if not items:
        return 0, "empty"
    if len(items) == 1:
        return items[0].buffer.blocks(ram), "single"
    w = [it.width for it in items]
    d = [it.depth for it in items]
    cost_h = ram.blocks_for(max(w), sum(d))
    cost_v = ram.blocks_for(sum(w), max(d))
    if cost_v < cost_h:
        return cost_v, "vertical"
    return cost_h, "horizontal"


@dataclasses.dataclass
class Packing:
    """A full packing solution: list of bins, each a list of item indices."""

    items: list[PackItem]
    bins: list[list[int]]
    ram: RamPrimitive = BRAM18

    def validate(self, max_height: int) -> None:
        seen: set[int] = set()
        for b in self.bins:
            if len(b) > max_height:
                raise ValueError(f"bin height {len(b)} > H_B={max_height}")
            regions = {self.items[i].region for i in b}
            if len(regions) > 1:
                raise ValueError(f"bin mixes regions {regions}")
            seen.update(b)
        if seen != set(range(len(self.items))):
            raise ValueError("packing is not a partition of the items")

    @property
    def total_blocks(self) -> int:
        return sum(bin_cost([self.items[i] for i in b], self.ram)[0] for b in self.bins)

    @property
    def efficiency(self) -> float:
        """Paper Eq. 1: useful parameter bits / physical RAM bits."""
        useful = sum(it.buffer.bits for it in self.items)
        blocks = self.total_blocks
        if blocks == 0:
            return 1.0
        return useful / (blocks * self.ram.capacity_bits)

    @property
    def heights(self) -> list[int]:
        return [len(b) for b in self.bins]

    @property
    def odd_height_bins(self) -> int:
        return sum(1 for b in self.bins if len(b) > 1 and len(b) % 2 == 1)

    def bin_widths_bits(self) -> list[int]:
        out = []
        for b in self.bins:
            its = [self.items[i] for i in b]
            _, layout = bin_cost(its, self.ram)
            if layout == "vertical":
                out.append(sum(it.width for it in its))
            else:
                out.append(max((it.width for it in its), default=0))
        return out


def baseline_packing(items: Sequence[PackItem], ram: RamPrimitive = BRAM18) -> Packing:
    """No packing: one buffer per memory structure (the FINN default)."""
    return Packing(list(items), [[i] for i in range(len(items))], ram)


# --------------------------------------------------------------------------
# First-fit decreasing
# --------------------------------------------------------------------------


def pack_ffd(
    items: Sequence[PackItem],
    max_height: int,
    ram: RamPrimitive = BRAM18,
) -> Packing:
    """First-fit-decreasing on buffer size; admits an item into the first bin
    where it reduces total block count versus opening a new bin."""
    order = sorted(range(len(items)), key=lambda i: -items[i].buffer.bits)
    bins: list[list[int]] = []
    bin_blocks: list[int] = []
    for i in order:
        it = items[i]
        solo = bin_cost([it], ram)[0]
        best_j, best_delta = -1, 0
        for j, b in enumerate(bins):
            if len(b) >= max_height:
                continue
            if items[b[0]].region != it.region:
                continue
            merged = bin_cost([items[k] for k in b] + [it], ram)[0]
            delta = merged - bin_blocks[j] - solo  # <0 means packing saves RAM
            if delta < best_delta:
                best_delta, best_j = delta, j
        if best_j >= 0:
            bins[best_j].append(i)
            bin_blocks[best_j] = bin_cost([items[k] for k in bins[best_j]], ram)[0]
        else:
            bins.append([i])
            bin_blocks.append(solo)
    p = Packing(list(items), bins, ram)
    p.validate(max_height)
    return p


# --------------------------------------------------------------------------
# Simulated annealing (MPack-style)
# --------------------------------------------------------------------------


def pack_anneal(
    items: Sequence[PackItem],
    max_height: int,
    ram: RamPrimitive = BRAM18,
    steps: int = 4000,
    t0: float = 2.0,
    seed: int = 0,
) -> Packing:
    rng = random.Random(seed)
    cur = pack_ffd(items, max_height, ram)
    bins = [list(b) for b in cur.bins]

    def cost_of(b: list[int]) -> int:
        return bin_cost([items[i] for i in b], ram)[0]

    costs = [cost_of(b) for b in bins]
    total = sum(costs)
    best_bins, best_total = [list(b) for b in bins], total
    n = len(items)
    for step in range(steps):
        t = t0 * (1.0 - step / steps) + 1e-6
        # move a random item to a random other bin (or a fresh bin)
        src = rng.randrange(len(bins))
        if not bins[src]:
            continue
        i = rng.choice(bins[src])
        dst = rng.randrange(len(bins) + 1)
        if dst == src:
            continue
        if dst < len(bins):
            if len(bins[dst]) >= max_height or (
                bins[dst] and items[bins[dst][0]].region != items[i].region
            ):
                continue
        old_src, old_dst = costs[src], costs[dst] if dst < len(bins) else 0
        new_src_bin = [k for k in bins[src] if k != i]
        new_dst_bin = (bins[dst] + [i]) if dst < len(bins) else [i]
        new_src, new_dst = cost_of(new_src_bin), cost_of(new_dst_bin)
        delta = (new_src + new_dst) - (old_src + old_dst)
        if delta <= 0 or rng.random() < math.exp(-delta / t):
            bins[src] = new_src_bin
            costs[src] = new_src
            if dst < len(bins):
                bins[dst] = new_dst_bin
                costs[dst] = new_dst
            else:
                bins.append(new_dst_bin)
                costs.append(new_dst)
            total += delta
            if total < best_total:
                best_total = total
                best_bins = [list(b) for b in bins if b]
    best_bins = [b for b in best_bins if b]
    p = Packing(list(items), best_bins, ram)
    p.validate(max_height)
    return p


# --------------------------------------------------------------------------
# Genetic algorithm (Kroes et al. [18]; paper Table III hyperparameters)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GaParams:
    """Table III. ``p_adm_w`` / ``p_adm_h`` are admission probabilities for
    width-increasing (vertical) and height-increasing (horizontal)
    co-locations during offspring repair; ``p_mut`` is per-gene mutation."""

    max_height: int = 4  # H_B
    population: int = 50  # N_p
    tournament: int = 5  # N_t
    p_adm_w: float = 0.0
    p_adm_h: float = 0.1
    p_mut: float = 0.3
    generations: int = 60
    seed: int = 0


GA_PARAMS_CNV = GaParams(population=50, p_mut=0.3)
GA_PARAMS_RN50 = GaParams(population=75, p_mut=0.4)


def _genome_cost(
    genome: list[int], items: Sequence[PackItem], ram: RamPrimitive, max_height: int
) -> int:
    groups: dict[int, list[int]] = {}
    for i, g in enumerate(genome):
        groups.setdefault(g, []).append(i)
    total = 0
    for b in groups.values():
        c, _ = bin_cost([items[i] for i in b], ram)
        total += c
        if len(b) > max_height:  # infeasible: heavy penalty
            total += 10_000 * (len(b) - max_height)
        if len({items[i].region for i in b}) > 1:
            total += 100_000
    return total


def pack_genetic(
    items: Sequence[PackItem],
    params: GaParams = GaParams(),
    ram: RamPrimitive = BRAM18,
) -> Packing:
    rng = random.Random(params.seed)
    n = len(items)
    if n == 0:
        return Packing([], [], ram)

    # Seed population: FFD solution + randomized variants.
    ffd = pack_ffd(items, params.max_height, ram)
    base = [0] * n
    for g, b in enumerate(ffd.bins):
        for i in b:
            base[i] = g

    def random_genome() -> list[int]:
        g = list(base)
        for i in range(n):
            if rng.random() < 0.5:
                g[i] = rng.randrange(n)
        return g

    pop = [list(base)] + [random_genome() for _ in range(params.population - 1)]
    fit = [_genome_cost(g, items, ram, params.max_height) for g in pop]

    def tournament() -> list[int]:
        cand = rng.sample(range(len(pop)), min(params.tournament, len(pop)))
        return pop[min(cand, key=lambda i: fit[i])]

    def repair(genome: list[int]) -> list[int]:
        """Greedy local repair with the paper's admission probabilities:
        try to merge under-full bins; admit width-growing merges with
        p_adm_w, height-growing merges with p_adm_h."""
        groups: dict[int, list[int]] = {}
        for i, g in enumerate(genome):
            groups.setdefault(g, []).append(i)
        # split over-full bins
        next_id = max(groups) + 1
        for g in list(groups):
            while len(groups[g]) > params.max_height:
                i = groups[g].pop()
                groups[next_id] = [i]
                next_id += 1
        # opportunistic merges of the two smallest bins in a region
        bins = list(groups.values())
        rng.shuffle(bins)
        merged: list[list[int]] = []
        for b in bins:
            placed = False
            for m in merged:
                if len(m) + len(b) > params.max_height:
                    continue
                if items[m[0]].region != items[b[0]].region:
                    continue
                c_sep = bin_cost([items[i] for i in m], ram)[0] + bin_cost(
                    [items[i] for i in b], ram
                )[0]
                c_mrg, layout = bin_cost([items[i] for i in m + b], ram)
                if c_mrg < c_sep:
                    m.extend(b)
                    placed = True
                    break
                # admission probabilities let the GA explore "paying" merges
                p = params.p_adm_w if layout == "vertical" else params.p_adm_h
                if c_mrg == c_sep and rng.random() < p:
                    m.extend(b)
                    placed = True
                    break
            if not placed:
                merged.append(list(b))
        out = [0] * n
        for g, b in enumerate(merged):
            for i in b:
                out[i] = g
        return out

    best_g, best_f = min(zip(pop, fit), key=lambda t: t[1])
    for _gen in range(params.generations):
        new_pop: list[list[int]] = []
        while len(new_pop) < params.population:
            a, b = tournament(), tournament()
            child = [a[i] if rng.random() < 0.5 else b[i] for i in range(n)]
            for i in range(n):
                if rng.random() < params.p_mut / n * 10:  # a few genes per child
                    child[i] = rng.randrange(n)
            child = repair(child)
            new_pop.append(child)
        pop = new_pop
        fit = [_genome_cost(g, items, ram, params.max_height) for g in pop]
        gbest, fbest = min(zip(pop, fit), key=lambda t: t[1])
        if fbest < best_f:
            best_g, best_f = list(gbest), fbest
        # elitism
        worst = max(range(len(pop)), key=lambda i: fit[i])
        pop[worst], fit[worst] = list(best_g), best_f

    groups: dict[int, list[int]] = {}
    for i, g in enumerate(best_g):
        groups.setdefault(g, []).append(i)
    p = Packing(list(items), [b for b in groups.values() if b], ram)
    p.validate(params.max_height)
    return p


SOLVERS: dict[str, Callable[..., Packing]] = {
    "ffd": pack_ffd,
    "anneal": pack_anneal,
}
