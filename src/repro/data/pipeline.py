"""Synthetic data pipelines with checkpointable, deterministic state.

Every batch is a pure function of ``(seed, step)`` — the pipeline "state"
is just the step counter, so capturing it in the checkpoint gives exact
resume-after-preemption (tested in tests/test_runtime.py). No dataset files
ship with the repo; token streams are Zipf-distributed (vocab-shaped) and
image batches are CIFAR-shaped Gaussians with class-conditional means so a
small CNN can actually descend on them.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(step=int(d["step"]))


@dataclasses.dataclass
class TokenPipeline:
    """Next-token LM batches: {tokens (B, S), labels (B, S)} int32."""

    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    state: PipelineState = dataclasses.field(default_factory=PipelineState)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        # Zipf-ish marginal over the vocab (realistic embedding traffic)
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __next__(self) -> dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    def __iter__(self):
        return self


@dataclasses.dataclass
class CifarPipeline:
    """CIFAR-10-shaped synthetic classification batches (paper's CNV)."""

    batch: int
    n_classes: int = 10
    hw: int = 32
    seed: int = 0
    state: PipelineState = dataclasses.field(default_factory=PipelineState)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        labels = rng.integers(0, self.n_classes, size=(self.batch,))
        # class-conditional channel means make the task learnable
        means = np.linspace(-1.0, 1.0, self.n_classes)[labels]
        x = rng.normal(
            means[:, None, None, None], 1.0, (self.batch, self.hw, self.hw, 3)
        )
        return {
            "images": x.astype(np.float32),
            "labels": labels.astype(np.int32),
        }

    def __next__(self):
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    def __iter__(self):
        return self
