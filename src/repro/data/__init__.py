from repro.data.pipeline import (  # noqa: F401
    CifarPipeline,
    PipelineState,
    TokenPipeline,
)
