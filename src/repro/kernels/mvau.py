"""Pallas TPU kernel: fused MVAU — packed matmul + integer thresholding.

The FINN Matrix-Vector-Activation Unit is the paper's unit of dataflow
compute: matrix-vector product on packed low-bit weights followed by the
streamlined BN+activation as multi-threshold comparison (paper §III-B,
Fig. 6). Fusing the thresholding into the matmul epilogue means the f32
accumulator never leaves VMEM — only the A-bit activation levels are
written back, shrinking the activation-write roofline term by 8-16x
exactly as the streamlined FPGA datapath carries A-bit streams.

Thresholds (N, L) and channel signs (N,) arrive as a second packed memory,
mirroring the paper's threshold memories co-packed with weights.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.kernels.packed_matmul import _decode_block


def _mvau_kernel(
    x_ref, w_ref, t_ref, sg_ref, o_ref, acc_ref, *, bits, bk, bn, nk
):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decode_block(w_ref[...], bits, bk, bn)
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k_idx == nk - 1)
    def _threshold():
        acc = acc_ref[...] * sg_ref[...]  # (bm, bn) sign-canonicalised
        t = t_ref[...]  # (bn, L) ascending thresholds
        levels = jnp.sum(
            (acc[:, :, None] >= t[None, :, :]).astype(jnp.int32), axis=-1
        )
        o_ref[...] = levels


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "offset", "bm", "bn", "bk", "interpret"),
)
def mvau(
    x: jnp.ndarray,
    packed_w: jnp.ndarray,
    thresholds: jnp.ndarray,
    signs: jnp.ndarray,
    *,
    bits: int,
    k: int,
    offset: int = 0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Streamlined MVAU: int32 levels = offset + #{l : sign*acc >= T_l}."""
    m, kk = x.shape
    assert kk == k
    per = 8 // bits
    n = packed_w.shape[1]
    n_lvl = thresholds.shape[1]
    assert thresholds.shape[0] == n and signs.shape == (n,)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % per == 0
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(_mvau_kernel, bits=bits, bk=bk, bn=bn, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk // per, bn), lambda i, j, kb: (kb, j)),
            pl.BlockSpec((bn, n_lvl), lambda i, j, kb: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, packed_w, thresholds, signs.reshape(1, n))
    return out + offset
