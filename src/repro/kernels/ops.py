"""Public jit'd entry points for the Pallas kernels.

Handles padding to hardware-aligned block shapes (MXU multiples of 128 in
the lane dim, 8 in the sublane dim — the TPU "fixed memory geometry" whose
mismatch with logical shapes is the paper's Eq. 1 inefficiency, paid here
once in padding rather than per-BRAM), backend selection (interpret mode on
CPU, compiled Mosaic on TPU), and batch-dim flattening.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import mvau as _mvau
from repro.kernels import packed_matmul as _pm
from repro.quant.quantizers import pack_bits


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_blocks(m: int, n: int, k: int, bits: int) -> tuple[int, int, int]:
    """Block shapes: MXU-aligned, working set bounded to ~2 MiB of VMEM."""
    per = 8 // bits
    bm = min(128, _round_up(m, 8))
    bn = min(128, _round_up(n, 128))
    bk = min(512, _round_up(k, max(256, per * 8)))
    return bm, bn, bk


def packed_matmul(
    x: jnp.ndarray,
    packed_w: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bits: int,
    k: int,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Batched packed matmul; pads all dims to block multiples.

    x: (..., K); packed_w: (K*bits/8, N); scale: (N,). Returns (..., N) f32.
    """
    if interpret is None:
        interpret = _on_cpu()
    per = 8 // bits
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    n = packed_w.shape[1]
    x2 = x.reshape(m, k)
    bm, bn, bk = _pick_blocks(m, n, k, bits)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    # pad the carrier with the code for weight value 0 so padded K rows are
    # exact no-ops (binary has no 0 code; its pad contributes sign(0-pad of x)
    # * 0-activation = 0 because x is zero-padded along K as well).
    wp = jnp.pad(packed_w, ((0, (kp - k) // per), (0, np_ - n)))
    sp = jnp.pad(scale, (0, np_ - n))
    out = _pm.packed_matmul(
        x2, wp, sp, bits=bits, k=kp, bm=bm, bn=bn, bk=bk, interpret=interpret
    )
    return out[:m, :n].reshape(*lead, n)


def stream_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray | None = None,
    *,
    bits: int = 0,
    k: int,
    stream_depth: int = 2,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Batched HBM-streaming matmul (``kernels.weight_stream``); pads to
    block multiples.

    x: (..., K); w: (K*bits/8, N) packed carrier or (K, N) dense (bits=0);
    scale: (N,) or None. Returns (..., N) f32. On CPU the jnp reference is
    used directly: interpret-mode DMA emulation is exercised by the kernel
    equivalence tests, while hot paths (the budgeted serve step) keep the
    reference math — bit-identical to the resident weight path, so a
    VMEM-budgeted decode produces token-identical output.
    """
    from repro.kernels import weight_stream as _ws
    from repro.kernels.ref import stream_matmul_ref

    if interpret is None:
        interpret = _on_cpu()
    per = 8 // bits if bits else 1
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    n = w.shape[1]
    x2 = x.reshape(m, k)
    if scale is None:
        scale = jnp.ones((n,), jnp.float32)
    if interpret:
        out = stream_matmul_ref(x2, w, scale, bits, k)
        return out[:m].reshape(*lead, n)
    bn = min(128, _round_up(n, 128))
    ck = min(512, _round_up(k, max(256, per * 8)))
    mp, np_, kp = _round_up(m, 8), _round_up(n, bn), _round_up(k, ck)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    # K padding: packed carriers pad with code 0; x is zero-padded along K
    # so binary's missing 0 code is still an exact no-op (see packed_matmul)
    wp = jnp.pad(w, ((0, (kp - k) // per), (0, np_ - n)))
    sp = jnp.pad(scale, (0, np_ - n))
    out = _ws.stream_matmul(
        x2, wp, sp,
        bits=bits, k=kp, bn=bn, ck=ck, stream_depth=stream_depth,
        interpret=False,
    )
    return out[:m, :n].reshape(*lead, n)


def mvau(
    x: jnp.ndarray,
    packed_w: jnp.ndarray,
    thresholds: jnp.ndarray,
    signs: jnp.ndarray,
    *,
    bits: int,
    k: int,
    offset: int = 0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused packed-matmul + thresholding; pads to block multiples."""
    if interpret is None:
        interpret = _on_cpu()
    per = 8 // bits
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    n = packed_w.shape[1]
    x2 = x.reshape(m, k)
    bm, bn, bk = _pick_blocks(m, n, k, bits)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(packed_w, ((0, (kp - k) // per), (0, np_ - n)))
    # padded channels get +inf thresholds (never crossed) and sign +1
    tp = jnp.pad(
        thresholds, ((0, np_ - n), (0, 0)), constant_values=jnp.inf
    )
    sg = jnp.pad(signs, (0, np_ - n), constant_values=1.0)
    out = _mvau.mvau(
        x2, wp, tp, sg,
        bits=bits, k=kp, offset=offset, bm=bm, bn=bn, bk=bk,
        interpret=interpret,
    )
    return out[:m, :n].reshape(*lead, n)


def pack_weights(w_values: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Float weight values (K, N) -> uint8 carrier (K*bits/8, N), padding K
    to a byte boundary. Inverse-decode convention matches ``ref.decode``."""
    per = 8 // bits
    k = w_values.shape[0]
    kp = _round_up(k, per)
    w = jnp.pad(w_values, ((0, kp - k),) + ((0, 0),) * (w_values.ndim - 1))
    if bits == 1:
        codes = (w > 0).astype(jnp.uint8)
    elif bits == 2:
        codes = (jnp.sign(w) + 1).astype(jnp.uint8)
    else:
        codes = (jnp.round(w) + 2 ** (bits - 1)).astype(jnp.uint8)
    return pack_bits(codes, bits)


# --------------------------------------------------------------------------
# Fused flash attention (kernels/flash_attention.py) with a custom VJP
# --------------------------------------------------------------------------


def _fa_pick(s: int, target: int) -> int:
    for d in range(min(target, s), 0, -1):
        if s % d == 0:
            return d
    return 1


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fa(q, k, v, causal, window, qb, kb, q_offset, interpret):
    out, _ = _fa_fwd(q, k, v, causal, window, qb, kb, q_offset, interpret)
    return out


def _fa_fwd(q, k, v, causal, window, qb, kb, q_offset, interpret):
    from repro.kernels import flash_attention as FK

    out, lse = FK.flash_fwd(
        q, k, v, causal=causal, window=window, qb=qb, kb=kb,
        q_offset=q_offset, interpret=interpret,
    )
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, qb, kb, q_offset, interpret, res, do):
    from repro.kernels import flash_attention as FK

    q, k, v, out, lse = res
    dq, dk_g, dv_g = FK.flash_bwd(
        q, k, v, out, lse, do, causal=causal, window=window, qb=qb, kb=kb,
        q_offset=q_offset, interpret=interpret,
    )
    bh, sk, d = dk_g.shape
    bkv = k.shape[0]
    g = bh // bkv
    # sum per-q-head partials over each GQA group
    dk = jnp.sum(dk_g.reshape(bkv, g, sk, d), axis=1).astype(k.dtype)
    dv = jnp.sum(dv_g.reshape(bkv, g, sk, d), axis=1).astype(v.dtype)
    return dq, dk, dv


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 256,
    kv_block: int = 512,
    q_offset: int = 0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused Pallas flash attention. q: (B, Sq, Hq, D); k/v: (B, Sk,
    Hkv, D). Differentiable (FA2 backward kernels)."""
    if interpret is None:
        interpret = _on_cpu()
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    qb = _fa_pick(sq, q_block)
    kb = _fa_pick(sk, kv_block)
    # (B, S, H, D) -> (B*H, S, D); BH row order b*H + h matches the
    # kernel's GQA index map (bh // g).
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    of = _fa(qf, kf, vf, causal, window, qb, kb, q_offset, interpret)
    return of.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
