"""Pallas TPU kernel: matmul with bit-packed weights, unpacked in VMEM.

This is the TPU adaptation of the paper's packed weight memories (DESIGN.md
§3): weights live in HBM as a dense uint8 carrier holding 8/``bits`` weights
per byte (the "optimally filled BRAM"), are staged into VMEM by the Pallas
grid pipeline (the GALS weight streamer), and are unpacked with VPU shift/
mask ops just before hitting the MXU. The HBM roofline term for weights
drops by 16x (bf16 -> 1 bit) / 8x (2 bit); the compensation cost is VPU
unpack work, not MXU cycles — the same surplus-resource trade the paper
makes with the memory-clock surplus (R_F).

Layout: ``x`` (M, K) activations; ``packed_w`` (K*bits/8, N) uint8 carrier
packed along the reduction dim (see ``quant.quantizers.pack_bits``);
``scale`` (N,) per-output-channel dequant scale. Out: (M, N) f32.

Grid: (M/bm, N/bn, K/bk), k innermost ("arbitrary"), accumulating into the
output block, which Pallas keeps VMEM-resident across the k sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _decode_block(w_packed, bits: int, bk: int, bn: int):
    """uint8 (bk*bits/8, bn) -> f32 (bk, bn) weight values, in-register.

    Weight k = i*per + j sits in carrier row i at bit-offset j*bits
    (matches ``pack_bits``). The unpack is per*2 VPU ops per carrier
    element — cheap relative to the 2*bk*bn MXU flops it feeds.
    """
    per = 8 // bits
    mask = jnp.uint8(2**bits - 1)
    planes = [
        ((w_packed >> jnp.uint8(j * bits)) & mask).astype(jnp.float32)
        for j in range(per)
    ]
    # (bk/per, per, bn) -> (bk, bn): row-major interleave of the planes.
    codes = jnp.stack(planes, axis=1).reshape(bk, bn)
    if bits == 1:
        return codes * 2.0 - 1.0  # {0,1} -> {-1,+1}
    if bits == 2:
        return codes - 1.0  # {0,1,2} -> {-1,0,+1}
    return codes - float(2 ** (bits - 1))


def _packed_matmul_kernel(x_ref, w_ref, s_ref, o_ref, *, bits, bk, bn, nk):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _decode_block(w_ref[...], bits, bk, bn)
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k_idx == nk - 1)
    def _scale():
        o_ref[...] *= s_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bits", "k", "bm", "bn", "bk", "interpret")
)
def packed_matmul(
    x: jnp.ndarray,
    packed_w: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bits: int,
    k: int,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """out[m, n] = sum_k x[m, k] * decode(packed_w)[k, n] * scale[n].

    Shapes must be pre-padded: M % bm == 0, N % bn == 0, K % bk == 0,
    and bk % (8/bits) == 0 (use ``ops.packed_matmul`` for auto-padding).
    """
    m, kk = x.shape
    assert kk == k, (kk, k)
    per = 8 // bits
    n = packed_w.shape[1]
    assert packed_w.shape[0] == k // per, (packed_w.shape, k, per)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % per == 0
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(
        _packed_matmul_kernel, bits=bits, bk=bk, bn=bn, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk // per, bn), lambda i, j, kb: (kb, j)),
            pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, packed_w, scale.reshape(1, n))
