"""Pallas TPU kernel: matmul streaming weights HBM -> VMEM through a ring.

This is the executor half of the FCMP port story (paper §IV-§V): a weight
block that the residency plan does *not* pin in VMEM stays in HBM and is
pulled through a ``stream_depth``-slot VMEM ring by manual async DMA, one
K-chunk ahead of the MXU per slot — the GALS weight streamer, with the
stream-ahead depth playing the role of the memory-clock ratio ``R_F``:
bit-packing leaves an HBM-bandwidth surplus (1/2-bit weights move 8-16x
fewer bytes than bf16), and that surplus is what lets the ring run deep
enough to hide HBM latency, exactly as the paper's frequency surplus lets
one BRAM port serve ``H_B`` logical buffers.

Unlike ``packed_matmul`` (whose weights ride the automatic grid pipeline,
i.e. are assumed VMEM-schedulable), the weight operand here is declared in
``pl.ANY``/HBM memory space and never materialises in VMEM beyond
``stream_depth`` chunks — the kernel's VMEM footprint is the *budget* the
residency plan reserved for streaming, independent of the weight size.

Layout: ``x`` (M, K) activations (VMEM-resident — decode batches are
small); ``w`` (Kc, N) weights in HBM, either a packed uint8 carrier
(``bits`` in {1, 2}, Kc = K*bits/8, see ``quant.quantizers.pack_bits``)
or dense float rows (``bits=0``, Kc = K); ``scale`` (N,) per-channel
dequant scale (ones for dense). Out: (M, N) f32.

Grid: (N/bn,) — one output column block per program; the K sweep is the
in-kernel DMA ring. The interpret path (tier-1 CPU) emulates the DMAs;
``ref.stream_matmul_ref`` is the numerical oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_chunk(w_chunk, bits: int, ck: int, bn: int):
    """Carrier chunk -> f32 (ck, bn) weight values, in-register.

    bits=0: dense rows, cast only. bits in {1,2}: the ``pack_bits``
    row-major interleave, matching ``packed_matmul._decode_block``.
    """
    if bits == 0:
        return w_chunk.astype(jnp.float32)
    per = 8 // bits
    mask = jnp.uint8(2**bits - 1)
    planes = [
        ((w_chunk >> jnp.uint8(j * bits)) & mask).astype(jnp.float32)
        for j in range(per)
    ]
    codes = jnp.stack(planes, axis=1).reshape(ck, bn)
    if bits == 1:
        return codes * 2.0 - 1.0  # {0,1} -> {-1,+1}
    return codes - 1.0  # {0,1,2} -> {-1,0,+1}


def _stream_kernel(
    x_ref, w_ref, s_ref, o_ref, *, bits, m, ck, bn, nk, depth
):
    j = pl.program_id(0)
    per = 8 // bits if bits else 1
    ckc = ck // per  # carrier rows per K chunk

    def body(scratch, sem):
        def chunk_dma(slot, i):
            return pltpu.make_async_copy(
                w_ref.at[pl.ds(i * ckc, ckc), pl.ds(j * bn, bn)],
                scratch.at[slot],
                sem.at[slot],
            )

        # warm-up: fill the ring stream_depth chunks ahead
        for i in range(min(depth, nk)):
            chunk_dma(i, i).start()

        def k_step(i, acc):
            slot = i % depth
            chunk_dma(slot, i).wait()
            w = _decode_chunk(scratch[slot], bits, ck, bn)
            acc = acc + jnp.dot(
                x_ref[:, pl.ds(i * ck, ck)].astype(jnp.float32),
                w,
                preferred_element_type=jnp.float32,
            )

            # the consumed slot immediately prefetches chunk i + depth
            @pl.when(i + depth < nk)
            def _():
                chunk_dma(slot, i + depth).start()

            return acc

        acc = jax.lax.fori_loop(
            0, nk, k_step, jnp.zeros((m, bn), jnp.float32)
        )
        o_ref[...] = acc * s_ref[...]

    pl.run_scoped(
        body,
        scratch=pltpu.VMEM((depth, ckc, bn), w_ref.dtype),
        sem=pltpu.SemaphoreType.DMA((depth,)),
    )


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "bn", "ck", "stream_depth", "interpret"),
)
def stream_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bits: int,
    k: int,
    bn: int = 128,
    ck: int = 256,
    stream_depth: int = 2,
    interpret: bool = False,
) -> jnp.ndarray:
    """out[m, n] = sum_k x[m, k] * decode(w)[k, n] * scale[n], w streamed.

    Shapes must be pre-padded: N % bn == 0, K % ck == 0, and
    ck % (8/bits) == 0 for packed weights (``ops.stream_matmul`` pads).
    ``stream_depth`` >= 2 is the DMA ring depth (R_F analogue).
    """
    m, kk = x.shape
    assert kk == k, (kk, k)
    per = 8 // bits if bits else 1
    n = w.shape[1]
    assert w.shape[0] == (k // per if bits else k), (w.shape, k, per)
    assert n % bn == 0 and k % ck == 0 and ck % per == 0
    assert stream_depth >= 2, "need a ring of >= 2 slots to overlap DMA"
    nk = k // ck
    kernel = functools.partial(
        _stream_kernel,
        bits=bits, m=m, ck=ck, bn=bn, nk=nk, depth=stream_depth,
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),  # x fully VMEM-resident
            pl.BlockSpec(memory_space=pltpu.ANY),    # w stays in HBM
            pl.BlockSpec((1, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, scale.reshape(1, n))
