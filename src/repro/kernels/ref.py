"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; the test
suite sweeps shapes/dtypes and asserts ``assert_allclose(kernel, ref)``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.quant.quantizers import unpack_bits


def decode_weights(packed: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """uint8 carrier -> float weight values.

    bits=1: codes {0,1} -> {-1,+1};  bits=2: codes {0,1,2} -> {-1,0,+1};
    bits=4/8: signed two's-complement-style codes centred at 2^(bits-1).
    """
    codes = unpack_bits(packed, bits, k).astype(jnp.float32)
    if bits == 1:
        return codes * 2.0 - 1.0
    if bits == 2:
        return codes - 1.0
    return codes - float(2 ** (bits - 1))


def packed_matmul_ref(
    x: jnp.ndarray, packed_w: jnp.ndarray, scale: jnp.ndarray, bits: int, k: int
) -> jnp.ndarray:
    """Oracle for ``packed_matmul``: unpack then dense f32 matmul.

    x: (M, K); packed_w: (K*bits/8, N) uint8; scale: (N,) per-channel.
    """
    w = decode_weights(packed_w, bits, k)
    out = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    return out * scale[None, :]


def stream_matmul_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray,
    bits: int,
    k: int,
) -> jnp.ndarray:
    """Oracle for ``weight_stream.stream_matmul``.

    The streaming kernel's math is chunked accumulation of the same
    product; the oracle materialises the decoded weight once and does a
    single f32 matmul — identical math to the resident (non-streamed)
    ``lm.packed_dense`` / ``layers.dense`` paths, which is what makes the
    budgeted and unbudgeted serve paths token-identical on CPU.

    x: (M, K); w: (K*bits/8, N) uint8 carrier, or (K, N) dense if bits=0;
    scale: (N,).
    """
    if bits == 0:
        vals = w.astype(jnp.float32)
    else:
        vals = decode_weights(w, bits, k)
    out = jnp.dot(
        x.astype(jnp.float32), vals, preferred_element_type=jnp.float32
    )
    return out * scale[None, :]


def mvau_ref(
    x: jnp.ndarray,
    packed_w: jnp.ndarray,
    thresholds: jnp.ndarray,
    signs: jnp.ndarray,
    offset: int,
    bits: int,
    k: int,
) -> jnp.ndarray:
    """Oracle for the fused MVAU: packed matmul -> integer thresholding.

    thresholds: (N, L) ascending per output channel; signs: (N,) in {-1,+1}.
    Returns int32 activation levels (paper §III-B streamlined datapath).
    """
    w = decode_weights(packed_w, bits, k)
    acc = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    acc = acc * signs[None, :]
    levels = jnp.sum(
        (acc[..., None] >= thresholds[None, :, :]).astype(jnp.int32), axis=-1
    )
    return levels + offset


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Dense-softmax oracle for the flash-attention kernels.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D). Returns (B, Sq, Hq, D).
    """
    import jax
    import numpy as np

    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
        k.astype(jnp.float32),
    ) / np.sqrt(d)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(m[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, d).astype(q.dtype)
