"""Pallas TPU kernels: fused flash attention (forward + backward).

The TPU execution path for the attention hot-spot (EXPERIMENTS.md §Perf
iteration 4). The jnp FA2 path (``models.flash``) is what the 512-device
dry-run lowers — XLA materialises every (g*qb, kb) score/probability tile
at fusion boundaries, ~81% of the smollm train-cell HBM traffic. In this
kernel those tiles live in VMEM scratch and never touch HBM: per-step HBM
traffic is q/k/v reads + out writes only.

Layouts: heads are flattened into the leading grid dim. q: (BH, Sq, D)
with BH = B*Hq; k/v: (BKV, Sk, D) with BKV = B*Hkv; GQA maps q-head
bh -> kv row (bh // Hq) * Hkv + (bh % Hq) // G in the BlockSpec index
maps — no materialised KV replication.

Grid: (BH, nq, nk), nk innermost ("arbitrary") so the online-softmax
scratch (m, l, acc) persists across the KV sweep. Causal / sliding-window
blocks that are fully masked are skipped with ``pl.when`` (they still pay
a grid step, but no MXU work or VMEM writes).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
    *, scale, qb, kb, nk, causal, window, q_offset,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = q_offset + qi * qb
    k_lo = kj * kb
    # visibility of this (qi, kj) block pair
    visible = True
    if causal:
        visible = jnp.asarray(k_lo <= q_lo + qb - 1)
    if window > 0:
        visible = jnp.logical_and(
            visible, jnp.asarray(k_lo + kb - 1 > q_lo - window)
        )

    @pl.when(visible)
    def _compute():
        s = jax.lax.dot_general(
            q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (qb, kb)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        ok = jnp.ones((qb, kb), jnp.bool_)
        if causal:
            ok &= q_pos >= k_pos
        if window > 0:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(l))[:, 0]


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_scr,
    *, scale, qb, kb, nk, causal, window, q_offset,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_lo = q_offset + qi * qb
    k_lo = kj * kb
    visible = True
    if causal:
        visible = jnp.asarray(k_lo <= q_lo + qb - 1)
    if window > 0:
        visible = jnp.logical_and(
            visible, jnp.asarray(k_lo + kb - 1 > q_lo - window)
        )

    @pl.when(visible)
    def _compute():
        s = jax.lax.dot_general(
            q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        ok = jnp.ones((qb, kb), jnp.bool_)
        if causal:
            ok &= q_pos >= k_pos
        if window > 0:
            ok &= q_pos - k_pos < window
        p = jnp.where(ok, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        dov = jax.lax.dot_general(
            do_ref[0].astype(jnp.float32), v_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dov - delta_ref[0][:, None]) * scale
        dq_scr[...] += jax.lax.dot_general(
            ds.astype(k_ref.dtype), k_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(kj == nk - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_scr, dv_scr,
    *, scale, qb, kb, nq, causal, window, q_offset,
):
    kj = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_lo = q_offset + qi * qb
    k_lo = kj * kb
    visible = True
    if causal:
        visible = jnp.asarray(k_lo <= q_lo + qb - 1)
    if window > 0:
        visible = jnp.logical_and(
            visible, jnp.asarray(k_lo + kb - 1 > q_lo - window)
        )

    @pl.when(visible)
    def _compute():
        s = jax.lax.dot_general(
            q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        ok = jnp.ones((qb, kb), jnp.bool_)
        if causal:
            ok &= q_pos >= k_pos
        if window > 0:
            ok &= q_pos - k_pos < window
        p = jnp.where(ok, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        dov = jax.lax.dot_general(
            do_ref[0].astype(jnp.float32), v_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dov - delta_ref[0][:, None]) * scale
        # dv += p^T do ; dk += ds^T q
        dv_scr[...] += jax.lax.dot_general(
            p.astype(do_ref.dtype), do_ref[0],
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        dk_scr[...] += jax.lax.dot_general(
            ds.astype(q_ref.dtype), q_ref[0],
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _dims(q, k, qb, kb):
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    g = bh // bkv
    return bh, bkv, g, sq, sk, d


def flash_fwd(
    q, k, v, *, causal=True, window=0, qb=256, kb=512, q_offset=0,
    interpret=False,
):
    """q: (BH, Sq, D); k/v: (BKV, Sk, D); BH % BKV == 0 (GQA).

    Returns (out (BH, Sq, D), lse (BH, Sq) f32).
    """
    bh, bkv, g, sq, sk, d = _dims(q, k, qb, kb)
    nq, nk = sq // qb, sk // kb
    scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, qb=qb, kb=kb, nk=nk,
        causal=causal, window=window, q_offset=q_offset,
    )
    kv_row = lambda bhi: (bhi // g, )  # BKV row for a BH row
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda bhi, qi, kj: (bhi, qi, 0)),
            pl.BlockSpec((1, kb, d), lambda bhi, qi, kj: (bhi // g, kj, 0)),
            pl.BlockSpec((1, kb, d), lambda bhi, qi, kj: (bhi // g, kj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qb, d), lambda bhi, qi, kj: (bhi, qi, 0)),
            pl.BlockSpec((1, qb), lambda bhi, qi, kj: (bhi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)


def flash_bwd(
    q, k, v, out, lse, do, *, causal=True, window=0, qb=256, kb=512,
    q_offset=0, interpret=False,
):
    """Returns (dq (BH,Sq,D), dk_g (BH,Sk,D), dv_g (BH,Sk,D)).

    dk_g/dv_g are per-q-head partials; sum groups of G rows to get the
    kv-head gradients (done in ``ops.flash_attention``'s VJP).
    """
    bh, bkv, g, sq, sk, d = _dims(q, k, qb, kb)
    nq, nk = sq // qb, sk // kb
    scale = 1.0 / math.sqrt(d)
    delta = jnp.sum(
        do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    )  # (BH, Sq)

    dq_kernel = functools.partial(
        _dq_kernel, scale=scale, qb=qb, kb=kb, nk=nk,
        causal=causal, window=window, q_offset=q_offset,
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda bhi, qi, kj: (bhi, qi, 0)),
            pl.BlockSpec((1, kb, d), lambda bhi, qi, kj: (bhi // g, kj, 0)),
            pl.BlockSpec((1, kb, d), lambda bhi, qi, kj: (bhi // g, kj, 0)),
            pl.BlockSpec((1, qb, d), lambda bhi, qi, kj: (bhi, qi, 0)),
            pl.BlockSpec((1, qb), lambda bhi, qi, kj: (bhi, qi)),
            pl.BlockSpec((1, qb), lambda bhi, qi, kj: (bhi, qi)),
        ],
        out_specs=pl.BlockSpec((1, qb, d), lambda bhi, qi, kj: (bhi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((qb, d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dkv_kernel = functools.partial(
        _dkv_kernel, scale=scale, qb=qb, kb=kb, nq=nq,
        causal=causal, window=window, q_offset=q_offset,
    )
    dk_g, dv_g = pl.pallas_call(
        dkv_kernel,
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda bhi, kj, qi: (bhi, qi, 0)),
            pl.BlockSpec((1, kb, d), lambda bhi, kj, qi: (bhi // g, kj, 0)),
            pl.BlockSpec((1, kb, d), lambda bhi, kj, qi: (bhi // g, kj, 0)),
            pl.BlockSpec((1, qb, d), lambda bhi, kj, qi: (bhi, qi, 0)),
            pl.BlockSpec((1, qb), lambda bhi, kj, qi: (bhi, qi)),
            pl.BlockSpec((1, qb), lambda bhi, kj, qi: (bhi, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, kb, d), lambda bhi, kj, qi: (bhi, kj, 0)),
            pl.BlockSpec((1, kb, d), lambda bhi, kj, qi: (bhi, kj, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sk, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, sk, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((kb, d), jnp.float32),
            pltpu.VMEM((kb, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk_g, dv_g
