"""Axis-role discovery over any mesh-like object.

The policy never touches devices: it reads only ``axis_names`` and
``shape`` from whatever it is handed — a real ``jax.sharding.Mesh``, the
512-placeholder dry-run mesh, or a bare test fake. ``MeshView`` snapshots
those two attributes so every downstream module works against one small,
explicit surface.

Roles are the floorplan regions of the paper's packing problem: an axis
carries either *tensor* parallelism (TP/EP — the 'model' axis), *batch*
parallelism (DP — 'pod' and 'data'), or *pipeline* stages ('stage').
``legalize.validate_spec`` enforces that a single PartitionSpec dim entry
never combines axes of different roles, the analogue of "bins never mix
regions" (``core.packing.Packing.validate``).
"""

from __future__ import annotations

import dataclasses
import math

# axis name -> role. Unknown axis names default to "batch": an unnamed
# extra axis behaves like plain DP, which is always numerically safe.
TENSOR, BATCH, PIPELINE = "tensor", "batch", "pipeline"
ROLE_OF_AXIS = {
    "model": TENSOR,
    "expert": TENSOR,
    "data": BATCH,
    "pod": BATCH,
    "replica": BATCH,
    "stage": PIPELINE,
}


@dataclasses.dataclass(frozen=True)
class MeshView:
    """The two attributes the policy is allowed to read, snapshotted."""

    axis_names: tuple[str, ...]
    sizes: tuple[int, ...]

    @classmethod
    def of(cls, mesh) -> "MeshView":
        if isinstance(mesh, MeshView):
            return mesh
        names = tuple(mesh.axis_names)
        shape = dict(mesh.shape)
        return cls(names, tuple(int(shape[a]) for a in names))

    @property
    def shape(self) -> dict[str, int]:
        return dict(zip(self.axis_names, self.sizes))

    def axis_size(self, axis: str) -> int:
        return self.shape[axis]

    def product(self, axes: tuple[str, ...]) -> int:
        shape = self.shape
        return math.prod(shape[a] for a in axes) if axes else 1

    def role(self, axis: str) -> str:
        return ROLE_OF_AXIS.get(axis, BATCH)

    # ------------------------------------------------------------ roles

    @property
    def tensor_axes(self) -> tuple[str, ...]:
        """TP/EP axes in mesh order (the compute 'region')."""
        return tuple(a for a in self.axis_names if self.role(a) == TENSOR)

    @property
    def batch_axes(self) -> tuple[str, ...]:
        """DP axes in mesh order (the batch 'region')."""
        return tuple(a for a in self.axis_names if self.role(a) == BATCH)

    @property
    def tp_size(self) -> int:
        return self.product(self.tensor_axes)

    @property
    def dp_size(self) -> int:
        return self.product(self.batch_axes)
