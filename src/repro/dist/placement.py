"""Engine placement over the mesh axes (fleet scale-out).

A fleet engine is a full model replica: it keeps the whole tensor-
parallel ('model') extent and owns a contiguous slice of a *batch* (DP)
axis — engines are the coarsest data-parallel unit, exactly the way the
paper's floorplan regions own whole SLRs while bins stack inside them.
The placement therefore only ever splits axes whose role is ``BATCH``
(``mesh_axes.ROLE_OF_AXIS``): splitting a tensor axis would change the
collectives inside an engine, and splitting the pipeline axis would put
one engine's stages on two engines.

Device-free like the rest of ``repro.dist``: the planner reads only
``axis_names``/``shape`` through ``MeshView``, so the launch drivers can
print production placements (16x16, 2x16x16) on a laptop.
"""

from __future__ import annotations

import dataclasses

from repro.dist.mesh_axes import MeshView


@dataclasses.dataclass(frozen=True)
class EnginePlacement:
    """One engine's slice of the fleet mesh."""

    engine_id: int
    axis: str  # the batch axis the fleet divides
    lo: int  # [lo, hi) slice of that axis
    hi: int
    view: MeshView  # the engine's own sub-mesh view

    @property
    def devices(self) -> int:
        return self.view.product(self.view.axis_names)

    def describe(self) -> str:
        shape = "x".join(str(s) for s in self.view.sizes)
        return (
            f"engine {self.engine_id}: {self.axis}[{self.lo}:{self.hi}] "
            f"-> {shape} ({self.devices} devices)"
        )


def plan_engine_placement(mesh, n_engines: int) -> list[EnginePlacement]:
    """Slice a mesh into ``n_engines`` replica sub-meshes.

    Picks the largest batch-role axis that ``n_engines`` divides (the
    divisibility rule of ``dist.legalize`` applied at engine granularity)
    and gives each engine a contiguous slice of it; every other axis is
    kept whole. Raises ``ValueError`` when no batch axis divides — there
    is no replication fallback here, because half an engine is not a
    meaningful spill target.
    """
    view = MeshView.of(mesh)
    if n_engines < 1:
        raise ValueError("need >= 1 engine")
    candidates = sorted(
        (a for a in view.batch_axes if view.axis_size(a) % n_engines == 0),
        key=view.axis_size,
        reverse=True,
    )
    if not candidates:
        sizes = {a: view.axis_size(a) for a in view.batch_axes}
        raise ValueError(
            f"{n_engines} engines divide no batch axis of {sizes}; "
            "choose an engine count dividing a data-parallel axis"
        )
    axis = candidates[0]
    per = view.axis_size(axis) // n_engines
    sub_sizes = tuple(
        per if a == axis else s
        for a, s in zip(view.axis_names, view.sizes)
    )
    sub = MeshView(view.axis_names, sub_sizes)
    return [
        EnginePlacement(i, axis, i * per, (i + 1) * per, sub)
        for i in range(n_engines)
    ]
