"""Per-family leaf rules: which dims of which leaves want which region.

Each rule is an *ordered candidate list* ``[(dim, axes), ...]`` handed to
``legalize.first_legal`` — the first divisible placement wins, later
entries are the fallback ladder, and an empty list (or no legal candidate)
means replicate. Negative dims count from the trailing edge so one rule
covers stacked (leading layer axis), expert-stacked and unstacked variants
of the same logical weight.

The naming convention is the one ``models.lm.init_params`` establishes:

* column-parallel (shard the output features): ``wq wk wv`` (+ ``x_``
  cross-attention twins), the SSM in-projections ``in_z in_x in_b in_c
  in_dt``, the FFN up-projections ``w1 w3`` and the MoE ``router``;
* row-parallel (shard the input features, so the matmul's partial sums
  meet in one all-reduce): ``wo``/``x_wo``, ``w2`` and the SSM ``out``;
* table-sharded on dim 0: ``embed`` / ``unembed`` (``vocab_pad`` keeps
  the padded vocab divisible by any realistic TP degree);
* expert-parallel: MoE expert stacks ``(L, E, d, ff)`` shard the expert
  axis first — the paper's best-fit family of many oddly-shaped buffers
  maps one expert group per model-axis slice;
* replicated: norms, biases and the per-channel quantization ``scale``
  vectors (small, consumed everywhere).
"""

from __future__ import annotations

COLUMN_PARALLEL = {
    "wq", "wk", "wv", "x_wq", "x_wk", "x_wv",
    "in_z", "in_x", "in_b", "in_c", "in_dt",
    "w1", "w3", "router",
}
ROW_PARALLEL = {"wo", "x_wo", "w2", "out"}
TABLE = {"embed", "unembed"}
CONV = {"conv_x", "conv_b", "conv_c"}
REPLICATED = {
    "ln1", "ln2", "ln_x", "final_norm", "enc_final_norm",
    "gate_norm", "dt_bias", "a_log", "d_skip", "scale",
}
# MoE expert stacks carry (layer, expert, in, out); only these leaf names
# ever have the expert lead under the 'moe' family.
EXPERT_STACKED = {"w1", "w3", "w2"}


def param_candidates(
    name: str,
    shape: tuple[int, ...],
    tensor_axes: tuple[str, ...],
    *,
    family: str = "dense",
) -> list[tuple[int, tuple[str, ...]]]:
    """Ordered (dim, axes) candidates for one named parameter leaf.

    ``name`` is the logical leaf name; packed carriers pass their parent
    weight's name (the carrier shards exactly like the weight it encodes —
    packing changed the word width, not the bin geometry).
    """
    tp = tuple(tensor_axes)
    if not tp or len(shape) < 1:
        return []
    if name in REPLICATED:
        return []
    if name in TABLE:
        # vocab dim first; the embedding width is the fallback
        return [(0, tp), (-1, tp)]
    if len(shape) < 2:
        return []
    if family == "moe" and name in EXPERT_STACKED and len(shape) == 4:
        # expert-parallel first, then the within-expert matmul dims
        col_or_row = (-1, tp) if name != "w2" else (-2, tp)
        return [(1, tp), col_or_row, ((-2, tp) if name != "w2" else (-1, tp))]
    if name in COLUMN_PARALLEL:
        return [(-1, tp), (-2, tp)]
    if name in ROW_PARALLEL:
        return [(-2, tp), (-1, tp)]
    if name in CONV:
        # (L, K, channels): channels only — K is the tap count (3..4)
        return [(-1, tp)]
    # unknown leaf: generic fallback, trailing dims first (features live
    # last by convention), never the leading stacked-layer dim
    return [(d, tp) for d in range(len(shape) - 1, 0, -1)]


def cache_candidates(
    name: str,
    shape: tuple[int, ...],
    tensor_axes: tuple[str, ...],
) -> list[tuple[int, tuple[str, ...]]]:
    """Tensor-region candidates for one decode-state leaf.

    Attention caches ``(L, B, S, H, D)`` prefer the KV-head dim; when the
    head count does not divide TP the head_dim is next — matching the
    split-d decode layout (``attention.decode_attention_split_d``) that
    keeps the cache resident instead of resharding it every step. SSM
    state ``(L, B, H, P, N)`` shards its head dim; conv rings shard their
    channel dim.
    """
    tp = tuple(tensor_axes)
    if not tp:
        return []
    if name in ("k", "v", "cross_k", "cross_v") and len(shape) == 5:
        return [(3, tp), (4, tp)]
    if name == "ssm" and len(shape) == 5:
        return [(2, tp), (3, tp)]
    if name in CONV and len(shape) == 4:
        return [(3, tp)]
    return []
