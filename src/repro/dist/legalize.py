"""Divisibility-constrained placement: the packing legality kernel.

A candidate placement is ``(dim, axes)``: shard array dim ``dim`` over the
mesh axes ``axes``. It is *legal* when the dim size divides the product of
the axis sizes — the analogue of the paper's bin-height constraint (a
buffer stack must fit the physical RAM geometry exactly; FCMP never splits
a word across blocks). ``first_legal`` walks an ordered candidate list and
falls back to replication when nothing divides — the paper's spill path.

``validate_spec`` enforces the two structural invariants on every spec the
policy emits:

* an axis is used at most once per spec (a physical block holds one bin),
* a single dim entry never mixes axes of different roles ("bins never mix
  regions", ``core.packing.Packing.validate``).
"""

from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.dist.mesh_axes import MeshView


def _as_axes(entry) -> tuple[str, ...]:
    """A PartitionSpec dim entry -> tuple of axis names (may be empty)."""
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def divides(dim_size: int, mesh: MeshView, axes: tuple[str, ...]) -> bool:
    """Bin-height legality: the dim splits evenly over the axis product."""
    prod = mesh.product(axes)
    return prod > 0 and dim_size % prod == 0


def first_legal(
    shape: tuple[int, ...],
    candidates: list[tuple[int, tuple[str, ...]]],
    mesh: MeshView,
) -> tuple[int, tuple[str, ...]] | None:
    """First candidate placement that is legal, or None (replicate).

    Negative dims are resolved against ``len(shape)``; candidates naming a
    dim the array does not have, or axes the mesh does not have, are
    skipped rather than raised — the same rule table serves every family
    and every mesh shape.
    """
    n = len(shape)
    for dim, axes in candidates:
        if dim < 0:
            dim += n
        if not 0 <= dim < n:
            continue
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            continue
        if divides(shape[dim], mesh, axes):
            return dim, axes
    return None


def spec_from_placements(
    shape: tuple[int, ...],
    placements: list[tuple[int, tuple[str, ...]]],
) -> P:
    """Full-rank PartitionSpec from resolved (dim, axes) placements."""
    entries: list = [None] * len(shape)
    for dim, axes in placements:
        if axes:
            entries[dim] = axes[0] if len(axes) == 1 else tuple(axes)
    return P(*entries)


def largest_dividing_suffix(
    mesh: MeshView, axes: tuple[str, ...], size: int
) -> tuple[str, ...]:
    """Longest suffix of ``axes`` whose product divides ``size``.

    Used for batch placement: the DP axes come ordered innermost-last
    (``('pod', 'data')``), and dropping axes from the *front* keeps the
    fast intra-pod axis sharded while the slow cross-DCN axis replicates —
    batch 16 on a 2x16x16 mesh shards over 'data' (16) and replicates
    over 'pod' (batch 32 divides the full ('pod', 'data') product and
    shards over both).
    """
    for start in range(len(axes)):
        cand = axes[start:]
        if cand and divides(size, mesh, cand):
            return cand
    return ()


def validate_spec(shape: tuple[int, ...], spec: P, mesh: MeshView) -> None:
    """Raise ValueError if ``spec`` breaks a packing invariant."""
    seen: set[str] = set()
    if len(spec) > len(shape):
        raise ValueError(f"spec {spec} longer than shape {shape}")
    for dim, entry in enumerate(spec):
        axes = _as_axes(entry)
        if not axes:
            continue
        roles = {mesh.role(a) for a in axes}
        if len(roles) > 1:
            raise ValueError(
                f"dim {dim} of spec {spec} mixes regions {sorted(roles)}"
            )
        for a in axes:
            if a not in mesh.axis_names:
                raise ValueError(f"spec {spec} names unknown axis {a!r}")
            if a in seen:
                raise ValueError(f"spec {spec} reuses axis {a!r}")
            seen.add(a)
        if not divides(shape[dim], mesh, axes):
            raise ValueError(
                f"dim {dim} ({shape[dim]}) of shape {shape} does not divide "
                f"axes {axes} (= {mesh.product(axes)})"
            )
