"""The sharding policy: legal PartitionSpecs for every pytree leaf.

Public API (all take any mesh-like exposing ``axis_names``/``shape``; no
real devices are required — the dry-run hands in 512 host placeholders and
the unit tests hand in bare fakes):

* ``param_specs(cfg, mesh)``              — specs mirroring
  ``lm.abstract_params(cfg)`` leaf-for-leaf (packed carriers included).
* ``batch_specs(cfg, mesh, global_batch)``— specs for the train/prefill
  batch leaves (tokens, labels, modality stubs).
* ``cache_specs(cfg, mesh, batch, seq_len)`` — specs for every decode-state
  leaf ``lm.init_cache`` creates (plus the encdec cross-attention caches).
* ``token_spec(cfg, mesh, global_batch)`` — the (B, 1) decode token.

Guarantees (asserted by ``tests/test_sharding_policy.py`` and the
hypothesis suite in ``tests/test_dist_policy_properties.py``):

* **legality** — every sharded dim divides the product of its mesh axes;
  when no placement divides, the leaf falls back to replication (never an
  unshardable spec);
* **effectiveness** — >= 85% of parameter bytes are tensor-sharded for
  every ARCH_IDS family on the production meshes;
* **region purity** — no spec dim mixes tensor- and batch-region axes
  (the paper's bins-never-mix-regions invariant);
* **completeness** — a spec exists for every cache leaf of every family.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.dist import rules
from repro.dist.legalize import (
    first_legal,
    largest_dividing_suffix,
    spec_from_placements,
    validate_spec,
)
from repro.dist.mesh_axes import MeshView

# Leaf names that are containers for a packed (FCMP-carrier) weight: the
# spec is derived from the *parent* weight name.
_PACKED_KEYS = ("packed", "scale")


def _path_names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def _leaf_name(path) -> str:
    """Logical leaf name: packed carriers report their parent weight."""
    names = _path_names(path)
    if names and names[-1] in _PACKED_KEYS:
        if names[-1] == "scale":
            return "scale"  # per-channel scales replicate
        return names[-2] if len(names) >= 2 else names[-1]
    return names[-1] if names else ""


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_specs(cfg, mesh):
    """PartitionSpec tree mirroring ``lm.abstract_params(cfg)``.

    Tensor-region only: parameters never occupy the batch axes (plain DP
    replicates them), so the optimizer state and checkpoint layers can
    apply this tree verbatim (``OptState`` mirrors the parameter tree).
    """
    from repro.models import lm

    mv = MeshView.of(mesh)
    abstract = lm.abstract_params(cfg)

    def rule(path, leaf):
        name = _leaf_name(path)
        cands = rules.param_candidates(
            name, tuple(leaf.shape), mv.tensor_axes, family=cfg.family
        )
        hit = first_legal(tuple(leaf.shape), cands, mv)
        spec = spec_from_placements(tuple(leaf.shape), [hit] if hit else [])
        validate_spec(tuple(leaf.shape), spec, mv)
        return spec

    return jax.tree_util.tree_map_with_path(rule, abstract)


def sharded_byte_fraction(cfg, mesh) -> float:
    """Fraction of parameter bytes with at least one sharded dim (the
    policy's effectiveness metric; the paper's Eq. 1 efficiency analogue).
    """
    import numpy as np

    from repro.models import lm

    specs = jax.tree.leaves(
        param_specs(cfg, mesh), is_leaf=lambda x: isinstance(x, P)
    )
    leaves = jax.tree.leaves(lm.abstract_params(cfg))
    total = sharded = 0
    for leaf, spec in zip(leaves, specs):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        total += nbytes
        if any(e is not None for e in spec):
            sharded += nbytes
    return sharded / max(total, 1)


# --------------------------------------------------------------------------
# Batch / token
# --------------------------------------------------------------------------


def _batch_placement(mv: MeshView, global_batch: int) -> tuple[str, ...]:
    """DP axes for the batch dim: the longest suffix-aligned run of batch
    axes whose product divides ``global_batch`` (replicate when none)."""
    return largest_dividing_suffix(mv, mv.batch_axes, global_batch)


def batch_specs(cfg, mesh, global_batch: int) -> dict[str, P]:
    """Specs for the train/prefill batch leaves.

    Batch-region only: activations shard over ('pod', 'data') — combining
    both DP axes in one dim entry is legal (same region); the tensor axis
    never appears (attention's batch-reshard constraint is a separate,
    explicitly-opted-in mechanism in ``launch.dryrun``).
    """
    from repro.models.config import modality_batch_leaves

    mv = MeshView.of(mesh)
    ba = _batch_placement(mv, global_batch)

    def batch_leaf(ndim: int) -> P:
        return spec_from_placements((global_batch,) + (1,) * (ndim - 1),
                                    [(0, ba)] if ba else [])

    out = {
        "tokens": batch_leaf(2),
        "labels": batch_leaf(2),
    }
    for name, rest in modality_batch_leaves(cfg).items():
        out[name] = batch_leaf(1 + len(rest))
    for name, spec in out.items():
        ndim = len(spec)
        validate_spec((global_batch,) + (1,) * (ndim - 1), spec, mv)
    return out


def token_spec(cfg, mesh, global_batch: int) -> P:
    """Spec for the (B, 1) decode token."""
    mv = MeshView.of(mesh)
    ba = _batch_placement(mv, global_batch)
    return spec_from_placements(
        (global_batch, 1), [(0, ba)] if ba else []
    )


# --------------------------------------------------------------------------
# Decode cache
# --------------------------------------------------------------------------


def cache_specs(
    cfg, mesh, global_batch: int, seq_len: int, *, cache=None
) -> dict[str, P]:
    """Specs for every decode-state leaf of ``lm.init_cache``.

    Completeness is structural: the cache tree is eval_shape'd (no
    allocation; pass an already-built abstract ``cache`` to skip the
    re-trace) and every leaf gets a spec — new cache leaves added to a
    family can never silently decode replicated. Attention caches shard
    (batch over DP, KV heads over TP — head_dim when heads don't divide,
    the split-d resident layout); SSM state shards its head dim; the
    scalar ``len`` replicates.
    """
    from repro.models import lm

    mv = MeshView.of(mesh)
    ba = _batch_placement(mv, global_batch)

    if cache is None:
        cache = jax.eval_shape(
            lambda: lm.init_cache(cfg, global_batch, seq_len)
        )
    if cfg.family == "encdec":
        # launch.specs appends the cross-attention caches to the decode
        # state; they shard exactly like the self-attention caches.
        from repro.models.encdec import with_cross_caches

        cache = with_cross_caches(cache, cfg, global_batch)
    else:
        cache = dict(cache)

    out: dict[str, P] = {}
    for name, leaf in cache.items():
        shape = tuple(leaf.shape)
        placements = []
        # batch dim: every cache leaf of rank >= 2 carries batch at dim 1
        if len(shape) >= 2 and ba and mv.product(ba) and shape[1] % mv.product(ba) == 0:
            placements.append((1, ba))
        hit = first_legal(
            shape, rules.cache_candidates(name, shape, mv.tensor_axes), mv
        )
        if hit:
            placements.append(hit)
        spec = spec_from_placements(shape, placements)
        validate_spec(shape, spec, mv)
        out[name] = spec
    return out
