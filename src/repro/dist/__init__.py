"""``repro.dist`` — the mesh-sharding subsystem.

This package is the JAX-native analogue of the paper's region-constrained
memory packing (FCMP). The correspondence, term by term:

===========================  ==============================================
paper (FPGA floorplan)       this package (device mesh)
===========================  ==============================================
logical parameter memory     a parameter / batch / cache pytree leaf
physical RAM block           a slice of a mesh axis
floorplan region (SLR)       a mesh-axis *role* (tensor / batch / pipeline)
bin (stack of buffers)       one dim entry of a ``PartitionSpec``
"bins never mix regions"     a dim entry never combines axes of different
                             roles (``legalize.validate_spec``)
bin height divisibility      a sharded dim must divide the product of its
                             mesh-axis sizes (``legalize.divides``)
packing fallback             replication, when no divisible placement
                             exists (the paper's "spill to URAM/LUTRAM")
===========================  ==============================================

Layering:

* ``mesh_axes``  — axis-role discovery over anything exposing
  ``axis_names`` / ``shape`` (a real ``jax.sharding.Mesh`` or a test fake;
  no devices are ever touched).
* ``legalize``   — the divisibility checker, candidate-placement search
  and the never-mix-regions spec validator.
* ``rules``      — per-family leaf rules: which dims of which named leaves
  prefer tensor-parallel, expert-parallel or table sharding.
* ``sharding``   — the public policy: ``param_specs``, ``batch_specs``,
  ``cache_specs``, ``token_spec``.
* ``placement``  — fleet scale-out: slice a mesh's batch axes into
  per-engine replica sub-meshes (``plan_engine_placement``).
"""

from repro.dist import sharding  # noqa: F401 — canonical entry point
from repro.dist.placement import (  # noqa: F401
    EnginePlacement,
    plan_engine_placement,
)
