"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, frontend_len, d). The encoder is a
bidirectional transformer over frames; the decoder is a causal transformer
with cross-attention into the encoder output. Decode shapes run the decoder
with a self-attention KV cache plus precomputed per-layer cross K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (
    cross_entropy,
    dense,
    embed,
    logits as unembed_logits,
    rms_norm,
)
from repro.models.lm import _attn_block, _dt, _ffn_block, init_cache


def _cross_attn_block(lp, cfg: ModelConfig, x, enc_k, enc_v):
    """Cross-attention: queries from decoder stream, K/V precomputed."""
    b, s, _ = x.shape
    h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
    q = dense(h, lp["x_wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    o = attn.flash_attention(q, enc_k, enc_v, causal=False)
    return x + dense(o.reshape(b, s, -1), lp["x_wo"])


def _cross_kv(lp, cfg: ModelConfig, enc_out):
    b, se, _ = enc_out.shape
    k = dense(enc_out, lp["x_wk"]).reshape(b, se, cfg.n_kv, cfg.hd)
    v = dense(enc_out, lp["x_wv"]).reshape(b, se, cfg.n_kv, cfg.hd)
    return k, v


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, F, d) stubbed frontend embeddings -> encoder states."""
    x = frames.astype(_dt(cfg))
    positions = jnp.arange(x.shape[1])[None, :]

    def layer(carry, lp):
        x, aux = carry
        x, _ = _attn_block(lp, cfg, x, positions, causal=False)
        x, a = _ffn_block(lp, cfg, x)
        return (x, aux + a), None

    (x, _), _ = jax.lax.scan(
        layer, (x, jnp.zeros((), jnp.float32)), params["enc_layers"]
    )
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def trunk(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    frames: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced decoder hidden states (pre-unembedding)."""
    enc = encode(params, cfg, frames)
    x = embed(tokens, params["embed"], _dt(cfg))
    positions = jnp.arange(x.shape[1])[None, :]

    def layer(carry, lp):
        x, aux = carry
        x, _ = _attn_block(lp, cfg, x, positions, causal=True)
        ek, ev = _cross_kv(lp, cfg, enc)
        x = _cross_attn_block(lp, cfg, x, ek, ev)
        x, a = _ffn_block(lp, cfg, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        layer, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    frames: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced decoder logits. tokens: (B, S); frames: (B, F, d)."""
    x, aux = trunk(params, cfg, tokens, frames)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(x, table, cfg.vocab), aux


def loss_fn(params, cfg, tokens, labels, frames, aux_weight: float = 0.0):
    lg, aux = forward(params, cfg, tokens, frames)
    return cross_entropy(lg, labels, cfg.vocab) + aux_weight * aux, (aux,)


def cross_cache_struct(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    """Abstract stand-in for one cross-attention cache leaf, (L, B, F,
    Hkv, D) — the single source of the shape for ``launch.specs`` (input
    stand-ins) and ``dist.sharding`` (cache specs)."""
    shape = (cfg.n_layers, batch, cfg.frontend_len, cfg.n_kv, cfg.hd)
    return jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype))


def with_cross_caches(cache: dict, cfg: ModelConfig, batch: int) -> dict:
    """Copy of ``cache`` with abstract cross-attention leaves appended —
    the one place the decode-state tree gains its encdec extras."""
    kv = cross_cache_struct(cfg, batch)
    out = dict(cache)
    out.setdefault("cross_k", kv)
    out.setdefault("cross_v", kv)
    return out


def init_decode_state(params, cfg: ModelConfig, frames, max_len: int) -> dict:
    """Precompute cross K/V for every decoder layer + empty self cache."""
    enc = encode(params, cfg, frames)
    xk, xv = jax.vmap(
        lambda lp: _cross_kv(lp, cfg, enc)
    )(params["layers"])  # (L, B, F, Hkv, D)
    cache = init_cache(cfg, frames.shape[0], max_len)
    cache["cross_k"], cache["cross_v"] = xk, xv
    return cache


def decode_step(
    params: dict, cfg: ModelConfig, token: jnp.ndarray, cache: dict
) -> tuple[jnp.ndarray, dict]:
    from repro.models.lm import _decode_attn_block

    x = embed(token, params["embed"], _dt(cfg))
    pos = cache["len"]
    b = x.shape[0]

    def layer(carry, inp):
        x, _aux = carry
        lp, kc, vc, xk, xv = inp
        x, kc, vc = _decode_attn_block(lp, cfg, x, kc, vc, pos)
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        q = dense(h, lp["x_wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
        o = attn.decode_attention(
            q, xk, xv, jnp.asarray(xk.shape[1], jnp.int32)
        )
        x = x + dense(o.reshape(b, 1, -1), lp["x_wo"])
        x, a = _ffn_block(lp, cfg, x)
        return (x, _aux + a), (kc, vc)

    (x, _), (ks, vs) = jax.lax.scan(
        layer,
        (x, jnp.zeros((), jnp.float32)),
        (params["layers"], cache["k"], cache["v"], cache["cross_k"],
         cache["cross_v"]),
    )
    new_cache = dict(cache, k=ks, v=vs, len=pos + 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(x, table, cfg.vocab), new_cache
