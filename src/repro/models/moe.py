"""Mixture-of-Experts FFN: capacity dispatch for training, dropless for
serving.

Expert-parallel design (DESIGN.md §5): expert weights are stacked on a
leading E axis and sharded over the 'model' mesh axis. The *training*
dispatch is batched over experts — each expert top-k-selects its C
highest-gate tokens (capacity C = tokens * top_k * capacity_factor / E),
gathers them, runs the FFN as one batched einsum over (E, C, d), and
scatter-adds the combined outputs. Everything is static-shaped (tokens
beyond capacity drop, standard GShard-style), so it lowers cleanly under
GSPMD at 512 devices.

*Serving* routes through ``moe_ffn_dropless`` instead: capacity
selection is a cross-token top-k, so a token's output depends on what
else shares its dispatch group — which breaks chunked prefill, prefix
caching, and padded batching. The dropless path gives every token its
full top-k mix with no competition, restoring per-token determinism,
and takes a per-expert stream mask so cold expert FFNs can pull their
weights HBM→VMEM under a residency budget.

This is the architecture family where the paper's insight bites hardest:
64 small (d_ff 1024/1408) expert FFNs are exactly the "many oddly-shaped
parameter buffers" whose packed storage the FCMP planner optimizes
(DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# Expert-parallel sharding hook (EXPERIMENTS.md §Perf iteration 6): pin
# the dispatched (E, C, d) tensors to the expert axis so GSPMD never
# "involuntarily" replicates the dispatch gather's transpose (a 5.4 GiB
# f32 all-reduce per MoE layer on olmoe train_4k).
_EP = {"axis": None}


def set_moe_ep_axis(axis) -> None:
    _EP["axis"] = axis


def _ep_shard_bec(t):
    """Pin a (B, E, ...) dispatch tensor: B on data, E on the EP axis."""
    if _EP["axis"] is None:
        return t
    from jax.sharding import PartitionSpec as P

    spec = P("data", _EP["axis"], *([None] * (t.ndim - 2)))
    return jax.lax.with_sharding_constraint(t, spec)


def moe_capacity(cfg: ModelConfig, group_tokens: int) -> int:
    """Per-group expert capacity (groups = batch rows, GShard-style).

    The raw capacity ``S * k * cf / E`` is rounded up to the 8-sublane
    boundary only *above* 8 (tiny groups keep their exact capacity
    instead of degenerating to the rounding grain), then clamped to the
    group size — the round-up may otherwise exceed ``group_tokens`` and
    gather out-of-range rows. Train-path only; serving routes dropless.
    """
    cap = int(
        group_tokens
        * cfg.experts_per_token
        * cfg.capacity_factor
        / cfg.n_experts
    )
    cap = max(1, cap)
    if cap >= 8:
        cap = (cap + 7) // 8 * 8
    return min(group_tokens, cap)


def _token_gates(x, router, cfg: ModelConfig):
    """Per-token top-k routing shared by both dispatch paths.

    Returns (gate (B, S, E) dense mix weights — zero off the top-k —
    probs (B, S, E), onehot (B, S, k, E)). Depends on each token's own
    hidden state only, never on the rest of the batch.
    """
    e, k = cfg.n_experts, cfg.experts_per_token
    gate_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), router
    )
    probs = jax.nn.softmax(gate_logits, axis=-1)  # (B, S, E)
    top_g, top_i = jax.lax.top_k(probs, k)  # (B, S, k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, axis=-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)  # (B, S, k, E)
    gate = jnp.einsum("bske,bsk->bse", onehot, top_g)
    return gate, probs, onehot


def moe_ffn_dropless(
    x: jnp.ndarray,
    router: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    w2: jnp.ndarray,
    cfg: ModelConfig,
    *,
    stream_mask: jnp.ndarray | None = None,
    stream_depth: int = 2,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dropless per-token dispatch: every token keeps its full top-k mix.

    No cross-token capacity competition — each token's output is a pure
    function of its own hidden state and the expert weights, so chunked
    prefill, bare-suffix prefill, and padded batching are exact (the
    serving entry points route here; training keeps ``moe_ffn``'s
    batched-capacity einsum). Experts are visited by a ``lax.scan`` —
    one (B*S, d) matmul trio per expert, weighted by the dense gate —
    which keeps the budgeted and unbudgeted paths on the *same*
    accumulation order, so expert streaming is bit-identical.

    ``stream_mask`` (E,) bool marks cold experts whose w1/w3/w2 stream
    HBM→VMEM through ``kernels.ops.stream_matmul`` (the manual-DMA ring;
    jnp reference on CPU, bit-identical to the resident path). None
    keeps every expert resident.

    Returns (output (B, S, d), per-expert routed-token counts (E,) f32
    — the expert-load gauge; padded rows route too and are counted).
    """
    from repro.kernels import ops

    b, s, d = x.shape
    e = cfg.n_experts
    ff = w1.shape[-1]
    gate, _, onehot = _token_gates(x, router, cfg)
    counts = jnp.sum(onehot, axis=(0, 1, 2))  # (E,)

    x2 = x.astype(jnp.float32)
    mask = (
        jnp.zeros((e,), bool)
        if stream_mask is None
        else jnp.asarray(stream_mask, bool)
    )

    def _resident(args):
        xr, w1e, w3e, w2e = args
        h = jax.nn.silu(xr @ w1e) * (xr @ w3e)
        return h @ w2e

    def _streamed(args):
        xr, w1e, w3e, w2e = args
        h = jax.nn.silu(
            ops.stream_matmul(
                xr, w1e, bits=0, k=d, stream_depth=stream_depth
            )
        ) * ops.stream_matmul(
            xr, w3e, bits=0, k=d, stream_depth=stream_depth
        )
        return ops.stream_matmul(
            h, w2e, bits=0, k=ff, stream_depth=stream_depth
        )

    def _one_expert(acc, leaf):
        w1e, w3e, w2e, ge, cold = leaf
        ye = jax.lax.cond(
            cold, _streamed, _resident,
            (x2.reshape(b * s, d), w1e.astype(jnp.float32),
             w3e.astype(jnp.float32), w2e.astype(jnp.float32)),
        )
        return acc + ge.reshape(b * s)[:, None] * ye, None

    acc, _ = jax.lax.scan(
        _one_expert,
        jnp.zeros((b * s, d), jnp.float32),
        (w1, w3, w2, gate.transpose(2, 0, 1), mask),
    )
    return acc.reshape(b, s, d).astype(x.dtype), counts


def moe_ffn(
    x: jnp.ndarray,
    router: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    w2: jnp.ndarray,
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d); router: (d, E); w1/w3: (E, d, ff); w2: (E, ff, d).

    GROUPED dispatch (§Perf iteration 6): each batch row is a dispatch
    group with its own per-expert capacity C = S*k*cf/E, so token
    gather/scatter never crosses the data axis (a global top-k needed a
    5.4 GiB distributed gather per layer); the only inter-device traffic
    is the (B, S, d) bf16 combine psum over the expert (model) axis.
    Returns (output (B, S, d), aux load-balance loss scalar).
    """
    b, s, d = x.shape
    e = cfg.n_experts

    # dense (B, S, E) gate matrix: zero where the expert is not in top-k
    gate, probs, onehot = _token_gates(x, router, cfg)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(onehot.sum(axis=2), axis=(0, 1))  # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    cap = moe_capacity(cfg, s)
    # per group, each expert picks its C strongest tokens (static shapes;
    # tokens beyond capacity drop — standard GShard behaviour)
    g_bes = gate.transpose(0, 2, 1)  # (B, E, S)
    sel_g, sel_i = jax.lax.top_k(g_bes, cap)  # (B, E, C)
    sel_i = _ep_shard_bec(sel_i)

    # row-local gather; activations stay in the compute dtype (bf16)
    xe = jnp.take_along_axis(
        x, sel_i.reshape(b, e * cap)[..., None], axis=1
    ).reshape(b, e, cap, d)
    xe = _ep_shard_bec(xe)
    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", xe, w1.astype(xe.dtype))
    ) * jnp.einsum("becd,edf->becf", xe, w3.astype(xe.dtype))
    ye = jnp.einsum("becf,efd->becd", h, w2.astype(h.dtype))  # (B, E, C, d)

    gate_scale = ((sel_g > 0.0) * sel_g).astype(ye.dtype)
    ye = _ep_shard_bec(ye * gate_scale[..., None])
    # row-local combine scatter, vmapped over the batch so the lowered
    # scatter carries explicit batching dims (GSPMD shards those; the
    # hand-indexed form was replicated at the GLOBAL batch — an 8.6 GiB
    # f32 all-reduce per layer). The cross-expert sum is the psum GSPMD
    # inserts over the 'model' axis.
    yf = jax.vmap(
        lambda y_r, i_r: jnp.zeros((s, d), ye.dtype).at[i_r].add(y_r)
    )(ye.reshape(b, e * cap, d), sel_i.reshape(b, e * cap))
    if _EP["axis"] is not None:
        from jax.sharding import PartitionSpec as P

        yf = jax.lax.with_sharding_constraint(yf, P("data", None, None))
    return yf, aux.astype(jnp.float32)
