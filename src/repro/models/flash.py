"""Flash attention with a FlashAttention-2-style custom VJP.

Why this exists (EXPERIMENTS.md §Perf): differentiating the naive
scan-of-scans online softmax lets JAX save every KV block's probability
tensor for the backward — the dry-run HLO shows stacked
f32 (nq, nk, B, h, g, qb, kb) residuals (16 GiB/device on smollm
train_4k). The custom VJP saves only (out, lse) and *recomputes* each
block's scores in the backward (the FlashAttention-2 recipe), restoring
the O(S) memory the technique promises.

Structural points (each one a logged §Perf iteration):
  1. **custom VJP + static causal block skipping** — the q/kv block loops
     are Python loops (trip counts are trace-time constants), so each q
     block scans only its causal/window-reachable KV prefix: ~2x fewer
     blocks for causal, ~S/window for sliding-window prefill.
  2. **bf16 p/ds into the MXU** with f32 accumulation (standard FA2).
  3. **dot-native layout**: everything runs in (B, Hkv, G*qb, D/kb)
     with heads as leading batch dims — one transpose at entry/exit
     instead of XLA relayout copies around every block dot (26% of the
     baseline traffic was transposes).
  4. **rank-(qb, kb) masks** as f32 addends broadcast in-fusion instead
     of full-rank pred selects (which XLA hoisted as multi-GiB booleans).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pick_block(s: int, target: int) -> int:
    for d in range(min(target, s), 0, -1):
        if s % d == 0:
            return d
    return 1


def _kv_range(qi: int, nk: int, qb: int, kb: int, q_offset: int,
              causal: bool, window: int) -> tuple[int, int]:
    """Static KV block range reachable from q block ``qi``."""
    q_lo = q_offset + qi * qb
    q_hi = q_lo + qb - 1
    stop = min(nk, (q_hi // kb) + 1) if causal else nk
    start = max(0, (q_lo - window + 1) // kb) if window > 0 else 0
    return start, stop


def _q_range(kj: int, nq: int, qb: int, kb: int, q_offset: int,
             causal: bool, window: int) -> tuple[int, int]:
    """Static q block range that can see KV block ``kj`` (bwd loop)."""
    k_lo, k_hi = kj * kb, kj * kb + kb - 1
    start = max(0, (k_lo - q_offset) // qb) if causal else 0
    stop = nq
    if window > 0:
        stop = min(nq, ((k_hi + window - 1 - q_offset) // qb) + 1)
    return start, stop


def _mask_addend(qi, kj, qb, kb, g, q_offset, causal, window):
    """(g*qb, kb) f32 additive mask for block (qi static, kj traced)."""
    q_pos = q_offset + qi * qb + jnp.arange(qb)
    k_pos = kj * kb + jnp.arange(kb)
    neg = jnp.zeros((qb, kb), jnp.float32)
    if causal:
        neg = jnp.where(q_pos[:, None] >= k_pos[None, :], neg, NEG_INF)
    if window > 0:
        neg = jnp.where(q_pos[:, None] - k_pos[None, :] < window, neg,
                        NEG_INF)
    return jnp.broadcast_to(neg[None], (g, qb, kb)).reshape(g * qb, kb)


def _heads_layout(x, hkv, g):
    """(B, S, Hkv*G, D) -> (B, Hkv, G, S, D)."""
    b, s, _, d = x.shape
    return x.reshape(b, s, hkv, g, d).transpose(0, 2, 3, 1, 4)


def _lowp_of(x):
    return jnp.bfloat16 if x.dtype == jnp.bfloat16 else x.dtype


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, qb, kb, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, qb, kb, q_offset)
    return out


def _flash_fwd_impl(q, k, v, causal, window, qb, kb, q_offset):
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    nq, nk = sq // qb, sk // kb
    scale = 1.0 / math.sqrt(d)
    lowp = _lowp_of(q)

    qh = _heads_layout(q, hkv, g)  # (B, Hkv, G, Sq, D)
    kh = k.transpose(0, 2, 1, 3)  # (B, Hkv, Sk, D)
    vh = v.transpose(0, 2, 1, 3)

    outs, lses = [], []
    for qi in range(nq):
        q_blk = qh[:, :, :, qi * qb : (qi + 1) * qb, :].reshape(
            b, hkv, g * qb, d
        )
        start, stop = _kv_range(qi, nk, qb, kb, q_offset, causal, window)
        if start >= stop:
            outs.append(jnp.zeros((b, hkv, g, qb, d), q.dtype))
            lses.append(jnp.full((b, hkv, g * qb), NEG_INF, jnp.float32))
            continue

        def step(carry, inp, qi=qi, q_blk=q_blk):
            m_run, l_run, acc = carry
            kj, k_blk, v_blk = inp
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            neg = _mask_addend(qi, kj, qb, kb, g, q_offset, causal, window)
            s = s + neg[None, None]
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where((neg < 0)[None, None], 0.0, p)
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(lowp), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g * qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g * qb), jnp.float32)
        a0 = jnp.zeros((b, hkv, g * qb, d), jnp.float32)
        n_blk = stop - start
        ks = jnp.moveaxis(
            kh[:, :, start * kb : stop * kb].reshape(b, hkv, n_blk, kb, d),
            2, 0,
        )
        vs = jnp.moveaxis(
            vh[:, :, start * kb : stop * kb].reshape(b, hkv, n_blk, kb, d),
            2, 0,
        )
        (m_f, l_f, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), (jnp.arange(start, stop), ks, vs)
        )
        out_qi = acc / jnp.maximum(l_f, 1e-30)[..., None]
        outs.append(out_qi.reshape(b, hkv, g, qb, d).astype(q.dtype))
        lses.append(m_f + jnp.log(jnp.maximum(l_f, 1e-30)))

    # (B, Hkv, G, nq, qb, D) -> (B, Sq, Hq, D): single exit transpose
    out = jnp.stack(outs, axis=3)
    out = out.transpose(0, 3, 4, 1, 2, 5).reshape(b, sq, hq, d)
    lse = jnp.stack(lses, axis=2)  # (B, Hkv, nq, G*qb)
    return out, lse


def _flash_fwd(q, k, v, causal, window, qb, kb, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, qb, kb, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, qb, kb, q_offset, res, do):
    q, k, v, out, lse = res
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    nq, nk = sq // qb, sk // kb
    scale = 1.0 / math.sqrt(d)
    lowp = _lowp_of(q)

    qh = _heads_layout(q, hkv, g)  # (B, Hkv, G, Sq, D)
    doh = _heads_layout(do, hkv, g)
    oh = _heads_layout(out, hkv, g)
    kh = k.transpose(0, 2, 1, 3)  # (B, Hkv, Sk, D)
    vh = v.transpose(0, 2, 1, 3)
    # FA2 preamble: delta[b,h,g,s] = sum_d do * o
    delta = jnp.einsum(
        "bhgsd,bhgsd->bhgs", doh.astype(jnp.float32), oh.astype(jnp.float32)
    )

    def q_slab(a, qs, qe):
        """(B,Hkv,G,Sq,D) -> scan xs (n, B, Hkv, G*qb, D) over blocks."""
        n = qe - qs
        sl = a[:, :, :, qs * qb : qe * qb, :].reshape(
            a.shape[0], hkv, g, n, qb, d
        )
        return jnp.moveaxis(sl, 3, 0).reshape(
            n, a.shape[0], hkv, g * qb, d
        )

    dq = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    dks, dvs = [], []
    for kj in range(nk):
        k_blk = kh[:, :, kj * kb : (kj + 1) * kb, :]
        v_blk = vh[:, :, kj * kb : (kj + 1) * kb, :]
        qs, qe = _q_range(kj, nq, qb, kb, q_offset, causal, window)
        if qs >= qe:
            dks.append(jnp.zeros((b, hkv, kb, d), jnp.float32))
            dvs.append(jnp.zeros((b, hkv, kb, d), jnp.float32))
            continue

        def q_step(carry, inp, kj=kj, k_blk=k_blk, v_blk=v_blk):
            dk_j, dv_j, dq_acc = carry
            qi, q_blk, do_blk, lse_blk, delta_blk = inp
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            neg = _mask_addend(qi, kj, qb, kb, g, q_offset, causal, window)
            p = jnp.exp(s + neg[None, None] - lse_blk[..., None])
            dov = jnp.einsum(
                "bhqd,bhkd->bhqk", do_blk, v_blk,
                preferred_element_type=jnp.float32,
            )
            ds = p * (dov - delta_blk[..., None]) * scale
            # bf16 p/ds into the MXU, f32 accumulation (§Perf iteration 2)
            p_lo, ds_lo = p.astype(lowp), ds.astype(lowp)
            dv_j = dv_j + jnp.einsum(
                "bhqk,bhqd->bhkd", p_lo, do_blk,
                preferred_element_type=jnp.float32,
            )
            dk_j = dk_j + jnp.einsum(
                "bhqk,bhqd->bhkd", ds_lo, q_blk,
                preferred_element_type=jnp.float32,
            )
            dq_i = jnp.einsum(
                "bhqk,bhkd->bhqd", ds_lo, k_blk,
                preferred_element_type=jnp.float32,
            ).reshape(dq_acc.shape[0], hkv, g, qb, d)
            old = jax.lax.dynamic_slice_in_dim(dq_acc, qi * qb, qb, axis=3)
            dq_acc = jax.lax.dynamic_update_slice_in_dim(
                dq_acc, old + dq_i, qi * qb, axis=3
            )
            return (dk_j, dv_j, dq_acc), None

        dk0 = jnp.zeros((b, hkv, kb, d), jnp.float32)
        dv0 = jnp.zeros((b, hkv, kb, d), jnp.float32)
        lse_xs = jnp.moveaxis(lse[:, :, qs:qe], 2, 0)  # (n, B, Hkv, G*qb)
        delta_xs = jnp.moveaxis(
            delta[:, :, :, qs * qb : qe * qb].reshape(
                b, hkv, g, qe - qs, qb
            ),
            3, 0,
        ).reshape(qe - qs, b, hkv, g * qb)
        (dk_j, dv_j, dq), _ = jax.lax.scan(
            q_step,
            (dk0, dv0, dq),
            (jnp.arange(qs, qe), q_slab(qh, qs, qe), q_slab(doh, qs, qe),
             lse_xs, delta_xs),
        )
        dks.append(dk_j)
        dvs.append(dv_j)

    # exit transposes (one per tensor)
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d).astype(q.dtype)
    dk = (
        jnp.stack(dks, axis=2)  # (B, Hkv, nk, kb, D)
        .reshape(b, hkv, sk, d)
        .transpose(0, 2, 1, 3)
        .astype(k.dtype)
    )
    dv = (
        jnp.stack(dvs, axis=2)
        .reshape(b, hkv, sk, d)
        .transpose(0, 2, 1, 3)
        .astype(v.dtype)
    )
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D); Hq % Hkv == 0."""
    sq, sk = q.shape[1], k.shape[1]
    qb = _pick_block(sq, q_block)
    kb = _pick_block(sk, kv_block)
    return _flash(q, k, v, causal, window, qb, kb, q_offset)
