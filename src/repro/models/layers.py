"""Shared neural-net layers: RMSNorm, RoPE, SwiGLU, embeddings, projections.

Linear layers optionally run through the FCMP packed-weight path
(``kernels.packed_matmul``) when the config requests 1/2-bit weights: the
quantized codes are carried bit-packed exactly as the paper's BRAM-packed
memories, and unpacked next to the compute unit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * g.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (..., K) @ w: (K, N) in the compute dtype of x."""
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))


def swiglu(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray, w2: jnp.ndarray):
    h = jax.nn.silu(dense(x, w1)) * dense(x, w3)
    return dense(h, w2)


def embed(tokens: jnp.ndarray, table: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def logits(x: jnp.ndarray, table: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Tied/untied unembedding; padded vocab columns masked to -inf."""
    out = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    out = out.astype(jnp.float32)
    pv = table.shape[0]
    if pv > vocab:
        mask = jnp.arange(pv) < vocab
        out = jnp.where(mask, out, -1e30)
    return out


def cross_entropy(
    logit: jnp.ndarray, labels: jnp.ndarray, vocab: int
) -> jnp.ndarray:
    """Mean next-token CE over all positions; logit (..., V), labels (...)."""
    logp = jax.nn.log_softmax(logit, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def chunked_softmax_xent(
    x: jnp.ndarray,
    table: jnp.ndarray,
    labels: jnp.ndarray,
    vocab: int,
    chunk: int = 512,
) -> jnp.ndarray:
    """Fused unembed + CE, scanned over sequence chunks.

    Never materialises the full (B, S, V) logits tensor — the live buffer is
    (B, chunk, V), and each chunk is rematerialised in the backward pass.
    x: (B, S, d) final hidden states; table: (V_padded, d); labels: (B, S).
    Returns the mean CE. The label pick is a masked reduction (iota ==
    label), not a gather, so it lowers to a partial sum + psum when the
    vocab dim is 'model'-sharded (no all-gather of logits).
    """
    b, s, d = x.shape
    c = min(chunk, s)
    if s % c != 0:  # fall back (smoke-test shapes)
        c = s
    nc = s // c
    xc = x.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    pv = table.shape[0]
    col = jnp.arange(pv)

    @jax.checkpoint
    def chunk_nll(xi, li):
        lg = jnp.einsum("bcd,vd->bcv", xi, table.astype(xi.dtype))
        lg = lg.astype(jnp.float32)
        if pv > vocab:
            lg = jnp.where(col < vocab, lg, -1e30)
        m = jnp.max(lg, axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
        picked = jnp.sum(
            jnp.where(col == li[..., None], lg, 0.0), axis=-1
        )
        return jnp.sum(lse - picked)

    def body(acc, inp):
        xi, li = inp
        return acc + chunk_nll(xi, li), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)
