"""Unified LM-family model: dense / MoE / SSM / hybrid / VLM, one codebase.

Design (DESIGN.md §2): one parameter pytree with *stacked* per-layer leaves
(leading axis = layer) consumed by ``lax.scan`` — this keeps HLO size and
compile time flat in depth (80-layer internvl2 compiles as fast as 16-layer
olmoe), and it is what makes the 512-device dry-run tractable on a CPU
host.

Entry points:
  * ``init_params(cfg, key)``      — real arrays (smoke tests / training)
  * ``abstract_params(cfg)``       — ShapeDtypeStructs (dry-run, no alloc)
  * ``forward(params, cfg, tokens, ...)``      — train/prefill logits
  * ``init_cache(cfg, batch, max_len)``        — decode state
  * ``prefill(params, cfg, tokens, cache)``    — fill cache, return logits
  * ``decode_step(params, cfg, token, cache)`` — one-token serve step

The FCMP packed-weight path: with ``cfg.w_bits`` in {1, 2} the FFN weight
leaves are stored as uint8 carriers + per-channel scales (8x/4x fewer HBM
bytes — the paper's OCM packing, DESIGN.md §3) and are decoded next to the
matmul. The decode is pure-jnp here so it lowers through GSPMD for the
dry-run; the Pallas ``packed_matmul`` kernel is the TPU execution path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ATTN_KV_FAMILIES, ModelConfig
from repro.models.layers import (
    apply_rope,
    cross_entropy,
    dense,
    embed,
    logits as unembed_logits,
    rms_norm,
    swiglu,
)


# --------------------------------------------------------------------------
# Packed (FCMP) weight leaves
# --------------------------------------------------------------------------


def _pack_leaf_shapes(shape: tuple[int, ...], bits: int):
    """(..., K, N) weight -> carrier (..., K*bits/8, N) uint8 + scale (...,N)."""
    *lead, k, n = shape
    per = 8 // bits
    assert k % per == 0, (shape, bits)
    return tuple(lead) + (k // per, n), tuple(lead) + (n,)


def make_packed(w: jnp.ndarray, bits: int) -> dict[str, jnp.ndarray]:
    """Quantize + pack a float weight (..., K, N) into the carrier format."""
    from repro.quant.quantizers import pack_bits

    axes = tuple(range(w.ndim - 1))
    if bits == 1:
        scale = jnp.mean(jnp.abs(w), axis=-2)  # (..., N)
        codes = (w > 0).astype(jnp.uint8)
    else:
        mean_abs = jnp.mean(jnp.abs(w), axis=-2, keepdims=True)
        delta = 0.7 * mean_abs
        mask = jnp.abs(w) > delta
        scale = jnp.sum(jnp.abs(w) * mask, axis=-2) / jnp.maximum(
            jnp.sum(mask, axis=-2), 1.0
        )
        codes = (jnp.sign(w) * mask + 1).astype(jnp.uint8)
    per = 8 // bits
    k = w.shape[-2]
    # pack along axis -2
    moved = jnp.moveaxis(codes, -2, 0)
    packed = pack_bits(moved, bits)
    packed = jnp.moveaxis(packed, 0, -2)
    return {"packed": packed, "scale": scale.astype(jnp.float32)}


def _unpack_codes(packed: jnp.ndarray, bits: int) -> jnp.ndarray:
    """uint8 carrier (..., Kc, N) -> codes (..., Kc*per, N) along axis -2."""
    per = 8 // bits
    mask = jnp.uint8(2**bits - 1)
    shifts = jnp.arange(per, dtype=jnp.uint8) * bits
    planes = (packed[..., None, :] >> shifts[:, None]) & mask  # (...,Kc,per,N)
    new_shape = packed.shape[:-2] + (packed.shape[-2] * per, packed.shape[-1])
    return planes.reshape(new_shape)


def packed_dense(x: jnp.ndarray, w: Any, bits: int) -> jnp.ndarray:
    """Matmul against a dense or packed weight leaf."""
    if not isinstance(w, dict):
        return dense(x, w)
    codes = _unpack_codes(w["packed"], bits).astype(x.dtype)
    vals = codes * 2.0 - 1.0 if bits == 1 else codes - 1.0
    out = jnp.einsum("...k,kn->...n", x, vals)
    return out * w["scale"].astype(x.dtype)


def packed_swiglu(x, w1, w3, w2, bits: int):
    h = jax.nn.silu(packed_dense(x, w1, bits)) * packed_dense(x, w3, bits)
    return packed_dense(h, w2, bits)


def _streamed_matmul(x: jnp.ndarray, w: Any, bits: int, depth: int):
    """Matmul with the weight left in HBM and streamed through a VMEM ring
    (``kernels.weight_stream``; the jnp reference on CPU — same math as
    the resident path, so budgeted decode stays token-identical)."""
    from repro.kernels.ops import stream_matmul

    kdim = x.shape[-1]
    if isinstance(w, dict):
        out = stream_matmul(
            x, w["packed"], w["scale"], bits=bits, k=kdim, stream_depth=depth
        )
    else:
        out = stream_matmul(x, w, None, bits=0, k=kdim, stream_depth=depth)
    return out.astype(x.dtype)


def streamed_swiglu(x, w1, w3, w2, bits: int, depth: int):
    """The FFN of a non-resident layer: every mat streamed HBM->VMEM."""
    h = jax.nn.silu(_streamed_matmul(x, w1, bits, depth)) * _streamed_matmul(
        x, w3, bits, depth
    )
    return _streamed_matmul(h, w2, bits, depth)


# --------------------------------------------------------------------------
# Parameter initialisation
# --------------------------------------------------------------------------


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _maybe_pack(w: jnp.ndarray, cfg: ModelConfig):
    if cfg.w_bits in (1, 2):
        return make_packed(w, cfg.w_bits)
    return w


def _init_attn(key, cfg: ModelConfig, n: int, d: int):
    """Stacked attention projections for ``n`` layers over width ``d``."""
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    dt = _dt(cfg)
    return {
        "wq": (jax.random.normal(ks[0], (n, d, hq * hd), dt) * s),
        "wk": (jax.random.normal(ks[1], (n, d, hkv * hd), dt) * s),
        "wv": (jax.random.normal(ks[2], (n, d, hkv * hd), dt) * s),
        "wo": (jax.random.normal(ks[3], (n, hq * hd, d), dt) * s),
    }


def _init_ffn(key, cfg: ModelConfig, n: int, d: int, ff: int, lead=()):
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    dt = _dt(cfg)
    shp1 = (n,) + lead + (d, ff)
    shp2 = (n,) + lead + (ff, d)
    # FCMP packing applies to the dense-FFN families; the MoE expert
    # einsums consume dense stacked weights (lead = (E,)), so packed
    # carriers are not produced for them.
    pack = _maybe_pack if not lead else (lambda w, _cfg: w)
    return {
        "w1": pack(jax.random.normal(ks[0], shp1, dt) * s, cfg),
        "w3": pack(jax.random.normal(ks[1], shp1, dt) * s, cfg),
        "w2": pack(jax.random.normal(ks[2], shp2, dt) * s * 0.5, cfg),
    }


def _init_ssm(key, cfg: ModelConfig, n: int):
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, k = cfg.ssm_heads, cfg.conv_kernel
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    dt = _dt(cfg)
    return {
        "in_z": jax.random.normal(ks[0], (n, d, di), dt) * s,
        "in_x": jax.random.normal(ks[1], (n, d, di), dt) * s,
        "in_b": jax.random.normal(ks[2], (n, d, st), dt) * s,
        "in_c": jax.random.normal(ks[3], (n, d, st), dt) * s,
        "in_dt": jax.random.normal(ks[4], (n, d, h), dt) * s,
        "dt_bias": jnp.zeros((n, h), jnp.float32),
        "conv_x": jax.random.normal(ks[5], (n, k, di), dt) * 0.3,
        "conv_b": jax.random.normal(ks[6], (n, k, st), dt) * 0.3,
        "conv_c": jax.random.normal(ks[7], (n, k, st), dt) * 0.3,
        "a_log": jnp.zeros((n, h), jnp.float32),  # A = -1
        "d_skip": jnp.ones((n, h), jnp.float32),
        "gate_norm": jnp.ones((n, di), jnp.float32),
        "out": jax.random.normal(ks[5], (n, di, d), dt) * di**-0.5,
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d, ff, l = cfg.d_model, cfg.d_ff, cfg.n_layers
    pv = cfg.padded_vocab
    keys = jax.random.split(key, 8)
    dt = _dt(cfg)
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (pv, d), dt) * 0.02,
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(keys[1], (pv, d), dt) * 0.02

    if cfg.family in ("dense", "vlm"):
        params["layers"] = {
            "ln1": jnp.ones((l, d), jnp.float32),
            "ln2": jnp.ones((l, d), jnp.float32),
            **_init_attn(keys[2], cfg, l, d),
            **_init_ffn(keys[3], cfg, l, d, ff),
        }
    elif cfg.family == "moe":
        params["layers"] = {
            "ln1": jnp.ones((l, d), jnp.float32),
            "ln2": jnp.ones((l, d), jnp.float32),
            **_init_attn(keys[2], cfg, l, d),
            "router": jax.random.normal(
                keys[4], (l, d, cfg.n_experts), jnp.float32
            )
            * 0.02,
            **_init_ffn(keys[3], cfg, l, d, ff, lead=(cfg.n_experts,)),
        }
    elif cfg.family == "ssm":
        params["layers"] = {
            "ln1": jnp.ones((l, d), jnp.float32),
            **_init_ssm(keys[2], cfg, l),
        }
    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        assert l % every == 0, (l, every)
        params["layers"] = {
            "ln1": jnp.ones((l, d), jnp.float32),
            **_init_ssm(keys[2], cfg, l),
        }
        shared_attn = _init_attn(keys[3], cfg, 1, d)
        params["shared"] = {
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
            **{k: v[0] for k, v in shared_attn.items()},
            **jax.tree.map(lambda v: v[0], _init_ffn(keys[5], cfg, 1, d, ff)),
        }
    elif cfg.family == "encdec":
        params["layers"] = {  # decoder
            "ln1": jnp.ones((l, d), jnp.float32),
            "ln_x": jnp.ones((l, d), jnp.float32),
            "ln2": jnp.ones((l, d), jnp.float32),
            **_init_attn(keys[2], cfg, l, d),
            **{
                f"x_{k}": v
                for k, v in _init_attn(keys[4], cfg, l, d).items()
            },
            **_init_ffn(keys[3], cfg, l, d, ff),
        }
        le = cfg.n_enc_layers
        params["enc_layers"] = {
            "ln1": jnp.ones((le, d), jnp.float32),
            "ln2": jnp.ones((le, d), jnp.float32),
            **_init_attn(keys[5], cfg, le, d),
            **_init_ffn(keys[6], cfg, le, d, ff),
        }
        params["enc_final_norm"] = jnp.ones((d,), jnp.float32)
    else:
        raise ValueError(cfg.family)
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0)
    )


# --------------------------------------------------------------------------
# Layer bodies
# --------------------------------------------------------------------------


# Optional batch-resharding constraint for the attention region. When the
# head count doesn't divide the TP degree, GSPMD falls back to running
# attention REPLICATED across the model axis (16x redundant compute and
# HBM traffic — measured on smollm, EXPERIMENTS.md §Perf iteration 5).
# Setting a spec like P(('data','model')) reshards q/k/v batch-wise over
# the whole mesh for the attention math instead.
_ATTN_BATCH_SHARD = {"spec": None}
# Sequence-sharded prefill attention (§Perf iteration 8): used when the
# batch can't be resharded (prefill batch 32 on 256+ devices).
_ATTN_SEQ_SHARD = {"mesh": None, "axis": "model", "batch_axes": ("pod", "data")}


def set_attn_batch_sharding(spec) -> None:
    """PartitionSpec for the attention batch dim, or None to disable."""
    _ATTN_BATCH_SHARD["spec"] = spec


def set_attn_seq_sharding(mesh, axis: str = "model",
                          batch_axes=("pod", "data")) -> None:
    """Enable (mesh != None) / disable sequence-sharded prefill attention."""
    _ATTN_SEQ_SHARD.update(mesh=mesh, axis=axis, batch_axes=batch_axes)


def _attn_shard(t):
    spec = _ATTN_BATCH_SHARD["spec"]
    if spec is None:
        return t
    return jax.lax.with_sharding_constraint(t, spec)


def _qkv(lp, cfg: ModelConfig, x, positions):
    """Pre-norm q/k/v projection + RoPE shared by EVERY attention path
    (full-sequence, chunked prefill, and via ``_decode_qkv`` the one-token
    decode paths); x: (B, S, d), positions: (B|1, S). Keeping this single
    is what keeps all paths numerically equal."""
    b, s, _ = x.shape
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = dense(h, lp["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = dense(h, lp["wk"]).reshape(b, s, cfg.n_kv, cfg.hd)
    v = dense(h, lp["wv"]).reshape(b, s, cfg.n_kv, cfg.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_block(lp, cfg: ModelConfig, x, positions, *, causal=True, window=0):
    """Full-sequence attention sub-block (pre-norm residual)."""
    b, s, d = x.shape
    q, k, v = _qkv(lp, cfg, x, positions)
    seq_mesh = _ATTN_SEQ_SHARD["mesh"]
    if (
        seq_mesh is not None
        and s % seq_mesh.shape[_ATTN_SEQ_SHARD["axis"]] == 0
    ):
        o = attn.flash_attention_seq_sharded(
            q, k, v, causal=causal, window=window,
            mesh=seq_mesh, axis=_ATTN_SEQ_SHARD["axis"],
            batch_axes=_ATTN_SEQ_SHARD["batch_axes"],
        )
    else:
        q, k, v = _attn_shard(q), _attn_shard(k), _attn_shard(v)
        o = attn.flash_attention(q, k, v, causal=causal, window=window)
    return x + dense(o.reshape(b, s, -1), lp["wo"]), (k, v)


def _ffn_block(lp, cfg: ModelConfig, x, ln_name="ln2", *, dropless=False,
               expert_mask=None, stream_depth=2):
    """Pre-norm FFN residual. ``dropless`` switches the moe family onto
    the per-token serving dispatch (``moe_ffn_dropless``), whose second
    return is the (E,) expert-load tally instead of the train-path aux
    loss; ``expert_mask`` ((E,) bool) marks experts whose weights stream
    HBM->VMEM under a residency budget."""
    h = rms_norm(x, lp[ln_name], cfg.norm_eps)
    if cfg.family == "moe":
        if dropless:
            y, counts = moe_lib.moe_ffn_dropless(
                h, lp["router"], lp["w1"], lp["w3"], lp["w2"], cfg,
                stream_mask=expert_mask, stream_depth=stream_depth,
            )
            return x + y, counts
        y, aux = moe_lib.moe_ffn(
            h, lp["router"], lp["w1"], lp["w3"], lp["w2"], cfg
        )
        return x + y, aux
    if cfg.w_bits in (1, 2):
        y = packed_swiglu(h, lp["w1"], lp["w3"], lp["w2"], cfg.w_bits)
    else:
        y = swiglu(h, lp["w1"], lp["w3"], lp["w2"])
    return x + y, jnp.zeros((), jnp.float32)


def _ffn_block_streamed(lp, cfg: ModelConfig, x, depth: int):
    """`_ffn_block` for a layer the residency plan left in HBM: same
    pre-norm residual shape, weights streamed (dense-FFN families only)."""
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    y = streamed_swiglu(h, lp["w1"], lp["w3"], lp["w2"], cfg.w_bits, depth)
    return x + y, jnp.zeros((), jnp.float32)


def _conv_tail(u: jnp.ndarray, k: int, prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """Last ``k-1`` pre-conv inputs of a (B, S, C) sequence, left-padded
    with zeros when the sequence is shorter — exactly the decode-time
    ``conv_decode_step`` buffer after the sequence has been consumed.
    ``prev`` (B, K-1, C) is the buffer carried in from an earlier chunk
    of the same sequence (suffix prefill)."""
    if prev is not None:
        u = jnp.concatenate([prev.astype(u.dtype), u], axis=1)
    b, s, c = u.shape
    tail = u[:, max(0, s - (k - 1)):]
    pad = (k - 1) - tail.shape[1]
    if pad > 0:
        tail = jnp.concatenate(
            [jnp.zeros((b, pad, c), u.dtype), tail], axis=1
        )
    return tail


def _ssm_block(lp, cfg: ModelConfig, x, state=None, conv_bufs=None):
    """Mamba2 block: train path (state None), one-token decode path
    (state given, S == 1), or sequence-with-state path (state given,
    S > 1 — a suffix resumed from a carried SSD state + conv buffers,
    the prefix-cache / chunked-hybrid prefill case).

    All paths return ``(x_out, new_state, new_bufs)``: the sequence
    paths' state/bufs are the *post-sequence* decode state (final SSD
    state + trailing pre-conv inputs), which is what lets a prefill hand
    a request straight to the per-token decode recurrence."""
    b = x.shape[0]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    z = dense(h, lp["in_z"])
    xi = dense(h, lp["in_x"])
    bi = dense(h, lp["in_b"])
    ci = dense(h, lp["in_c"])
    dt = jax.nn.softplus(
        dense(h, lp["in_dt"]).astype(jnp.float32) + lp["dt_bias"]
    )
    if state is None or x.shape[1] > 1:
        k = cfg.conv_kernel
        cx, cb, cc = conv_bufs if conv_bufs is not None else (None,) * 3
        new_bufs = (
            _conv_tail(xi, k, cx), _conv_tail(bi, k, cb),
            _conv_tail(ci, k, cc),
        )
        xi = ssm_lib.causal_conv(xi, lp["conv_x"], state=cx)
        bi = ssm_lib.causal_conv(bi, lp["conv_b"], state=cb)
        ci = ssm_lib.causal_conv(ci, lp["conv_c"], state=cc)
        s = x.shape[1]
        xh = xi.reshape(b, s, cfg.ssm_heads, cfg.ssm_head_dim)
        y, new_state = ssm_lib.ssd_chunked(
            xh, dt, lp["a_log"], bi, ci, lp["d_skip"], cfg.ssm_chunk,
            h0=state,
        )
        y = y.reshape(b, s, cfg.d_inner)
    else:
        cx, cb, cc = conv_bufs
        xi1, cx = ssm_lib.conv_decode_step(cx, xi[:, 0], lp["conv_x"])
        bi1, cb = ssm_lib.conv_decode_step(cb, bi[:, 0], lp["conv_b"])
        ci1, cc = ssm_lib.conv_decode_step(cc, ci[:, 0], lp["conv_c"])
        xh = xi1.reshape(b, cfg.ssm_heads, cfg.ssm_head_dim)
        y1, new_state = ssm_lib.ssd_decode_step(
            state, xh, dt[:, 0], lp["a_log"], bi1, ci1, lp["d_skip"]
        )
        y = y1.reshape(b, 1, cfg.d_inner)
        new_bufs = (cx, cb, cc)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 lp["gate_norm"], cfg.norm_eps)
    return x + dense(y, lp["out"]), new_state, new_bufs


# --------------------------------------------------------------------------
# Forward (train / prefill, full sequence)
# --------------------------------------------------------------------------


def trunk(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    prefix_embeds: jnp.ndarray | None = None,
    remat: str = "none",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """All layers + final norm, *without* the unembedding.

    Returns (hidden states over the token positions (B, S, d), aux loss).
    ``prefix_embeds`` (B, P, d) are pre-computed modality embeddings (vlm
    patches) prepended to the token embeddings.
    """
    x = embed(tokens, params["embed"], _dt(cfg))
    n_prefix = 0
    if prefix_embeds is not None:
        n_prefix = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :]

    layer_fn = _make_layer_fn(cfg, positions)
    if remat == "full":
        layer_fn = jax.checkpoint(layer_fn)
    elif remat == "dots":
        layer_fn = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )

    if cfg.family == "hybrid":
        x, aux = _hybrid_stack(params, cfg, x, positions, layer_fn)
    else:
        (x, aux), _ = jax.lax.scan(
            lambda carry, lp: (layer_fn(carry, lp), None),
            (x, jnp.zeros((), jnp.float32)),
            params["layers"],
        )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, n_prefix:], aux


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    prefix_embeds: jnp.ndarray | None = None,
    remat: str = "none",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. tokens: (B, S) int32. Returns (logits, aux)."""
    x, aux = trunk(
        params, cfg, tokens, prefix_embeds=prefix_embeds, remat=remat
    )
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(x, table, cfg.vocab), aux


def _make_layer_fn(cfg: ModelConfig, positions):
    def layer_fn(carry, lp):
        x, aux = carry
        if cfg.family in ("dense", "vlm", "moe"):
            x, _ = _attn_block(
                lp, cfg, x, positions, causal=True, window=cfg.sliding_window
            )
            x, a = _ffn_block(lp, cfg, x)
            return (x, aux + a)
        if cfg.family in ("ssm", "hybrid"):
            x, _, _ = _ssm_block(lp, cfg, x)
            return (x, aux)
        raise ValueError(cfg.family)

    return layer_fn


def _hybrid_stack(params, cfg: ModelConfig, x, positions, layer_fn):
    """Zamba2: scan over super-blocks of ``every`` ssm layers + one
    application of the single shared attention/FFN block."""
    every = cfg.hybrid_attn_every
    n_super = cfg.n_layers // every
    shaped = jax.tree.map(
        lambda v: v.reshape((n_super, every) + v.shape[1:]), params["layers"]
    )
    shared = params["shared"]

    def super_block(carry, lps):
        def inner(c, lp):
            return layer_fn(c, lp), None

        carry, _ = jax.lax.scan(inner, carry, lps)
        x, aux = carry
        x, _ = _attn_block(shared, cfg, x, positions, causal=True)
        x, a = _ffn_block(shared, cfg, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        super_block, (x, jnp.zeros((), jnp.float32)), shaped
    )
    return x, aux


def loss_fn(
    params, cfg: ModelConfig, tokens, labels, *, prefix_embeds=None,
    remat: str = "none", aux_weight: float = 0.01, ce_chunk: int = 0,
):
    """Training loss. ``ce_chunk > 0`` switches to the fused chunked
    unembed+CE (never materialises (B, S, V) logits — required for the
    128k-vocab train cells, EXPERIMENTS.md §Perf)."""
    from repro.models.layers import chunked_softmax_xent

    table_of = lambda: (
        params["embed"] if cfg.tie_embeddings else params["unembed"]
    )
    if ce_chunk:
        x, aux = trunk(
            params, cfg, tokens, prefix_embeds=prefix_embeds, remat=remat
        )
        ce = chunked_softmax_xent(
            x, table_of(), labels, cfg.vocab, chunk=ce_chunk
        )
    else:
        lg, aux = forward(
            params, cfg, tokens, prefix_embeds=prefix_embeds, remat=remat
        )
        ce = cross_entropy(lg, labels, cfg.vocab)
    return ce + aux_weight * aux, (ce, aux)


# --------------------------------------------------------------------------
# Decode: cache init, prefill, single-token step
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decode-state pytree. Attention caches are (L, B, W, Hkv, D) with W =
    min(max_len, sliding_window); ssm state is (L, B, H, P, N)."""
    dt = _dt(cfg)
    cache: dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    w = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv_shape = (cfg.n_layers, batch, w, cfg.n_kv, cfg.hd)
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        cache["k"] = jnp.zeros(kv_shape, dt)
        cache["v"] = jnp.zeros(kv_shape, dt)
    if cfg.family in ("ssm", "hybrid"):
        l = cfg.n_layers
        cache["ssm"] = jnp.zeros(
            (l, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        )
        k = cfg.conv_kernel
        cache["conv_x"] = jnp.zeros((l, batch, k - 1, cfg.d_inner), dt)
        cache["conv_b"] = jnp.zeros((l, batch, k - 1, cfg.ssm_state), dt)
        cache["conv_c"] = jnp.zeros((l, batch, k - 1, cfg.ssm_state), dt)
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.hybrid_attn_every
        cache["k"] = jnp.zeros(
            (n_super, batch, max_len, cfg.n_kv, cfg.hd), dt
        )
        cache["v"] = jnp.zeros(
            (n_super, batch, max_len, cfg.n_kv, cfg.hd), dt
        )
    return cache


# Decode-path split-d attention (EXPERIMENTS.md §Perf iteration 7): when
# KV heads don't divide TP, GSPMD re-shards the whole cache every step;
# the shard_map path in ``attention.decode_attention_split_d`` keeps the
# cache resident in its head_dim-sharded layout instead.
_DECODE_SPLIT_D = {"mesh": None, "axis": "model", "batch_axes": ("data",)}


def set_decode_split_d(mesh, axis: str = "model",
                       batch_axes=("pod", "data")) -> None:
    """Enable (mesh != None) / disable the split-d decode attention."""
    _DECODE_SPLIT_D.update(mesh=mesh, axis=axis, batch_axes=batch_axes)


def _decode_qkv(lp, cfg, x, pos_b):
    """One-token q/k/v for the decode paths (per-slot ring and
    pool-indexed paged); ``pos_b`` is (B, 1) positions. Delegates to the
    shared ``_qkv`` so every path stays numerically equal."""
    return _qkv(lp, cfg, x, pos_b)


def _decode_attn_block(lp, cfg, x, k_cache, v_cache, pos, *, window=0):
    """One-token attention against one layer's cache; returns new k/v row."""
    b = x.shape[0]
    pos_b = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k, v = _decode_qkv(lp, cfg, x, pos_b)
    w = k_cache.shape[1]
    slot = pos % w if window else jnp.minimum(pos, w - 1)
    k_cache = attn.cache_insert(k_cache, k, slot)
    v_cache = attn.cache_insert(v_cache, v, slot)
    if _DECODE_SPLIT_D["mesh"] is not None:
        o = attn.decode_attention_split_d(
            q, k_cache, v_cache, jnp.minimum(pos + 1, w), window=window,
            mesh=_DECODE_SPLIT_D["mesh"], axis=_DECODE_SPLIT_D["axis"],
            batch_axes=_DECODE_SPLIT_D["batch_axes"],
        )
    else:
        o = attn.decode_attention(
            q, k_cache, v_cache, jnp.minimum(pos + 1, w), window=window
        )
    return x + dense(o.reshape(b, 1, -1), lp["wo"]), k_cache, v_cache


def decode_step(
    params: dict, cfg: ModelConfig, token: jnp.ndarray, cache: dict
) -> tuple[jnp.ndarray, dict]:
    """One serving step: token (B, 1) -> (logits (B, 1, V), new cache)."""
    x = embed(token, params["embed"], _dt(cfg))
    pos = cache["len"]
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe"):
        def layer_fn(carry, lp_kv):
            x, aux = carry
            lp, kc, vc = lp_kv
            x, kc, vc = _decode_attn_block(
                lp, cfg, x, kc, vc, pos, window=cfg.sliding_window
            )
            x, a = _ffn_block(lp, cfg, x)
            return (x, aux + a), (kc, vc)

        (x, _), (ks, vs) = jax.lax.scan(
            layer_fn,
            (x, jnp.zeros((), jnp.float32)),
            (params["layers"], cache["k"], cache["v"]),
        )
        new_cache["k"], new_cache["v"] = ks, vs

    elif cfg.family == "ssm":
        def layer_fn(x, lp_state):
            lp, st, cx, cb, cc = lp_state
            x, st, bufs = _ssm_block(lp, cfg, x, state=st, conv_bufs=(cx, cb, cc))
            return x, (st, *bufs)

        x, (sts, cxs, cbs, ccs) = jax.lax.scan(
            layer_fn,
            x,
            (
                params["layers"],
                cache["ssm"],
                cache["conv_x"],
                cache["conv_b"],
                cache["conv_c"],
            ),
        )
        new_cache.update(ssm=sts, conv_x=cxs, conv_b=cbs, conv_c=ccs)

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_super = cfg.n_layers // every
        shaped = jax.tree.map(
            lambda v: v.reshape((n_super, every) + v.shape[1:]),
            params["layers"],
        )
        ssm_states = jax.tree.map(
            lambda v: v.reshape((n_super, every) + v.shape[1:]),
            (cache["ssm"], cache["conv_x"], cache["conv_b"], cache["conv_c"]),
        )
        shared = params["shared"]

        def super_block(x, inp):
            lps, (sts, cxs, cbs, ccs), kc, vc = inp

            def inner(x, lp_state):
                lp, st, cx, cb, cc = lp_state
                x, st, bufs = _ssm_block(
                    lp, cfg, x, state=st, conv_bufs=(cx, cb, cc)
                )
                return x, (st, *bufs)

            x, new_states = jax.lax.scan(inner, x, (lps, sts, cxs, cbs, ccs))
            x, kc, vc = _decode_attn_block(shared, cfg, x, kc, vc, pos)
            x, _ = _ffn_block(shared, cfg, x)
            return x, (new_states, kc, vc)

        x, (new_states, ks, vs) = jax.lax.scan(
            super_block, x, (shaped, ssm_states, cache["k"], cache["v"])
        )
        sts, cxs, cbs, ccs = new_states
        merge = lambda v: v.reshape((cfg.n_layers,) + v.shape[2:])
        new_cache.update(
            ssm=merge(sts), conv_x=merge(cxs), conv_b=merge(cbs),
            conv_c=merge(ccs), k=ks, v=vs,
        )
    else:
        raise ValueError(f"decode not supported for family {cfg.family}")

    new_cache["len"] = pos + 1
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(x, table, cfg.vocab), new_cache


def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    prefix_embeds=None,
) -> jnp.ndarray:
    """Prefill = the full-sequence forward (cache materialisation is the
    serving engine's job; the dry-run lowers the compute graph)."""
    lg, _ = forward(params, cfg, tokens, prefix_embeds=prefix_embeds)
    return lg


def prefill_with_cache(
    params: dict, cfg: ModelConfig, tokens: jnp.ndarray, last_idx: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence prefill that *keeps* the per-layer K/V rows.

    tokens: (B, S) right-padded prompts; ``last_idx`` the index of the last
    real token. Causality makes the padded tail inert for positions
    <= last_idx in every attention-KV family — dense/vlm trivially, and
    moe because serving routes through the dropless per-token dispatch
    (``moe_ffn_dropless``: a padded row's routing never touches a real
    row's output). Returns (next-token logits (B, 1, V), ks, vs) with
    ks/vs stacked (L, B, S, n_kv, hd) — already RoPE'd, i.e. exactly the
    rows the decode cache stores; the moe family appends a per-layer
    expert-load tally (L, E). Attention-KV families only.
    """
    if cfg.family not in ATTN_KV_FAMILIES:
        raise ValueError(f"prefill_with_cache: unsupported family {cfg.family}")
    moe = cfg.family == "moe"
    x = embed(tokens, params["embed"], _dt(cfg))
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]

    def layer_fn(carry, lp):
        x, aux = carry
        x, (k, v) = _attn_block(
            lp, cfg, x, positions, causal=True, window=cfg.sliding_window
        )
        if moe:
            x, counts = _ffn_block(lp, cfg, x, dropless=True)
            return (x, aux), (k, v, counts)
        x, a = _ffn_block(lp, cfg, x)
        return (x, aux + a), (k, v)

    (x, _), outs = jax.lax.scan(
        layer_fn, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    lg = unembed_logits(x_last, table, cfg.vocab)
    if moe:
        ks, vs, counts = outs
        return lg, ks, vs, counts
    ks, vs = outs
    return lg, ks, vs


def decode_step_paged(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    row_table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    stream_mask: jnp.ndarray | None = None,
    stream_depth: int = 2,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One serving step against a shared row-addressed KV pool.

    token: (B, 1) next token per decode lane; pool_k/pool_v:
    (L, R, n_kv, hd) physical pools; row_table: (B, S_max) physical row
    index of each lane's logical cache position (scratch-row padded);
    lengths: (B,) tokens already held per lane. The new token's K/V row is
    scattered to ``row_table[b, lengths[b]]``, then each lane attends over
    its gathered rows with per-lane positions (no lockstep shared length —
    lanes at different depths coexist in one batched step).

    ``stream_mask`` turns on the budgeted weight-residency path
    (``runtime.residency``). For the dense-FFN families it is (L,) bool:
    layers flagged True run their FFN through the HBM->VMEM weight
    streamer with ring depth ``stream_depth`` instead of the resident
    in-VMEM matmul. For moe it is (L, E) bool: per-(layer, expert) cold
    flags consumed by the dropless dispatch, which streams the flagged
    experts' w1/w3/w2 and keeps the pinned (hot) experts resident.
    Either way the mask is scanned with the layer leaves so the model
    still compiles as one scan.

    Returns (logits (B, 1, V), new pool_k, new pool_v); the moe family
    appends a per-layer expert-load tally (L, E).
    """
    if cfg.family not in ATTN_KV_FAMILIES:
        raise ValueError(f"decode_step_paged: unsupported family {cfg.family}")
    moe = cfg.family == "moe"
    x = embed(token, params["embed"], _dt(cfg))
    b = x.shape[0]
    s_max = row_table.shape[1]
    pos_b = lengths[:, None]  # (B, 1) position of the incoming token
    write_rows = jnp.take_along_axis(
        row_table, jnp.clip(lengths, 0, s_max - 1)[:, None], axis=1
    )[:, 0]

    def layer_fn(carry, lp_kv):
        x, aux = carry
        if stream_mask is None:
            lp, pk, pv = lp_kv  # pk/pv: (R, n_kv, hd) one layer's pool
            streamed = None
        else:
            lp, pk, pv, streamed = lp_kv
        q, k, v = _decode_qkv(lp, cfg, x, pos_b)
        pk = pk.at[write_rows].set(k[:, 0])
        pv = pv.at[write_rows].set(v[:, 0])
        o = attn.decode_attention(
            q, pk[row_table], pv[row_table], (lengths + 1)[:, None],
            window=cfg.sliding_window,
        )
        x = x + dense(o.reshape(b, 1, -1), lp["wo"])
        if moe:
            x, counts = _ffn_block(
                lp, cfg, x, dropless=True, expert_mask=streamed,
                stream_depth=stream_depth,
            )
            return (x, aux), (pk, pv, counts)
        if stream_mask is None:
            x, a = _ffn_block(lp, cfg, x)
        else:
            x, a = jax.lax.cond(
                streamed,
                lambda h: _ffn_block_streamed(lp, cfg, h, stream_depth),
                lambda h: _ffn_block(lp, cfg, h),
                x,
            )
        return (x, aux + a), (pk, pv)

    xs = (params["layers"], pool_k, pool_v)
    if stream_mask is not None:
        xs = xs + (stream_mask,)
    (x, _), outs = jax.lax.scan(
        layer_fn, (x, jnp.zeros((), jnp.float32)), xs
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    lg = unembed_logits(x, table, cfg.vocab)
    if moe:
        pks, pvs, counts = outs
        return lg, pks, pvs, counts
    pks, pvs = outs
    return lg, pks, pvs


def prefill_chunk_paged(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    row_table: jnp.ndarray,
    write_rows: jnp.ndarray,
    start: jnp.ndarray,
    last_idx: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefill one chunk of a prompt against the shared KV pool.

    Chunked prefill (ROADMAP): a prompt longer than the scheduler's
    admission token budget is split across rounds instead of monopolizing
    one round with a single huge prefill step. Each chunk attends over the
    request's *already-pooled* prefix (gathered through ``row_table``)
    plus itself, causally — flash attention with ``q_offset = start`` —
    and scatters its own K/V rows into the pool. ``start`` doubles as the
    matched-prefix offset of a prefix-cache hit: the warm path prefills
    only the unmatched suffix, attending over the adopted shared blocks
    exactly as it would over its own earlier chunks.

    tokens: (B, C) chunk tokens, right-padded; write_rows: (B, C) physical
    pool row per chunk token (scratch row for padding); row_table:
    (B, S_max) the request's full row table; start: () position of the
    chunk's first token; last_idx: () in-chunk index of the prompt's last
    token (only meaningful on the final chunk). Attention-KV families
    only — moe included: the dropless per-token dispatch makes a chunk
    boundary invisible to routing, so chunked == single-shot exactly.

    Returns (logits at last_idx (B, 1, V), new pool_k, new pool_v); the
    moe family appends a per-layer expert-load tally (L, E).
    """
    if cfg.family not in ATTN_KV_FAMILIES:
        raise ValueError(
            f"prefill_chunk_paged: unsupported family {cfg.family}"
        )
    moe = cfg.family == "moe"
    x = embed(tokens, params["embed"], _dt(cfg))
    b, c, _ = x.shape
    positions = start + jnp.arange(c)[None, :]  # (1, C) broadcast over B

    def layer_fn(carry, lp_kv):
        x, aux = carry
        lp, pk, pv = lp_kv
        q, k, v = _qkv(lp, cfg, x, positions)
        pk = pk.at[write_rows].set(k)
        pv = pv.at[write_rows].set(v)
        # gathered rows sit at logical positions 0..S_max-1; rows past the
        # chunk (scratch padding included) are masked by causality
        o = attn.chunk_attention(
            q, pk[row_table], pv[row_table], positions,
            window=cfg.sliding_window,
        )
        x = x + dense(o.reshape(b, c, -1), lp["wo"])
        if moe:
            x, counts = _ffn_block(lp, cfg, x, dropless=True)
            return (x, aux), (pk, pv, counts)
        x, a = _ffn_block(lp, cfg, x)
        return (x, aux + a), (pk, pv)

    (x, _), outs = jax.lax.scan(
        layer_fn,
        (x, jnp.zeros((), jnp.float32)),
        (params["layers"], pool_k, pool_v),
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    lg = unembed_logits(x_last, table, cfg.vocab)
    if moe:
        pks, pvs, counts = outs
        return lg, pks, pvs, counts
    pks, pvs = outs
    return lg, pks, pvs


def verify_chunk_paged(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    row_table: jnp.ndarray,
    write_rows: jnp.ndarray,
    starts: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Score a depth-C draft chain per lane against the shared KV pool.

    The speculative-decoding verifier (``runtime.speculative``): each lane
    feeds its pending token plus the drafter's proposals as one chunk, so
    the target scores every draft position in ONE batched step instead of
    C sequential ``decode_step_paged`` calls. ``prefill_chunk_paged``
    generalised two ways: ``starts`` is per-lane (B,) — decode lanes sit
    at different depths — and the full (B, C, V) logits come back, because
    longest-accepted-prefix selection needs the distribution at every
    draft position, not just the last. K/V rows for the fed chain scatter
    into the lanes' own (private, refcounted) blocks; rows past a lane's
    accepted prefix are dead weight the next chain overwrites, which is
    what makes rejection rollback free.

    tokens: (B, C) draft chains, right-padded; write_rows: (B, C) physical
    pool row per chain token (scratch row for padding); starts: (B,)
    position of each lane's first fed token. Attention-KV families only —
    moe included (dropless dispatch is chunk-invariant); the moe family
    appends a per-layer expert-load tally (L, E).
    """
    if cfg.family not in ATTN_KV_FAMILIES:
        raise ValueError(
            f"verify_chunk_paged: unsupported family {cfg.family}"
        )
    moe = cfg.family == "moe"
    x = embed(tokens, params["embed"], _dt(cfg))
    b, c, _ = x.shape
    positions = starts[:, None] + jnp.arange(c)[None, :]  # (B, C)

    def layer_fn(carry, lp_kv):
        x, aux = carry
        lp, pk, pv = lp_kv
        q, k, v = _qkv(lp, cfg, x, positions)
        pk = pk.at[write_rows].set(k)
        pv = pv.at[write_rows].set(v)
        o = attn.chunk_attention(
            q, pk[row_table], pv[row_table], positions,
            window=cfg.sliding_window,
        )
        x = x + dense(o.reshape(b, c, -1), lp["wo"])
        if moe:
            x, counts = _ffn_block(lp, cfg, x, dropless=True)
            return (x, aux), (pk, pv, counts)
        x, a = _ffn_block(lp, cfg, x)
        return (x, aux + a), (pk, pv)

    (x, _), outs = jax.lax.scan(
        layer_fn,
        (x, jnp.zeros((), jnp.float32)),
        (params["layers"], pool_k, pool_v),
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    lg = unembed_logits(x, table, cfg.vocab)
    if moe:
        pks, pvs, counts = outs
        return lg, pks, pvs, counts
    pks, pvs = outs
    return lg, pks, pvs


# --------------------------------------------------------------------------
# Hybrid (Zamba2) paged serving: shared-attention KV pages through the
# pool, SSM conv/state stays resident per decode lane
# --------------------------------------------------------------------------


def init_ssm_lane_state(cfg: ModelConfig, slots: int) -> dict:
    """Per-lane resident SSM decode state for the hybrid paged scheduler.

    Unlike the attention KV cache, this state is fixed-size per lane (the
    SSD recurrence is O(1) in sequence length), so it never pages: leaves
    are (L, slots, ...) and a lane's slice is overwritten on admission.
    """
    dt = _dt(cfg)
    l, k = cfg.n_layers, cfg.conv_kernel
    return {
        "ssm": jnp.zeros(
            (l, slots, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
        "conv_x": jnp.zeros((l, slots, k - 1, cfg.d_inner), dt),
        "conv_b": jnp.zeros((l, slots, k - 1, cfg.ssm_state), dt),
        "conv_c": jnp.zeros((l, slots, k - 1, cfg.ssm_state), dt),
    }


def prefill_with_cache_hybrid(
    params: dict, cfg: ModelConfig, tokens: jnp.ndarray, last_idx: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, dict]:
    """Hybrid full-sequence prefill keeping *both* kinds of decode state.

    tokens: (B, S) prompts — hybrid prompts must be **unpadded** (the
    final SSD state integrates every position, so padded tails would
    pollute it; the scheduler prefills hybrids one-trace-per-length like
    MoE). Returns (next-token logits (B, 1, V), ks, vs stacked
    (n_super, B, S, n_kv, hd) — the shared attention blocks' KV rows for
    pool insertion — and the lane-state dict of ``init_ssm_lane_state``
    leaves shaped (L, B, ...)).
    """
    if cfg.family != "hybrid":
        raise ValueError(
            f"prefill_with_cache_hybrid: family {cfg.family!r} is not hybrid"
        )
    x = embed(tokens, params["embed"], _dt(cfg))
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    every = cfg.hybrid_attn_every
    n_super = cfg.n_layers // every
    shaped = jax.tree.map(
        lambda v: v.reshape((n_super, every) + v.shape[1:]), params["layers"]
    )
    shared = params["shared"]

    def super_block(carry, lps):
        x, aux = carry

        def inner(c, lp):
            y, st, bufs = _ssm_block(lp, cfg, c)
            return y, (st, *bufs)

        x, states = jax.lax.scan(inner, x, lps)
        x, (k, v) = _attn_block(shared, cfg, x, positions, causal=True)
        x, a = _ffn_block(shared, cfg, x)
        return (x, aux + a), (states, k, v)

    (x, _), (states, ks, vs) = jax.lax.scan(
        super_block, (x, jnp.zeros((), jnp.float32)), shaped
    )
    sts, cxs, cbs, ccs = states  # leaves (n_super, every, B, ...)
    merge = lambda v: v.reshape((cfg.n_layers,) + v.shape[2:])
    lane_state = {
        "ssm": merge(sts), "conv_x": merge(cxs),
        "conv_b": merge(cbs), "conv_c": merge(ccs),
    }
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(x_last, table, cfg.vocab), ks, vs, lane_state


def decode_step_paged_hybrid(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    row_table: jnp.ndarray,
    lengths: jnp.ndarray,
    lane_state: dict,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, dict]:
    """``decode_step_paged`` for the hybrid family.

    The shared attention block of each super-block scatters/gathers its
    KV rows through the pool (pool_k/pool_v are (n_super, R, n_kv, hd),
    addressed by the same per-lane ``row_table``/``lengths`` as the
    attention families), while the SSM recurrence advances the resident
    per-lane ``lane_state`` (leaves (L, B, ...)). Returns
    (logits (B, 1, V), new pool_k, new pool_v, new lane_state).
    """
    if cfg.family != "hybrid":
        raise ValueError(
            f"decode_step_paged_hybrid: family {cfg.family!r} is not hybrid"
        )
    x = embed(token, params["embed"], _dt(cfg))
    b = x.shape[0]
    s_max = row_table.shape[1]
    pos_b = lengths[:, None]
    write_rows = jnp.take_along_axis(
        row_table, jnp.clip(lengths, 0, s_max - 1)[:, None], axis=1
    )[:, 0]
    every = cfg.hybrid_attn_every
    n_super = cfg.n_layers // every
    shaped = jax.tree.map(
        lambda v: v.reshape((n_super, every) + v.shape[1:]), params["layers"]
    )
    states = jax.tree.map(
        lambda v: v.reshape((n_super, every) + v.shape[1:]),
        (
            lane_state["ssm"], lane_state["conv_x"],
            lane_state["conv_b"], lane_state["conv_c"],
        ),
    )
    shared = params["shared"]

    def super_block(x, inp):
        lps, (sts, cxs, cbs, ccs), pk, pv = inp

        def inner(x, lp_state):
            lp, st, cx, cb, cc = lp_state
            x, st, bufs = _ssm_block(
                lp, cfg, x, state=st, conv_bufs=(cx, cb, cc)
            )
            return x, (st, *bufs)

        x, new_states = jax.lax.scan(inner, x, (lps, sts, cxs, cbs, ccs))
        q, k, v = _decode_qkv(shared, cfg, x, pos_b)
        pk = pk.at[write_rows].set(k[:, 0])
        pv = pv.at[write_rows].set(v[:, 0])
        o = attn.decode_attention(
            q, pk[row_table], pv[row_table], (lengths + 1)[:, None]
        )
        x = x + dense(o.reshape(b, 1, -1), shared["wo"])
        x, _ = _ffn_block(shared, cfg, x)
        return x, (new_states, pk, pv)

    x, (new_states, pks, pvs) = jax.lax.scan(
        super_block, x, (shaped, states, pool_k, pool_v)
    )
    sts, cxs, cbs, ccs = new_states
    merge = lambda v: v.reshape((cfg.n_layers,) + v.shape[2:])
    new_lane = {
        "ssm": merge(sts), "conv_x": merge(cxs),
        "conv_b": merge(cbs), "conv_c": merge(ccs),
    }
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(x, table, cfg.vocab), pks, pvs, new_lane


def prefill_suffix_paged_hybrid(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    row_table: jnp.ndarray,
    write_rows: jnp.ndarray,
    start: jnp.ndarray,
    last_idx: jnp.ndarray,
    lane_state: dict,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, dict]:
    """Hybrid prefill of a prompt *suffix*, resuming from carried state.

    The prefix-cache warm path for zamba2: positions ``0..start-1`` were
    served by a cached prefix — their shared-attention KV rows sit in the
    pool (gathered through ``row_table``) and the SSM recurrence resumes
    from ``lane_state``, the anchor snapshot taken when the prefix was
    committed (leaves shaped (L, B, ...) as in ``init_ssm_lane_state``).
    The suffix's SSD scan seeds ``ssd_chunked`` with the carried state
    and the causal convs take their left context from the carried conv
    buffers, so the result is the cold full-prompt prefill's — this is
    also the machinery chunked hybrid prefill needs (SSD state carried
    across chunks).

    tokens: (B, C) **unpadded** suffix (hybrid prompts never pad);
    write_rows: (B, C) physical pool row per suffix token; start: ()
    position of the suffix's first token; last_idx: () in-suffix index
    of the prompt's last token. Returns (logits at last_idx (B, 1, V),
    new pool_k, new pool_v, new lane_state).
    """
    if cfg.family != "hybrid":
        raise ValueError(
            f"prefill_suffix_paged_hybrid: family {cfg.family!r} is not hybrid"
        )
    x = embed(tokens, params["embed"], _dt(cfg))
    b, c, _ = x.shape
    positions = start + jnp.arange(c)[None, :]
    every = cfg.hybrid_attn_every
    n_super = cfg.n_layers // every
    shaped = jax.tree.map(
        lambda v: v.reshape((n_super, every) + v.shape[1:]), params["layers"]
    )
    states = jax.tree.map(
        lambda v: v.reshape((n_super, every) + v.shape[1:]),
        (
            lane_state["ssm"], lane_state["conv_x"],
            lane_state["conv_b"], lane_state["conv_c"],
        ),
    )
    shared = params["shared"]

    def super_block(x, inp):
        lps, (sts, cxs, cbs, ccs), pk, pv = inp

        def inner(x, lp_state):
            lp, st, cx, cb, cc = lp_state
            x, st, bufs = _ssm_block(
                lp, cfg, x, state=st, conv_bufs=(cx, cb, cc)
            )
            return x, (st, *bufs)

        x, new_states = jax.lax.scan(inner, x, (lps, sts, cxs, cbs, ccs))
        q, k, v = _qkv(shared, cfg, x, positions)
        pk = pk.at[write_rows].set(k)
        pv = pv.at[write_rows].set(v)
        o = attn.chunk_attention(q, pk[row_table], pv[row_table], positions)
        x = x + dense(o.reshape(b, c, -1), shared["wo"])
        x, _ = _ffn_block(shared, cfg, x)
        return x, (new_states, pk, pv)

    x, (new_states, pks, pvs) = jax.lax.scan(
        super_block, x, (shaped, states, pool_k, pool_v)
    )
    sts, cxs, cbs, ccs = new_states
    merge = lambda v: v.reshape((cfg.n_layers,) + v.shape[2:])
    new_lane = {
        "ssm": merge(sts), "conv_x": merge(cxs),
        "conv_b": merge(cbs), "conv_c": merge(ccs),
    }
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(x_last, table, cfg.vocab), pks, pvs, new_lane


# --------------------------------------------------------------------------
# Sampling (host-side: the scheduler samples from materialised logits)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Decode sampling policy. ``temperature == 0`` is exact greedy (the
    default and the special case every equivalence test pins); top-k and
    top-p restrict the support *before* renormalising. Seed-determinism
    is the scheduler's contract: it draws from an rng keyed on
    (seed, request id, position), so a request's output is independent of
    lane placement and co-resident requests."""

    temperature: float = 0.0
    top_k: int = 0  # 0 = unrestricted
    top_p: float = 1.0  # 1.0 = unrestricted
    seed: int = 0

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


def sample_logits(
    row,
    sp: SamplingParams,
    rng=None,
) -> int:
    """Draw one token from a (V,) numpy logits row under ``sp``.

    Greedy (temperature 0) never touches ``rng`` (it may be None); top_k=1
    collapses to greedy regardless of temperature; top_k >= V is
    unrestricted.
    """
    import numpy as np

    row = np.asarray(row, np.float64)
    if sp.is_greedy or sp.top_k == 1:
        return int(np.argmax(row))
    logits = row / sp.temperature
    top_k = min(sp.top_k, len(row))
    if top_k > 0:
        kth = np.partition(logits, -top_k)[-top_k]
        logits = np.where(logits >= kth, logits, -np.inf)
    logits = logits - np.max(logits)
    probs = np.exp(logits)
    probs /= probs.sum()
    if sp.top_p < 1.0:
        order = np.argsort(-probs)
        csum = np.cumsum(probs[order])
        # smallest prefix whose mass reaches top_p (>= 1 token)
        cut = int(np.searchsorted(csum, sp.top_p)) + 1
        mask = np.zeros_like(probs, bool)
        mask[order[:cut]] = True
        probs = np.where(mask, probs, 0.0)
        probs /= probs.sum()
    return int(rng.choice(len(probs), p=probs))
