"""Unified model configuration for every assigned architecture.

One ``ModelConfig`` describes the whole LM family: dense GQA transformers,
MoE, SSM (Mamba2/SSD), hybrid (Zamba2), encoder-decoder (Whisper backbone)
and VLM (InternVL2 backbone). ``family`` selects the layer recipe; unused
fields stay at their zero defaults.

Weight quantization (``w_bits``) plugs the paper's packed-weight technique
into any architecture: 1/2-bit weights are stored in the uint8 carrier
format consumed by ``kernels.packed_matmul`` — the TPU analogue of the
paper's optimally-packed BRAMs (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Families whose decode state is a growing attention KV cache — the ones
# the paged serving path (runtime.kv_pool / lm.decode_step_paged) covers.
# ssm/hybrid keep fixed-size per-slot state; encdec has its own decoder.
ATTN_KV_FAMILIES = ("dense", "vlm", "moe")

# Families the KV-pool serving path covers. Hybrid joins the attention-KV
# families: its shared attention blocks hold a growing KV cache (one per
# super-block) that pages through the pool, while the SSM conv/state stays
# fixed-size resident per decode lane (lm.decode_step_paged_hybrid).
PAGED_FAMILIES = ATTN_KV_FAMILIES + ("hybrid",)

# Families whose prompts can prefill in budget-sized chunks across rounds.
# MoE qualifies because serving routes through the dropless per-token
# dispatch (moe_ffn_dropless): a chunk boundary is invisible to routing,
# so chunked == single-shot exactly (the train-path capacity dispatch
# would not chunk — it is cross-token). Hybrid chunks statefully: the
# scheduler carries the SSD/conv state between chunks through the same
# carried-state kernels that power warm suffix prefill
# (lm.prefill_suffix_paged_hybrid), so chunk boundaries are exact resume
# points rather than approximations.
CHUNKABLE_FAMILIES = ("dense", "vlm", "moe", "hybrid")

# Families whose prompt KV can be served out of the radix prefix cache
# (runtime.prefix_cache): a new request adopts the shared blocks of its
# longest committed prefix and prefills only the unmatched suffix. MoE
# qualifies under dropless serving routing — a bare-suffix prefill routes
# each suffix token independently, so it reproduces the cold full-prompt
# prefill exactly. Hybrid qualifies because the cache stores an SSM-state
# anchor next to the shared-attention KV blocks.
PREFIX_CACHE_FAMILIES = ("dense", "vlm", "moe", "hybrid")

# Families whose dense FFN stores 1/2-bit weights as packed uint8 carriers
# when w_bits is set (lm._init_ffn packs every non-expert FFN; MoE expert
# einsums and SSM blocks have no dense FFN to pack). Packed carriers are
# inference-only: launch.train rejects --quant for these families.
PACKING_FAMILIES = ("dense", "vlm", "encdec", "hybrid")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    sliding_window: int = 0  # 0 -> full attention
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- hybrid (Zamba2): one shared attention block every k SSM layers ---
    hybrid_attn_every: int = 0
    # --- encoder-decoder (Whisper backbone) ---
    n_enc_layers: int = 0
    frontend_len: int = 0  # stubbed frontend sequence length (frames/patches)
    # --- vlm ---
    n_patches: int = 0  # stubbed image-patch prefix length
    # --- common ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    vocab_pad: int = 256  # pad vocab so ('model',) sharding always divides
    w_bits: int = 0  # 0 = dense bf16/f32 weights; 1/2 = packed (FCMP analogue)
    dtype: Any = "bfloat16"

    # ---------------- derived ----------------

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, self.vocab_pad)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def n_kv_cache_layers(self) -> int:
        """Layers that hold a growing KV cache: every layer for the
        attention families, one per super-block for hybrid (the shared
        attention block), none for pure SSM."""
        if self.family == "hybrid":
            return self.n_layers // max(1, self.hybrid_attn_every)
        if self.family in ATTN_KV_FAMILIES or self.family == "encdec":
            return self.n_layers
        return 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM, hybrid, or sliding-window attention."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned family decodes (whisper has a decoder)

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        dense_ffn = 3 * d * ff
        per_layer = 0
        if self.family in ("dense", "vlm", "encdec"):
            per_layer = attn + dense_ffn + 2 * d
        elif self.family == "moe":
            per_layer = (
                attn + self.n_experts * dense_ffn + d * self.n_experts + 2 * d
            )
        elif self.family in ("ssm", "hybrid"):
            di, st, nhs = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = (
                d * (2 * di + 2 * st + nhs)  # in-proj (z, x, B, C, dt)
                + self.conv_kernel * (di + 2 * st)  # causal conv
                + di * d  # out-proj
                + 2 * nhs  # A_log, D
                + di  # gate norm
            )
            per_layer = ssm + 2 * d
        total = self.n_layers * per_layer
        if self.family == "hybrid":
            n_shared = self.n_layers // max(1, self.hybrid_attn_every)
            shared = attn + dense_ffn + 2 * self.d_model
            total += shared  # one copy, reused n_shared times
        if self.family == "encdec":
            # encoder self-attn + cross-attn in decoder
            total += self.n_enc_layers * (attn + dense_ffn + 2 * d)
            total += self.n_layers * (attn + 2 * d)  # cross-attention
        emb = v * d
        total += emb if self.tie_embeddings else 2 * emb
        return total

    def active_params(self) -> int:
        """Params touched per token (MoE activates top-k of E experts)."""
        if self.family != "moe":
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        inactive = (
            self.n_layers
            * (self.n_experts - self.experts_per_token)
            * 3
            * d
            * ff
        )
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


def modality_batch_leaves(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    """Extra (non-token) batch leaves per family: name -> per-example
    shape (batch dim excluded). Single source for the launch stand-ins
    (``launch.specs.abstract_batch``) and the sharding policy
    (``dist.sharding.batch_specs``)."""
    if cfg.family == "vlm":
        return {"prefix_embeds": (cfg.n_patches, cfg.d_model)}
    if cfg.family == "encdec":
        return {"frames": (cfg.frontend_len, cfg.d_model)}
    return {}


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs; reason string when skipped
    (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "SKIP(full-attention: 500k dense KV is sub-quadratic-only)"
    if cfg.family == "encdec" and shape.name == "long_500k":
        return False, "SKIP(full-attention: 500k dense KV is sub-quadratic-only)"
    return True, ""


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family (CPU-runnable)."""
    small = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        vocab_pad=64,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2)
        if cfg.experts_per_token
        else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=16,
        hybrid_attn_every=min(cfg.hybrid_attn_every, 2)
        if cfg.hybrid_attn_every
        else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        frontend_len=min(cfg.frontend_len, 32) if cfg.frontend_len else 0,
        n_patches=min(cfg.n_patches, 16) if cfg.n_patches else 0,
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
