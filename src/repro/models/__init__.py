"""Model zoo: unified LM family + encoder-decoder + the paper's CNNs."""

from repro.models.config import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    reduced,
    shape_applicable,
)
