"""Attention: blockwise online-softmax ("flash") scan + decode paths.

``flash_attention`` is the lowering-friendly pure-jnp path used everywhere
(training, prefill, dry-run): a nested ``lax.scan`` over query blocks
(outer) and KV blocks (inner) keeps the live score tile at
(q_block x kv_block) regardless of sequence length — this is what makes the
32k-prefill and 4k-train cells compile within HBM. GQA is handled by
grouping query heads over each KV head. Sliding-window masking supports the
h2o-danube cells.

Decode paths attend one query token against a (possibly sequence-sharded)
KV cache with a dense masked softmax — at decode the score tensor is
(B, H, S) which is small and shards over ('data', 'model', ...).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import compat

NEG_INF = -1e30


def _pick_block(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is <= target (handles non-power-of-2
    sequence lengths like whisper's 1500 frames or vlm's 32768+256)."""
    for d in range(min(target, s), 0, -1):
        if s % d == 0:
            return d
    return 1


def _mask(
    q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool, window: int
) -> jnp.ndarray:
    """(Q, K) boolean validity mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Flash attention; implementation selected by ``set_attn_impl``.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D); Hq % Hkv == 0. Returns
    (B, Sq, Hq, D).
      * 'fa2' (default): custom-VJP FlashAttention-2 with static causal
        block skipping (``models.flash``, EXPERIMENTS.md §Perf iter. 1+).
      * 'scan': the original scan-of-scans online softmax below — the
        paper-faithful §Perf BASELINE and the numerical reference.
    """
    if _ATTN_IMPL["name"] == "scan":
        return flash_attention_scan(
            q, k, v, causal=causal, window=window, q_block=q_block,
            kv_block=kv_block, q_offset=q_offset,
        )
    from repro.models.flash import flash_attention as _fa2

    return _fa2(
        q, k, v, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, q_offset=q_offset,
    )


_ATTN_IMPL = {"name": "fa2"}


def set_attn_impl(name: str) -> None:
    """'fa2' | 'scan' — switch the attention path (A/B in the dry-run)."""
    assert name in ("fa2", "scan"), name
    _ATTN_IMPL["name"] = name


def flash_attention_scan(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Naive scan-of-scans online softmax (reference; §Perf baseline)."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qb = _pick_block(sq, q_block)
    kb = _pick_block(sk, kv_block)
    nq, nk = sq // qb, sk // kb

    # (B, Sq, Hkv, G, D) -> blocks (nq, B, qb, Hkv, G, D)
    qg = q.reshape(b, nq, qb, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kg = k.reshape(b, nk, kb, hkv, d).transpose(1, 0, 2, 3, 4)
    vg = v.reshape(b, nk, kb, hkv, d).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk
        q_pos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(carry, kj_blk):
            m_run, l_run, acc = carry
            kj, k_blk, v_blk = kj_blk
            k_pos = kj * kb + jnp.arange(kb)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_blk, k_blk, preferred_element_type=jnp.float32
            ) * scale
            valid = _mask(q_pos, k_pos, causal, window)
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            # fully-masked blocks (possible with sliding windows) would give
            # exp(NEG_INF - NEG_INF) = 1: zero them explicitly.
            p = jnp.where(valid[None, None, None], p, 0.0)
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qb), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qb, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kg, vg)
        )
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        # (B, Hkv, G, qb, D) -> (B, qb, Hkv, G, D)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, blocks = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    # (nq, B, qb, Hkv, G, D) -> (B, Sq, Hq, D)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,
    *,
    window: int = 0,
) -> jnp.ndarray:
    """One-token attention against a cache.

    q: (B, 1, Hq, D); caches: (B, S, Hkv, D); cache_len: () current length
    (the new token's position is cache_len - 1 after insertion).
    """
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(s)
    valid = pos[None] < cache_len
    if window > 0:
        valid &= pos[None] > cache_len - 1 - window
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def chunk_attention(
    q: jnp.ndarray,
    k_rows: jnp.ndarray,
    v_rows: jnp.ndarray,
    q_pos: jnp.ndarray,
    *,
    window: int = 0,
) -> jnp.ndarray:
    """Multi-token causal attention against gathered pool rows.

    ``decode_attention`` generalised to a C-token query chunk (the chunked
    prefill path): q: (B, C, Hq, D); k_rows/v_rows: (B, S, Hkv, D) rows
    gathered from the KV pool in logical order (row i holds position i);
    q_pos: (B, C) absolute positions of the chunk tokens. Rows beyond the
    chunk (scratch padding included) are masked by causality; ``q_pos``
    may be traced, so one trace serves every chunk offset.
    """
    b, c, hq, d = q.shape
    _, s, hkv, _ = k_rows.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, c, hkv, g, d)
    scores = jnp.einsum(
        "bqhgd,bshd->bhgqs", qg, k_rows, preferred_element_type=jnp.float32
    ) * scale
    k_pos = jnp.arange(s)
    valid = q_pos[:, :, None] >= k_pos[None, None, :]  # (B, C, S)
    if window > 0:
        valid &= q_pos[:, :, None] - k_pos[None, None, :] < window
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgqs,bshd->bqhgd", p.astype(v_rows.dtype), v_rows,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, c, hq, d).astype(q.dtype)


def cache_insert(
    cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray
) -> jnp.ndarray:
    """Insert (B, 1, Hkv, D) at ring position ``pos`` (static cache size)."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new, pos, axis=1)


def flash_attention_seq_sharded(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    mesh=None,
    axis: str = "model",
    batch_axes=("pod", "data"),
):
    """Sequence-sharded prefill attention (EXPERIMENTS.md §Perf iter. 8).

    For prefill cells whose head count doesn't divide TP and whose batch
    doesn't divide the mesh (smollm/phi3 prefill_32k), GSPMD replicates
    the attention math 16x over the model axis. Here each model shard
    computes its own q-sequence slice against the replicated K/V
    (shard_map), with the causal mask offset by the shard's position —
    attention compute and block traffic drop by the TP degree. Forward
    only (prefill has no backward; the scan path accepts a traced
    q_offset).
    """
    from jax.sharding import PartitionSpec as P

    sq = q.shape[1]
    tp = mesh.shape[axis]
    local_s = sq // tp

    def local(q_l, k_l, v_l):
        off = jax.lax.axis_index(axis) * local_s
        return flash_attention_scan(
            q_l, k_l, v_l, causal=causal, window=window, q_offset=off,
        )

    ba = tuple(a for a in batch_axes if a in mesh.axis_names)
    bspec = ba if ba else None
    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bspec, axis, None, None),
            P(bspec, None, None, None),
            P(bspec, None, None, None),
        ),
        out_specs=P(bspec, axis, None, None),
        # the scan carries start from unvarying constants; outputs vary
        # with the shard via axis_index — skip the vma consistency check
        check_vma=False,
    )(q, k, v)


def decode_attention_split_d(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,
    *,
    window: int = 0,
    mesh=None,
    axis: str = "model",
    batch_axes=("data",),
):
    """Decode attention with the KV cache head_dim-sharded over ``axis``.

    For archs whose KV-head count doesn't divide TP (phi3's 10 on a 16-way
    axis) GSPMD re-shards the whole cache every decode step ("involuntary
    full rematerialization", ~350 ms/step of HBM on phi3/decode_32k). This
    shard_map keeps the cache resident in its d-sharded layout: each shard
    computes partial scores over its d-slice, one (B, H, G, S) f32 psum
    reconstructs the logits, softmax runs replicated, and the PV product
    returns d-sharded — exactly what the row-sharded output projection
    wants (EXPERIMENTS.md §Perf iteration 7).
    """
    from jax.sharding import PartitionSpec as P

    d_model_axis = axis

    def local(q_l, k_l, v_l, cl):
        b, _, hq, dl = q_l.shape
        _, s, hkv, _ = k_l.shape
        g = hq // hkv
        # per-shard partial scores over the local d slice
        qg = q_l.reshape(b, hkv, g, dl)
        part = jnp.einsum(
            "bhgd,bshd->bhgs", qg, k_l, preferred_element_type=jnp.float32
        )
        scores = jax.lax.psum(part, d_model_axis) / math.sqrt(
            dl * jax.lax.psum(1, d_model_axis)
        )
        pos = jnp.arange(s)
        valid = pos[None] < cl
        if window > 0:
            valid &= pos[None] > cl - 1 - window
        scores = jnp.where(valid[:, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum(
            "bhgs,bshd->bhgd", p.astype(v_l.dtype), v_l,
            preferred_element_type=jnp.float32,
        )
        return out.reshape(b, 1, hq, dl).astype(q_l.dtype)

    ba = tuple(a for a in batch_axes if a in mesh.axis_names)
    spec = P(ba if ba else None, None, None, axis)
    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, spec, spec, P()),
        out_specs=spec,
        # partial scores vary per d-shard and are psum-reconstructed inside
        check_vma=False,
    )(q, k_cache, v_cache, cache_len)
