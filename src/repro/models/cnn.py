"""The paper's own models in JAX: CNV (BNN-Pynq) and quantized ResNet-50.

Two execution paths per model, mirroring the paper's §III:
  * **QAT training path** — float graph with STE weight quantizers
    (binary/ternary inside blocks, 8-bit first/last) and LSQ activations,
    BN before every quantized activation (``quant.quantizers``).
  * **Streamlined dataflow path** — the FPGA datapath: BN+activation folded
    into integer thresholds (``quant.streamline``), convolutions lowered to
    im2col + the fused packed ``mvau`` kernel. Bit-exact vs the QAT graph
    at matching parameters (tested), and the thing the FCMP packing planner
    operates on.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig  # noqa: F401  (public surface)
from repro.quant.quantizers import init_act_scale, int_act, quantize_weight
from repro.quant.streamline import ThresholdSpec, bn_act_to_thresholds, thresholding


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    c_in: int
    c_out: int
    k: int
    stride: int = 1
    pad: int = 0
    w_bits: int = 1
    a_bits: int = 2
    pool: bool = False  # 2x2 maxpool after activation


def cnv_topology(w_bits: int = 1, a_bits: int = 2) -> list[ConvSpec]:
    """BNN-Pynq CNV: 6 valid convs + 2 maxpools + 3 FC (paper §V)."""
    return [
        ConvSpec("conv0", 3, 64, 3, w_bits=8, a_bits=a_bits),
        ConvSpec("conv1", 64, 64, 3, w_bits=w_bits, a_bits=a_bits, pool=True),
        ConvSpec("conv2", 64, 128, 3, w_bits=w_bits, a_bits=a_bits),
        ConvSpec("conv3", 128, 128, 3, w_bits=w_bits, a_bits=a_bits, pool=True),
        ConvSpec("conv4", 128, 256, 3, w_bits=w_bits, a_bits=a_bits),
        ConvSpec("conv5", 256, 256, 3, w_bits=w_bits, a_bits=a_bits),
        ConvSpec("fc0", 256, 512, 1, w_bits=w_bits, a_bits=a_bits),
        ConvSpec("fc1", 512, 512, 1, w_bits=w_bits, a_bits=a_bits),
        ConvSpec("fc2", 512, 10, 1, w_bits=8, a_bits=0),  # logits
    ]


def init_cnn_params(specs: list[ConvSpec], key: jax.Array) -> dict:
    params: dict[str, Any] = {}
    keys = jax.random.split(key, len(specs))
    for sp, k in zip(specs, keys):
        fan_in = sp.k * sp.k * sp.c_in
        params[sp.name] = {
            "w": jax.random.normal(k, (sp.k, sp.k, sp.c_in, sp.c_out))
            * (fan_in**-0.5),
            "bn_gamma": jnp.ones((sp.c_out,)),
            "bn_beta": jnp.zeros((sp.c_out,)),
            "bn_mu": jnp.zeros((sp.c_out,)),
            "bn_var": jnp.ones((sp.c_out,)),
            "act_scale": init_act_scale(max(sp.a_bits, 2)),
        }
    return params


def _conv(x, w, stride, pad):
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(
    params: dict, specs: list[ConvSpec], x: jnp.ndarray, train: bool = True
) -> jnp.ndarray:
    """QAT float path. x: (B, H, W, C). Returns logits (B, n_classes)."""
    for i, sp in enumerate(specs):
        p = params[sp.name]
        if sp.k == 1 and x.ndim == 4 and x.shape[1] * x.shape[2] > 1 and i > 0:
            # first FC flattens the spatial map
            x = x.reshape(x.shape[0], 1, 1, -1)
            # (flatten keeps channel count: CNV pools to 1x1 before fc0)
        w = quantize_weight(p["w"], sp.w_bits)
        x = _conv(x, w, sp.stride, sp.pad)
        if sp.a_bits > 0:
            mu, var = p["bn_mu"], p["bn_var"]
            if train:
                axes = (0, 1, 2)
                mu = jnp.mean(x, axis=axes)
                var = jnp.var(x, axis=axes)
            z = p["bn_gamma"] * (x - mu) / jnp.sqrt(var + 1e-5) + p["bn_beta"]
            x = int_act(z, p["act_scale"], sp.a_bits)
        if sp.pool:
            x = _maxpool2(x)
    return x.reshape(x.shape[0], -1)


def streamline_params(params: dict, specs: list[ConvSpec]) -> dict:
    """Fold BN+act into thresholds per layer (paper §III-B)."""
    out = {}
    for sp in specs:
        p = params[sp.name]
        entry: dict[str, Any] = {"w": quantize_weight(p["w"], sp.w_bits)}
        if sp.a_bits > 0:
            entry["thresholds"] = bn_act_to_thresholds(
                p["bn_gamma"], p["bn_beta"], p["bn_mu"], p["bn_var"],
                p["act_scale"], sp.a_bits,
            )
        out[sp.name] = entry
    return out


def cnn_forward_streamlined(
    sparams: dict, specs: list[ConvSpec], x: jnp.ndarray
) -> jnp.ndarray:
    """Dataflow path: conv -> integer thresholding (no BN, no float act).

    Bit-exact vs ``cnn_forward(train=False)`` given the same parameters.
    """
    for i, sp in enumerate(specs):
        p = sparams[sp.name]
        if sp.k == 1 and x.ndim == 4 and x.shape[1] * x.shape[2] > 1 and i > 0:
            x = x.reshape(x.shape[0], 1, 1, -1)
        x = _conv(x, p["w"], sp.stride, sp.pad)
        if sp.a_bits > 0:
            spec: ThresholdSpec = p["thresholds"]
            x = thresholding(x, spec)
        if sp.pool:
            x = _maxpool2(x)
    return x.reshape(x.shape[0], -1)


def im2col(x: jnp.ndarray, k: int, stride: int = 1, pad: int = 0):
    """(B, H, W, C) -> (B*Ho*Wo, k*k*C) patches — the MVAU input stream."""
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    idx_h = jnp.arange(ho) * stride
    idx_w = jnp.arange(wo) * stride
    patches = jnp.stack(
        [
            xp[:, i + di, j + dj]
            for di in range(k)
            for dj in range(k)
            for i, j in [(idx_h[:, None], idx_w[None, :])]
        ],
        axis=-2,
    )  # (B, Ho, Wo, k*k, C)
    return patches.reshape(b * ho * wo, k * k * c), (b, ho, wo)


def conv_as_mvau(
    x: jnp.ndarray, w: jnp.ndarray, spec: ThresholdSpec, w_bits: int,
    stride: int = 1, pad: int = 0, use_kernel: bool = True,
):
    """Convolution on the streamlined datapath via im2col + fused MVAU
    kernel (packed weights + thresholding) — the FINN execution model."""
    from repro.kernels import ops

    k, _, c_in, c_out = w.shape
    cols, (b, ho, wo) = im2col(x, k, stride, pad)
    wm = w.reshape(k * k * c_in, c_out)
    if use_kernel and w_bits in (1, 2):
        # per-channel magnitude folds into the thresholds: T' = T / alpha
        alpha = jnp.max(jnp.abs(wm), axis=0)
        alpha = jnp.where(alpha == 0, 1.0, alpha)
        packed = ops.pack_weights(wm / alpha[None, :], w_bits)
        thr = spec.thresholds / alpha[:, None]
        levels = ops.mvau(
            cols, packed, thr, spec.signs,
            bits=w_bits, k=k * k * c_in, offset=int(spec.offset),
        )
    else:
        acc = cols @ wm
        levels = (
            jnp.sum(
                (acc * spec.signs[None] )[..., None] >= spec.thresholds[None],
                axis=-1,
            )
            + int(spec.offset)
        )
    vals = levels.astype(jnp.float32) * spec.scale
    return vals.reshape(b, ho, wo, c_out)
