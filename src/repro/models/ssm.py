"""Mamba2 / SSD (state-space duality) layer: chunked train scan + O(1) decode.

The SSD recurrence  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t,
y_t = C_t h_t + D x_t  is evaluated in chunked ("quadratic-in-chunk") form
(Dao & Gu 2024, arXiv:2405.21060 §6): within a chunk of length Q the output
is an attention-like matmul with a decay mask; across chunks a short
``lax.scan`` carries the (H, P, N) state. This keeps the lowering matmul-
dominated (MXU-friendly) and the live activation window at Q x Q — the same
structural trick as the flash-attention scan.

Decode is the pure recurrence on a persistent state — O(1) per token, which
is why the ssm/hybrid archs run the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def segsum(log_decay: jnp.ndarray) -> jnp.ndarray:
    """Stable 'segment sum': L[i, j] = sum_{k=j+1..i} a_k for i >= j else -inf.

    log_decay: (..., Q). Returns (..., Q, Q) lower-triangular log-decay mask.
    """
    q = log_decay.shape[-1]
    cs = jnp.cumsum(log_decay, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    a_log: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    d_skip: jnp.ndarray,
    chunk: int,
    h0: jnp.ndarray | None = None,
):
    """SSD forward.

    x: (Bt, S, H, P) inputs; dt: (Bt, S, H) positive step sizes;
    a_log: (H,) with A = -exp(a_log) < 0; b, c: (Bt, S, N) shared across
    heads (ngroups=1); d_skip: (H,) skip gain.
    Returns y: (Bt, S, H, P) and final state (Bt, H, P, N).
    """
    bt, s, h, p = x.shape
    n = b.shape[-1]
    if s % chunk != 0:  # largest divisor of s <= requested chunk
        chunk = next(c for c in range(min(chunk, s), 0, -1) if s % c == 0)
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,)

    xc = x.reshape(bt, nc, chunk, h, p)
    dtc = dt.reshape(bt, nc, chunk, h).astype(jnp.float32)
    bc = b.reshape(bt, nc, chunk, n)
    cc = c.reshape(bt, nc, chunk, n)

    dta = dtc * a  # (Bt, nc, Q, H) log-decay per step
    # intra-chunk: Y_intra = ((C B^T) * decay_mask * dt) X
    lmask = segsum(dta.transpose(0, 1, 3, 2))  # (Bt, nc, H, Q, Q)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)  # (Bt, nc, Q, Q)
    w = cb[:, :, None] * jnp.exp(lmask)  # (Bt, nc, H, Q, Q)
    w = w * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # dt at source step
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", w.astype(x.dtype), xc)

    # chunk-final states: S_c = sum_k exp(sum_{j>k} dta_j) dt_k B_k x_k
    dta_cum = jnp.cumsum(dta, axis=2)
    decay_to_end = jnp.exp(dta_cum[:, :, -1:, :] - dta_cum)  # (Bt,nc,Q,H)
    sc = jnp.einsum(
        "bcqn,bcqh,bcqhp->bchpn",
        bc.astype(jnp.float32),
        decay_to_end * dtc,
        xc.astype(jnp.float32),
    )  # (Bt, nc, H, P, N)
    chunk_decay = jnp.exp(dta_cum[:, :, -1, :])  # (Bt, nc, H)

    # inter-chunk recurrence over nc chunks
    def step(hprev, inp):
        s_c, dec = inp  # (Bt,H,P,N), (Bt,H)
        hnew = hprev * dec[..., None, None] + s_c
        return hnew, hprev

    if h0 is None:
        h0 = jnp.zeros((bt, h, p, n), jnp.float32)
    hfinal, hprevs = jax.lax.scan(
        step,
        h0,
        (sc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)  # (Bt, nc, H, P, N)

    # inter-chunk contribution: y_inter = C_t exp(cum decay) h_prev
    in_decay = jnp.exp(dta_cum)  # decay from chunk start to t (inclusive)
    y_inter = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", cc.astype(jnp.float32), in_decay, hprevs
    )

    y = (y_intra.astype(jnp.float32) + y_inter).reshape(bt, s, h, p)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), hfinal


def ssd_decode_step(
    h: jnp.ndarray,
    x: jnp.ndarray,
    dt: jnp.ndarray,
    a_log: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    d_skip: jnp.ndarray,
):
    """One-token recurrence. h: (Bt, H, P, N); x: (Bt, H, P); dt: (Bt, H);
    b, c: (Bt, N). Returns (y (Bt, H, P), h_new)."""
    dt = dt.astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))
    dec = jnp.exp(dt * a)[..., None, None]  # (Bt, H, 1, 1)
    inc = (dt[..., None] * x.astype(jnp.float32))[..., None] * b[
        :, None, None, :
    ].astype(jnp.float32)
    h_new = h * dec + inc
    y = jnp.einsum("bhpn,bn->bhp", h_new, c.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * d_skip[None, :, None]
    return y.astype(x.dtype), h_new


def causal_conv(
    x: jnp.ndarray, w: jnp.ndarray, state: jnp.ndarray | None = None
):
    """Depthwise causal conv1d. x: (Bt, S, C); w: (K, C).

    ``state`` (Bt, K-1, C) holds the trailing pre-conv inputs of an
    already-consumed prefix (the decode-path conv buffer): when given,
    the left context comes from it instead of zero padding — this is
    what lets a suffix prefill resume mid-sequence (prefix-cache hits,
    chunked hybrid prefill) with the exact cold-start conv windows.
    """
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # windows: out[t] = sum_j x[t - K + 1 + j] * w[j]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(k):
        out = out + xp[:, j : j + x.shape[1]].astype(jnp.float32) * w[j].astype(
            jnp.float32
        )
    return jax.nn.silu(out).astype(x.dtype)


def conv_decode_step(buf: jnp.ndarray, xt: jnp.ndarray, w: jnp.ndarray):
    """buf: (Bt, K-1, C) trailing inputs; xt: (Bt, C). Returns (y, buf')."""
    k = w.shape[0]
    window = jnp.concatenate([buf, xt[:, None]], axis=1)  # (Bt, K, C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    return jax.nn.silu(y).astype(xt.dtype), window[:, 1:]
