"""AdamW, written directly against the pytree API (no optax dependency).

Moments are stored in f32 regardless of parameter dtype (mixed-precision
training: bf16 params + f32 optimizer state, DESIGN.md §5); the state tree
mirrors the parameter tree so the sharding policy applies verbatim, and the
checkpoint layer serialises it like any other pytree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray  # () int32
    mu: Any  # first moment, f32, same tree as params
    nu: Any  # second moment, f32


def _is_frozen(p, g) -> bool:
    """Leaves excluded from differentiation — FCMP-packed carriers are
    inference-only: integer (packed uint8) params, or float0 tangents
    from value_and_grad(allow_int)."""
    return g.dtype == jax.dtypes.float0 or not jnp.issubdtype(
        p.dtype, jnp.inexact
    )


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100

    def init(self, params) -> OptState:
        # mu and nu must be DISTINCT buffer trees (aliased trees break
        # donation: "attempt to donate the same buffer twice"). Frozen
        # integer leaves (packed uint8 carriers) never update, so they get
        # scalar placeholders instead of full-shape dead moment buffers.
        def moment(p):
            if not jnp.issubdtype(p.dtype, jnp.inexact):
                return jnp.zeros((), jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        return OptState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(moment, params),
            jax.tree.map(moment, params),
        )

    def schedule(self, step) -> jnp.ndarray:
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        return self.lr * warm

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state)."""
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
                if g.dtype != jax.dtypes.float0
            )
        )
        clip = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self.schedule(step)
        bc1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            if _is_frozen(p, g):  # packed carriers pass through untouched
                return p, m, v
            g = g.astype(jnp.float32) * clip
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh, vh = m / bc1, v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(step, new_mu, new_nu)
