from repro.optim.adamw import AdamW, OptState  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_topk,
    decompress_topk,
    int8_allreduce,
    topk_error_feedback_update,
)
