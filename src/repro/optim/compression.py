"""Distributed-optimization tricks: gradient compression.

Two schemes, both standard at 1000+-node scale and both in the spirit of
the paper (spend surplus compute/precision headroom to relieve the
bottleneck resource — there OCM, here cross-pod bandwidth):

* **top-k sparsification with error feedback**: only the k largest-magnitude
  gradient entries cross the slow (inter-pod DCN) links; the residual is
  carried in a local error-feedback buffer so the compression is unbiased
  over time (Stich et al.).
* **int8 quantized all-reduce**: per-tensor symmetric int8 with an f32
  scale, 4x fewer bytes on the wire for the intra-pod all-reduce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_topk(g: jnp.ndarray, k: int):
    """Flatten and keep the k largest-|.| entries: (values, indices)."""
    flat = g.reshape(-1).astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def decompress_topk(values, idx, shape):
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    return flat.at[idx].set(values).reshape(shape)


def topk_error_feedback_update(g, err, k: int):
    """One error-feedback step: returns (sparse (values, idx), new_err).

    The transmitted gradient is ``sparsify(g + err)``; the untransmitted
    remainder becomes the next error buffer.
    """
    corrected = g.astype(jnp.float32) + err
    values, idx = compress_topk(corrected, k)
    transmitted = decompress_topk(values, idx, g.shape)
    new_err = corrected - transmitted
    return (values, idx), transmitted, new_err


def int8_quantize(g: jnp.ndarray):
    scale = jnp.max(jnp.abs(g)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def int8_allreduce(g: jnp.ndarray, axis_name: str):
    """Quantize-then-psum inside shard_map: ~4x wire-byte reduction.

    All participants must agree on ONE scale before quantizing (summing
    codes quantized at different scales is not meaningful), so the scale
    itself is a scalar pmax — 4 bytes of extra traffic. Accumulation
    happens in int32 (psum of int8 codes upcast), exact w.r.t. the codes.
    """
    gmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
    scale = jnp.maximum(gmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n
