"""A deterministic, dependency-free fallback for the ``hypothesis`` API.

The property suites (``tests/test_core_packing.py``, ``tests/test_kernels``,
``tests/test_dist_policy_properties.py``) are written against real
hypothesis — declared in ``pyproject.toml``'s ``test`` extra and installed
in CI. The hermetic container image, however, cannot pip-install, so
``tests/conftest.py`` installs this stub into ``sys.modules`` when the real
library is absent: property tests then run as deterministic random sweeps
(seeded per test + example index) instead of silently not collecting.

Only the surface the repo uses is implemented: ``given``, ``settings``,
``strategies.integers / sampled_from / booleans / data``. Shrinking,
example databases and health checks are out of scope — a stub failure
reports the drawn example values in the assertion context instead.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 15


# ------------------------------------------------------------ strategies


class Strategy:
    def example(self, rng: random.Random):
        raise NotImplementedError


class _Integers(Strategy):
    def __init__(self, min_value: int, max_value: int):
        self.min_value, self.max_value = min_value, max_value

    def example(self, rng):
        return rng.randint(self.min_value, self.max_value)

    def __repr__(self):
        return f"integers({self.min_value}, {self.max_value})"


class _SampledFrom(Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng):
        return rng.choice(self.elements)

    def __repr__(self):
        return f"sampled_from({self.elements!r})"


class _Booleans(Strategy):
    def example(self, rng):
        return bool(rng.randint(0, 1))


class _DataStrategy(Strategy):
    """Marker: the test draws interactively via ``data.draw``."""

    def example(self, rng):
        return DataObject(rng)


class DataObject:
    def __init__(self, rng: random.Random):
        self._rng = rng
        self.drawn: list = []  # interactive draws, reported on failure

    def draw(self, strategy: Strategy, label: str | None = None):
        value = strategy.example(self._rng)
        self.drawn.append(value if label is None else (label, value))
        return value

    def __repr__(self):
        return f"data(drawn={self.drawn!r})"


def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> Strategy:
    return _Integers(min_value, max_value)


def sampled_from(elements) -> Strategy:
    return _SampledFrom(elements)


def booleans() -> Strategy:
    return _Booleans()


def data() -> Strategy:
    return _DataStrategy()


# ------------------------------------------------------------ decorators


def given(**strategies):
    def decorate(f):
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            n = (
                getattr(wrapper, "_stub_max_examples", None)
                or getattr(f, "_stub_max_examples", None)
                or DEFAULT_MAX_EXAMPLES
            )
            for i in range(n):
                # crc32, not hash(): stable across processes regardless of
                # PYTHONHASHSEED, so failures replay identically.
                seed = zlib.crc32(
                    f"{f.__module__}.{f.__qualname__}:{i}".encode()
                )
                rng = random.Random(seed)
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    f(*args, **kwargs, **drawn)
                except Exception as e:
                    # hypothesis shrinks and prints the example; the stub
                    # at least names it (DataObject repr includes draws)
                    raise AssertionError(
                        f"stub-hypothesis falsifying example #{i}: {drawn!r}"
                    ) from e

        # pytest derives fixtures from the (wrapped) signature: hide the
        # strategy-drawn parameters, keep any genuine fixtures/parametrize
        # arguments the test also takes.
        sig = inspect.signature(f)
        params = [p for n, p in sig.parameters.items() if n not in strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        wrapper.hypothesis_stub = True
        return wrapper

    return decorate


def settings(max_examples: int | None = None, deadline=None, **_ignored):
    """Records max_examples; works above or below ``@given``."""

    def decorate(f):
        if max_examples:
            f._stub_max_examples = max_examples
        return f

    return decorate


# ------------------------------------------------------------ installer


def install() -> None:
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules."""
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "data"):
        setattr(st, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    hyp.__version__ = "0.0-stub"
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


def install_if_missing() -> bool:
    """Install the stub unless real hypothesis imports. True if stubbed."""
    try:
        import hypothesis  # noqa: F401

        return False
    except ImportError:
        install()
        return True
