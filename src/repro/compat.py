"""Version-tolerant shims over moving jax APIs.

The repo targets the Pallas/TPU toolchain across several jax releases, and
two API points have drifted underneath it:

* ``shard_map`` graduated from ``jax.experimental.shard_map.shard_map`` to
  ``jax.shard_map``, and its replication-check keyword was renamed
  ``check_rep`` -> ``check_vma``.
* the Pallas TPU compiler-parameter dataclass was renamed
  ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``.

Every call site in the repo goes through this module so a jax upgrade (or
downgrade inside the container image) is a one-file concern.
"""

from __future__ import annotations

import inspect
from typing import Any

import jax


def _kwarg_name(fn, default: str) -> str:
    """Which replication-check kwarg ``fn`` takes (the module promotion
    and the kwarg rename landed in *different* jax releases, so the two
    must be detected independently)."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return default
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return default


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old.

    ``check_vma`` (new name) is translated to ``check_rep`` (old name) when
    the resolved function still takes it. ``None`` leaves the library
    default in place on either version.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        default = "check_vma"
    else:
        from jax.experimental.shard_map import shard_map as sm

        default = "check_rep"
    kwargs: dict[str, Any] = {}
    if check_vma is not None:
        kwargs[_kwarg_name(sm, default)] = check_vma
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def tpu_compiler_params(**kwargs):
    """Pallas TPU compiler params across the TPUCompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
