"""Sharded, async, atomic checkpointing with elastic restore.

Layout (one directory per step)::

    <root>/step_000042/
        manifest.json        # tree structure, shapes, dtypes, shard map,
                             # data-pipeline state, mesh shape at save time
        <leaf>.s00.npy ...   # per-leaf shards, split along axis 0

Guarantees needed at 1000+-node scale (DESIGN.md §6):
  * **atomic commit** — shards are written into ``.tmp-step_N`` and the
    directory is ``rename``d only after all files + manifest are fsync'd;
    a reader never sees a partial checkpoint.
  * **async** — ``save(..., blocking=False)`` snapshots to host memory
    (device_get) and writes on a background thread; training continues.
  * **elastic restore** — shards are logical-axis splits, not device dumps,
    so a checkpoint written on a (16, 16) mesh restores onto (2, 16, 16) or
    onto 1 CPU device (``restore_resharded``) — resharding is a device_put
    with the *new* sharding, never a format change.
  * retention — keep the newest ``keep`` checkpoints, delete older ones.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def _unflatten_like(template, values: dict[str, Any]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        if key not in values:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(values[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, n_shards: int = 4):
        self.root = root
        self.keep = keep
        self.n_shards = n_shards
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save

    def save(
        self, step: int, tree, extra: dict | None = None, blocking: bool = True
    ) -> None:
        # snapshot to host memory first: the training step can proceed
        host = {
            k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()
        }
        self.wait()
        if blocking:
            self._write(step, host, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, extra: dict) -> None:
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = os.path.join(self.root, f".tmp-step_{step:08d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest: dict[str, Any] = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in host.items():
            fname = key.replace("/", "__")
            ns = min(self.n_shards, max(1, arr.shape[0] if arr.ndim else 1))
            shards = np.array_split(arr, ns, axis=0) if arr.ndim else [arr]
            files = []
            for i, sh in enumerate(shards):
                f = f"{fname}.s{i:02d}.npy"
                with open(os.path.join(tmp, f), "wb") as fh:
                    np.save(fh, sh)
                    fh.flush()
                    os.fsync(fh.fileno())
                files.append(f)
            manifest["leaves"][key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "files": files,
            }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as fh:
            json.dump(manifest, fh)
            fh.flush()
            os.fsync(fh.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.root, f"step_{s:08d}"), ignore_errors=True
            )

    # ---------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None):
        """Returns (tree_like_template, extra dict). Host numpy arrays."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as fh:
            manifest = json.load(fh)
        values = {}
        for key, meta in manifest["leaves"].items():
            parts = [np.load(os.path.join(d, f)) for f in meta["files"]]
            arr = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
            values[key] = arr.reshape(meta["shape"]).astype(meta["dtype"])
        return _unflatten_like(template, values), manifest["extra"]


def restore_resharded(
    manager: CheckpointManager, template, shardings, step: int | None = None
):
    """Elastic restore: place restored leaves with *new* shardings (a
    different mesh shape than at save time). ``shardings`` is a pytree of
    jax.sharding.Sharding matching ``template`` (or None leaves = default)."""
    host_tree, extra = manager.restore(template, step)

    def put(arr, sh):
        return jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)

    return jax.tree.map(put, host_tree, shardings), extra
