"""Quantization-aware-training substrate (the Brevitas analogue, §III-A)."""

from repro.quant.quantizers import (  # noqa: F401
    binary_weight,
    int_act,
    int_weight,
    lsq_quantize,
    pack_bits,
    ternary_weight,
    unpack_bits,
)
from repro.quant.streamline import (  # noqa: F401
    ThresholdSpec,
    bn_act_to_thresholds,
    thresholding,
)
