"""FINN streamlining: fold BatchNorm + quantized activation into integer
thresholding (paper §III-B).

A streamlined MVAU computes ``o = sum_k [acc >= T_k]`` on the raw integer
accumulator instead of ``quant_act(BN(acc))`` — bit-exact, and the T_k are
what the FCMP weight/threshold memories actually store.

Derivation: the A-bit activation maps z to level l when z crosses the l-th
activation-domain threshold t_l = s * (l - 2^(A-1) + 0.5) (mid-rise, signed).
With z = gamma * (acc - mu) / sigma + beta, the accumulator-domain
threshold is

    T_l = (t_l - beta) * sigma / gamma + mu          (gamma > 0)

and the comparison flips for gamma < 0, which we normalise by negating both
accumulator and thresholds (FINN does the same sign-canonicalisation).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ThresholdSpec:
    """Per-channel thresholds: shape (channels, n_levels-1), ascending."""

    thresholds: jnp.ndarray
    signs: jnp.ndarray  # +1/-1 per channel (gamma sign canonicalisation)
    offset: float  # output integer offset (signed representation)
    scale: jnp.ndarray  # activation scale s (to map level -> value)


def act_level_thresholds(scale, bits: int, signed: bool = True):
    """Activation-domain decision boundaries of an LSQ-style quantizer."""
    if signed:
        levels = jnp.arange(-(2 ** (bits - 1)) + 1, 2 ** (bits - 1))
        offset = -(2 ** (bits - 1))
    else:
        levels = jnp.arange(1, 2**bits)
        offset = 0
    # round-to-nearest: boundary between l-1 and l sits at (l - 0.5) * s
    return (levels - 0.5) * scale, float(offset)


def bn_act_to_thresholds(
    gamma, beta, mu, var, act_scale, bits: int, eps: float = 1e-5
) -> ThresholdSpec:
    """Fold BN(gamma,beta,mu,var) + quant-act(scale,bits) into thresholds."""
    gamma = jnp.asarray(gamma)
    sigma = jnp.sqrt(jnp.asarray(var) + eps)
    t_act, offset = act_level_thresholds(jnp.asarray(act_scale), bits)
    # broadcast: (C, L)
    t_act = jnp.broadcast_to(t_act, (gamma.shape[0], t_act.shape[-1]))
    safe_gamma = jnp.where(jnp.abs(gamma) < 1e-12, 1e-12, gamma)
    T = (t_act - beta[:, None]) * (sigma / safe_gamma)[:, None] + mu[:, None]
    signs = jnp.where(gamma >= 0, 1.0, -1.0)
    # canonicalise: for gamma<0 comparisons flip; store ascending thresholds
    T = jnp.where(signs[:, None] > 0, T, -T)
    T = jnp.sort(T, axis=1)
    return ThresholdSpec(T, signs, offset, jnp.asarray(act_scale))


def thresholding(acc, spec: ThresholdSpec):
    """Integer thresholding: o = offset + sum_k [sign*acc >= T_k].

    ``acc``: (..., C) raw accumulator. Returns the quantized activation
    *value* (level * scale) so it is drop-in for BN+act in the float graph.
    """
    x = acc * spec.signs
    level = jnp.sum(
        (x[..., None] >= spec.thresholds).astype(jnp.int32), axis=-1
    ) + int(spec.offset)
    return level.astype(acc.dtype) * spec.scale


def thresholding_int(acc, spec: ThresholdSpec):
    """Integer-only output (what the FPGA datapath carries)."""
    x = acc * spec.signs
    return jnp.sum(
        (x[..., None] >= spec.thresholds).astype(jnp.int32), axis=-1
    ) + int(spec.offset)


def reference_bn_act(acc, gamma, beta, mu, var, act_scale, bits, eps=1e-5):
    """The unstreamlined graph: BN then round-to-nearest signed quant."""
    z = gamma * (acc - mu) / jnp.sqrt(var + eps) + beta
    qn, qp = 2 ** (bits - 1), 2 ** (bits - 1) - 1
    return jnp.clip(jnp.round(z / act_scale), -qn, qp) * act_scale
