"""Quantizers: binary / ternary / int-N weights, LSQ learned-scale acts.

Matches the paper's §III-A training configuration:
  * ResBlock conv weights: 1-bit (binary, sign * scale) or 2-bit (ternary),
  * first/last layer weights: signed 8-bit,
  * activations: signed 2-bit everywhere, 4-bit around the residual adds,
  * scale factors learned with LSQ (Esser et al. [24] / Jain et al. [25]).

All quantizers are differentiable via straight-through estimators; LSQ uses
the exact Esser et al. gradient through a ``custom_vjp``. Bit-packing
helpers convert quantized weights to the dense int8 carrier format consumed
by the Pallas ``packed_matmul`` kernel (the TPU OCM-packing analogue).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _ste(x, q):
    """Straight-through: forward q, backward identity."""
    return x + jax.lax.stop_gradient(q - x)


# --------------------------------------------------------------------------
# LSQ (learned step size quantization)
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_quantize(x, scale, qn: int, qp: int):
    """LSQ: q = clip(round(x/s), -qn, qp) * s with the Esser et al. VJP."""
    s = jnp.maximum(scale, 1e-8)
    return jnp.clip(jnp.round(x / s), -qn, qp) * s


def _lsq_fwd(x, scale, qn, qp):
    s = jnp.maximum(scale, 1e-8)
    v = x / s
    q = jnp.clip(jnp.round(v), -qn, qp)
    return q * s, (v, q, s)


def _lsq_bwd(qn, qp, res, g):
    v, q, s = res
    inside = (v >= -qn) & (v <= qp)
    dx = jnp.where(inside, g, 0.0)
    # d(q*s)/ds: inside -> round(v) - v ; clipped -> -qn or qp
    ds_elem = jnp.where(inside, q - v, q)
    # LSQ grad-scale normalisation: 1/sqrt(n * qp)
    gscale = 1.0 / np.sqrt(max(1, v.size) * max(1, qp))
    ds = jnp.sum(g * ds_elem) * gscale
    return dx, jnp.asarray(ds, dtype=s.dtype).reshape(())


lsq_quantize.defvjp(_lsq_fwd, _lsq_bwd)


def int_act(x, scale, bits: int, signed: bool = True):
    """LSQ-quantized activation (2-bit / 4-bit in the paper)."""
    if signed:
        qn, qp = 2 ** (bits - 1), 2 ** (bits - 1) - 1
    else:
        qn, qp = 0, 2**bits - 1
    return lsq_quantize(x, scale, qn, qp)


def init_act_scale(bits: int = 2) -> jnp.ndarray:
    # LSQ init ~ 2<|x|>/sqrt(qp); a constant works for synthetic training
    return jnp.asarray(2.0 / np.sqrt(2 ** (bits - 1) - 0.5), jnp.float32)


# --------------------------------------------------------------------------
# Weight quantizers (STE)
# --------------------------------------------------------------------------


def binary_weight(w):
    """1-bit: sign(w) * E|w| per output channel (last axis = out)."""
    axes = tuple(range(w.ndim - 1))
    alpha = jnp.mean(jnp.abs(w), axis=axes, keepdims=True)
    q = jnp.where(w >= 0, 1.0, -1.0) * alpha
    return _ste(w, q)


def ternary_weight(w, delta_frac: float = 0.7):
    """2-bit ternary (Li et al. [17]): t = 0.7*E|w|, levels {-a, 0, +a}."""
    axes = tuple(range(w.ndim - 1))
    mean_abs = jnp.mean(jnp.abs(w), axis=axes, keepdims=True)
    delta = delta_frac * mean_abs
    mask = (jnp.abs(w) > delta).astype(w.dtype)
    alpha_num = jnp.sum(jnp.abs(w) * mask, axis=axes, keepdims=True)
    alpha = alpha_num / jnp.maximum(jnp.sum(mask, axis=axes, keepdims=True), 1.0)
    q = jnp.sign(w) * mask * alpha
    return _ste(w, q)


def int_weight(w, bits: int = 8):
    """Symmetric signed int-N weight quant (first/last layers, 8-bit)."""
    qp = 2 ** (bits - 1) - 1
    axes = tuple(range(w.ndim - 1))
    s = jnp.max(jnp.abs(w), axis=axes, keepdims=True) / qp
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(w / s), -qp - 1, qp) * s
    return _ste(w, q)


def quantize_weight(w, w_bits: int):
    if w_bits == 1:
        return binary_weight(w)
    if w_bits == 2:
        return ternary_weight(w)
    return int_weight(w, w_bits)


# --------------------------------------------------------------------------
# Bit packing (carrier format for kernels/packed_matmul)
# --------------------------------------------------------------------------


def pack_bits(q_codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack integer codes in [0, 2^bits) along axis 0 into a uint8 carrier.

    For bits=1: 8 weights/byte; bits=2: 4 weights/byte; bits=4: 2/byte.
    Axis 0 (the reduction dim) must be a multiple of 8//bits.
    """
    assert bits in (1, 2, 4)
    per = 8 // bits
    k = q_codes.shape[0]
    assert k % per == 0, f"reduction dim {k} not a multiple of {per}"
    q = q_codes.astype(jnp.uint8).reshape((k // per, per) + q_codes.shape[1:])
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits).reshape(
        (1, per) + (1,) * (q_codes.ndim - 1)
    )
    return jnp.sum(q << shifts, axis=1).astype(jnp.uint8)


def unpack_bits(packed: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """Inverse of ``pack_bits``: uint8 carrier -> integer codes, axis 0."""
    assert bits in (1, 2, 4)
    per = 8 // bits
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits).reshape(
        (1, per) + (1,) * (packed.ndim - 1)
    )
    mask = jnp.uint8(2**bits - 1)
    codes = (packed[:, None] >> shifts) & mask
    out = codes.reshape((packed.shape[0] * per,) + packed.shape[1:])
    return out[:k]


def codes_from_binary(w_sign: jnp.ndarray) -> jnp.ndarray:
    """{-1,+1} -> {0,1} codes."""
    return (w_sign > 0).astype(jnp.uint8)


def binary_from_codes(codes: jnp.ndarray) -> jnp.ndarray:
    return codes.astype(jnp.float32) * 2.0 - 1.0


def codes_from_ternary(w_tern: jnp.ndarray) -> jnp.ndarray:
    """{-1,0,+1} -> {0,1,2} codes (2-bit)."""
    return (w_tern + 1).astype(jnp.uint8)


def ternary_from_codes(codes: jnp.ndarray) -> jnp.ndarray:
    return codes.astype(jnp.float32) - 1.0
