"""Architecture registry: the 10 assigned archs + the paper's own models.

``get_config(name)`` returns the full-size ``ModelConfig`` (exercised only
via the dry-run); ``get_smoke_config(name)`` returns the reduced same-family
config used by the CPU smoke tests. FPGA-side accelerator configs (the
paper's CNV / ResNet-50) are exposed via ``get_accelerator(name)``.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced

ARCH_IDS = [
    "h2o_danube_1p8b",
    "llama3p2_1b",
    "phi3_medium_14b",
    "smollm_360m",
    "internvl2_76b",
    "whisper_tiny",
    "olmoe_1b_7b",
    "moonshot_v1_16b_a3b",
    "zamba2_2p7b",
    "mamba2_1p3b",
]

# assignment ids (dashes/dots) -> module names
ALIASES = {
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "llama3.2-1b": "llama3p2_1b",
    "phi3-medium-14b": "phi3_medium_14b",
    "smollm-360m": "smollm_360m",
    "internvl2-76b": "internvl2_76b",
    "whisper-tiny": "whisper_tiny",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "zamba2-2.7b": "zamba2_2p7b",
    "mamba2-1.3b": "mamba2_1p3b",
}

ACCEL_IDS = ["cnv_w1a1", "cnv_w2a2", "rn50_w1a2", "rn50_w2a2"]


def canonical(name: str) -> str:
    """Canonical module id for an arch/accelerator name.

    Unknown names raise ``ValueError`` listing the valid ids, so every
    ``--arch``-taking driver (train / serve / dryrun) fails cleanly
    instead of surfacing a raw ``ModuleNotFoundError``.
    """
    cand = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    if cand not in ARCH_IDS and cand not in ACCEL_IDS:
        raise ValueError(
            f"unknown arch {name!r}; valid archs: {', '.join(ARCH_IDS)}; "
            f"valid accelerators: {', '.join(ACCEL_IDS)}"
        )
    return cand


def canonical_arch(name: str) -> str:
    """``canonical`` restricted to LM archs (what ``--arch`` drivers take)."""
    cand = canonical(name)
    if cand in ACCEL_IDS:
        raise ValueError(
            f"{name!r} is an FPGA accelerator config, not an LM arch; "
            f"use get_accelerator(). Valid archs: {', '.join(ARCH_IDS)}"
        )
    return cand


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_arch(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_arch(name)}")
    if hasattr(mod, "SMOKE"):
        return mod.SMOKE
    return reduced(mod.CONFIG)


def get_accelerator(name: str):
    cand = canonical(name)
    if cand not in ACCEL_IDS:
        raise ValueError(
            f"{name!r} is not an accelerator config; valid accelerators: "
            f"{', '.join(ACCEL_IDS)}"
        )
    mod = importlib.import_module(f"repro.configs.{cand}")
    return mod.ACCEL


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
