"""Architecture registry: the 10 assigned archs + the paper's own models.

``get_config(name)`` returns the full-size ``ModelConfig`` (exercised only
via the dry-run); ``get_smoke_config(name)`` returns the reduced same-family
config used by the CPU smoke tests. FPGA-side accelerator configs (the
paper's CNV / ResNet-50) are exposed via ``get_accelerator(name)``.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced

ARCH_IDS = [
    "h2o_danube_1p8b",
    "llama3p2_1b",
    "phi3_medium_14b",
    "smollm_360m",
    "internvl2_76b",
    "whisper_tiny",
    "olmoe_1b_7b",
    "moonshot_v1_16b_a3b",
    "zamba2_2p7b",
    "mamba2_1p3b",
]

# assignment ids (dashes/dots) -> module names
ALIASES = {
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "llama3.2-1b": "llama3p2_1b",
    "phi3-medium-14b": "phi3_medium_14b",
    "smollm-360m": "smollm_360m",
    "internvl2-76b": "internvl2_76b",
    "whisper-tiny": "whisper_tiny",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "zamba2-2.7b": "zamba2_2p7b",
    "mamba2-1.3b": "mamba2_1p3b",
}

ACCEL_IDS = ["cnv_w1a1", "cnv_w2a2", "rn50_w1a2", "rn50_w2a2"]


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    if hasattr(mod, "SMOKE"):
        return mod.SMOKE
    return reduced(mod.CONFIG)


def get_accelerator(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.ACCEL


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
