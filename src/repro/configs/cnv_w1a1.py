"""CNV-W1A1 (BNN-Pynq, CIFAR-10 binarized CNN on Zynq 7020) — paper §V."""

from repro.configs.accel import make_cnv

ACCEL = make_cnv(1)
