"""RN50-W1A2 (binary-weight ResNet-50 on Alveo U250) — paper §III/§V."""

from repro.configs.accel import make_rn50

ACCEL = make_rn50(1)
