"""FPGA accelerator configs for the paper's own designs (CNV, ResNet-50).

An ``AccelConfig`` carries everything the FCMP methodology needs: the
MVAU layer set, the target device, weight precision, the packing GA
hyper-parameters (paper Table III), and the baseline operating clocks
(paper Table V). ``buffers()`` derives the logical weight memories at a
throughput-maximising folding, which is what the packing benchmarks and
Table IV/V reproductions consume.
"""

from __future__ import annotations

import dataclasses
import functools

from repro.core.buffers import Folding, LayerSpec, buffer_set
from repro.core.folding import FoldingSolution, search_folding
from repro.core.packing import GaParams
from repro.core.resource_model import DEVICES, FpgaDevice
from repro.core.topologies import cnv_layers, resblock_slr_map, resnet50_layers


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    name: str
    kind: str  # "cnv" | "rn50"
    w_bits: int
    a_bits: int
    device: FpgaDevice
    ga: GaParams
    f_compute_mhz: float  # baseline compute clock (paper Table V)
    f_memory_mhz: float  # target memory clock for H_B=4 (R_F = 2)
    # The paper's folding solutions target a throughput design point
    # (RN50: 2703 FPS at 195 MHz -> max II ~ 72k cycles); the search stops
    # there instead of greedily filling the LUT budget, which reproduces
    # the paper's buffer shapes (and hence its baseline OCM efficiency).
    target_ii: int | None = None

    @functools.cached_property
    def layers(self) -> list[LayerSpec]:
        if self.kind == "cnv":
            return cnv_layers(self.w_bits)
        return resnet50_layers(self.w_bits)

    @functools.cached_property
    def folding(self) -> FoldingSolution:
        return search_folding(
            self.layers, self.device, target_ii=self.target_ii
        )

    def buffers(self):
        return buffer_set(self.layers, self.folding.foldings)

    def regions(self) -> list[str]:
        """SLR assignment (Alveo floorplan constraint; single region on Zynq)."""
        if self.device.slrs <= 1:
            return ["slr0"] * len(self.layers)
        return resblock_slr_map(self.layers, self.device.slrs)


def make_cnv(w_bits: int, device: str = "zynq7020") -> AccelConfig:
    return AccelConfig(
        name=f"cnv_w{w_bits}a{w_bits}",
        kind="cnv",
        w_bits=w_bits,
        a_bits=w_bits,
        device=DEVICES[device],
        ga=GaParams(max_height=4, population=50, tournament=5,
                    p_adm_w=0.0, p_adm_h=0.1, p_mut=0.3),
        f_compute_mhz=100.0,
        f_memory_mhz=200.0,
        # BNN-Pynq CNV bottleneck: conv1 at PE=32/SIMD=32 -> 36 folds x
        # 28^2 pixels = 28224 cycles (~3500 FPS at 100 MHz)
        target_ii=28_224,
    )


def make_rn50(w_bits: int, device: str = "u250") -> AccelConfig:
    return AccelConfig(
        name=f"rn50_w{w_bits}a2",
        kind="rn50",
        w_bits=w_bits,
        a_bits=2,
        device=DEVICES[device],
        ga=GaParams(max_height=4, population=75, tournament=5,
                    p_adm_w=0.0, p_adm_h=0.1, p_mut=0.4),
        f_compute_mhz=200.0,
        f_memory_mhz=400.0,
        # paper Table II: 2703 FPS at 195 MHz -> max II ~ 72k cycles
        target_ii=72_000,
    )
