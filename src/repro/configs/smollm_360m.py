"""smollm-360m — llama-arch small dense LM [hf:HuggingFaceTB/SmolLM].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, head_dim=64,
tied embeddings.
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv=5,
    d_ff=2560,
    vocab=49_152,
    head_dim=64,
    tie_embeddings=True,
)

SMOKE = reduced(CONFIG, n_heads=3, n_kv=1)
