"""mamba2-1.3b — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060].

48L d_model=2048 (attn-free, d_ff=0) vocab=50280, ssm_state=128,
head_dim=64, expand=2 (d_inner=4096, 64 SSM heads). O(1) decode state ->
runs long_500k.
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=32,  # unused by the ssm family (attention-free); kept for hd math
    n_kv=32,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
)

SMOKE = reduced(CONFIG)
