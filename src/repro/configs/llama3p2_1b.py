"""llama3.2-1b — small llama3 dense LM [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256, head_dim=64,
tied embeddings (as in the released model), rope_theta=500000.
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv=8,
    d_ff=8192,
    vocab=128_256,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=500_000.0,
)

SMOKE = reduced(CONFIG)
