"""phi3-medium-14b — dense LM, RoPE SwiGLU GQA [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
40 heads / 10 KV heads are not divisible by the 16-way model axis; the
sharding policy shards the flattened head*hd projection dim (5120 / 1280,
both divisible) instead — DESIGN.md §5.
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv=10,
    d_ff=17_920,
    vocab=100_352,
)

SMOKE = reduced(CONFIG, n_heads=4, n_kv=2)
