"""RN50-W2A2 (ternary-weight ResNet-50 on Alveo U250) — paper §III/§V."""

from repro.configs.accel import make_rn50

ACCEL = make_rn50(2)
