"""CNV-W2A2 (BNN-Pynq, CIFAR-10 ternary CNN on Zynq 7020) — paper §V."""

from repro.configs.accel import make_cnv

ACCEL = make_cnv(2)
