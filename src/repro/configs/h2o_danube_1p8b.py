"""h2o-danube-1.8b — dense LM, llama+mistral mix with sliding-window
attention [arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
The sliding window makes this the one *dense* arch that runs long_500k
(live KV is capped at the window, DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv=8,
    d_ff=6912,
    vocab=32_000,
    sliding_window=4096,
    rope_theta=10_000.0,
)

SMOKE = reduced(CONFIG)
