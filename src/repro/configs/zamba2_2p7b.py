"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
One *shared* attention+FFN block is applied every 6 mamba layers (9
applications of the same parameters — Zamba's weight-shared global
mixer). Attention-free between the shared blocks -> runs long_500k.
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    d_ff=10_240,
    vocab=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,
)

SMOKE = reduced(CONFIG, n_layers=4, hybrid_attn_every=2)
