"""internvl2-76b — VLM: InternViT + InternLM2 backbone [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. Per the
assignment, only the transformer BACKBONE is modelled; the vision
frontend is a STUB — ``input_specs()`` supplies precomputed patch
embeddings (256 patches per image tile, InternVL's pixel-unshuffled
448x448 tile).
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=28_672,
    vocab=128_256,
    n_patches=256,
)

SMOKE = reduced(CONFIG)
