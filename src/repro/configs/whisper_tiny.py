"""whisper-tiny — encoder-decoder audio backbone [arXiv:2212.04356].

4L (decoder) + 4L (encoder), d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
The conv frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (B, 1500, d) — Whisper's 30 s / 2x-strided mel frontend yields
1500 frames.
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_ff=1536,
    vocab=51_865,
    n_enc_layers=4,
    frontend_len=1500,
)

SMOKE = reduced(CONFIG, n_heads=4, n_kv=4)
