"""olmoe-1b-7b — MoE LM, 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (kv=16, i.e. MHA) d_ff=1024 per expert,
vocab=50304, 64 experts / top-8. The many small (2048x1024) expert FFNs
are the closest LM analogue to the paper's "many oddly-shaped parameter
buffers" — the FCMP planner's best-fit family (DESIGN.md §4).
"""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1024,
    vocab=50_304,
    n_experts=64,
    experts_per_token=8,
)

SMOKE = reduced(CONFIG)
