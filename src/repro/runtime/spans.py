"""Per-request lifecycle spans: the latency-decomposition layer.

``runtime.tracker`` (PR 6) made serving *round*-observable: one delta
record per scheduler round, replay-exact. This module makes it
*request*-observable with the same contract. A ``SpanRecorder`` rides
inside the scheduler/engine and emits one span per lifecycle phase —

    queue          submit -> admission (head-of-line + budget wait)
    prefix_lookup  radix-cache probe at admission (zero-width; carries
                   the matched-prefix length)
    prefill        one span per prefill step (chunked prompts get one
                   span per chunk, ``tokens``/``chunk_start`` attrs)
    decode         one span per round's contiguous run of decode steps
                   a lane participated in (``steps`` attr)
    handoff        prefilled KV in flight prefill->decode engine
                   (virtual interconnect transit, ``kv_bytes`` attr)
    wait           any gap the recorder tiles between two phases (round
                   overhead, other lanes' work, import transit wait)
    requeue        a drain abort marker (``aborted: true``): the
                   request restarts cold elsewhere; spans recorded for
                   it on this engine are excluded from decomposition

— through ``Tracker.log_spans`` as ``kind="span"`` records, interleaved
with the round records in the same JSONL file.

The decomposition contract (checked by ``validate_trace``, the span
analogue of ``tracker.replay_summary``): for every completed request,
its spans tile the closed interval [t_submit, t_done] *exactly* — each
span starts at the previous span's end (float-equal: the recorder
rounds every timestamp once, at the source, to ``NDIGITS`` decimals and
derived stamps reuse the same values) — and the engine-event stamps
(admit/first/done) land on span boundaries. Summing phase durations up
to the first-token boundary therefore telescopes to exactly the
submit-relative TTFT, and the remainder to the decode time.

``SLOMonitor`` folds the same per-request milestones into streaming
log-bucket histograms (TTFT submit- and admit-relative, TPOT, queue
wait) plus multi-window SLO burn rates: the fraction of requests
violating ``traffic.SloPolicy`` in a sliding virtual-time window,
divided by the policy's error budget (1 - target). Burn > 1 means the
window is eating budget faster than the policy allows — the standard
SRE burn-rate alert shape, here on the virtual clock.
"""

from __future__ import annotations

import math
from collections import deque
from typing import Callable, Iterable

# one rounding, at the source: every timestamp the recorder hands out is
# rounded once to this many decimals (1 ns on the virtual clock), so any
# two stamps of the same instant are float-equal after a JSON round-trip
NDIGITS = 9

SPAN_PHASES = (
    "queue",
    "prefix_lookup",
    "prefill",
    "draft",  # speculative: drafter prefill / chain proposal
    "verify",  # speculative: batched target verification of the chain
    "decode",
    "handoff",
    "wait",
    "requeue",
)


class VirtualClock:
    """A mutable virtual-seconds clock an Engine and its recorder share.

    ``Engine.clock`` historically was a bare float assigned from outside
    (router arrival alignment, import waits); the shared object keeps
    that write path while letting the scheduler's charge hook and the
    span recorder observe the same instant mid-round.
    """

    __slots__ = ("t",)

    def __init__(self, t: float = 0.0):
        self.t = t

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class SpanRecorder:
    """Records one request's lifecycle as contiguous spans.

    ``clock`` is any zero-arg callable returning seconds (an Engine
    passes its ``VirtualClock.now``; a bare scheduler passes
    ``time.monotonic``). Spans buffer in-process and ``flush`` emits
    them through ``tracker.log_spans`` (dropped when ``tracker`` is
    None, so an untracked engine pays only the bookkeeping).

    Contiguity is guaranteed *by construction*: ``mark``/``open`` tile
    the gap since the request's previous span end with an explicit
    ``wait`` span instead of leaving a hole.
    """

    def __init__(
        self,
        clock: Callable[[], float],
        *,
        tracker=None,
        engine: int | None = None,
        role: str | None = None,
    ):
        self._clock = clock
        self.tracker = tracker
        self.engine = engine
        self.role = role
        self._open: dict[int, tuple[str, float, dict]] = {}
        self._last: dict[int, float] = {}
        self._buf: list[dict] = []
        # (kind, rid, t) exact milestone stamps; an Engine drains these
        self.events: list[tuple[str, int, float]] = []
        self.n_spans = 0

    # ---------------- time ----------------

    def now(self) -> float:
        return round(self._clock(), NDIGITS)

    @staticmethod
    def _r(t: float) -> float:
        return round(t, NDIGITS)

    # ---------------- span plumbing ----------------

    def _emit(self, rid: int, phase: str, t0: float, t1: float, attrs: dict):
        self._last[rid] = t1
        self.n_spans += 1
        if self.tracker is None:
            return
        span = {"rid": rid, "phase": phase, "t0": t0, "t1": t1}
        if self.engine is not None:
            span["engine"] = self.engine
        if self.role is not None:
            span["role"] = self.role
        span.update(attrs)
        self._buf.append(span)

    def _fill_wait(self, rid: int, t0: float) -> None:
        last = self._last.get(rid)
        if last is not None and t0 > last:
            self._emit(rid, "wait", last, t0, {})

    def mark(
        self, rid: int, phase: str, t0: float, t1: float, **attrs
    ) -> None:
        """Record a closed span, tiling any gap since the request's
        previous span with a ``wait``."""
        t0, t1 = self._r(t0), self._r(t1)
        self._fill_wait(rid, t0)
        self._emit(rid, phase, t0, t1, attrs)

    def open(self, rid: int, phase: str, t0: float | None = None, **attrs):
        t0 = self.now() if t0 is None else self._r(t0)
        self._fill_wait(rid, t0)
        self._open[rid] = (phase, t0, attrs)

    def close(self, rid: int, t1: float | None = None, **attrs) -> float:
        """Close the request's open span; returns the close time."""
        t1 = self.now() if t1 is None else self._r(t1)
        phase, t0, a = self._open.pop(rid)
        self._emit(rid, phase, t0, t1, {**a, **attrs})
        return t1

    def seed(self, rid: int, t: float) -> None:
        """Start a request's timeline at ``t`` without emitting a span
        (a decode engine seeds at the handoff payload's ready time)."""
        self._last[rid] = self._r(t)

    def abort(self, rid: int, t: float | None = None, reason: str = ""):
        """Terminate a request's timeline on this engine (drain/requeue):
        whatever was open or pending closes as an ``aborted`` span, and
        ``validate_trace`` excludes this engine's spans for the rid."""
        t = self.now() if t is None else self._r(t)
        flag = {"aborted": True, "reason": reason}
        if rid in self._open:
            phase, t0, a = self._open.pop(rid)
            self._emit(rid, phase, t0, t, {**a, **flag})
        else:
            t0 = self._last.get(rid, t)
            self._emit(rid, "requeue", t0, t, flag)
        self._last.pop(rid, None)

    def forget(self, rid: int) -> None:
        """Drop per-rid state after a terminal event (done/handoff)."""
        self._open.pop(rid, None)
        self._last.pop(rid, None)

    # ---------------- milestones ----------------

    def event(self, kind: str, rid: int, t: float | None = None) -> None:
        self.events.append(
            (kind, rid, self.now() if t is None else self._r(t))
        )

    def drain_events(self) -> list[tuple[str, int, float]]:
        out, self.events = self.events, []
        return out

    # ---------------- emission ----------------

    def flush(self) -> None:
        if self._buf:
            self.tracker.log_spans(self._buf)
            self._buf = []


# --------------------------------------------------------------------------
# decomposition: the span analogue of tracker.replay_summary
# --------------------------------------------------------------------------


def iter_span_records(records: Iterable[dict]) -> list[dict]:
    return [r for r in records if r.get("kind") == "span"]


def request_spans(records: Iterable[dict]) -> dict[int, list[dict]]:
    """Spans per rid, aborted engine-visits excluded, time-ordered.

    A drained-and-requeued request restarts cold on another engine; the
    spans it recorded on the drained engine end in an ``aborted`` marker
    and the whole (rid, engine) visit is dropped — the surviving spans
    are the request's *served* timeline (possibly spanning a prefill and
    a decode engine, joined by the handoff span).
    """
    by_visit: dict[tuple[int, int | None], list[dict]] = {}
    for s in iter_span_records(records):
        by_visit.setdefault((s["rid"], s.get("engine")), []).append(s)
    out: dict[int, list[dict]] = {}
    for (rid, _eng), spans in by_visit.items():
        if any(s.get("aborted") for s in spans):
            continue
        out.setdefault(rid, []).extend(spans)
    for spans in out.values():
        spans.sort(key=lambda s: (s["t0"], s["t1"]))
    return out


def request_events(records: Iterable[dict]) -> dict[int, dict[str, float]]:
    """Milestone stamps per rid from the metrics records' event lists
    (first "first" wins; last "admit"/"done" win — a requeued request
    re-admits, and only its final admission leads anywhere)."""
    out: dict[int, dict[str, float]] = {}
    for r in records:
        if r.get("kind", "metrics") != "metrics":
            continue
        for kind, rid, t in r.get("events", ()):
            d = out.setdefault(int(rid), {})
            if kind == "first":
                d.setdefault("first", t)
            else:
                d[kind] = t
    return out


def decompose(
    records: Iterable[dict],
) -> dict[int, dict[str, float]]:
    """Per-request phase durations (seconds) up to the done stamp."""
    out: dict[int, dict[str, float]] = {}
    for rid, spans in request_spans(records).items():
        agg: dict[str, float] = {}
        for s in spans:
            agg[s["phase"]] = agg.get(s["phase"], 0.0) + (s["t1"] - s["t0"])
        out[rid] = agg
    return out


def validate_trace(records: Iterable[dict]) -> list[str]:
    """The decomposition invariant: for every request with a ``done``
    event, its (non-aborted) spans tile [t_submit, t_done] exactly —
    each span starts float-equal at the previous one's end — the
    admit/first/done stamps land on span boundaries, and the phase
    durations telescope to submit-relative TTFT + decode time. Returns
    human-readable violations (empty == the trace decomposes exactly).
    """
    records = list(records)
    spans_by = request_spans(records)
    events_by = request_events(records)
    errors: list[str] = []
    for rid, ev in sorted(events_by.items()):
        if "done" not in ev:
            continue
        spans = spans_by.get(rid)
        if not spans:
            errors.append(f"rid {rid}: done event but no surviving spans")
            continue
        bounds = {spans[0]["t0"]}
        cursor = spans[0]["t0"]
        for s in spans:
            if s["t0"] != cursor:
                errors.append(
                    f"rid {rid}: span {s['phase']} starts at {s['t0']!r}, "
                    f"previous span ended at {cursor!r} (gap/overlap)"
                )
            cursor = s["t1"]
            bounds.add(s["t1"])
        if cursor != ev["done"]:
            errors.append(
                f"rid {rid}: spans end at {cursor!r}, done at "
                f"{ev['done']!r}"
            )
        for kind in ("admit", "first"):
            if kind in ev and ev[kind] not in bounds:
                errors.append(
                    f"rid {rid}: {kind} stamp {ev[kind]!r} is not a span "
                    "boundary"
                )
        # the telescoped check: phase sums reproduce TTFT + decode time
        t0 = spans[0]["t0"]
        if "first" in ev:
            pre = math.fsum(
                s["t1"] - s["t0"] for s in spans if s["t1"] <= ev["first"]
            )
            if abs(pre - (ev["first"] - t0)) > 1e-9:
                errors.append(
                    f"rid {rid}: sum(phase spans before first) = {pre!r} "
                    f"!= ttft {ev['first'] - t0!r}"
                )
        total = math.fsum(s["t1"] - s["t0"] for s in spans)
        if abs(total - (ev["done"] - t0)) > 1e-9:
            errors.append(
                f"rid {rid}: sum(phase spans) = {total!r} != "
                f"t_done - t_submit = {ev['done'] - t0!r}"
            )
    return errors


# --------------------------------------------------------------------------
# streaming SLO monitoring
# --------------------------------------------------------------------------


class StreamingHist:
    """Fixed-memory log-bucketed latency histogram (virtual seconds)."""

    def __init__(
        self, lo: float = 1e-7, hi: float = 1e4, per_decade: int = 8
    ):
        self.lo = lo
        n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
        self._step = math.log10(hi / lo) / (n - 1)
        self._counts = [0] * (n + 2)  # + underflow/overflow
        self.n = 0
        self._min = math.inf
        self._max = -math.inf

    def add(self, v: float) -> None:
        if v is None or math.isnan(v):
            return
        self.n += 1
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        if v < self.lo:
            self._counts[0] += 1
        else:
            i = int(math.log10(v / self.lo) / self._step) + 1
            self._counts[min(i, len(self._counts) - 1)] += 1

    def _edge(self, i: int) -> float:
        return self.lo * 10 ** (i * self._step)

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket holding the q-th percentile,
        clamped to the exact observed min/max."""
        if self.n == 0:
            return 0.0
        target = q / 100.0 * self.n
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= target and c:
                hi = self._max if i >= len(self._counts) - 1 else self._edge(i)
                return min(max(hi, self._min), self._max)
        return self._max

    def summary(self) -> dict:
        return {
            "n": self.n,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "max": self._max if self.n else 0.0,
        }


class SLOMonitor:
    """Streaming request-latency histograms + multi-window burn rates.

    ``observe`` once per completed request with its virtual-time
    milestones; ``burn_rates(now)`` reports, per sliding window, the
    violation rate against ``slo`` divided by the error budget
    ``1 - slo.target`` (burn > 1.0: the window consumes error budget
    faster than the policy tolerates). With no policy the histograms
    still stream and burn rates are empty.
    """

    MAX_EVENTS = 100_000

    def __init__(self, slo=None, windows: tuple[float, ...] = (60.0, 300.0, 900.0)):
        self.slo = slo
        self.windows = tuple(windows)
        self.ttft = StreamingHist()
        self.ttft_admit = StreamingHist()
        self.tpot = StreamingHist()
        self.queue_wait = StreamingHist()
        self._events: deque[tuple[float, bool]] = deque(maxlen=self.MAX_EVENTS)
        self.observed = 0
        self.violations = 0

    def observe(
        self,
        *,
        t: float,
        ttft: float = math.nan,
        ttft_admit: float = math.nan,
        tpot: float = math.nan,
        queue_wait: float = math.nan,
    ) -> None:
        self.ttft.add(ttft)
        self.ttft_admit.add(ttft_admit)
        self.tpot.add(tpot)
        self.queue_wait.add(queue_wait)
        self.observed += 1
        if self.slo is not None:
            ok = (math.isnan(ttft) or ttft <= self.slo.ttft) and (
                math.isnan(tpot) or tpot <= self.slo.tpot
            )
            self.violations += not ok
            self._events.append((t, ok))

    def burn_rates(self, now: float) -> dict[str, float]:
        if self.slo is None or not self._events:
            return {}
        budget = max(1e-9, 1.0 - getattr(self.slo, "target", 0.9))
        out = {}
        for w in self.windows:
            tot = bad = 0
            for t, ok in reversed(self._events):
                if t < now - w:
                    break
                tot += 1
                bad += not ok
            rate = bad / tot if tot else 0.0
            out[f"burn_{int(w)}s"] = round(rate / budget, 4)
        return out

    def summary(self, now: float | None = None) -> dict:
        out = {
            "observed": self.observed,
            "ttft": {k: _r6(v) for k, v in self.ttft.summary().items()},
            "ttft_admit": {
                k: _r6(v) for k, v in self.ttft_admit.summary().items()
            },
            "tpot": {k: _r6(v) for k, v in self.tpot.summary().items()},
            "queue_wait": {
                k: _r6(v) for k, v in self.queue_wait.summary().items()
            },
        }
        if self.slo is not None:
            out["violations"] = self.violations
            if now is not None:
                out.update(self.burn_rates(now))
        return out


def _r6(v):
    return round(v, 6) if isinstance(v, float) else v
