"""Event-sourced memory ledger: byte-exact KV/cache/VMEM telemetry (ISSUE 9).

The span recorder (``runtime.spans``) gave the fleet an exact *time*
decomposition; this module is the *memory* counterpart. Every KV-pool
mutation — ``admit`` / block growth in ``ensure_rows`` / ``adopt_prefix``
(including its COW copies) / ``release`` / ``retain_cached`` / ``uncache``
/ prefix-cache eviction — emits a ``kind="mem"`` delta record through the
tracker backends, interleaved with round metrics and spans on one JSONL
stream. Static owners (VMEM weight-residency, the expert stream ring)
emit ``op="reserve"`` records so the byte attribution covers the whole
accelerator budget, not just the KV pool.

Record schema (``kind="mem"``)::

    {"kind": "mem", "op": "admit", "owner": "request", "rid": 3,
     "t": 12.25, "engine": 0, "role": "decode",
     "d_held_blocks": 2, "d_held_tokens": 7, "d_free_blocks": -2,
     "d_alloc_blocks": 2, "d_bytes": 98304}

``op="attach"`` records carry *absolute* gauges plus pool geometry
(``n_blocks``, ``block_tokens``, ``block_bytes``) and reset the
integration state for that engine id — engine ids are reused across soak
phases, so a fresh attach means a fresh pool. All other records carry
sparse ``d_``-prefixed deltas against the previous snapshot of the same
pool, which makes the exactness contract hold *by construction*:

    integrating the deltas from the last ``attach`` reproduces every
    ``PoolStats`` gauge in every round-metrics record int-exact, and the
    derived floats (Eq.-1 shared-counted-once ``pool_utilization``,
    ``pool_occupancy``) round-exact — ``validate_ledger`` asserts this
    over a full trace, across drain/restore and disagg phases.

``MemPressureMonitor`` consumes the same gauges as a streaming signal:
occupancy burn rates against a ``MemPolicy`` target over multiple
windows (mirroring ``SLOMonitor``), eviction-storm detection, a
fragmentation trend, and a ``fragmentation_report()`` snapshot captured
at the occupancy peak — the admission/scale signal the ROADMAP
elastic-fleet item consumes via ``Engine.summary()["mem"]`` and
``FleetRunResult.mem_summary``.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

from repro.runtime.spans import NDIGITS, StreamingHist, _r6

__all__ = [
    "MemLedger",
    "MemPolicy",
    "MemPressureMonitor",
    "kv_block_bytes",
    "summarize_ledger",
    "validate_ledger",
]

#: Integrated gauge vector. Every ``d_<key>`` delta and every ``attach``
#: absolute refers to one of these; ``validate_ledger`` checks each against
#: the ``pool_<key>`` gauge of round-metrics records.
GAUGES = (
    "held_blocks",
    "held_tokens",
    "free_blocks",
    "committed_blocks",
    "shared_blocks",
    "cached_blocks",
    "evictable_blocks",
    "alloc_blocks",
    "freed_blocks",
    "cow_copies",
)


def kv_block_bytes(pool) -> int:
    """Bytes of KV cache backing one pool block (both K and V planes).

    The pool arrays are row-addressed (L, n_blocks * block_tokens, n_kv,
    hd); a block is ``block_tokens`` rows of both planes.
    """
    k = pool.k
    layers, _, n_kv, hd = k.shape
    return int(k.dtype.itemsize) * layers * pool.block_tokens * n_kv * hd * 2


def _snapshot(pool) -> dict:
    s = pool.stats()
    return {
        "held_blocks": s.held_blocks,
        "held_tokens": s.held_tokens,
        "free_blocks": s.free_blocks,
        "committed_blocks": s.committed_blocks,
        "shared_blocks": s.shared_blocks,
        "cached_blocks": s.cached_blocks,
        "evictable_blocks": s.evictable_blocks,
        "alloc_blocks": pool.alloc_blocks,
        "freed_blocks": pool.freed_blocks,
        "cow_copies": pool.cow_copies,
    }


class MemLedger:
    """Buffered ``kind="mem"`` record emitter for one KV pool.

    Mirrors ``SpanRecorder``: stamped with engine/role, timestamped from a
    shared clock callable, buffered until ``flush()`` hands the batch to
    ``tracker.log_mem``. With no tracker, records are counted and dropped
    (the snapshot diffing still runs so a late ``attach`` stays exact).

    The scheduler calls ``sync()`` + ``flush()`` at the *top* of its round
    emission, before the metrics record is built — ``sync`` folds the
    ``note_tokens``-driven ``held_tokens`` drift (which deliberately does
    not emit per decode step) into one residual record, so integration is
    exact at every round boundary without a per-token record flood.
    """

    def __init__(
        self,
        clock: Callable[[], float],
        *,
        tracker=None,
        engine: int | None = None,
        role: str | None = None,
    ):
        self._clock = clock
        self.tracker = tracker
        self.engine = engine
        self.role = role
        self.pool = None
        self.block_bytes = 0
        self._base: dict | None = None
        self._buf: list[dict] = []
        self.n_records = 0
        self.n_dropped = 0

    # ------------------------------------------------------------ emission

    def now(self) -> float:
        return round(float(self._clock()), NDIGITS)

    def attach(self, pool) -> None:
        """Bind to ``pool`` and emit the absolute-gauge baseline record."""
        self.pool = pool
        pool.ledger = self
        self.block_bytes = kv_block_bytes(pool)
        self._base = _snapshot(pool)
        rec = {
            "op": "attach",
            "owner": "pool",
            "t": self.now(),
            "n_blocks": pool.usable_blocks,
            "block_tokens": pool.block_tokens,
            "block_bytes": self.block_bytes,
            **self._base,
        }
        self._emit(rec)

    def record(self, op: str, *, owner: str, **attrs) -> None:
        """Diff the pool against the last snapshot and emit the deltas.

        Called from inside the pool's mutating methods; nested emissions
        (an eviction triggered mid-``ensure_rows``) stay exact because
        each record diffs against the snapshot the previous one left.
        """
        if self.pool is None:
            return
        cur = _snapshot(self.pool)
        rec = {"op": op, "owner": owner, "t": self.now()}
        rec.update({k: v for k, v in attrs.items() if v is not None})
        changed = False
        for key in GAUGES:
            d = cur[key] - self._base[key]
            if d:
                rec["d_" + key] = d
                changed = True
        d_bytes = (
            (cur["alloc_blocks"] - self._base["alloc_blocks"])
            - (cur["freed_blocks"] - self._base["freed_blocks"])
        ) * self.block_bytes
        if d_bytes:
            rec["d_bytes"] = d_bytes
        self._base = cur
        if not changed and op == "sync":
            return  # nothing drifted since the last event
        self._emit(rec)

    def sync(self) -> None:
        """Emit a residual record folding un-evented gauge drift."""
        self.record("sync", owner="pool")

    def reserve(self, owner: str, nbytes: int, **attrs) -> None:
        """Static byte reservation (weight-resident VMEM, stream ring).

        Carries ``nbytes`` rather than ``d_`` deltas: reserve records
        attribute non-pool memory and are ignored by gauge integration.
        """
        rec = {"op": "reserve", "owner": owner, "t": self.now(), "nbytes": int(nbytes)}
        rec.update({k: v for k, v in attrs.items() if v is not None})
        self._emit(rec)

    def _emit(self, rec: dict) -> None:
        if self.engine is not None:
            rec["engine"] = self.engine
        if self.role is not None:
            rec["role"] = self.role
        self.n_records += 1
        if self.tracker is None:
            self.n_dropped += 1
            return
        self._buf.append(rec)

    def flush(self) -> None:
        if self._buf and self.tracker is not None:
            self.tracker.log_mem(self._buf)
        self._buf = []


# ---------------------------------------------------------------- validation


_METRIC_TO_GAUGE = {
    "pool_held_blocks": "held_blocks",
    "pool_held_tokens": "held_tokens",
    "pool_free_blocks": "free_blocks",
    "pool_committed_blocks": "committed_blocks",
    "pool_shared_blocks": "shared_blocks",
    "pool_cached_blocks": "cached_blocks",
    "pool_evictable_blocks": "evictable_blocks",
    "pool_alloc_blocks": "alloc_blocks",
    "pool_freed_blocks": "freed_blocks",
    "pool_cow_copies": "cow_copies",
}


def validate_ledger(records: list[dict]) -> list[str]:
    """Check the ledger exactness contract over an interleaved stream.

    Walks metrics + mem records in arrival order, integrating ``d_``
    deltas per engine id (an ``attach`` resets that engine's state — pool
    ids are reused across soak phases). At every round-metrics record
    carrying pool gauges, the integrated state must match int-exact, and
    the derived ``pool_utilization`` / ``pool_occupancy`` floats must
    match their 4-digit roundings computed from integrated integers.
    Returns a list of error strings; empty means the contract holds.
    """
    errors: list[str] = []
    state: dict = {}  # engine id -> integrated gauges
    geom: dict = {}  # engine id -> (n_blocks, block_tokens)
    n_mem = 0
    for i, r in enumerate(records):
        kind = r.get("kind", "metrics")
        eng = r.get("engine")
        if kind == "mem":
            n_mem += 1
            op = r.get("op")
            if op == "attach":
                missing = [k for k in GAUGES if k not in r]
                if missing:
                    errors.append(f"record {i}: attach missing gauges {missing}")
                    continue
                state[eng] = {k: r[k] for k in GAUGES}
                geom[eng] = (r.get("n_blocks", 0), r.get("block_tokens", 1))
                continue
            if op == "reserve":
                continue  # static owner; no pool-gauge deltas
            st = state.get(eng)
            if st is None:
                errors.append(
                    f"record {i}: mem op={op!r} for engine {eng!r} before attach"
                )
                continue
            for key in GAUGES:
                st[key] += r.get("d_" + key, 0)
        elif kind == "metrics" and "pool_held_blocks" in r:
            st = state.get(eng)
            if st is None:
                errors.append(
                    f"record {i}: pool gauges for engine {eng!r} before attach"
                )
                continue
            for mk, gk in _METRIC_TO_GAUGE.items():
                if mk in r and r[mk] != st[gk]:
                    errors.append(
                        f"record {i}: engine {eng!r} {mk}={r[mk]} != "
                        f"integrated {gk}={st[gk]}"
                    )
            n_blocks, block_tokens = geom[eng]
            hb, ht = st["held_blocks"], st["held_tokens"]
            util = 1.0 if hb == 0 else ht / (hb * block_tokens)
            if "pool_utilization" in r and r["pool_utilization"] != round(util, 4):
                errors.append(
                    f"record {i}: engine {eng!r} pool_utilization="
                    f"{r['pool_utilization']} != {round(util, 4)}"
                )
            occ = hb / max(1, n_blocks)
            if "pool_occupancy" in r and r["pool_occupancy"] != round(occ, 4):
                errors.append(
                    f"record {i}: engine {eng!r} pool_occupancy="
                    f"{r['pool_occupancy']} != {round(occ, 4)}"
                )
    if n_mem == 0:
        errors.append("stream has no kind='mem' records (ledger never attached?)")
    return errors


def summarize_ledger(records: list[dict]) -> dict:
    """Owner attribution over a stream: peaks, churn, bytes, reserves.

    Feeds ``report.py mem``. Walks the stream integrating per-engine
    gauges; at each engine's occupancy peak it freezes the owner split
    (request-held vs prefix-cache-held blocks overlap — cached blocks a
    live request shares are counted in both columns, matching Eq. 1's
    shared-counted-once convention at the pool level).
    """
    per: dict = {}
    for r in records:
        if r.get("kind", "metrics") != "mem":
            continue
        eng = r.get("engine")
        op = r.get("op")
        e = per.setdefault(
            eng,
            {
                "engine": eng,
                "n_blocks": 0,
                "block_bytes": 0,
                "state": dict.fromkeys(GAUGES, 0),
                "peak_held_blocks": 0,
                "peak_t": 0.0,
                "peak_cached_blocks": 0,
                "peak_evictable_blocks": 0,
                "peak_shared_blocks": 0,
                "evicted_blocks": 0,
                "n_records": 0,
                "reserved_bytes": {},
            },
        )
        e["n_records"] += 1
        if op == "attach":
            e["state"] = {k: r[k] for k in GAUGES}
            e["n_blocks"] = max(e["n_blocks"], r.get("n_blocks", 0))
            e["block_bytes"] = r.get("block_bytes", e["block_bytes"])
            continue
        if op == "reserve":
            owner = r.get("owner", "?")
            e["reserved_bytes"][owner] = e["reserved_bytes"].get(owner, 0) + r.get(
                "nbytes", 0
            )
            continue
        st = e["state"]
        for key in GAUGES:
            st[key] += r.get("d_" + key, 0)
        if op == "evict":
            e["evicted_blocks"] += r.get("freed", 0)
        if st["held_blocks"] > e["peak_held_blocks"]:
            e["peak_held_blocks"] = st["held_blocks"]
            e["peak_t"] = r.get("t", 0.0)
            e["peak_cached_blocks"] = st["cached_blocks"]
            e["peak_evictable_blocks"] = st["evictable_blocks"]
            e["peak_shared_blocks"] = st["shared_blocks"]
    out = []
    for eng in sorted(per, key=lambda x: (x is None, x)):
        e = per[eng]
        st = e.pop("state")
        nb = max(1, e["n_blocks"])
        e["peak_occupancy"] = round(e["peak_held_blocks"] / nb, 4)
        e["alloc_blocks"] = st["alloc_blocks"]
        e["freed_blocks"] = st["freed_blocks"]
        e["cow_copies"] = st["cow_copies"]
        e["alloc_mib"] = _r6(st["alloc_blocks"] * e["block_bytes"] / 2**20)
        out.append(e)
    return {"engines": out}


# ------------------------------------------------------------- pressure


@dataclasses.dataclass(frozen=True)
class MemPolicy:
    """Memory-pressure target, the analogue of ``SloPolicy`` for bytes.

    ``max_occupancy`` is the pool-occupancy ceiling a round should stay
    under; ``target`` is the fraction of rounds that must respect it (so
    the error budget is ``1 - target`` and burn rates read like SLO burn
    rates: >1.0 means the budget is being spent faster than sustainable).
    ``storm_fraction`` flags an eviction storm when more than that
    fraction of the pool is evicted inside the shortest window;
    ``frag_drop`` flags a fragmentation trend when short-window mean
    Eq.-1 utilization drops that far below the long-window mean.
    """

    max_occupancy: float = 0.90
    target: float = 0.95
    storm_fraction: float = 0.5
    frag_drop: float = 0.15


class MemPressureMonitor:
    """Streaming memory-pressure signal over multi-window burn rates.

    Fed once per scheduler round with the live pool; keeps O(window)
    state. ``signal()`` collapses to ``"ok"`` / ``"pressure"`` /
    ``"storm"`` — the admission/scale input for elastic fleets.
    """

    MAX_EVENTS = 100_000

    def __init__(self, policy: MemPolicy | None = None, windows=(60.0, 300.0, 900.0)):
        self.policy = policy or MemPolicy()
        self.windows = tuple(windows)
        self._events: deque = deque(maxlen=self.MAX_EVENTS)  # (t, ok)
        self._evict: deque = deque(maxlen=self.MAX_EVENTS)  # (t, cumulative)
        self._util: deque = deque(maxlen=self.MAX_EVENTS)  # (t, utilization)
        self.occ_hist = StreamingHist(lo=1e-4, hi=1.0)
        self.observed = 0
        self.violations = 0
        self.peak_held_blocks = 0
        self.peak_occupancy = 0.0
        self.peak_t = 0.0
        self.frag_at_peak: dict | None = None
        self.headroom_blocks = 0
        self.evicted_blocks = 0
        self._n_blocks = 0

    def observe(self, *, t: float, pool, evicted_blocks: int = 0) -> None:
        s = pool.stats()
        self.observed += 1
        ok = s.occupancy <= self.policy.max_occupancy
        if not ok:
            self.violations += 1
        self._events.append((t, ok))
        self._evict.append((t, evicted_blocks))
        self._util.append((t, s.utilization))
        self.occ_hist.add(max(s.occupancy, 1e-4))
        self.headroom_blocks = s.free_blocks + s.evictable_blocks
        self.evicted_blocks = evicted_blocks
        self._n_blocks = s.n_blocks
        if s.held_blocks > self.peak_held_blocks:
            self.peak_held_blocks = s.held_blocks
            self.peak_occupancy = s.occupancy
            self.peak_t = t
            self.frag_at_peak = pool.fragmentation_report()

    # ---------------------------------------------------------- windows

    def burn_rates(self, now: float) -> dict[str, float]:
        """Occupancy-budget burn per window; >1.0 burns faster than target."""
        budget = max(1e-9, 1.0 - self.policy.target)
        out = {}
        for w in self.windows:
            lo = now - w
            n = bad = 0
            for t, ok in reversed(self._events):
                if t < lo:
                    break
                n += 1
                bad += not ok
            out[f"{int(w)}s"] = _r6(bad / n / budget) if n else 0.0
        return out

    def eviction_rates(self, now: float) -> dict[str, int]:
        """Blocks evicted inside each window (from cumulative samples)."""
        out = {}
        for w in self.windows:
            lo = now - w
            newest = oldest = None
            for t, cum in reversed(self._evict):
                if t < lo:
                    break
                if newest is None:
                    newest = cum
                oldest = cum
            out[f"{int(w)}s"] = (newest - oldest) if newest is not None else 0
        return out

    def frag_trend(self, now: float) -> dict:
        """Short- vs long-window mean Eq.-1 utilization drift."""
        short_w, long_w = min(self.windows), max(self.windows)
        sums = {short_w: [0.0, 0], long_w: [0.0, 0]}
        for t, u in reversed(self._util):
            if t < now - long_w:
                break
            sums[long_w][0] += u
            sums[long_w][1] += 1
            if t >= now - short_w:
                sums[short_w][0] += u
                sums[short_w][1] += 1
        short = sums[short_w][0] / sums[short_w][1] if sums[short_w][1] else 1.0
        long = sums[long_w][0] / sums[long_w][1] if sums[long_w][1] else 1.0
        return {
            "short_utilization": _r6(short),
            "long_utilization": _r6(long),
            "degrading": short < long - self.policy.frag_drop,
        }

    def signal(self, now: float) -> str:
        shortest = f"{int(min(self.windows))}s"
        if self._n_blocks and (
            self.eviction_rates(now)[shortest]
            > self.policy.storm_fraction * self._n_blocks
        ):
            return "storm"
        if self.burn_rates(now)[shortest] > 1.0:
            return "pressure"
        return "ok"

    def summary(self, now: float | None = None) -> dict:
        out = {
            "observed": self.observed,
            "violations": self.violations,
            "policy": dataclasses.asdict(self.policy),
            "peak_held_blocks": self.peak_held_blocks,
            "peak_occupancy": _r6(self.peak_occupancy),
            "peak_t": _r6(self.peak_t),
            "headroom_blocks": self.headroom_blocks,
            "evicted_blocks": self.evicted_blocks,
            "occupancy": self.occ_hist.summary(),
            "frag_at_peak": self.frag_at_peak,
        }
        if now is not None:
            out["burn_rates"] = self.burn_rates(now)
            out["eviction_rates"] = self.eviction_rates(now)
            out["frag_trend"] = self.frag_trend(now)
            out["signal"] = self.signal(now)
        return out
