"""Step builders: one jittable train / prefill / serve step per config.

These close over the ``ModelConfig`` and optimizer so the same callable
serves the smoke tests (1 CPU device), the end-to-end examples, and the
512-device dry-run (where it is lowered with sharded ShapeDtypeStructs).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_lib
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW


def _split_batch(cfg: ModelConfig, batch: dict):
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    return batch["tokens"], batch["labels"], kwargs


def make_loss_fn(
    cfg: ModelConfig, *, remat: str = "full", ce_chunk: int = 0
) -> Callable:
    def loss(params, batch):
        if cfg.family == "encdec":
            l, _ = encdec_lib.loss_fn(
                params, cfg, batch["tokens"], batch["labels"], batch["frames"]
            )
            return l
        tokens, labels, kw = _split_batch(cfg, batch)
        l, _ = lm.loss_fn(
            params, cfg, tokens, labels,
            remat=remat, ce_chunk=ce_chunk, **kw,
        )
        return l

    return loss


def make_train_step(
    cfg: ModelConfig,
    opt: AdamW | None = None,
    *,
    remat: str = "full",
    ce_chunk: int = 0,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    opt = opt or AdamW()
    loss = make_loss_fn(cfg, remat=remat, ce_chunk=ce_chunk)

    def step(params, opt_state, batch):
        # allow_int: FCMP-packed uint8 carriers are inference-only leaves;
        # they get float0 tangents here and AdamW skips them entirely.
        l, grads = jax.value_and_grad(loss, allow_int=True)(params, batch)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, {"loss": l}

    return step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """(params, batch) -> next-token logits (B, 1, V).

    Slices the hidden states *before* the unembedding so the full (B, S, V)
    logits tensor is never built — at 32k x 128k-vocab that tensor is the
    whole HBM budget (EXPERIMENTS.md §Perf).
    """

    from repro.models.layers import logits as unembed_logits

    def step(params, batch):
        if cfg.family == "encdec":
            x, _ = encdec_lib.trunk(
                params, cfg, batch["tokens"], batch["frames"]
            )
        else:
            tokens, _, kw = _split_batch(cfg, batch)
            x, _ = lm.trunk(params, cfg, tokens, **kw)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        return unembed_logits(x[:, -1:, :], table, cfg.vocab)

    return step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """(params, token, cache) -> (logits (B, 1, V), new cache)."""

    def step(params, token, cache):
        if cfg.family == "encdec":
            return encdec_lib.decode_step(params, cfg, token, cache)
        return lm.decode_step(params, cfg, token, cache)

    return step


def make_paged_serve_step(cfg: ModelConfig) -> Callable:
    """Pool-indexed serve step for the continuous-batching scheduler.

    (params, token (B,1), pool_k, pool_v, row_table (B,S_max), lengths (B,))
    -> (logits (B,1,V), new pool_k, new pool_v). Each decode lane gathers
    its KV rows from the shared physical pool through ``row_table`` and
    scatters the new token's row back — the gather/scatter analog of the
    paper's round-robin port schedule over a packed BRAM. The moe family
    appends a per-layer expert-load tally (L, E) to the return. Jit with
    ``donate_argnums=(2, 3)`` so the pool updates in place.
    """

    if cfg.family == "hybrid":
        # extended signature: the per-lane SSM state travels with the step
        # (params, token, pool_k, pool_v, row_table, lengths, lane_state)
        # -> (logits, pool_k, pool_v, lane_state)
        def hybrid_step(
            params, token, pool_k, pool_v, row_table, lengths, lane_state
        ):
            return lm.decode_step_paged_hybrid(
                params, cfg, token, pool_k, pool_v, row_table, lengths,
                lane_state,
            )

        return hybrid_step

    def step(params, token, pool_k, pool_v, row_table, lengths):
        return lm.decode_step_paged(
            params, cfg, token, pool_k, pool_v, row_table, lengths
        )

    return step


def make_pool_prefill_step(cfg: ModelConfig) -> Callable:
    """Batched prefill that returns the KV rows for pool insertion.

    (params, tokens (B, S), last_idx ()) -> (next-token logits (B, 1, V),
    ks, vs stacked (L, B, S, n_kv, hd)). One call fills a request's whole
    prompt — time-to-first-token is one step, not S serve steps. The
    hybrid step additionally returns the per-lane SSM state dict
    (``lm.prefill_with_cache_hybrid``); the moe step appends a per-layer
    expert-load tally (L, E).
    """

    if cfg.family == "hybrid":
        def hybrid_step(params, tokens, last_idx):
            return lm.prefill_with_cache_hybrid(params, cfg, tokens, last_idx)

        return hybrid_step

    def step(params, tokens, last_idx):
        return lm.prefill_with_cache(params, cfg, tokens, last_idx)

    return step


def make_chunk_prefill_step(cfg: ModelConfig) -> Callable:
    """One prompt-chunk prefill against the pool (chunked admission).

    (params, tokens (B, C), pool_k, pool_v, row_table (B, S_max),
    write_rows (B, C), start (), last_idx ()) -> (logits at last_idx
    (B, 1, V), new pool_k, new pool_v). ``start`` is traced, so one trace
    serves every chunk offset of every request. Jit with
    ``donate_argnums=(2, 3)`` so the pool updates in place.
    """

    def step(params, tokens, pool_k, pool_v, row_table, write_rows, start,
             last_idx):
        return lm.prefill_chunk_paged(
            params, cfg, tokens, pool_k, pool_v, row_table, write_rows,
            start, last_idx,
        )

    return step


def make_verify_step(cfg: ModelConfig) -> Callable:
    """Batched draft-chain verification against the pool (speculative).

    (params, tokens (B, C), pool_k, pool_v, row_table (B, S_max),
    write_rows (B, C), starts (B,)) -> (full logits (B, C, V), new
    pool_k, new pool_v). One call scores every lane's pending token plus
    its drafter proposals at per-lane offsets; ``runtime.speculative``
    turns the returned distributions into a longest-accepted prefix. Jit
    with ``donate_argnums=(2, 3)`` so the pool updates in place.
    """

    def step(params, tokens, pool_k, pool_v, row_table, write_rows, starts):
        return lm.verify_chunk_paged(
            params, cfg, tokens, pool_k, pool_v, row_table, write_rows,
            starts,
        )

    return step


def make_hybrid_suffix_prefill_step(cfg: ModelConfig) -> Callable:
    """Hybrid prompt-suffix prefill resuming from carried SSM state.

    (params, tokens (B, C) unpadded suffix, pool_k, pool_v, row_table
    (B, S_max), write_rows (B, C), start (), last_idx (), lane_state) ->
    (logits at last_idx (B, 1, V), new pool_k, new pool_v, new
    lane_state). The prefix-cache warm path for zamba2: the matched
    prefix's shared-attention KV is gathered from the pool and the SSD
    recurrence seeds from the anchor's lane-state snapshot. Jit with
    ``donate_argnums=(2, 3, 8)``.
    """

    def step(params, tokens, pool_k, pool_v, row_table, write_rows, start,
             last_idx, lane_state):
        return lm.prefill_suffix_paged_hybrid(
            params, cfg, tokens, pool_k, pool_v, row_table, write_rows,
            start, last_idx, lane_state,
        )

    return step


def make_budgeted_paged_serve_step(
    cfg: ModelConfig, stream_mask: tuple, stream_depth: int
) -> Callable:
    """The paged serve step under a ``runtime.residency`` plan: weight
    regions the plan left in HBM stream through the
    ``kernels.weight_stream`` ring (depth = the plan's R_F analogue);
    resident regions run the standard in-VMEM path. ``stream_mask`` is
    (L,) per-layer flags for the dense-FFN families, (L, E) per-expert
    flags for moe (consumed by the dropless dispatch). Same signature as
    ``make_paged_serve_step``.
    """
    mask = jnp.asarray(stream_mask, bool)

    def step(params, token, pool_k, pool_v, row_table, lengths):
        return lm.decode_step_paged(
            params, cfg, token, pool_k, pool_v, row_table, lengths,
            stream_mask=mask, stream_depth=stream_depth,
        )

    return step
