"""Speculative decoding over the paged KV pool: drafters + resolution.

Decode is HBM-bound: every step re-reads the full weight set to emit one
token per lane. Speculate-and-verify buys back that sweep — a cheap
drafter proposes a depth-``k`` chain per decode lane, and the target
model scores *all* chain positions in ONE batched paged-attention call
(``lm.verify_chunk_paged``), accepting the longest prefix whose sampled
tokens match the proposals. Each verify step therefore yields between 1
and ``k`` tokens for roughly one decode step's weight traffic.

The paper's artifact supplies the drafter for free: an FCMP-packed
1-bit/2-bit arch (arXiv:2011.07317) is a cheap low-precision twin of its
dense counterpart — same attention weights, FFN mats swapped for packed
carriers at 1/16th (w1) or 1/8th (w2) the bytes — so its decode roofline
is a fraction of the target's (``StepCostModel.for_config`` already
discounts packed FFN HBM traffic). Families without packable FFNs (moe)
fall back to the self-drafting n-gram drafter: a deterministic
suffix-match lookup over the request's own prompt+output history, free
of model cost entirely.

Token identity is structural, not probabilistic: the verifier samples
position ``m`` from the target's own logits with the same
(seed, rid, m)-keyed rng that non-speculative decode would use, and a
position's logits only depend on accepted (= identical) earlier tokens.
Drafter quality moves the acceptance rate, never the output.

Drafter eligibility:

    target family   model drafter (packed twin)   ngram drafter
    dense           yes                           yes
    vlm             yes                           yes
    moe             no (expert FFNs not packed)   yes
    hybrid          no — rejected with an actionable error: SSM lane
                    state has no per-position rollback, so draft-chain
                    rejection cannot restore the lane recurrence
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.runtime.steps import make_paged_serve_step, make_pool_prefill_step

# families verify_chunk_paged serves (hybrid's SSM lanes cannot roll back)
SPEC_FAMILIES = ("dense", "vlm", "moe")
# families whose FFN leaves pack into FCMP carriers -> model drafters
MODEL_DRAFT_FAMILIES = ("dense", "vlm")

NGRAM = "ngram"


@functools.lru_cache(maxsize=None)
def _jitted_draft_decode(cfg: ModelConfig):
    return jax.jit(make_paged_serve_step(cfg), donate_argnums=(2, 3))


@functools.lru_cache(maxsize=None)
def _jitted_draft_prefill(cfg: ModelConfig):
    return jax.jit(make_pool_prefill_step(cfg))


# in-place row insertion into the drafter's donated KV buffers (same
# pattern as kv_pool._row_scatter; one trace per pool/row-count shape)
_draft_scatter = jax.jit(
    lambda pool, rows, vals: pool.at[:, rows].set(vals), donate_argnums=(0,)
)


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """CLI-level speculative knobs (``--speculate`` / ``--spec-depth``).

    ``drafter`` is ``"ngram"`` or a canonical arch id from
    ``configs.ARCH_IDS``; ``quant`` is the packed-carrier width for model
    drafters (the w_bits of the twin)."""

    drafter: str
    depth: int = 4
    quant: int = 2


@dataclasses.dataclass(frozen=True)
class LaneDraft:
    """One decode lane's view, handed to the drafter each verify cycle."""

    slot: int
    rid: int
    pending: int  # last sampled token, not yet fed to the target
    out_len: int  # len(request.output) — the next sample's rng position
    n_rows: int  # KV rows the target pool holds for this request
    history: np.ndarray  # prompt + output so far (pending included)


def _sample_keyed(row, sp: lm.SamplingParams, rid: int, pos: int) -> int:
    """The scheduler's (seed, rid, position)-keyed sampler, shared so a
    model drafter's proposals use the exact rng the verifier will."""
    rng = np.random.default_rng(np.random.SeedSequence([sp.seed, rid, pos]))
    return int(lm.sample_logits(row, sp, rng))


# --------------------------------------------------------------------------
# Drafter twins: FFN packing / dequantization
# --------------------------------------------------------------------------


def pack_ffn_params(params: dict, bits: int) -> dict:
    """The packed twin of a dense/vlm param set: FFN leaves (w1/w3/w2)
    swapped for FCMP carriers, everything else shared by reference.
    Already-packed leaves (a quantized target) pass through."""
    lay = dict(params["layers"])
    for k in ("w1", "w3", "w2"):
        if not isinstance(lay[k], dict):
            lay[k] = lm.make_packed(lay[k], bits)
    return {**params, "layers": lay}


def dequantize_ffn_params(params: dict, bits: int) -> dict:
    """The dense counterpart of a packed twin: FFN leaves replaced by
    their decoded carrier values, so ``pack_ffn_params`` of the result
    round-trips losslessly (quantization is idempotent on its own
    codebook). This is the spec_bench pairing: random smoke weights have
    no trained drafter/target correlation, so the bench serves the
    packed arch's dense execution as the target — with real checkpoints
    the natural pair is a trained dense target and its packed twin."""

    def dequant(w):
        if isinstance(w, dict):
            p = w
        else:
            p = lm.make_packed(w, bits)
        codes = lm._unpack_codes(p["packed"], bits).astype(jnp.float32)
        vals = codes * 2.0 - 1.0 if bits == 1 else codes - 1.0
        out = vals * p["scale"][..., None, :]
        return out.astype(w.dtype if not isinstance(w, dict) else out.dtype)

    lay = dict(params["layers"])
    for k in ("w1", "w3", "w2"):
        lay[k] = dequant(lay[k])
    return {**params, "layers": lay}


# --------------------------------------------------------------------------
# Resolution: --speculate <drafter> against a target config
# --------------------------------------------------------------------------


def compatible_drafters(cfg: ModelConfig, *, smoke: bool = False) -> list[str]:
    """Drafter names servable against ``cfg``: ``ngram`` plus every
    canonical arch of a packable family whose vocab matches the target
    (logit rows must index the same token space)."""
    from repro import configs

    out = [NGRAM]
    for arch in configs.ARCH_IDS:
        try:
            dcfg = (
                configs.get_smoke_config(arch)
                if smoke
                else configs.get_config(arch)
            )
        except ValueError:
            continue
        if dcfg.family in MODEL_DRAFT_FAMILIES and dcfg.vocab == cfg.vocab:
            out.append(arch)
    return out


@dataclasses.dataclass(frozen=True)
class ResolvedSpec:
    """A validated drafter choice for one target config.

    ``draft_cfg`` is the serving-size drafter config (None for ngram);
    ``draft_full_cfg`` is the full-size one the fleet's virtual clock
    charges (``StepCostModel.for_config`` — the packed twin's FFN bytes
    are discounted there, which is where the TPOT win comes from);
    ``twin`` marks a drafter of the target's own arch, built by packing
    the served params rather than initialising fresh ones."""

    spec: SpecConfig
    draft_cfg: ModelConfig | None
    draft_full_cfg: ModelConfig | None
    twin: bool

    def build(self, cfg: ModelConfig, params, *, slots: int, max_len: int):
        """Per-engine drafter state (each engine drafts its own lanes)."""
        if self.draft_cfg is None:
            return Speculator(NgramDrafter(), depth=self.spec.depth)
        if self.twin:
            dparams = pack_ffn_params(params, self.draft_cfg.w_bits)
        else:
            # no distilled checkpoint in the smoke harness: a foreign
            # drafter arch serves freshly-initialised weights (acceptance
            # will be poor; the twin pairing is the supported fast path)
            dparams = lm.init_params(self.draft_cfg, jax.random.key(0))
        drafter = ModelDrafter(
            self.draft_cfg, dparams, slots=slots, max_len=max_len
        )
        return Speculator(drafter, depth=self.spec.depth)


def resolve(
    cfg: ModelConfig, spec: SpecConfig, *, smoke: bool = False
) -> ResolvedSpec:
    """Validate ``--speculate``/``--spec-depth`` against the target.

    Raises ``ValueError`` (the CLIs' exit-2 path) with an actionable
    message listing the compatible drafters when the arch is unknown,
    un-packable, vocab-mismatched, or the target family cannot verify."""
    if cfg.family not in SPEC_FAMILIES:
        raise ValueError(
            f"speculative decoding: family {cfg.family!r} has no draft-tree "
            f"verification path (SSM lane state cannot roll back a rejected "
            f"chain); serve one of {SPEC_FAMILIES} or drop --speculate"
        )
    if spec.depth < 2:
        raise ValueError(
            f"--spec-depth {spec.depth} proposes no draft tokens; "
            "use a depth >= 2 (or drop --speculate)"
        )
    if spec.quant not in (1, 2):
        raise ValueError(
            f"--spec-quant {spec.quant} is not a packed carrier width; "
            "FCMP packs 1- or 2-bit codes"
        )
    if spec.drafter == NGRAM:
        return ResolvedSpec(spec, None, None, twin=False)

    from repro import configs

    options = ", ".join(compatible_drafters(cfg, smoke=smoke))
    try:
        arch = configs.canonical(spec.drafter)
        dcfg = (
            configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
        )
        dfull = configs.get_config(arch)
    except ValueError:
        raise ValueError(
            f"unknown drafter arch {spec.drafter!r}; compatible drafters "
            f"for {cfg.name}: {options}"
        ) from None
    if dcfg.family not in MODEL_DRAFT_FAMILIES:
        raise ValueError(
            f"drafter arch {spec.drafter!r} (family {dcfg.family!r}) has no "
            f"packed twin — only {MODEL_DRAFT_FAMILIES} FFNs pack into FCMP "
            f"carriers; compatible drafters for {cfg.name}: {options}"
        )
    if dcfg.vocab != cfg.vocab:
        raise ValueError(
            f"drafter arch {spec.drafter!r} vocab {dcfg.vocab} != target "
            f"{cfg.name} vocab {cfg.vocab} — proposals would index a "
            f"different token space; compatible drafters: {options}"
        )
    twin = dcfg.name == cfg.name
    dcfg = dataclasses.replace(dcfg, w_bits=spec.quant)
    dfull = dataclasses.replace(dfull, w_bits=spec.quant)
    return ResolvedSpec(spec, dcfg, dfull, twin=twin)


# --------------------------------------------------------------------------
# Drafters
# --------------------------------------------------------------------------


class NgramDrafter:
    """Self-drafting suffix-match lookup over the request's own history.

    Proposes the continuation that followed the most recent earlier
    occurrence of the current suffix (longest suffix first, down to one
    token; last-token repetition when nothing matches). Deterministic and
    model-free — zero charge on the virtual clock — so any accepted token
    is pure profit. Works for every SPEC_FAMILIES target, including moe.
    """

    is_model = False
    max_suffix = 8
    window = 512

    def start_lane(self, slot: int, prompt: np.ndarray) -> tuple[int, int]:
        return 0, 0

    def release_lane(self, slot: int) -> None:
        pass

    def accept(self, slot: int, n_rows: int) -> None:
        pass

    def _continuation(self, ctx: np.ndarray, n: int) -> np.ndarray:
        out = np.full((n,), int(ctx[-1]), np.int32)  # repeat-last fallback
        ln = len(ctx)
        for m in range(min(self.max_suffix, ln - 1), 0, -1):
            suffix = ctx[ln - m:]
            # most recent earlier occurrence of the suffix
            for s in range(ln - m - 1, -1, -1):
                if np.array_equal(ctx[s : s + m], suffix):
                    cont = ctx[s + m : s + m + n]
                    out[: len(cont)] = cont
                    if len(cont) < n and len(cont) > 0:
                        out[len(cont):] = int(cont[-1])
                    return out
        return out

    def propose(
        self, lanes: list[LaneDraft], k: int, sampling: lm.SamplingParams
    ) -> tuple[np.ndarray, int]:
        props = np.zeros((len(lanes), k - 1), np.int32)
        for j, ln in enumerate(lanes):
            ctx = ln.history[-self.window:]
            props[j] = self._continuation(np.asarray(ctx, np.int32), k - 1)
        return props, 0


class ModelDrafter:
    """A packed-twin (or foreign-arch) model drafter with private KV.

    The drafter runs the standard paged decode step over its own
    fixed-geometry pool: lane ``i`` owns the contiguous rows
    ``[1 + i*S, 1 + (i+1)*S)`` (row 0 is scratch for prefill padding),
    so its row table is static and rollback is just clamping the lane
    length — the rollout feeds exactly the tokens the verifier feeds, so
    rows under the accepted prefix are already correct and rows past it
    are overwritten by the next chain.
    """

    is_model = True

    def __init__(
        self, cfg: ModelConfig, params, *, slots: int, max_len: int
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.s = max_len
        rows = 1 + slots * max_len
        shape = (cfg.n_kv_cache_layers, rows, cfg.n_kv, cfg.hd)
        dt = jnp.dtype(cfg.dtype)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        table = 1 + np.arange(slots)[:, None] * max_len + np.arange(max_len)
        self._row_table_dev = jnp.asarray(table.astype(np.int32))
        self.lengths = np.zeros((slots,), np.int32)
        self._decode = _jitted_draft_decode(cfg)
        self._prefill = _jitted_draft_prefill(cfg)

    @property
    def cache_bytes(self) -> int:
        return self.k.nbytes + self.v.nbytes

    def start_lane(self, slot: int, prompt: np.ndarray) -> tuple[int, int]:
        """Prefill the drafter's own KV for the prompt (one padded step;
        the target's prefix-cache hits don't transfer — the drafter's
        rows are its own model's). Returns (tokens, steps) to charge."""
        p = len(prompt)
        padded = np.zeros((1, self.s), np.int32)
        padded[0, :p] = prompt
        _, ks, vs = self._prefill(self.params, jnp.asarray(padded), p - 1)
        rows = np.zeros((self.s,), np.int32)  # padded tail -> scratch row 0
        rows[:p] = 1 + slot * self.s + np.arange(p)
        self.k = _draft_scatter(
            self.k, jnp.asarray(rows), ks[:, 0].astype(self.k.dtype)
        )
        self.v = _draft_scatter(
            self.v, jnp.asarray(rows), vs[:, 0].astype(self.v.dtype)
        )
        self.lengths[slot] = p
        return p, 1

    def release_lane(self, slot: int) -> None:
        self.lengths[slot] = 0

    def accept(self, slot: int, n_rows: int) -> None:
        """Settle a verified chain: the accepted prefix's rows were fed
        identically here and in the target, so rollback = length clamp."""
        self.lengths[slot] = n_rows

    def propose(
        self, lanes: list[LaneDraft], k: int, sampling: lm.SamplingParams
    ) -> tuple[np.ndarray, int]:
        """Roll the drafter ``k`` steps: feed each lane's pending token
        then its own proposals, sampling with the verifier's own
        (seed, rid, position) rng keys so greedy *and* seeded chains
        match whenever the logits agree. The k-th step emits no proposal
        — it writes the KV row of the last proposal, so a fully-accepted
        chain leaves the drafter cache complete."""
        token = np.zeros((self.slots, 1), np.int32)
        lengths = self.lengths.copy()
        for ln in lanes:
            if lengths[ln.slot] != ln.n_rows:
                raise RuntimeError(
                    f"drafter lane {ln.slot} holds {lengths[ln.slot]} rows; "
                    f"target holds {ln.n_rows} — mirror out of sync"
                )
            token[ln.slot, 0] = ln.pending
        props = np.zeros((len(lanes), k - 1), np.int32)
        steps = 0
        for step in range(k):
            logits, self.k, self.v = self._decode(
                self.params,
                jnp.asarray(token),
                self.k,
                self.v,
                self._row_table_dev,
                jnp.asarray(lengths),
            )
            steps += 1
            rows = np.asarray(logits[:, 0, :])
            for j, ln in enumerate(lanes):
                lengths[ln.slot] += 1
                if step < k - 1:
                    d = _sample_keyed(
                        rows[ln.slot], sampling, ln.rid, ln.out_len + step
                    )
                    props[j, step] = d
                    token[ln.slot, 0] = d
        return props, steps


class Speculator:
    """The scheduler-facing bundle: one drafter + the draft depth."""

    def __init__(self, drafter, *, depth: int):
        self.drafter = drafter
        self.depth = depth

    @property
    def is_model(self) -> bool:
        return self.drafter.is_model

    @property
    def name(self) -> str:
        if self.is_model:
            return f"{self.drafter.cfg.name}@w{self.drafter.cfg.w_bits}"
        return NGRAM

    def start_lane(self, slot: int, prompt: np.ndarray) -> tuple[int, int]:
        return self.drafter.start_lane(slot, prompt)

    def release_lane(self, slot: int) -> None:
        self.drafter.release_lane(slot)

    def accept(self, slot: int, n_rows: int) -> None:
        self.drafter.accept(slot, n_rows)

    def propose(self, lanes, k, sampling) -> tuple[np.ndarray, int]:
        return self.drafter.propose(lanes, k, sampling)


def build_speculator(
    cfg: ModelConfig,
    params,
    spec: SpecConfig,
    *,
    slots: int,
    max_len: int,
    smoke: bool = False,
) -> Speculator:
    """One-shot resolve + build for single-engine callers (serve.py)."""
    return resolve(cfg, spec, smoke=smoke).build(
        cfg, params, slots=slots, max_len=max_len
    )
