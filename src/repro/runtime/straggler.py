"""Straggler detection: per-host step-time EWMA vs the fleet median.

At multi-pod scale a single slow host (thermal throttling, failing HBM,
noisy neighbour on the DCN) gates every synchronous step. The monitor keeps
an EWMA of per-host step times, flags hosts slower than ``k x median``, and
exposes a hook the runtime uses to trigger mitigation (re-shard away from
the host / evict + elastic restart — simulated in tests, since this
container has one real host).
"""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    alpha: float = 0.2  # EWMA coefficient
    threshold: float = 1.5  # flag hosts slower than threshold x median
    min_steps: int = 3  # warm-up before flagging
    on_straggler: Callable[[int, float, float], None] | None = None

    def __post_init__(self):
        self.ewma = [0.0] * self.n_hosts
        self.count = 0
        self.flagged: set[int] = set()

    def record_step(self, host_times: list[float]) -> list[int]:
        """Feed one synchronous step's per-host wall times; returns newly
        flagged host ids."""
        assert len(host_times) == self.n_hosts
        for h, t in enumerate(host_times):
            if self.count == 0:
                self.ewma[h] = t
            else:
                self.ewma[h] = (1 - self.alpha) * self.ewma[h] + self.alpha * t
        self.count += 1
        newly = []
        if self.count >= self.min_steps:
            med = sorted(self.ewma)[self.n_hosts // 2]
            for h, e in enumerate(self.ewma):
                if e > self.threshold * med and h not in self.flagged:
                    self.flagged.add(h)
                    newly.append(h)
                    if self.on_straggler is not None:
                        self.on_straggler(h, e, med)
                elif e <= self.threshold * med and h in self.flagged:
                    self.flagged.discard(h)  # recovered
        return newly

    @property
    def healthy_hosts(self) -> list[int]:
        return [h for h in range(self.n_hosts) if h not in self.flagged]
