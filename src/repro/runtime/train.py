"""Fault-tolerant training loop: checkpoint/restart, preemption recovery,
straggler monitoring, async checkpointing.

The loop is deliberately model-agnostic: it drives any jitted
``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``
(built by ``models.steps.make_train_step``). State = (params, opt_state,
pipeline step counter) — all captured in the checkpoint, so a restart after
preemption replays byte-identically (tested with a simulated kill).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class TrainLoopConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_async: bool = True
    log_every: int = 10
    n_hosts: int = 1  # simulated host count for straggler monitoring


class PreemptionError(RuntimeError):
    """Raised by test hooks to simulate a node failure mid-run."""


@dataclasses.dataclass
class TrainLoop:
    step_fn: Callable  # (params, opt_state, batch) -> (params, opt, metrics)
    pipeline: Any  # data pipeline with .batch_at(step) and .state.step
    ckpt: CheckpointManager | None = None
    config: TrainLoopConfig = dataclasses.field(default_factory=TrainLoopConfig)
    # test hooks
    pre_step_hook: Callable[[int], None] | None = None
    host_time_fn: Callable[[int], list[float]] | None = None

    def restore_or_init(self, params, opt_state):
        """Resume from the latest checkpoint if one exists."""
        start = 0
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            (params, opt_state), extra = self.ckpt.restore(
                (params, opt_state)
            )
            start = int(extra["data_step"])
            self.pipeline.state.step = start
        return params, opt_state, start

    def run(self, params, opt_state, start_step: int = 0):
        cfg = self.config
        monitor = StragglerMonitor(cfg.n_hosts)
        metrics_log: list[dict] = []
        step = start_step
        while step < cfg.n_steps:
            if self.pre_step_hook is not None:
                self.pre_step_hook(step)
            t0 = time.monotonic()
            batch = {
                k: jax.device_put(v)
                for k, v in self.pipeline.batch_at(step).items()
            }
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics)
            dt = time.monotonic() - t0
            host_times = (
                self.host_time_fn(step)
                if self.host_time_fn is not None
                else [dt] * cfg.n_hosts
            )
            flagged = monitor.record_step(host_times)
            entry = {
                "step": step,
                "time_s": dt,
                "stragglers": flagged,
                **{k: float(np.asarray(v)) for k, v in metrics.items()},
            }
            metrics_log.append(entry)
            step += 1
            self.pipeline.state.step = step
            if self.ckpt is not None and step % cfg.ckpt_every == 0:
                self.ckpt.save(
                    step,
                    (params, opt_state),
                    extra={"data_step": step},
                    blocking=not cfg.ckpt_async,
                )
        if self.ckpt is not None:
            self.ckpt.save(
                step, (params, opt_state), extra={"data_step": step},
                blocking=True,
            )
        return params, opt_state, metrics_log
