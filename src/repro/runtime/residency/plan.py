"""Compile a weight-residency plan: which blocks live in VMEM, which stream.

The paper's §V porting result is that FCMP packing lets a fixed on-chip
memory hold more of the model, so the design ports to a smaller device
with less throughput loss than re-folding. The TPU analogue planned here:

  * the *streamable set* is the dense-FFN weight blocks — exactly the
    weight memories FCMP packs on FPGA (conv/FC MVAU buffers <-> FFN
    matmuls); attention projections, norms and the embedding are the
    "datapath" side and are accounted as fixed HBM traffic,
  * ``core.vmem_plan.pack_blocks`` runs the paper's bin-packing solvers
    over the blocks' int8 carriers so oddly-shaped blocks co-locate into
    shared (8, 128) VMEM tile bins (Eq. 1 one level down the hierarchy),
  * a greedy knapsack pins the highest-traffic-per-tile *regions* (one
    layer / one expert — the executor's stream granularity) until the
    VMEM budget is spent; everything else re-streams from HBM each
    decode step through ``kernels.weight_stream``,
  * the GALS ``R_F`` knob maps to the streamer's ring depth
    (``stream_ahead_depth``): bit-packing leaves an HBM bandwidth surplus
    (bf16 -> 1/2-bit moves 8-16x fewer bytes) and that surplus is what
    funds deep prefetch, the way the paper's memory-clock surplus funds
    bin heights > N_ports.

Traffic enters the plan the way it enters the paper's Eq. 2: a block's
pin value is the HBM bytes it would otherwise move *per decode step*
(MoE expert blocks are read with probability top_k/E, the hybrid shared
block once per super-block), so the same model packs differently under
different serving mixes.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core.gals import N_PORTS
from repro.core.packing import Packing, bin_cost
from repro.core.resource_model import TPU_V5E, TPU_TIERS, TpuChip
from repro.core.vmem_plan import WeightBlock, pack_blocks, vmem_tile_ram
from repro.models.config import ModelConfig

MAX_STREAM_DEPTH = 8


@dataclasses.dataclass(frozen=True)
class TrafficProfile:
    """What the serve tier is asked to do (the §V 'at what traffic?')."""

    lanes: int = 8  # concurrent decode lanes (batch)
    prompt_len: int = 512
    gen_len: int = 128

    @property
    def mean_context(self) -> int:
        """Average KV rows held per lane over a request's decode phase."""
        return self.prompt_len + self.gen_len // 2


def _dtype_bits(cfg: ModelConfig) -> int:
    return jnp.dtype(cfg.dtype).itemsize * 8


def _block_bits(cfg: ModelConfig) -> int:
    return cfg.w_bits if cfg.w_bits in (1, 2) else _dtype_bits(cfg)


def weight_blocks(cfg: ModelConfig) -> tuple[WeightBlock, ...]:
    """The streamable weight-block set of one model replica.

    One block per FFN matmul per layer, named ``L{l}.{mat}`` (MoE experts
    ``L{l}.e{e}.{mat}``, the hybrid shared block ``shared.{mat}``), with
    ``bits_per_weight`` the packed precision (or the dense dtype width).
    """
    bits = _block_bits(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    mats = {"w1": (d, ff), "w3": (d, ff), "w2": (ff, d)}
    blocks: list[WeightBlock] = []
    if cfg.family in ("dense", "vlm", "encdec"):
        for l in range(cfg.n_layers):
            for mat, (r, c) in mats.items():
                blocks.append(WeightBlock(f"L{l:03d}.{mat}", r, c, bits))
    elif cfg.family == "moe":
        # expert einsums consume dense stacked weights (lm._init_ffn):
        # expert blocks carry the dense dtype width, not cfg.w_bits
        ebits = _dtype_bits(cfg)
        for l in range(cfg.n_layers):
            for e in range(cfg.n_experts):
                for mat, (r, c) in mats.items():
                    blocks.append(
                        WeightBlock(f"L{l:03d}.e{e}.{mat}", r, c, ebits)
                    )
    elif cfg.family == "hybrid":
        for mat, (r, c) in mats.items():
            blocks.append(WeightBlock(f"shared.{mat}", r, c, bits))
    else:  # ssm: no dense FFN to pack or stream
        pass
    return tuple(blocks)


def _region_of(name: str) -> str:
    """The executor granularity a block belongs to: its layer for dense
    FFN mats (``L000``), its expert for MoE (``L000.e3``), the shared
    block for hybrid. Bins never mix regions and the knapsack pins whole
    regions, so the plan's resident set is exactly what the executor can
    keep resident — pinning 2 of a layer's 3 mats would spend VMEM the
    layer-granular stream mask could not exploit."""
    return name.rsplit(".", 1)[0]


def read_weight(name: str, cfg: ModelConfig) -> float:
    """Expected reads of a block per decode step (the Eq. 2 traffic term)."""
    if cfg.family == "moe" and ".e" in name:
        return cfg.experts_per_token / max(1, cfg.n_experts)
    if cfg.family == "hybrid" and name.startswith("shared."):
        return cfg.n_layers / max(1, cfg.hybrid_attn_every)
    return 1.0


def fixed_hbm_bytes(cfg: ModelConfig, traffic: TrafficProfile) -> int:
    """Per-decode-step HBM bytes outside the plan: attention projections,
    the unembedding row product, and the lanes' KV-row reads."""
    d, hd = cfg.d_model, cfg.hd
    dt = jnp.dtype(cfg.dtype).itemsize
    attn = cfg.n_layers * (
        d * cfg.n_heads * hd + 2 * d * cfg.n_kv * hd + cfg.n_heads * hd * d
    )
    unembed = cfg.padded_vocab * d
    kv = (
        traffic.lanes
        * cfg.n_layers
        * 2
        * cfg.n_kv
        * hd
        * traffic.mean_context
    )
    return (attn + unembed + kv) * dt


def stream_ahead_depth(cfg: ModelConfig, max_height: int = 4) -> int:
    """GALS Eq. 2 mapped to the DMA ring: R_F is the HBM-bandwidth surplus
    of bit-packing (dense-dtype bits / packed bits), and the ring depth is
    the virtual ports that surplus funds per bin height,
    ``N_ports * R_F / H_B`` — clamped to [2, 8] (a ring needs 2 slots to
    overlap at all; deeper than 8 buys nothing at TPU DMA latency)."""
    bits = _block_bits(cfg)
    r_f = _dtype_bits(cfg) / bits
    depth = math.floor(N_PORTS * r_f / max_height)
    return max(2, min(MAX_STREAM_DEPTH, depth))


@dataclasses.dataclass(frozen=True)
class RuntimeResidencyPlan:
    """A compiled residency schedule, hashable so jitted steps key on it."""

    model: str
    chip: str
    blocks: tuple[WeightBlock, ...]
    bins: tuple[tuple[int, ...], ...]  # tile-bin membership (block indices)
    bin_tiles: tuple[int, ...]  # physical VMEM tiles per bin
    resident: tuple[bool, ...]  # per *bin*
    vmem_budget_bytes: int
    stream_ahead: int
    read_weights: tuple[float, ...]  # per block

    # ---------------- derived ----------------

    def _tile_bytes(self, chip: TpuChip) -> int:
        return chip.sublane * chip.lane

    @property
    def _chip(self) -> TpuChip:
        return TPU_TIERS.get(self.chip.removeprefix("tpu_"), TPU_V5E)

    @property
    def resident_bytes(self) -> int:
        tb = self._tile_bytes(self._chip)
        return sum(
            t * tb for t, r in zip(self.bin_tiles, self.resident) if r
        )

    def block_resident(self) -> dict[str, bool]:
        out = {}
        for b, r in zip(self.bins, self.resident):
            for i in b:
                out[self.blocks[i].name] = r
        return out

    @property
    def resident_block_count(self) -> int:
        return sum(
            len(b) for b, r in zip(self.bins, self.resident) if r
        )

    @property
    def resident_fraction(self) -> float:
        return self.resident_block_count / max(1, len(self.blocks))

    @property
    def streamable_bytes_per_step(self) -> float:
        """Expected HBM bytes per decode step of the *whole* streamable
        set (every FFN weight block, resident or not) — the baseline the
        budgeted roofline subtracts pinned blocks from."""
        return sum(
            w * b.padded_bytes(self._chip)
            for b, w in zip(self.blocks, self.read_weights)
        )

    @property
    def streamed_bytes_per_step(self) -> float:
        """Expected HBM bytes re-read per decode step for cold blocks."""
        res = self.block_resident()
        return sum(
            w * b.padded_bytes(self._chip)
            for b, w in zip(self.blocks, self.read_weights)
            if not res[b.name]
        )

    @property
    def hbm_traffic_reduction(self) -> float:
        return 1.0 - self.streamed_bytes_per_step / max(
            1.0, self.streamable_bytes_per_step
        )

    @property
    def ring_bytes(self) -> int:
        """VMEM held by the prefetch ring: ``stream_ahead`` slots, each
        sized for the largest streamed block (the ring is a fixed-shape
        double-plus buffer, so every slot pays the worst case). The
        memory ledger reports this as the ``ring-slot`` owner."""
        res = self.block_resident()
        slot = max(
            (
                b.padded_bytes(self._chip)
                for b in self.blocks
                if not res[b.name]
            ),
            default=0,
        )
        return int(self.stream_ahead * slot)

    def layer_stream_mask(self, cfg: ModelConfig) -> tuple[bool, ...]:
        """Per-layer 'FFN is streamed' flags for the executor: a layer
        only runs resident if *all* of its FFN mats are pinned (the
        region-granular knapsack guarantees all-or-nothing per layer, so
        no pinned byte is stranded in a streamed layer)."""
        res = self.block_resident()
        mask = []
        for l in range(cfg.n_layers):
            prefix = f"L{l:03d}."
            mine = [r for n, r in res.items() if n.startswith(prefix)]
            mask.append(not (mine and all(mine)))
        return tuple(mask)

    def expert_stream_mask(
        self, cfg: ModelConfig
    ) -> tuple[tuple[bool, ...], ...]:
        """Per-(layer, expert) 'FFN is streamed' flags for the moe
        executor: an expert runs resident only if *all three* of its mats
        are pinned (the knapsack pins whole ``L{l}.e{e}`` regions, so this
        is all-or-nothing per expert — the expert-granular analogue of
        ``layer_stream_mask``). Shape (n_layers, n_experts), scanned with
        the stacked layer leaves so each layer sees its (E,) row."""
        res = self.block_resident()
        mask = []
        for l in range(cfg.n_layers):
            row = []
            for e in range(cfg.n_experts):
                prefix = f"L{l:03d}.e{e}."
                mine = [r for n, r in res.items() if n.startswith(prefix)]
                row.append(not (mine and all(mine)))
            mask.append(tuple(row))
        return tuple(mask)

    def summary(self) -> dict:
        return {
            "model": self.model,
            "chip": self.chip,
            "n_blocks": len(self.blocks),
            "n_bins": len(self.bins),
            "vmem_budget_mib": round(self.vmem_budget_bytes / 2**20, 3),
            "resident_blocks": self.resident_block_count,
            "resident_fraction": round(self.resident_fraction, 4),
            "resident_mib": round(self.resident_bytes / 2**20, 3),
            "streamed_mib_per_step": round(
                self.streamed_bytes_per_step / 2**20, 3
            ),
            "hbm_traffic_reduction": round(self.hbm_traffic_reduction, 4),
            "stream_ahead": self.stream_ahead,
        }


def compile_residency_plan(
    cfg: ModelConfig,
    *,
    vmem_budget_bytes: int,
    traffic: TrafficProfile = TrafficProfile(),
    chip: TpuChip = TPU_V5E,
    solver: str = "ffd",
    max_height: int = 4,
) -> RuntimeResidencyPlan:
    """Plan = pack carriers into tile bins, then knapsack *regions* into
    VMEM.

    Bins are region-constrained (one layer / one MoE expert / the hybrid
    shared block — ``_region_of``) and the knapsack pins whole regions,
    ranked by traffic value density: expected HBM bytes avoided per step
    per VMEM byte pinned. Under a tight budget the plan keeps the regions
    the traffic profile actually re-reads (every step for dense layers,
    top_k/E of steps for MoE experts), and every pinned byte is one the
    layer-granular executor can exploit.
    """
    blocks = weight_blocks(cfg)
    weights = tuple(read_weight(b.name, cfg) for b in blocks)
    regions = tuple(_region_of(b.name) for b in blocks)
    packing: Packing = pack_blocks(
        blocks, chip=chip, max_height=max_height, solver=solver,
        regions=regions,
    )
    ram = vmem_tile_ram(chip)
    tile_bytes = chip.sublane * chip.lane
    bins = tuple(tuple(b) for b in packing.bins)
    bin_tiles = tuple(
        bin_cost([packing.items[i] for i in b], ram)[0] for b in bins
    )
    groups: dict[str, list[int]] = {}
    for j, b in enumerate(bins):
        groups.setdefault(regions[b[0]], []).append(j)

    def group_cost(js: list[int]) -> int:
        return sum(bin_tiles[j] for j in js) * tile_bytes

    def density(js: list[int]) -> float:
        avoided = sum(
            weights[i] * blocks[i].padded_bytes(chip)
            for j in js
            for i in bins[j]
        )
        return avoided / max(1, group_cost(js))

    order = sorted(groups.values(), key=density, reverse=True)
    resident = [False] * len(bins)
    used = 0
    for js in order:
        cost = group_cost(js)
        if used + cost <= vmem_budget_bytes:
            for j in js:
                resident[j] = True
            used += cost
    return RuntimeResidencyPlan(
        model=cfg.name,
        chip=chip.name,
        blocks=blocks,
        bins=bins,
        bin_tiles=bin_tiles,
        resident=tuple(resident),
        vmem_budget_bytes=vmem_budget_bytes,
        stream_ahead=stream_ahead_depth(cfg, max_height),
        read_weights=weights,
    )
