"""Budgeted weight-residency runtime (the executed VMEM analogue of FCMP).

``plan`` compiles a :class:`RuntimeResidencyPlan` from (model config x
device VMEM budget x traffic profile) with the ``core.packing`` solvers
running over ``core.vmem_plan.WeightBlock`` carriers; ``executor`` threads
the plan into the paged serve step so hot blocks stay pinned in VMEM and
cold blocks are double-buffer-streamed HBM->VMEM by
``kernels.weight_stream``.
"""

from repro.runtime.residency.plan import (
    RuntimeResidencyPlan,
    TrafficProfile,
    compile_residency_plan,
    stream_ahead_depth,
    weight_blocks,
)
from repro.runtime.residency.executor import make_budgeted_paged_serve_step

__all__ = [
    "RuntimeResidencyPlan",
    "TrafficProfile",
    "compile_residency_plan",
    "stream_ahead_depth",
    "weight_blocks",
    "make_budgeted_paged_serve_step",
]
