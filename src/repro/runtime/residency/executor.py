"""Execute a residency plan: budgeted paged decode over a split weight set.

The plan's ``layer_stream_mask`` partitions layers into *resident* (FFN
weights pinned — the standard in-VMEM matmul path) and *streamed* (FFN
weights pulled HBM->VMEM per step by ``kernels.weight_stream``, ring depth
= the plan's ``stream_ahead``, i.e. the GALS R_F). The mask is scanned
alongside the stacked layer leaves so the whole model still compiles as
one ``lax.scan`` — HLO size stays flat in depth, and a ``lax.cond``
selects the path per layer at run time.

Numerics: on CPU the streamed branch resolves to the ``kernels.ref``
oracle, whose math is identical to the resident branch — which is what
makes ``--vmem-budget`` serve output token-identical to the unbudgeted
path (the acceptance gate). On TPU the Pallas streaming kernel runs and
matches to matmul-accumulation tolerance.
"""

from __future__ import annotations

import functools
from typing import Callable

from repro.models.config import ModelConfig
from repro.runtime.residency.plan import RuntimeResidencyPlan


def supports_budgeted_decode(cfg: ModelConfig) -> bool:
    """Budgeted decode = paged decode + a streamable FFN weight set:
    the dense-FFN attention families (per-layer stream mask) and moe
    (per-(layer, expert) mask over the dropless dispatch)."""
    return cfg.family in ("dense", "vlm", "moe")


def make_budgeted_paged_serve_step(
    cfg: ModelConfig, plan: RuntimeResidencyPlan
) -> Callable:
    """Pool-indexed serve step running against the plan's budgeted set.

    Same signature as ``steps.make_paged_serve_step``: (params, token,
    pool_k, pool_v, row_table, lengths) -> (logits, pool_k, pool_v)
    (+ a per-layer expert-load tally for moe). The mask granularity
    follows the family: (L,) layers for dense/vlm, (L, E) experts for
    moe — cold experts stream their w1/w3/w2 through the DMA ring while
    the knapsack-pinned hot experts stay resident.
    """
    if not supports_budgeted_decode(cfg):
        raise ValueError(
            f"budgeted decode needs a streamable-FFN attention family; "
            f"got {cfg.family!r} (ssm/hybrid state is out of the "
            "residency executor's scope)"
        )
    if cfg.family == "moe":
        mask = plan.expert_stream_mask(cfg)
        assert len(mask) == cfg.n_layers and all(
            len(row) == cfg.n_experts for row in mask
        ), (len(mask), cfg.n_layers, cfg.n_experts)
    else:
        mask = plan.layer_stream_mask(cfg)
        assert len(mask) == cfg.n_layers, (len(mask), cfg.n_layers)
    from repro.runtime.steps import make_budgeted_paged_serve_step as _mk

    return _mk(cfg, mask, plan.stream_ahead)


@functools.lru_cache(maxsize=None)
def cached_budgeted_step(cfg: ModelConfig, plan: RuntimeResidencyPlan):
    """jit-compiled budgeted step, cached per (config, plan) so schedulers
    and benchmark A/B runs share compilations (mirrors
    ``scheduler._jitted_decode``)."""
    import jax

    return jax.jit(
        make_budgeted_paged_serve_step(cfg, plan), donate_argnums=(2, 3)
    )
