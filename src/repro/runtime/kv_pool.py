"""Shared physical KV pool for continuous-batching decode (serving FCMP).

The paper packs many logical weight buffers into one physical BRAM and
compensates with a faster memory clock (``core.gals``); the serving analog
packs many per-request KV caches into one contiguous physical pool and
compensates with the scheduler's decode/admission interleave. The mapping:

    logical buffer      -> one request's KV cache
    physical BRAM block -> a fixed ``block_tokens``-row pool block
    bin height H_B      -> co-resident requests per pool
    paper Eq. 1         -> ``utilization()`` (held tokens / held rows)

Blocks are **refcounted**: the FCMP move of sharing one physical memory
between several logical consumers applies to KV too, because identical
prompt prefixes produce identical KV rows. A request's block table may
alias blocks held by other requests and/or pinned by the radix prefix
cache (``runtime.prefix_cache``); a block returns to the free list only
when its last holder lets go. Shared blocks are read-only for everyone
but the original writer; a request that must write into a *partially*
matched block first takes a private copy (``adopt_prefix``'s
copy-on-write of the tail block). Cached blocks with no live request
holder are reclaimable: under admission pressure the pool asks its
attached cache (the ``evictor`` hook) to evict LRU entries.

Block geometry and fragmentation accounting reuse ``core.packing`` /
``core.resource_model`` directly: a request's footprint is a
``WeightBuffer`` (width 1 "lane", depth = tokens), a pool block is a
``RamPrimitive`` with a single legal aspect ratio ``(1, block_tokens)``,
and ``pack_ffd`` provides the first-fit-decreasing machinery for the
block-size sweep and the tail-sharing lower bound.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffers import WeightBuffer
from repro.core.packing import PackItem, baseline_packing, pack_ffd
from repro.core.resource_model import RamPrimitive
from repro.models.config import PAGED_FAMILIES, ModelConfig

SCRATCH_BLOCK = 0  # block 0 is never allocated; idle slots write/read it

# in-place row insertion into a donated pool buffer (one trace per
# (pool shape, row count); the .at[].set outside jit would copy the pool)
_row_scatter = jax.jit(
    lambda pool, rows, vals: pool.at[:, rows].set(vals), donate_argnums=(0,)
)

# copy-on-write block duplication: gather the source block's rows and
# scatter them into the destination block, in place on the donated pool
_block_copy = jax.jit(
    lambda pool, dst, src: pool.at[:, dst].set(pool[:, src]),
    donate_argnums=(0,),
)


def kv_block_ram(block_tokens: int) -> RamPrimitive:
    """A pool block as a RAM primitive: one legal shape, 1 x block_tokens."""
    return RamPrimitive(
        name="KVBLOCK",
        capacity_bits=block_tokens,
        n_ports=2,
        configs=((1, block_tokens),),
    )


def request_buffer(rid: int, n_tokens: int) -> WeightBuffer:
    """A request's KV footprint as a logical buffer (1 lane x tokens)."""
    return WeightBuffer(f"req{rid}", width_bits=1, depth_words=n_tokens, w_bits=1)


def choose_block_tokens(
    lengths: list[int],
    candidates: tuple[int, ...] = (4, 8, 16, 32, 64),
    overhead_rows: float = 0.5,
) -> int:
    """Pick the block size minimising lifetime pool waste for a length mix.

    A decode cache *grows* 1 -> L tokens, so the cost of a block size is
    the request-lifetime average of (allocated rows - held tokens) plus a
    per-block bookkeeping overhead (block-table entries, gather indices).
    This is the same blocks_for() geometry sweep ``core.packing.bin_cost``
    runs over BRAM aspect ratios: small blocks waste little tail but pay
    per-block overhead, large blocks the reverse — ``overhead_rows`` is
    what stops "always pick the smallest shape".
    """
    if not lengths:
        return candidates[0]
    counts = Counter(lengths)
    best_t, best_cost = candidates[0], None
    for t in candidates:
        ram = kv_block_ram(t)
        cost = 0.0
        for length, n in counts.items():
            blocks = [
                request_buffer(0, l).blocks(ram)
                for l in range(1, max(2, length + 1))
            ]
            waste = sum(b * t - l for l, b in enumerate(blocks, start=1))
            cost += n * (waste + overhead_rows * sum(blocks)) / len(blocks)
        if best_cost is None or cost < best_cost:
            best_t, best_cost = t, cost
    return best_t


@dataclasses.dataclass
class PoolStats:
    n_blocks: int
    block_tokens: int
    held_blocks: int  # unique physical blocks held by live requests
    held_tokens: int  # useful rows in them, each physical row counted once
    free_blocks: int
    committed_blocks: int
    shared_blocks: int = 0  # request-held blocks with > 1 request holder
    cached_blocks: int = 0  # blocks pinned by the prefix cache
    evictable_blocks: int = 0  # cached blocks no live request holds

    @property
    def utilization(self) -> float:
        """Serving Eq. 1: useful KV rows / physical rows held.

        Both terms are per *physical* block — a block shared by N
        requests contributes its rows once, not N times, so sharing
        raises effective utilization instead of double-counting it.
        """
        if self.held_blocks == 0:
            return 1.0
        return self.held_tokens / (self.held_blocks * self.block_tokens)

    @property
    def occupancy(self) -> float:
        return self.held_blocks / max(1, self.n_blocks)


class KVPool:
    """One contiguous physical KV cache with refcounted block sharing.

    Device side: ``k``/``v`` are (L, n_blocks * block_tokens, n_kv, hd)
    row-addressed arrays (the block is an allocator concept only). Host
    side: a free-block inventory, per-request block tables that may
    *alias* each other on shared prefixes, a per-block refcount, and the
    set of blocks pinned by the attached prefix cache.

    Admission reserves a *commitment* (the request's full block need from
    ``blocks_for``) but hands out blocks lazily as tokens arrive, so
    utilization stays high while on-demand growth can never fail:

        invariant:  sum(committed - held) over live requests
                    <= free blocks + evictable cached blocks

    (Shared blocks adopted from the cache count as held without touching
    the free list, so a prefix hit only *shrinks* a request's residual
    claim on the free list — the invariant stays conservative.)
    """

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        n_blocks: int,
        block_tokens: int,
        dtype=None,
    ):
        if cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                f"KVPool serves the paged families {PAGED_FAMILIES}; got "
                f"{cfg.family!r} (pure-ssm decode state is fixed-size per "
                "slot and holds no KV rows)"
            )
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the scratch block)")
        self.cfg = cfg
        self.n_blocks = n_blocks
        self.block_tokens = block_tokens
        self.ram = kv_block_ram(block_tokens)
        dt = jnp.dtype(dtype or cfg.dtype)
        rows = n_blocks * block_tokens
        # hybrid holds one growing KV cache per *shared* attention block
        # (n_super of them), not per layer
        shape = (cfg.n_kv_cache_layers, rows, cfg.n_kv, cfg.hd)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        # block 0 reserved as scratch for idle decode lanes
        self._free: list[int] = list(range(n_blocks - 1, SCRATCH_BLOCK, -1))
        self._held: dict[int, list[int]] = {}
        self._tokens: dict[int, int] = {}
        self._committed: dict[int, int] = {}
        # open speculative brackets: rid -> blocks grown by begin_draft
        # and not yet settled by end_draft (owner="draft" ledger class)
        self._draft: dict[int, int] = {}
        self._refs: dict[int, int] = {}  # block -> live holders (+1 cached)
        self._cached: set[int] = set()  # blocks pinned by the prefix cache
        # incremental aggregates so the per-decode-step stats() read is
        # O(1) instead of rescanning every block table (validate()
        # cross-checks them against a full recount)
        self._users: Counter = Counter()  # block -> live *request* holders
        self._used: dict[int, int] = {}  # block -> deepest row any holder uses
        self._used_total = 0
        self._shared = 0  # blocks with > 1 request holder
        self._evictable = 0  # cached blocks with no request holder
        # the attached prefix cache's eviction hook: (blocks needed) ->
        # blocks actually returned to the free list
        self.evictor: Callable[[int], int] | None = None
        # lifetime counters (runtime.tracker records + soak conservation:
        # alloc - freed always equals the referenced-block count)
        self.alloc_blocks = 0
        self.freed_blocks = 0
        self.cow_copies = 0
        # the attached memory ledger (runtime.memledger.MemLedger.attach);
        # every mutation below notifies it so integrated deltas reproduce
        # stats() exactly at any point between mutations
        self.ledger = None

    @classmethod
    def for_slots(
        cls,
        cfg: ModelConfig,
        *,
        slots: int,
        max_len: int,
        block_tokens: int,
        dtype=None,
    ) -> "KVPool":
        """A pool sized so ``slots`` concurrent max_len requests always fit
        (their full block commitments, plus the scratch block)."""
        per_slot = -(-max_len // block_tokens)
        return cls(
            cfg,
            n_blocks=1 + slots * per_slot,
            block_tokens=block_tokens,
            dtype=dtype,
        )

    # ---------------- geometry ----------------

    def blocks_for(self, n_tokens: int) -> int:
        return request_buffer(0, n_tokens).blocks(self.ram)

    @property
    def usable_blocks(self) -> int:
        return self.n_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        return len(self._cached)

    @property
    def evictable_blocks(self) -> int:
        """Cached blocks no live request holds — reclaimable on demand.

        A cached block with refcount 1 is pinned only by the cache; the
        radix tree's prefix-chain structure guarantees its whole subtree
        is equally unheld, so every such block is evictable bottom-up.
        """
        return self._evictable

    # ---------------- incremental accounting ----------------

    def _add_user(self, block: int) -> None:
        self._users[block] += 1
        if self._users[block] == 2:
            self._shared += 1
        if self._users[block] == 1 and block in self._cached:
            self._evictable -= 1

    def _drop_user(self, block: int) -> None:
        c = self._users[block] - 1
        if c == 0:
            del self._users[block]
            self._used_total -= self._used.pop(block, 0)
            if block in self._cached:
                self._evictable += 1
        else:
            self._users[block] = c
            if c == 1:
                self._shared -= 1

    def _count_use(self, block: int, rows: int) -> None:
        old = self._used.get(block, 0)
        if rows > old:
            self._used[block] = rows
            self._used_total += rows - old

    @property
    def outstanding_commitment(self) -> int:
        return sum(
            max(0, self._committed[r] - len(self._held[r])) for r in self._held
        )

    def ref_count(self, block: int) -> int:
        return self._refs.get(block, 0)

    def max_rows(self, max_tokens: int) -> int:
        """Fixed gather width for a serve step admitting <= max_tokens."""
        return self.blocks_for(max_tokens) * self.block_tokens

    # ---------------- lifecycle ----------------

    def can_admit(self, total_tokens: int) -> bool:
        need = self.blocks_for(total_tokens)
        avail = self.free_blocks + self.evictable_blocks
        return avail - self.outstanding_commitment >= need

    def admit(self, rid: int, total_tokens: int) -> None:
        if rid in self._held:
            raise ValueError(f"request {rid} already admitted")
        if not self.can_admit(total_tokens):
            raise RuntimeError(
                f"pool cannot admit request {rid} "
                f"({self.blocks_for(total_tokens)} blocks needed, "
                f"{self.free_blocks + self.evictable_blocks - self.outstanding_commitment}"
                " uncommitted)"
            )
        self._committed[rid] = self.blocks_for(total_tokens)
        self._held[rid] = []
        self._tokens[rid] = 0
        if self.ledger is not None:
            self.ledger.record(
                "admit", owner="request", rid=rid, committed=self._committed[rid]
            )

    def _pop_free(self) -> int:
        """Take a block off the free list, evicting cached blocks first
        when it is empty. Commitment accounting guarantees this succeeds
        for any in-commitment growth."""
        if not self._free and self.evictor is not None:
            self.evictor(1)
        if not self._free:
            raise RuntimeError("pool free list empty and nothing evictable")
        b = self._free.pop()
        self._refs[b] = 1
        self.alloc_blocks += 1
        return b

    def ensure_rows(self, rid: int, n_tokens: int) -> None:
        """Grow the request's block list to hold ``n_tokens`` rows."""
        held = self._held[rid]
        before = len(held)
        while len(held) * self.block_tokens < n_tokens:
            if len(held) >= self._committed[rid]:
                raise RuntimeError(
                    f"request {rid} exceeds its {self._committed[rid]}-block "
                    "commitment"
                )
            b = self._pop_free()
            self._add_user(b)
            held.append(b)
        # note_tokens-driven row-coverage drift deliberately does not
        # emit (it would flood one record per decode token); the ledger's
        # round sync() folds it in. Block growth is an event.
        if self.ledger is not None and len(held) > before:
            self.ledger.record(
                "grow", owner="request", rid=rid, grown=len(held) - before
            )

    def note_tokens(self, rid: int, n_tokens: int) -> None:
        """Record the request's token count (monotone while held: a
        smaller count than already noted keeps the deeper coverage)."""
        self.ensure_rows(rid, n_tokens)
        old = self._tokens[rid]
        if n_tokens <= old:
            return
        self._tokens[rid] = n_tokens
        held, t = self._held[rid], self.block_tokens
        for idx in range(0 if old == 0 else (old - 1) // t,
                         (n_tokens - 1) // t + 1):
            self._count_use(held[idx], min(t, n_tokens - idx * t))

    def begin_draft(self, rid: int, n_tokens: int) -> None:
        """Grow the request's block list to cover a speculative draft
        chain ending at row ``n_tokens``, without advancing the token
        count. Draft rows land in the request's own (private) blocks, so
        a rejected suffix needs no data movement to undo: ``end_draft``
        returns the surplus blocks and the stale rows are overwritten by
        the next chain. Blocks grown here are charged to the ``draft``
        owner class in the ledger, distinct from committed request growth.
        """
        held = self._held[rid]
        before = len(held)
        while len(held) * self.block_tokens < n_tokens:
            if len(held) >= self._committed[rid]:
                raise RuntimeError(
                    f"draft for request {rid} exceeds its "
                    f"{self._committed[rid]}-block commitment"
                )
            b = self._pop_free()
            self._add_user(b)
            held.append(b)
        grown = len(held) - before
        if grown:
            self._draft[rid] = self._draft.get(rid, 0) + grown
            if self.ledger is not None:
                self.ledger.record(
                    "draft_grow", owner="draft", rid=rid, grown=grown
                )

    def end_draft(self, rid: int, n_tokens: int) -> None:
        """Settle a draft chain at its accepted length: rows through
        ``n_tokens`` become committed coverage (``note_tokens``); draft
        blocks past the accepted prefix are released back to the free
        list. Exactly inverts ``begin_draft`` when nothing is accepted
        into the drafted blocks, so the ledger integrates to zero across
        a fully-rejected chain."""
        draft = self._draft.pop(rid, 0)
        held = self._held[rid]
        keep = max(self.blocks_for(n_tokens), len(held) - draft)
        freed = 0
        while len(held) > keep:
            b = held.pop()
            self._drop_user(b)
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)
                self.freed_blocks += 1
            freed += 1
        self.note_tokens(rid, n_tokens)
        if self.ledger is not None and (draft or freed):
            self.ledger.record(
                "draft_end", owner="draft", rid=rid,
                kept=draft - freed, freed=freed,
            )

    def draft_rids(self) -> tuple[int, ...]:
        """Requests currently holding draft-class blocks (empty outside a
        begin_draft/end_draft bracket — the soak leak probe)."""
        return tuple(self._draft)

    def adopt_prefix(
        self,
        rid: int,
        shared: tuple[int, ...],
        tail_block: int | None,
        n_tokens: int,
    ) -> None:
        """Alias a matched prefix's blocks into a fresh request's table.

        ``shared`` are the cache's full blocks covering rows
        ``[0, len(shared) * block_tokens)`` — adopted read-only, refcount
        bumped. ``tail_block`` (required iff ``n_tokens`` is not
        block-aligned) holds the partially-matched block: the request
        will *write* rows ``n_tokens..`` of that block span, so it gets a
        private **copy-on-write** duplicate instead of an alias — the
        partial-block-divergence rule that keeps shared rows immutable.
        Must run right after ``admit``, before any rows are held.
        """
        held = self._held[rid]
        if held or self._tokens[rid]:
            raise RuntimeError(
                f"request {rid} must adopt a prefix before holding rows"
            )
        t = self.block_tokens
        if len(shared) != n_tokens // t:
            raise ValueError(
                f"{len(shared)} shared blocks cannot cover "
                f"{n_tokens // t} full blocks of {n_tokens} tokens"
            )
        if (tail_block is None) != (n_tokens % t == 0):
            raise ValueError(
                f"tail block required iff the matched prefix ({n_tokens} "
                f"tokens) ends mid-block (block_tokens={t})"
            )
        if len(shared) + (tail_block is not None) > self._committed[rid]:
            raise RuntimeError(
                f"adopted prefix exceeds request {rid}'s commitment"
            )
        for b in shared:
            if b == SCRATCH_BLOCK or b not in self._refs:
                raise ValueError(f"cannot adopt unallocated block {b}")
            self._refs[b] += 1
            self._add_user(b)
            held.append(b)
        if tail_block is not None:
            if tail_block == SCRATCH_BLOCK or tail_block not in self._refs:
                raise ValueError(f"cannot adopt unallocated block {tail_block}")
            new = self._pop_free()
            src = np.arange(tail_block * t, (tail_block + 1) * t)
            dst = np.arange(new * t, (new + 1) * t)
            self.k = _block_copy(self.k, jnp.asarray(dst), jnp.asarray(src))
            self.v = _block_copy(self.v, jnp.asarray(dst), jnp.asarray(src))
            self._add_user(new)
            held.append(new)
            self.cow_copies += 1
        self.note_tokens(rid, n_tokens)
        if self.ledger is not None:
            self.ledger.record(
                "adopt_prefix",
                owner="request",
                rid=rid,
                shared=len(shared),
                cow=int(tail_block is not None),
            )

    def release(self, rid: int) -> None:
        if rid not in self._held:
            raise ValueError(
                f"release of unknown request {rid}: it was never admitted "
                "or was already released (double free) — its blocks are "
                "not on the free list twice"
            )
        for b in self._held.pop(rid):
            self._drop_user(b)
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)
                self.freed_blocks += 1
        del self._tokens[rid], self._committed[rid]
        self._draft.pop(rid, None)
        if self.ledger is not None:
            self.ledger.record("release", owner="request", rid=rid)

    # ---------------- prefix-cache pinning ----------------

    def retain_cached(self, block: int) -> None:
        """Pin a block on behalf of the prefix cache (one pin per block)."""
        if block == SCRATCH_BLOCK or block not in self._refs:
            raise ValueError(f"cannot cache unallocated block {block}")
        if block in self._cached:
            raise ValueError(f"block {block} already cached")
        self._cached.add(block)
        self._refs[block] += 1
        if self.ledger is not None:
            self.ledger.record("retain_cached", owner="prefix-cache", block=block)

    def uncache(self, block: int) -> int:
        """Drop the cache's pin; returns 1 if the block went free, else 0.

        Eviction can never reclaim a block a live request holds: the
        refcount only reaches zero when no block table references it.
        """
        if block not in self._cached:
            raise ValueError(f"block {block} is not cached")
        self._cached.remove(block)
        self._refs[block] -= 1
        freed = 0
        if self._refs[block] == 0:
            del self._refs[block]
            self._free.append(block)
            self._evictable -= 1  # it was cache-only; now it is free
            self.freed_blocks += 1
            freed = 1
        if self.ledger is not None:
            self.ledger.record("uncache", owner="prefix-cache", block=block)
        return freed

    # ---------------- introspection ----------------

    def live_requests(self) -> list[int]:
        return list(self._held)

    def blocks_of(self, rid: int) -> tuple[int, ...]:
        return tuple(self._held[rid])

    def blocks_held(self, rid: int) -> int:
        return len(self._held[rid])

    def tokens_held(self, rid: int) -> int:
        return self._tokens[rid]

    # ---------------- device-side addressing ----------------

    def rows_of(self, rid: int, pad_to: int | None = None) -> np.ndarray:
        """Physical row indices of the request's tokens, scratch-padded."""
        t = self.block_tokens
        rows = np.concatenate(
            [np.arange(b * t, (b + 1) * t) for b in self._held[rid]]
        ) if self._held[rid] else np.zeros((0,), np.int64)
        if pad_to is not None:
            pad = np.full((pad_to - len(rows),), SCRATCH_BLOCK * t, np.int64)
            rows = np.concatenate([rows, pad])
        return rows.astype(np.int32)

    def scratch_rows(self, pad_to: int) -> np.ndarray:
        return np.full((pad_to,), SCRATCH_BLOCK * self.block_tokens, np.int32)

    def write_prefill(
        self,
        rid: int,
        ks: jnp.ndarray,
        vs: jnp.ndarray,
        n_tokens: int | None = None,
    ) -> None:
        """Scatter a prefilled (L, P, n_kv, hd) KV prefix into the pool.

        Cold-path only: the request's blocks must be private (a warm
        prefix-cache admission writes its suffix through the chunked
        prefill steps instead, which never touch adopted shared rows).
        ``ks``/``vs`` may be right-padded past ``n_tokens`` (the prefill
        bucket); padded rows land in the scratch block so the jitted
        scatter traces once per bucket size, and the donated pool buffer
        updates in place instead of copying the whole pool per admission.
        """
        p = n_tokens if n_tokens is not None else ks.shape[1]
        self.note_tokens(rid, p)
        rows = self.rows_of(rid)[:p]
        if ks.shape[1] > p:
            pad = np.full(
                (ks.shape[1] - p,), SCRATCH_BLOCK * self.block_tokens, np.int32
            )
            rows = np.concatenate([rows, pad])
        rows = jnp.asarray(rows)
        self.k = _row_scatter(self.k, rows, ks.astype(self.k.dtype))
        self.v = _row_scatter(self.v, rows, vs.astype(self.v.dtype))

    def export_blocks(
        self, rid: int, n_tokens: int | None = None
    ) -> tuple[tuple[int, ...], np.ndarray, np.ndarray]:
        """Snapshot a request's KV for handoff, serialized in block-id
        order: returns (block ids, K rows, V rows) with the row payloads
        shaped (L, n_tokens, n_kv, hd) — rows_of() gathers rows in the
        order the blocks were allocated, so the ids fully describe the
        payload layout and a block-granular transport could ship the
        physical blocks as-is. Shared (prefix-cache) blocks export by
        value like any other: the importing pool allocates its own
        blocks, so refcounts stay engine-local and intact."""
        ids = tuple(self._held[rid])
        n = n_tokens if n_tokens is not None else self._tokens[rid]
        rows = jnp.asarray(self.rows_of(rid)[:n])
        return ids, np.asarray(self.k[:, rows]), np.asarray(self.v[:, rows])

    # ---------------- accounting / reporting ----------------

    def stats(self) -> PoolStats:
        # the per-block aggregates (deepest row any holder uses, holder
        # counts, shared/evictable tallies) are maintained incrementally
        # on admit/grow/adopt/release, so this read — which the
        # scheduler takes every decode step — never rescans block tables
        return PoolStats(
            n_blocks=self.usable_blocks,
            block_tokens=self.block_tokens,
            held_blocks=len(self._users),
            held_tokens=self._used_total,
            free_blocks=self.free_blocks,
            committed_blocks=self.outstanding_commitment,
            shared_blocks=self._shared,
            cached_blocks=len(self._cached),
            evictable_blocks=self._evictable,
        )

    def validate(self) -> None:
        """Allocator invariants: refcounts exact, no free+referenced
        overlap, free-list uniqueness, full accounting."""
        if len(self._free) != len(set(self._free)):
            raise AssertionError("free list holds duplicate blocks")
        holders: Counter = Counter()
        for bs in self._held.values():
            holders.update(bs)
        referenced = set(holders) | self._cached
        if SCRATCH_BLOCK in referenced or SCRATCH_BLOCK in self._free:
            raise AssertionError("scratch block entered circulation")
        if referenced != set(self._refs):
            raise AssertionError("refcount keys out of sync with holders")
        for b in referenced:
            want = holders[b] + (1 if b in self._cached else 0)
            if self._refs[b] != want:
                raise AssertionError(
                    f"block {b} refcount {self._refs[b]} != {want} holders"
                )
        if referenced & set(self._free):
            raise AssertionError("block simultaneously referenced and free")
        if len(referenced) + len(self._free) != self.usable_blocks:
            raise AssertionError("blocks leaked")
        for rid, bs in self._held.items():
            if len(bs) != len(set(bs)):
                raise AssertionError(f"request {rid} holds a block twice")
            if self._tokens[rid] > len(bs) * self.block_tokens:
                raise AssertionError(f"request {rid} overflows its blocks")
        for rid, n in self._draft.items():
            if rid not in self._held or n > len(self._held[rid]):
                raise AssertionError(
                    f"draft bracket for request {rid} out of sync"
                )
        # incremental aggregates must equal a full recount
        used: dict[int, int] = {}
        t = self.block_tokens
        for rid, bs in self._held.items():
            for i, b in enumerate(bs):
                r = min(t, max(0, self._tokens[rid] - i * t))
                if r:  # draft-grown blocks carry no committed rows yet
                    used[b] = max(used.get(b, 0), r)
        if holders != self._users:
            raise AssertionError("per-block holder counts drifted")
        if used != {b: r for b, r in self._used.items()} or (
            sum(used.values()) != self._used_total
        ):
            raise AssertionError("per-block row-coverage drifted")
        if self._shared != sum(1 for n in holders.values() if n > 1):
            raise AssertionError("shared-block tally drifted")
        if self._evictable != sum(
            1 for b in self._cached if self._refs[b] == 1
        ):
            raise AssertionError("evictable-block tally drifted")
        # lifetime conservation: every allocation is either still
        # referenced or was returned to the free list exactly once
        if self.alloc_blocks - self.freed_blocks != len(self._refs):
            raise AssertionError(
                f"block conservation violated: {self.alloc_blocks} allocated"
                f" - {self.freed_blocks} freed != {len(self._refs)} live"
            )

    def fragmentation_report(self) -> dict:
        """Baseline (private blocks) vs the ``pack_ffd`` tail-sharing bound.

        The physical placement treats each request's logical footprint as
        its own buffer (prefix sharing aside), i.e. ``baseline_packing``;
        FFD with height H_B=4 quotes what packing request tails into
        shared blocks would save — the serving analog of the paper's
        baseline-vs-FCMP BRAM comparison.
        """
        items = [
            PackItem(request_buffer(rid, self._tokens[rid]))
            for rid in sorted(self._held)
            if self._tokens[rid] > 0
        ]
        base = baseline_packing(items, self.ram)
        packed = pack_ffd(items, max_height=4, ram=self.ram)
        return {
            "baseline_blocks": base.total_blocks,
            "ffd_blocks": packed.total_blocks,
            "baseline_efficiency": base.efficiency,
            "ffd_efficiency": packed.efficiency,
        }
