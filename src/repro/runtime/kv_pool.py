"""Shared physical KV pool for continuous-batching decode (serving FCMP).

The paper packs many logical weight buffers into one physical BRAM and
compensates with a faster memory clock (``core.gals``); the serving analog
packs many per-request KV caches into one contiguous physical pool and
compensates with the scheduler's decode/admission interleave. The mapping:

    logical buffer      -> one request's KV cache
    physical BRAM block -> a fixed ``block_tokens``-row pool block
    bin height H_B      -> co-resident requests per pool
    paper Eq. 1         -> ``utilization()`` (held tokens / held rows)

Block geometry and fragmentation accounting reuse ``core.packing`` /
``core.resource_model`` directly: a request's footprint is a
``WeightBuffer`` (width 1 "lane", depth = tokens), a pool block is a
``RamPrimitive`` with a single legal aspect ratio ``(1, block_tokens)``,
and ``pack_ffd`` provides the first-fit-decreasing machinery for the
block-size sweep and the tail-sharing lower bound.

The pool is block-granular and blocks are private to one request (KV rows
cannot be shared, unlike read-only weights), so physical placement is
``baseline_packing`` of the request buffers; ``fragmentation_report()``
also quotes the ``pack_ffd`` bound — what tail-sharing would save — the
same baseline-vs-packed comparison the paper's Table II makes for BRAM.
"""

from __future__ import annotations

import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffers import WeightBuffer
from repro.core.packing import PackItem, baseline_packing, pack_ffd
from repro.core.resource_model import RamPrimitive
from repro.models.config import PAGED_FAMILIES, ModelConfig

SCRATCH_BLOCK = 0  # block 0 is never allocated; idle slots write/read it

# in-place row insertion into a donated pool buffer (one trace per
# (pool shape, row count); the .at[].set outside jit would copy the pool)
_row_scatter = jax.jit(
    lambda pool, rows, vals: pool.at[:, rows].set(vals), donate_argnums=(0,)
)


def kv_block_ram(block_tokens: int) -> RamPrimitive:
    """A pool block as a RAM primitive: one legal shape, 1 x block_tokens."""
    return RamPrimitive(
        name="KVBLOCK",
        capacity_bits=block_tokens,
        n_ports=2,
        configs=((1, block_tokens),),
    )


def request_buffer(rid: int, n_tokens: int) -> WeightBuffer:
    """A request's KV footprint as a logical buffer (1 lane x tokens)."""
    return WeightBuffer(f"req{rid}", width_bits=1, depth_words=n_tokens, w_bits=1)


def choose_block_tokens(
    lengths: list[int],
    candidates: tuple[int, ...] = (4, 8, 16, 32, 64),
    overhead_rows: float = 0.5,
) -> int:
    """Pick the block size minimising lifetime pool waste for a length mix.

    A decode cache *grows* 1 -> L tokens, so the cost of a block size is
    the request-lifetime average of (allocated rows - held tokens) plus a
    per-block bookkeeping overhead (block-table entries, gather indices).
    This is the same blocks_for() geometry sweep ``core.packing.bin_cost``
    runs over BRAM aspect ratios: small blocks waste little tail but pay
    per-block overhead, large blocks the reverse — ``overhead_rows`` is
    what stops "always pick the smallest shape".
    """
    if not lengths:
        return candidates[0]
    counts = Counter(lengths)
    best_t, best_cost = candidates[0], None
    for t in candidates:
        ram = kv_block_ram(t)
        cost = 0.0
        for length, n in counts.items():
            blocks = [
                request_buffer(0, l).blocks(ram)
                for l in range(1, max(2, length + 1))
            ]
            waste = sum(b * t - l for l, b in enumerate(blocks, start=1))
            cost += n * (waste + overhead_rows * sum(blocks)) / len(blocks)
        if best_cost is None or cost < best_cost:
            best_t, best_cost = t, cost
    return best_t


@dataclasses.dataclass
class PoolStats:
    n_blocks: int
    block_tokens: int
    held_blocks: int
    held_tokens: int
    free_blocks: int
    committed_blocks: int

    @property
    def utilization(self) -> float:
        """Serving Eq. 1: useful KV rows / physical rows held."""
        if self.held_blocks == 0:
            return 1.0
        return self.held_tokens / (self.held_blocks * self.block_tokens)

    @property
    def occupancy(self) -> float:
        return self.held_blocks / max(1, self.n_blocks)


class KVPool:
    """One contiguous physical KV cache, allocated/freed per request.

    Device side: ``k``/``v`` are (L, n_blocks * block_tokens, n_kv, hd)
    row-addressed arrays (the block is an allocator concept only). Host
    side: a free-block inventory plus per-request block tables.

    Admission reserves a *commitment* (the request's full block need from
    ``blocks_for``) but hands out blocks lazily as tokens arrive, so
    utilization stays high while on-demand growth can never fail:

        invariant:  sum(committed - held) over live requests <= free blocks
    """

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        n_blocks: int,
        block_tokens: int,
        dtype=None,
    ):
        if cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                f"KVPool serves the paged families {PAGED_FAMILIES}; got "
                f"{cfg.family!r} (pure-ssm decode state is fixed-size per "
                "slot and holds no KV rows)"
            )
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the scratch block)")
        self.cfg = cfg
        self.n_blocks = n_blocks
        self.block_tokens = block_tokens
        self.ram = kv_block_ram(block_tokens)
        dt = jnp.dtype(dtype or cfg.dtype)
        rows = n_blocks * block_tokens
        # hybrid holds one growing KV cache per *shared* attention block
        # (n_super of them), not per layer
        shape = (cfg.n_kv_cache_layers, rows, cfg.n_kv, cfg.hd)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        # block 0 reserved as scratch for idle decode lanes
        self._free: list[int] = list(range(n_blocks - 1, SCRATCH_BLOCK, -1))
        self._held: dict[int, list[int]] = {}
        self._tokens: dict[int, int] = {}
        self._committed: dict[int, int] = {}

    @classmethod
    def for_slots(
        cls,
        cfg: ModelConfig,
        *,
        slots: int,
        max_len: int,
        block_tokens: int,
        dtype=None,
    ) -> "KVPool":
        """A pool sized so ``slots`` concurrent max_len requests always fit
        (their full block commitments, plus the scratch block)."""
        per_slot = -(-max_len // block_tokens)
        return cls(
            cfg,
            n_blocks=1 + slots * per_slot,
            block_tokens=block_tokens,
            dtype=dtype,
        )

    # ---------------- geometry ----------------

    def blocks_for(self, n_tokens: int) -> int:
        return request_buffer(0, n_tokens).blocks(self.ram)

    @property
    def usable_blocks(self) -> int:
        return self.n_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def outstanding_commitment(self) -> int:
        return sum(
            max(0, self._committed[r] - len(self._held[r])) for r in self._held
        )

    def max_rows(self, max_tokens: int) -> int:
        """Fixed gather width for a serve step admitting <= max_tokens."""
        return self.blocks_for(max_tokens) * self.block_tokens

    # ---------------- lifecycle ----------------

    def can_admit(self, total_tokens: int) -> bool:
        need = self.blocks_for(total_tokens)
        return self.free_blocks - self.outstanding_commitment >= need

    def admit(self, rid: int, total_tokens: int) -> None:
        if rid in self._held:
            raise ValueError(f"request {rid} already admitted")
        if not self.can_admit(total_tokens):
            raise RuntimeError(
                f"pool cannot admit request {rid} "
                f"({self.blocks_for(total_tokens)} blocks needed, "
                f"{self.free_blocks - self.outstanding_commitment} uncommitted)"
            )
        self._committed[rid] = self.blocks_for(total_tokens)
        self._held[rid] = []
        self._tokens[rid] = 0

    def ensure_rows(self, rid: int, n_tokens: int) -> None:
        """Grow the request's block list to hold ``n_tokens`` rows."""
        held = self._held[rid]
        while len(held) * self.block_tokens < n_tokens:
            if len(held) >= self._committed[rid]:
                raise RuntimeError(
                    f"request {rid} exceeds its {self._committed[rid]}-block "
                    "commitment"
                )
            # commitment accounting guarantees the free list is non-empty
            held.append(self._free.pop())

    def note_tokens(self, rid: int, n_tokens: int) -> None:
        self.ensure_rows(rid, n_tokens)
        self._tokens[rid] = n_tokens

    def release(self, rid: int) -> None:
        for b in self._held.pop(rid):
            self._free.append(b)
        del self._tokens[rid], self._committed[rid]

    def live_requests(self) -> list[int]:
        return list(self._held)

    def blocks_held(self, rid: int) -> int:
        return len(self._held[rid])

    def tokens_held(self, rid: int) -> int:
        return self._tokens[rid]

    # ---------------- device-side addressing ----------------

    def rows_of(self, rid: int, pad_to: int | None = None) -> np.ndarray:
        """Physical row indices of the request's tokens, scratch-padded."""
        t = self.block_tokens
        rows = np.concatenate(
            [np.arange(b * t, (b + 1) * t) for b in self._held[rid]]
        ) if self._held[rid] else np.zeros((0,), np.int64)
        if pad_to is not None:
            pad = np.full((pad_to - len(rows),), SCRATCH_BLOCK * t, np.int64)
            rows = np.concatenate([rows, pad])
        return rows.astype(np.int32)

    def scratch_rows(self, pad_to: int) -> np.ndarray:
        return np.full((pad_to,), SCRATCH_BLOCK * self.block_tokens, np.int32)

    def write_prefill(
        self,
        rid: int,
        ks: jnp.ndarray,
        vs: jnp.ndarray,
        n_tokens: int | None = None,
    ) -> None:
        """Scatter a prefilled (L, P, n_kv, hd) KV prefix into the pool.

        ``ks``/``vs`` may be right-padded past ``n_tokens`` (the prefill
        bucket); padded rows land in the scratch block so the jitted
        scatter traces once per bucket size, and the donated pool buffer
        updates in place instead of copying the whole pool per admission.
        """
        p = n_tokens if n_tokens is not None else ks.shape[1]
        self.note_tokens(rid, p)
        rows = self.rows_of(rid)[:p]
        if ks.shape[1] > p:
            pad = np.full(
                (ks.shape[1] - p,), SCRATCH_BLOCK * self.block_tokens, np.int32
            )
            rows = np.concatenate([rows, pad])
        rows = jnp.asarray(rows)
        self.k = _row_scatter(self.k, rows, ks.astype(self.k.dtype))
        self.v = _row_scatter(self.v, rows, vs.astype(self.v.dtype))

    def export_blocks(
        self, rid: int, n_tokens: int | None = None
    ) -> tuple[tuple[int, ...], np.ndarray, np.ndarray]:
        """Snapshot a request's KV for handoff, serialized in block-id
        order: returns (block ids, K rows, V rows) with the row payloads
        shaped (L, n_tokens, n_kv, hd) — rows_of() gathers rows in the
        order the blocks were allocated, so the ids fully describe the
        payload layout and a block-granular transport could ship the
        physical blocks as-is."""
        ids = tuple(self._held[rid])
        n = n_tokens if n_tokens is not None else self._tokens[rid]
        rows = jnp.asarray(self.rows_of(rid)[:n])
        return ids, np.asarray(self.k[:, rows]), np.asarray(self.v[:, rows])

    # ---------------- accounting / reporting ----------------

    def stats(self) -> PoolStats:
        held_blocks = sum(len(b) for b in self._held.values())
        return PoolStats(
            n_blocks=self.usable_blocks,
            block_tokens=self.block_tokens,
            held_blocks=held_blocks,
            held_tokens=sum(self._tokens.values()),
            free_blocks=self.free_blocks,
            committed_blocks=self.outstanding_commitment,
        )

    def validate(self) -> None:
        """Allocator invariants: partition, no overlap, full accounting."""
        held = [b for bs in self._held.values() for b in bs]
        if len(held) != len(set(held)):
            raise AssertionError("block allocated to two requests")
        if SCRATCH_BLOCK in held or SCRATCH_BLOCK in self._free:
            raise AssertionError("scratch block entered circulation")
        if set(held) & set(self._free):
            raise AssertionError("block simultaneously held and free")
        if len(held) + len(self._free) != self.usable_blocks:
            raise AssertionError("blocks leaked")
        for rid, bs in self._held.items():
            if self._tokens[rid] > len(bs) * self.block_tokens:
                raise AssertionError(f"request {rid} overflows its blocks")

    def fragmentation_report(self) -> dict:
        """Baseline (private blocks) vs the ``pack_ffd`` tail-sharing bound.

        The physical placement is one-request-per-block (KV rows are
        mutable, unlike the paper's read-only weights), i.e.
        ``baseline_packing``; FFD with height H_B=4 quotes what packing
        request tails into shared blocks would save — the serving analog
        of the paper's baseline-vs-FCMP BRAM comparison.
        """
        items = [
            PackItem(request_buffer(rid, self._tokens[rid]))
            for rid in sorted(self._held)
            if self._tokens[rid] > 0
        ]
        base = baseline_packing(items, self.ram)
        packed = pack_ffd(items, max_height=4, ram=self.ram)
        return {
            "baseline_blocks": base.total_blocks,
            "ffd_blocks": packed.total_blocks,
            "baseline_efficiency": base.efficiency,
            "ffd_efficiency": packed.efficiency,
        }
