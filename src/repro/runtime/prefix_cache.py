"""Radix-tree prefix index over committed token-id sequences.

The serving analog of the paper's FCMP cascade one level up: the KV pool
already packs many requests into one physical memory; the prefix cache
makes *identical logical content* share the same physical blocks. A
committed prompt's KV blocks stay pinned after the request releases
them; a new request walks the tree, adopts the blocks of its longest
cached prefix (refcount bump in ``KVPool``), and prefills only the
unmatched suffix — identical prefixes are prefilled and stored once, not
N times.

Structure: one node per **full** pool block, keyed by the block's
``block_tokens`` token ids; children hang off their parent's exact token
path, so a root-to-node walk spells out a committed prefix and the
blocks along it are exactly the rows a matching request can alias.
Matching may also stop *inside* a block (a divergence mid-block, or the
always-prefill-the-last-token cap): the partially-matched block is
returned separately and the pool duplicates it copy-on-write, because
the adopter will write its own rows into that block span.

Hybrid (zamba2) requests need more than KV rows to skip prefill — the
SSM recurrence must resume from the matched position. Nodes therefore
carry **anchors**: a committed prompt's exact end position, its partial
tail block (if unaligned), and a host-side snapshot of the per-request
SSM lane state at that position. A hybrid lookup returns the deepest
anchor whose token path prefixes the new prompt; the scheduler seeds
``lm.prefill_suffix_paged_hybrid`` with the snapshot.

Eviction is LRU over leaves (childless, anchor-free nodes) and anchors
whose blocks no live request shares; it runs on demand through the
pool's ``evictor`` hook when admission needs blocks, so cached blocks
cost nothing until memory pressure exists. Eviction can never free a
block a live request holds — ``KVPool.uncache`` only releases blocks at
refcount zero.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.runtime.kv_pool import KVPool


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """One lookup result: alias ``shared``, COW ``tail_block``, start
    the suffix prefill at token ``matched``."""

    matched: int  # usable matched tokens (the suffix prefill offset)
    shared: tuple[int, ...]  # full blocks to alias (refcount bump)
    tail_block: int | None  # partially-matched block to copy-on-write
    lane_state: Any = None  # hybrid anchor's SSM snapshot (host pytree)


class _Anchor:
    """A hybrid resume point: prompt end + SSM state at that position."""

    __slots__ = ("tail", "tail_block", "n_tokens", "lane_state", "stamp")

    def __init__(self, tail, tail_block, n_tokens, lane_state, stamp):
        self.tail = tail  # tokens past the node's block path (< block)
        self.tail_block = tail_block  # their partial block, or None
        self.n_tokens = n_tokens  # == node depth * block_tokens + len(tail)
        self.lane_state = lane_state  # np leaves (L, 1, ...) at n_tokens
        self.stamp = stamp


class _Node:
    __slots__ = ("key", "block", "children", "anchors", "parent", "stamp")

    def __init__(self, key, block, parent, stamp):
        self.key = key  # tuple of block_tokens token ids
        self.block = block  # the physical pool block holding their KV
        self.children: dict[tuple, _Node] = {}
        self.anchors: list[_Anchor] = []
        self.parent = parent
        self.stamp = stamp


class PrefixCache:
    """Block-granular radix index over a ``KVPool``'s committed prompts."""

    def __init__(self, pool: KVPool):
        self.pool = pool
        self.bt = pool.block_tokens
        self.root = _Node((), None, None, 0)
        self._nodes: set[_Node] = set()  # flat registry for eviction scans
        # pin multiset: the pool holds ONE pin per cached block; a block
        # can be pinned here by several units (an anchor's partial tail
        # block becomes a full node when the finished conversation is
        # re-committed with its generated tokens), so the pool pin is
        # taken on the first retain and dropped on the last release
        self._pins: dict[int, int] = {}
        self._clock = 0
        self.hits = 0
        self.lookups = 0
        self.evicted_blocks = 0
        pool.evictor = self.evict

    def _retain(self, block: int) -> None:
        n = self._pins.get(block, 0)
        if n == 0:
            self.pool.retain_cached(block)
        self._pins[block] = n + 1

    def _release_pin(self, block: int) -> int:
        """Drop one cache-unit pin; returns blocks actually freed."""
        n = self._pins[block] - 1
        if n > 0:
            self._pins[block] = n
            return 0
        del self._pins[block]
        return self.pool.uncache(block)

    # ---------------- internals ----------------

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    @staticmethod
    def _tokens(prompt) -> tuple[int, ...]:
        return tuple(int(t) for t in np.asarray(prompt).tolist())

    def _walk(self, toks: tuple[int, ...], touch: bool):
        """Descend full-block matches. Returns (chain of (node, block),
        final node, tokens matched in full blocks, partial-child info)."""
        node, depth, chain = self.root, 0, []
        while depth + self.bt <= len(toks):
            key = toks[depth : depth + self.bt]
            child = node.children.get(key)
            if child is None:
                break
            if touch:
                child.stamp = self._tick()
            chain.append(child.block)
            node, depth = child, depth + self.bt
        # longest partial match among the divergent children
        partial_len, partial_block = 0, None
        rest = toks[depth:]
        for key, child in node.children.items():
            n = 0
            for a, b in zip(key, rest):
                if a != b:
                    break
                n += 1
            if n > partial_len:
                partial_len, partial_block = n, child.block
        return chain, node, depth, partial_len, partial_block

    # ---------------- lookup ----------------

    def lookup(self, prompt, *, anchor: bool = False, peek: bool = False):
        """Longest-cached-prefix match for a prompt.

        ``anchor=True`` (hybrid) returns only anchor-bearing prefixes —
        positions where an SSM snapshot exists. The match is always
        capped at ``len(prompt) - 1``: at least one real token must
        prefill so the request has logits to sample its first output
        from. Returns a ``PrefixMatch`` or None; ``peek`` skips LRU
        stamps and hit accounting (router scoring).
        """
        toks = self._tokens(prompt)
        cap = len(toks) - 1
        if not peek:
            self.lookups += 1
        if cap <= 0:
            return None
        chain, node, depth, partial_len, partial_block = self._walk(
            toks, touch=not peek
        )
        if anchor:
            best = None
            n, d = node, depth
            while n is not None:  # deepest-first up the matched path
                for a in n.anchors:
                    if a.n_tokens > cap or a.n_tokens <= 0:
                        continue
                    if toks[d : d + len(a.tail)] != a.tail:
                        continue
                    if best is None or a.n_tokens > best[0].n_tokens:
                        best = (a, d)
                if best is not None:
                    break
                n, d = n.parent, d - self.bt
            if best is None:
                return None
            a, d = best
            if not peek:
                a.stamp = self._tick()
                self.hits += 1
            return PrefixMatch(
                matched=a.n_tokens,
                shared=tuple(chain[: d // self.bt]),
                tail_block=a.tail_block,
                lane_state=a.lane_state,
            )
        m = min(depth + partial_len, cap)
        if m <= 0:
            return None
        shared = tuple(chain[: m // self.bt])
        tail = None
        if m % self.bt:
            tail = chain[m // self.bt] if m // self.bt < len(chain) else (
                partial_block
            )
        if not peek:
            self.hits += 1
        return PrefixMatch(matched=m, shared=shared, tail_block=tail)

    def match_tokens(self, prompt, *, anchor: bool = False) -> int:
        """Router scoring: matched tokens without touching LRU state."""
        m = self.lookup(prompt, anchor=anchor, peek=True)
        return 0 if m is None else m.matched

    # ---------------- commit ----------------

    def commit(self, prompt, blocks, lane_state=None) -> None:
        """Index a prefilled prompt's blocks.

        Every *full* block becomes (or refreshes) a radix node, pinned in
        the pool; the request keeps using the blocks — the pin just keeps
        them alive past release. ``lane_state`` (hybrid) additionally
        records an anchor at the exact prompt end, pinning the partial
        tail block when the prompt is not block-aligned. When a node for
        a block's token key already exists (another request committed the
        same prefix first), the existing physical block wins and the new
        one stays private to its request.
        """
        toks = self._tokens(prompt)
        node, depth = self.root, 0
        i = 0
        while depth + self.bt <= len(toks):
            key = toks[depth : depth + self.bt]
            child = node.children.get(key)
            if child is None:
                child = _Node(key, blocks[i], node, self._tick())
                self._retain(blocks[i])
                node.children[key] = child
                self._nodes.add(child)
            else:
                child.stamp = self._tick()
            node, depth, i = child, depth + self.bt, i + 1
        if lane_state is not None:
            tail = toks[depth:]
            tail_block = blocks[i] if tail else None
            for a in node.anchors:
                if a.tail == tail:  # refresh, keep the older snapshot
                    a.stamp = self._tick()
                    return
            if tail_block is not None:
                self._retain(tail_block)
            node.anchors.append(
                _Anchor(tail, tail_block, len(toks), lane_state, self._tick())
            )

    # ---------------- eviction (the pool's evictor hook) ----------------

    def evict(self, n_blocks: int) -> int:
        """Free at least ``n_blocks`` cached blocks if possible, LRU
        first. Only units whose blocks no live request shares are
        victims (evicting a shared block would free nothing and lose a
        hot prefix); anchors go before their node, leaves before their
        parents — the prefix-chain refcount structure guarantees a
        refcount-1 subtree is reclaimable bottom-up.

        One registry scan seeds a stamp-ordered heap of current victims;
        as victims drain, parents (or anchor-stripped nodes) that become
        reclaimable are pushed with *their* stamps — exact LRU across
        chains, at one scan per evict() call instead of one per freed
        block."""
        import heapq

        def reclaimable(node: _Node) -> bool:
            return (
                node.parent is not None
                and not node.children
                and self.pool.ref_count(node.block) == 1
            )

        heap = []  # (stamp, seq, node, anchor | None)
        seq = 0
        for node in (self.root, *self._nodes):
            rec = reclaimable(node)
            for a in node.anchors:
                # an anchor is a victim only when evicting it gains
                # something: its tail block frees, or it is the last
                # thing keeping a reclaimable node alive (evicting a
                # zero-gain anchor would just burn hybrid resume points
                # without reclaiming a block)
                frees_tail = a.tail_block is not None and (
                    self.pool.ref_count(a.tail_block) == 1
                    and self._pins.get(a.tail_block, 0) == 1
                )
                if frees_tail or rec:
                    heap.append((a.stamp, seq := seq + 1, node, a))
            if rec and not node.anchors:
                heap.append((node.stamp, seq := seq + 1, node, None))
        heapq.heapify(heap)
        freed = 0
        while heap and freed < n_blocks:
            _, _, node, anchor = heapq.heappop(heap)
            if anchor is not None:
                if anchor not in node.anchors:
                    continue  # already drained
                node.anchors.remove(anchor)
                if anchor.tail_block is not None:
                    freed += self._release_pin(anchor.tail_block)
                exposed = node if reclaimable(node) else None
            else:
                if node.children or node.anchors or node not in self._nodes:
                    continue  # condition changed since seeding
                node.parent.children.pop(node.key)
                self._nodes.discard(node)
                freed += self._release_pin(node.block)
                exposed = (
                    node.parent if reclaimable(node.parent) else None
                )
                # a parent anchor sharing this block (pin multiset) may
                # just have become the block's last pin — now a victim
                for a in node.parent.anchors:
                    if (
                        a.tail_block is not None
                        and self.pool.ref_count(a.tail_block) == 1
                        and self._pins.get(a.tail_block, 0) == 1
                    ):
                        heapq.heappush(
                            heap, (a.stamp, seq := seq + 1, node.parent, a)
                        )
            if exposed is not None:
                if not exposed.anchors:
                    heapq.heappush(
                        heap, (exposed.stamp, seq := seq + 1, exposed, None)
                    )
                else:
                    # the anchors are now the last thing keeping a
                    # reclaimable node alive — victims they weren't at
                    # seed time (re-pushes are deduped at pop)
                    for a in exposed.anchors:
                        heapq.heappush(
                            heap, (a.stamp, seq := seq + 1, exposed, a)
                        )
        self.evicted_blocks += freed
        # the per-block frees already emitted through pool.uncache; this
        # zero-delta summary attributes the storm (requested vs freed) so
        # report.py/mem and the pressure monitor can count churn episodes
        if self.pool.ledger is not None and freed:
            self.pool.ledger.record(
                "evict", owner="prefix-cache", requested=n_blocks, freed=freed
            )
        return freed

    # ---------------- reporting ----------------

    def stats(self) -> dict:
        return {
            "nodes": len(self._nodes),
            "anchors": sum(len(n.anchors) for n in self._nodes)
            + len(self.root.anchors),
            "cached_blocks": self.pool.cached_blocks,
            "evictable_blocks": self.pool.evictable_blocks,
            "lookups": self.lookups,
            "hits": self.hits,
            "evicted_blocks": self.evicted_blocks,
        }
