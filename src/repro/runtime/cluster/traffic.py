"""Seed-deterministic synthetic serving traffic + SLO metrics.

The fleet layer measures time on a *virtual clock* (``cluster.engine``):
arrivals, TTFT, TPOT and goodput are all in virtual seconds, so a trace
replays bit-identically on any host — which is what lets CI gate fleet
speedups the way it gates token equivalence. The generator draws Poisson
arrivals, a discrete prompt/output length mix, and session reuse (a
fraction of arrivals continue an existing session — the router's
affinity policy keeps those on one engine so a future prefix cache could
actually hit).

SLO metrics follow the serving literature:

  * TTFT — time to first token: from arrival to the first token being
    available *on the engine that serves the client* (for disaggregated
    serving that is the decode engine, so a decode backlog shows up in
    TTFT, exactly the failure mode mis-provisioned fleets exhibit);
  * TPOT — time per output token over the decode phase;
  * goodput — generated tokens of SLO-meeting requests per virtual
    second (a request outside its TTFT/TPOT SLO contributes nothing).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

LengthMix = tuple[tuple[int, float], ...]


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """A synthetic workload: Poisson arrivals over a length/session mix."""

    n_requests: int = 32
    arrival_rate: float = 100.0  # requests per virtual second
    prompt_lens: LengthMix = ((8, 0.5), (16, 0.35), (24, 0.15))
    gen_lens: LengthMix = ((8, 0.7), (16, 0.3))
    session_reuse: float = 0.3  # fraction of arrivals continuing a session
    vocab: int = 512
    seed: int = 0

    def _mean(self, mix: LengthMix) -> float:
        w = sum(p for _, p in mix)
        return sum(l * p for l, p in mix) / w

    @property
    def mean_prompt_len(self) -> float:
        return self._mean(self.prompt_lens)

    @property
    def mean_gen_len(self) -> float:
        return self._mean(self.gen_lens)

    @property
    def max_total_tokens(self) -> int:
        return max(l for l, _ in self.prompt_lens) + max(
            l for l, _ in self.gen_lens
        )


@dataclasses.dataclass(frozen=True)
class ClientRequest:
    """One arrival. ``rid`` is the fleet-global request id — the sampler
    is keyed on it, so the token stream is engine-placement-invariant."""

    rid: int
    t_arrival: float
    prompt: np.ndarray
    max_new_tokens: int
    session: int

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new_tokens


def synthesize(spec: TrafficSpec) -> list[ClientRequest]:
    """Generate the trace. Deterministic in ``spec.seed`` only."""
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed, 0x7AFF1C]))
    plens, pw = zip(*spec.prompt_lens)
    glens, gw = zip(*spec.gen_lens)
    pw = np.asarray(pw, float) / sum(pw)
    gw = np.asarray(gw, float) / sum(gw)
    t = 0.0
    n_sessions = 0
    out: list[ClientRequest] = []
    for rid in range(spec.n_requests):
        t += float(rng.exponential(1.0 / spec.arrival_rate))
        if n_sessions and float(rng.random()) < spec.session_reuse:
            session = int(rng.integers(n_sessions))
        else:
            session = n_sessions
            n_sessions += 1
        p = int(rng.choice(plens, p=pw))
        g = int(rng.choice(glens, p=gw))
        prompt = rng.integers(0, spec.vocab, size=(p,)).astype(np.int32)
        out.append(ClientRequest(rid, t, prompt, g, session))
    return out


# --------------------------------------------------------------------------
# SLO accounting
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SloPolicy:
    """Per-request latency objectives, in virtual seconds.

    ``target`` is the availability objective: the fraction of requests
    that must meet the TTFT/TPOT bounds. Its complement (1 - target) is
    the error budget that ``spans.SLOMonitor`` burn rates are measured
    against. TTFT here is *submit-relative* (arrival to first token),
    so queue wait counts against the objective.
    """

    ttft: float
    tpot: float
    target: float = 0.9


@dataclasses.dataclass
class RequestTiming:
    """Virtual-time milestones of one request's life in the fleet."""

    rid: int
    t_arrival: float
    t_first: float = math.nan
    t_done: float = math.nan
    n_tokens: int = 0
    t_admit: float = math.nan  # engine admission (end of queue wait)

    @property
    def ttft(self) -> float:
        """Submit-relative TTFT: arrival to first token. This is the
        client's TTFT — queue wait included — and the one SLO policies
        are enforced against."""
        return self.t_first - self.t_arrival

    @property
    def ttft_admit(self) -> float:
        """Admission-relative TTFT: engine pickup to first token. The
        historical (pre-span) reading — it hides queue wait, which is
        why reports carry both."""
        return self.t_first - self.t_admit

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_arrival

    @property
    def tpot(self) -> float:
        if self.n_tokens <= 1:
            return 0.0
        return (self.t_done - self.t_first) / (self.n_tokens - 1)

    def meets(self, slo: SloPolicy) -> bool:
        return (
            not math.isnan(self.t_first)
            and not math.isnan(self.t_done)
            and self.ttft <= slo.ttft
            and self.tpot <= slo.tpot
        )


def _pct(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


@dataclasses.dataclass
class SloReport:
    """Percentile latencies + goodput for one fleet run."""

    n_requests: int
    completed: int
    makespan: float
    generated_tokens: int
    ttft_p50: float
    ttft_p95: float
    ttft_p99: float
    tpot_p50: float
    tpot_p95: float
    tpot_p99: float
    slo_met: int
    goodput_tokens_per_s: float
    throughput_tokens_per_s: float
    # admission-relative TTFT + queue wait (ttft_* above is
    # submit-relative; the spread between the two IS the queue)
    ttft_admit_p50: float = 0.0
    ttft_admit_p95: float = 0.0
    ttft_admit_p99: float = 0.0
    queue_wait_p50: float = 0.0
    queue_wait_p95: float = 0.0
    queue_wait_p99: float = 0.0

    def row(self) -> dict:
        return {
            k: (round(v, 6) if isinstance(v, float) else v)
            for k, v in dataclasses.asdict(self).items()
        }


def slo_report(
    timings: dict[int, RequestTiming], slo: SloPolicy
) -> SloReport:
    done = [t for t in timings.values() if not math.isnan(t.t_done)]
    ttfts = [t.ttft for t in done]
    tpots = [t.tpot for t in done]
    admits = [t for t in done if not math.isnan(t.t_admit)]
    ttfts_admit = [t.ttft_admit for t in admits]
    waits = [t.queue_wait for t in admits]
    makespan = max((t.t_done for t in done), default=0.0)
    met = [t for t in done if t.meets(slo)]
    total = sum(t.n_tokens for t in done)
    good = sum(t.n_tokens for t in met)
    return SloReport(
        n_requests=len(timings),
        completed=len(done),
        makespan=makespan,
        generated_tokens=total,
        ttft_p50=_pct(ttfts, 50),
        ttft_p95=_pct(ttfts, 95),
        ttft_p99=_pct(ttfts, 99),
        tpot_p50=_pct(tpots, 50),
        tpot_p95=_pct(tpots, 95),
        tpot_p99=_pct(tpots, 99),
        slo_met=len(met),
        goodput_tokens_per_s=good / makespan if makespan > 0 else 0.0,
        throughput_tokens_per_s=total / makespan if makespan > 0 else 0.0,
        ttft_admit_p50=_pct(ttfts_admit, 50),
        ttft_admit_p95=_pct(ttfts_admit, 95),
        ttft_admit_p99=_pct(ttfts_admit, 99),
        queue_wait_p50=_pct(waits, 50),
        queue_wait_p95=_pct(waits, 95),
        queue_wait_p99=_pct(waits, 99),
    )
