"""Fleet router: policy-driven dispatch over N engine replicas.

The router owns the cluster-global intake queue and dispatches arrivals
to engines under two policies:

  * ``least-loaded`` — the engine with the fewest committed + queued
    tokens that can take the request's *full* token commitment (the
    admission rule is token-budget-aware across engines: a request is
    never parked on an engine whose budget cannot hold it, so one hot
    engine cannot hoard the queue while others idle);
  * ``affinity`` — requests carrying a session id stick to the engine
    that served the session before (falling back to least-loaded when
    that engine is full or drained, and re-pinning). Keeping a session's
    requests co-located is what makes prefix/KV reuse possible at all —
    the reuse-aware handoff argument of ShortcutFusion (arXiv
    2106.08167) applied to placement;
  * ``prefix-aware`` — engines are scored by how many of the request's
    prompt tokens their radix prefix cache already holds, weighted by
    session affinity (the pinned engine's match counts double: its
    cached blocks are likeliest still hot). The engine with the highest
    score wins; with no cached prefix anywhere the policy degrades to
    affinity-then-least-loaded. This is where session affinity starts
    paying off in *reused blocks*, not just placement.

Dispatch is FIFO: the head of the backlog blocks until some engine can
accept it (no starvation, deterministic order). ``drain_engine`` stops
an engine's intake and requeues its not-yet-admitted requests at the
front of the backlog; in-flight requests finish where they are. Because
sampling is keyed on the fleet-global request id, a drained-and-requeued
request reproduces its exact token stream on the new engine — the
router invariant the tests pin (no request lost, duplicated, or
perturbed by a drain).

``FleetCluster`` runs the shared virtual-time event loop (see
``cluster.engine``): engines advance independent clocks, the loop always
steps the furthest-behind busy engine, and arrivals are delivered in
virtual-time order — a deterministic discrete-event simulation whose
per-token work is the real model.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque

from repro.models.config import CHUNKABLE_FAMILIES, ModelConfig
from repro.models.lm import SamplingParams
from repro.runtime.cluster.engine import Engine, StepCostModel
from repro.runtime.spans import SLOMonitor
from repro.runtime.cluster.traffic import (
    ClientRequest,
    RequestTiming,
    SloPolicy,
    SloReport,
    slo_report,
)
from repro.runtime.scheduler import RequestState


class Router:
    """Global intake queue + engine-selection policy."""

    POLICIES = ("least-loaded", "affinity", "prefix-aware")

    def __init__(self, engines: list[Engine], policy: str = "least-loaded"):
        if policy not in self.POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; valid: {self.POLICIES}"
            )
        self.engines = engines
        self.policy = policy
        self.backlog: deque[ClientRequest] = deque()
        self.affinity: dict[int, int] = {}  # session -> engine_id
        # rid -> engine ids it was queued on (len > 1 after a drain move)
        self.assignments: dict[int, list[int]] = {}

    def _fits_somewhere(self, creq: ClientRequest) -> bool:
        """Whether some undrained engine could *ever* hold this request.

        Chunkable-family engines are not bounded by their admission token
        budget: the scheduler admits an over-budget prompt solo and
        streams it through budget-sized prefill chunks, so only the pool
        capacity and ``max_len`` are hard walls (fleet-level chunked
        admission)."""
        def ceiling(e: Engine) -> int:
            cap = min(
                e.scheduler.max_len,
                e.scheduler.pool.usable_blocks
                * e.scheduler.pool.block_tokens,
            )
            if e.cfg.family not in CHUNKABLE_FAMILIES:
                cap = min(cap, e.scheduler.token_budget)
            return cap

        return any(
            not e.drained and creq.total_tokens <= ceiling(e)
            for e in self.engines
        )

    def offer(self, creq: ClientRequest) -> None:
        if not self._fits_somewhere(creq):
            raise ValueError(
                f"request {creq.rid} needs {creq.total_tokens} tokens; no "
                "undrained engine can ever hold it"
            )
        self.backlog.append(creq)

    def requeue(self, creqs: list[ClientRequest]) -> None:
        """Put drained requests back at the front, preserving order."""
        self.backlog.extendleft(reversed(creqs))

    def _pick(self, creq: ClientRequest) -> Engine | None:
        cands = [e for e in self.engines if e.can_accept(creq.total_tokens)]
        if not cands:
            return None
        if self.policy in ("affinity", "prefix-aware"):
            pinned = self.affinity.get(creq.session)
            if self.policy == "prefix-aware":
                # matched-prefix length x session affinity: the pinned
                # engine's cached tokens weigh double
                scored = [
                    (
                        e.prefix_match_tokens(creq.prompt)
                        * (2 if e.engine_id == pinned else 1),
                        e,
                    )
                    for e in cands
                ]
                best = max(s for s, _ in scored)
                if best > 0:
                    return min(
                        (e for s, e in scored if s == best),
                        key=lambda e: (e.load_tokens, e.engine_id),
                    )
            for e in cands:
                if e.engine_id == pinned:
                    return e
        return min(cands, key=lambda e: (e.load_tokens, e.engine_id))

    def dispatch(self) -> int:
        """Move backlog head(s) onto engines; returns dispatched count."""
        n = 0
        while self.backlog:
            creq = self.backlog[0]
            engine = self._pick(creq)
            if engine is None:
                break  # FIFO: head-of-line waits for budget to free
            self.backlog.popleft()
            if not engine.has_work():
                # an idle engine cannot have started before the arrival
                engine.clock = max(engine.clock, creq.t_arrival)
            # queue wait is measured from the client arrival (also after
            # a drain/requeue: the request's clock never restarts)
            engine.submit(
                creq.prompt,
                creq.max_new_tokens,
                creq.rid,
                t_submit=creq.t_arrival,
            )
            self.affinity[creq.session] = engine.engine_id
            self.assignments.setdefault(creq.rid, []).append(
                engine.engine_id
            )
            n += 1
        return n


@dataclasses.dataclass
class FleetRunResult:
    """Outputs + virtual-time telemetry of one cluster run."""

    outputs: dict[int, list[int]]
    timings: dict[int, RequestTiming]
    engine_summaries: list[dict]
    assignments: dict[int, list[int]]
    # fleet-level SLOMonitor.summary(): streaming TTFT/TPOT/queue-wait
    # histograms + multi-window burn rates (empty without completions)
    slo_summary: dict = dataclasses.field(default_factory=dict)
    # fleet-level memory-pressure view (memledger.MemPressureMonitor):
    # worst per-engine signal, peak occupancy, eviction churn — the
    # admission/scale input for the ROADMAP elastic-fleet item
    mem_summary: dict = dataclasses.field(default_factory=dict)

    def report(self, slo: SloPolicy) -> SloReport:
        return slo_report(self.timings, slo)


class FleetCluster:
    """N identical serve engines (prefill + decode each) behind a router."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_engines: int,
        slots: int,
        max_len: int,
        block_tokens: int,
        cost: StepCostModel,
        policy: str = "least-loaded",
        token_budget: int | None = None,
        sampling: SamplingParams | None = None,
        prefix_cache: bool = False,
        speculative=None,
        tracker=None,
        trace_spans: bool = True,
        slo: SloPolicy | None = None,
        mem_policy=None,
    ):
        self.cfg = cfg
        self.tracker = tracker
        self.slo = slo
        self.engines = [
            Engine(
                i,
                cfg,
                params,
                slots=slots,
                max_len=max_len,
                block_tokens=block_tokens,
                cost=cost,
                role="both",
                token_budget=token_budget,
                sampling=sampling,
                prefix_cache=prefix_cache,
                speculative=speculative,
                tracker=tracker,
                trace_spans=trace_spans,
                slo=slo,
                mem_policy=mem_policy,
            )
            for i in range(n_engines)
        ]
        self.router = Router(self.engines, policy)
        self.timings: dict[int, RequestTiming] = {}
        self._by_rid: dict[int, ClientRequest] = {}
        # fleet-level streaming SLO view: fed from completion events
        # with full (submit, admit, first, done) milestones — the
        # cross-engine complement of each engine's own monitor
        self.slo_monitor = SLOMonitor(slo)

    # hooks the disaggregated subclass specialises -----------------------

    def _route_payloads(self) -> None:
        return None  # no prefill->decode traffic in a symmetric fleet

    def _in_flight(self) -> bool:
        return False

    # --------------------------------------------------------------------

    def drain_engine(self, engine_id: int) -> list[int]:
        """Stop an engine's intake; requeue its queued requests. Returns
        the moved request ids."""
        engine = next(
            e for e in self.engines if e.engine_id == engine_id
        )
        moved = engine.drain()
        self.router.requeue([self._by_rid[r.rid] for r in moved])
        return [r.rid for r in moved]

    def restore_engine(self, engine_id: int) -> None:
        """Reopen a drained engine's intake (soak churn: engines cycle
        out and back without being rebuilt, caches intact)."""
        next(
            e for e in self.engines if e.engine_id == engine_id
        ).undrain()

    def _absorb_events(self, engine: Engine) -> None:
        for kind, rid, t in engine.events:
            timing = self.timings[rid]
            if kind == "admit":
                # last admission wins: a drained-and-requeued request
                # re-admits elsewhere, and only that one leads anywhere
                timing.t_admit = t
            elif kind == "first" and math.isnan(timing.t_first):
                timing.t_first = t
            elif kind == "done":
                timing.t_done = t
                req = engine.scheduler.requests.get(rid)
                n = len(req.output) if req is not None else 0
                self.slo_monitor.observe(
                    t=t,
                    ttft=timing.ttft,
                    ttft_admit=timing.ttft_admit,
                    tpot=(t - timing.t_first) / (n - 1) if n > 1 else 0.0,
                    queue_wait=timing.queue_wait,
                )
        engine.events.clear()

    def run(
        self,
        trace: list[ClientRequest],
        *,
        drain_at: tuple[int, float] | None = None,
        max_rounds: int | None = None,
        round_hook=None,
    ) -> FleetRunResult:
        """Serve the trace to completion on the virtual clock.

        ``round_hook(engine, round_index)``, when given, runs after every
        engine round — the soak harness's periodic invariant probe
        (pool ``validate()``, cursor/lane leak checks) without the run
        loop knowing what an invariant is."""
        pending = deque(
            sorted(trace, key=lambda r: (r.t_arrival, r.rid))
        )
        # arrivals rounded like every span/event stamp (spans.NDIGITS),
        # so queue_wait = t_admit - t_arrival can never go dust-negative
        self.timings = {
            r.rid: RequestTiming(r.rid, round(r.t_arrival, 9))
            for r in trace
        }
        self._by_rid = {r.rid: r for r in trace}
        limit = max_rounds or 64 + 4 * sum(
            r.total_tokens for r in trace
        )
        rounds = 0
        drain_pending = drain_at
        while True:
            busy = [e for e in self.engines if e.has_work()]
            t_round = min((e.clock for e in busy), default=math.inf)
            t_arr = pending[0].t_arrival if pending else math.inf
            t_evt = min(t_round, t_arr)
            if drain_pending is not None and t_evt >= drain_pending[1]:
                self.drain_engine(drain_pending[0])
                drain_pending = None
            while pending and pending[0].t_arrival <= t_evt:
                self.router.offer(pending.popleft())
            self.router.dispatch()
            self._route_payloads()
            busy = [e for e in self.engines if e.has_work()]
            if not busy:
                if pending:
                    continue  # next iteration jumps to the arrival
                if self.router.backlog or self._in_flight():
                    raise RuntimeError(
                        f"cluster stuck: {len(self.router.backlog)} "
                        "backlogged requests and no engine can accept"
                    )
                break
            engine = min(busy, key=lambda e: (e.clock, e.engine_id))
            engine.step_round()
            self._absorb_events(engine)
            rounds += 1
            if round_hook is not None:
                round_hook(engine, rounds)
            if rounds > limit:
                raise RuntimeError(
                    f"cluster failed to drain after {rounds} rounds"
                )
        return self._finish()

    def _finish(self) -> FleetRunResult:
        outputs: dict[int, list[int]] = {}
        for e in self.engines:
            e.scheduler.pool.validate()
            e.spans.flush()  # drained engines may hold buffered aborts
            # a drain after the last emitted round leaves release records
            # buffered; sync + flush keeps the mem stream complete
            e.ledger.sync()
            e.ledger.flush()
            for rid, req in e.scheduler.requests.items():
                if req.state is RequestState.HANDOFF:
                    continue  # finished on a decode engine
                if rid in outputs:
                    raise AssertionError(
                        f"request {rid} completed on two engines"
                    )
                outputs[rid] = req.output
        for rid, timing in self.timings.items():
            timing.n_tokens = len(outputs.get(rid, ()))
        clock = max((e.clock for e in self.engines), default=0.0)
        mems = {
            e.engine_id: e.mem_monitor.summary(now=e.clock)
            for e in self.engines
        }
        sig_rank = {"ok": 0, "pressure": 1, "storm": 2}
        mem_summary = {
            "peak_occupancy": max(
                (m["peak_occupancy"] for m in mems.values()), default=0.0
            ),
            "evicted_blocks": sum(m["evicted_blocks"] for m in mems.values()),
            "headroom_blocks": min(
                (m["headroom_blocks"] for m in mems.values()), default=0
            ),
            "signal": max(
                (m.get("signal", "ok") for m in mems.values()),
                key=lambda s: sig_rank.get(s, 0),
                default="ok",
            ),
            "pressure_engines": sorted(
                eid
                for eid, m in mems.items()
                if m.get("signal", "ok") != "ok"
            ),
        }
        return FleetRunResult(
            outputs=outputs,
            timings=self.timings,
            engine_summaries=[e.summary() for e in self.engines],
            assignments=dict(self.router.assignments),
            slo_summary=self.slo_monitor.summary(now=clock),
            mem_summary=mem_summary,
        )
