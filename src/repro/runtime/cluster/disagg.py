"""Disaggregated prefill/decode serving with GALS-ratio provisioning.

The paper's GALS transformation splits each MVAU into a memory domain
and a compute domain and buys back throughput with the frequency ratio
``R_F = F_m / F_c`` (Eq. 2: a packed memory feeds ``H_B`` streams iff
``H_B <= N_ports * R_F``). One level up, a serving fleet has the same
two-domain shape:

    memory domain (producer)   -> prefill engines: bandwidth-bound,
                                  turn prompts into KV state
    compute domain (consumer)  -> decode engines: latency-bound, burn
                                  KV state into tokens
    async FIFO between domains -> the KV-block handoff (payloads
                                  serialized through pool block ids)
    rate ratio R_F             -> measured per-engine request rates
                                  rho_p / rho_d
    bin height H_B             -> decode engines fed per prefill engine
    Eq. 2 feasibility          -> ceil(n_d / n_p) <= N_ports * R_F
                                  via ``core.gals.required_rf``

``provision_split`` turns a total engine count plus measured
prefill/decode token rates into the (n_prefill, n_decode) split: among
all splits it maximises sustainable request throughput
``min(n_p * rho_p, n_d * rho_d)``, preferring splits whose ratio
satisfies Eq. 2 (the decode domain is never starved of prefilled KV) and
then the larger decode side. The handoff FIFO is a single stream per
prefill engine, so ``N_PORTS`` here is 1 — a prefill engine feeds
``floor(R_F)`` decode engines without throughput loss, exactly the
paper's virtual-port arithmetic.

Decode on engine B of a request prefilled on engine A is token-identical
to single-engine serving: the payload carries the exact KV rows (in
block-id order) plus the first sampled token — and, for hybrids, the
SSM lane-state snapshot at the prompt end — and sampling is keyed on
(seed, global rid, position).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.gals import required_rf
from repro.models.config import PAGED_FAMILIES, ModelConfig
from repro.models.lm import SamplingParams
from repro.runtime.cluster.engine import Engine, StepCostModel
from repro.runtime.cluster.router import FleetCluster, Router
from repro.runtime.cluster.traffic import TrafficSpec
from repro.runtime.spans import SLOMonitor

# one KV-handoff stream per prefill engine (the async-FIFO analogue)
HANDOFF_PORTS = 1


@dataclasses.dataclass(frozen=True)
class RoleRates:
    """Measured per-engine request service rates (requests / virtual s)."""

    prefill_req_rate: float  # rho_p: prompts one prefill engine sustains
    decode_req_rate: float  # rho_d: requests one decode engine sustains

    @property
    def r_f(self) -> float:
        """The fleet-level frequency ratio F_m / F_c."""
        return self.prefill_req_rate / self.decode_req_rate


def measured_role_rates(
    cost: StepCostModel, spec: TrafficSpec, *, slots: int
) -> RoleRates:
    """Rates under the cluster's own cost model at the trace's mean
    prompt/output lengths — the simulator's 'measurement'; a production
    deployment would plug wall-clock rates in here instead."""
    rho_p = cost.prefill_rate(spec.mean_prompt_len) / spec.mean_prompt_len
    rho_d = cost.decode_rate(slots) / spec.mean_gen_len
    return RoleRates(prefill_req_rate=rho_p, decode_req_rate=rho_d)


def provision_split(
    n_engines: int, rates: RoleRates, n_ports: int = HANDOFF_PORTS
) -> tuple[int, int]:
    """(n_prefill, n_decode) from the Eq. 2 ratio algebra (see module
    docstring). Needs at least one engine per role."""
    if n_engines < 2:
        raise ValueError("disaggregation needs >= 2 engines")
    best_key = None
    best = (1, n_engines - 1)
    for n_p in range(1, n_engines):
        n_d = n_engines - n_p
        h_b = math.ceil(n_d / n_p)  # decode consumers per prefill producer
        rf_needed = required_rf(h_b, n_ports)  # Eq. 2 inverted
        fed = rates.r_f + 1e-9 >= float(rf_needed)
        throughput = min(
            n_p * rates.prefill_req_rate, n_d * rates.decode_req_rate
        )
        key = (throughput, fed, n_d)
        if best_key is None or key > best_key:
            best_key, best = key, (n_p, n_d)
    return best


class DisaggCluster(FleetCluster):
    """Prefill engines feed decode engines through KV-block handoffs.

    ``split`` forces an (n_prefill, n_decode) role split; when None the
    GALS-ratio provisioning above sizes it from the traffic spec.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_engines: int,
        slots: int,
        max_len: int,
        block_tokens: int,
        cost: StepCostModel,
        spec: TrafficSpec | None = None,
        split: tuple[int, int] | None = None,
        policy: str = "least-loaded",
        token_budget: int | None = None,
        sampling: SamplingParams | None = None,
        prefix_cache: bool = False,
        speculative=None,
        tracker=None,
        trace_spans: bool = True,
        slo=None,
        mem_policy=None,
    ):
        # hybrids now disaggregate too: the PrefillHandoff payload carries
        # the SSM lane-state snapshot next to the KV-block rows
        if cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                "disaggregated serving ships KV-block payloads; family "
                f"{cfg.family!r} decode state does not fit the wire format"
            )
        if split is None:
            if spec is None:
                raise ValueError("need a TrafficSpec (or explicit split)")
            split = provision_split(
                n_engines, measured_role_rates(cost, spec, slots=slots)
            )
        n_p, n_d = split
        if n_p < 1 or n_d < 1 or n_p + n_d != n_engines:
            raise ValueError(f"bad split {split} for {n_engines} engines")
        self.cfg = cfg
        self.split = split
        self.tracker = tracker
        self.slo = slo
        mk = lambda i, role: Engine(
            i,
            cfg,
            params,
            slots=slots,
            max_len=max_len,
            block_tokens=block_tokens,
            cost=cost,
            role=role,
            token_budget=token_budget,
            sampling=sampling,
            prefix_cache=prefix_cache,
            speculative=speculative,
            tracker=tracker,
            trace_spans=trace_spans,
            slo=slo,
            mem_policy=mem_policy,
        )
        self.prefill_engines = [mk(i, "prefill") for i in range(n_p)]
        self.decode_engines = [mk(n_p + i, "decode") for i in range(n_d)]
        self.engines = self.prefill_engines + self.decode_engines
        # arrivals route over the prefill tier only
        self.router = Router(self.prefill_engines, policy)
        self.timings = {}
        self._by_rid = {}
        self._awaiting: list = []  # payloads no decode engine can hold yet
        self.slo_monitor = SLOMonitor(slo)

    def _route_payloads(self) -> None:
        """Move prefilled KV payloads to the least-loaded decode engine
        that can hold their full token commitment."""
        ready = self._awaiting
        self._awaiting = []
        for e in self.prefill_engines:
            ready.extend(e.outbox)
            e.outbox.clear()
        ready.sort(key=lambda rp: (rp[0], rp[1].rid))
        for ready_at, payload in ready:
            cands = [
                d
                for d in self.decode_engines
                if d.can_accept(payload.total_tokens)
            ]
            if not cands:
                self._awaiting.append((ready_at, payload))
                continue
            target = min(cands, key=lambda d: (d.load_tokens, d.engine_id))
            target.offer_import(ready_at, payload)

    def _in_flight(self) -> bool:
        return bool(self._awaiting)
