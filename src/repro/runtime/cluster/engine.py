"""One fleet engine replica: a ``runtime.scheduler.Scheduler`` + KV pool
behind a virtual clock.

Real tokens, virtual seconds. Every engine runs the actual model (its
token streams are bit-exact against single-engine serving — the
acceptance gate), but *time* is charged from a roofline-derived
``StepCostModel`` so N engines genuinely overlap in virtual time on a
one-host CI runner, and a trace replays deterministically. The cost
model is calibrated from a (usually full-size) ``ModelConfig`` against
the ``perf.roofline`` hardware constants: decode steps are HBM-bound
(weight re-reads), prefill is MXU-bound per token plus one weight sweep
per step, and a prefill->decode handoff pays the KV payload over ICI.
"""

from __future__ import annotations

import bisect
import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.models.config import (
    CHUNKABLE_FAMILIES,
    PACKING_FAMILIES,
    ModelConfig,
)
from repro.models.lm import SamplingParams
from repro.perf.roofline import HW, HwModel
from repro.runtime.kv_pool import KVPool
from repro.runtime.scheduler import PrefillHandoff, Scheduler
from repro.runtime.spans import SLOMonitor, SpanRecorder, VirtualClock


@dataclasses.dataclass(frozen=True)
class StepCostModel:
    """Virtual-seconds cost of the scheduler's unit operations."""

    prefill_s_per_token: float  # MXU term: 2 * N_active flops / peak
    prefill_s_per_step: float  # one weight sweep HBM->compute per step
    decode_s_per_step: float  # one batched decode step (all lanes)
    handoff_s_per_token: float  # KV rows over the interconnect
    round_overhead_s: float = 1e-6  # host bookkeeping per round

    @classmethod
    def for_config(
        cls, cfg: ModelConfig, *, slots: int, hw: HwModel = HW
    ) -> "StepCostModel":
        """Calibrate from a model config (typically the *full-size* arch:
        the fleet serves the smoke config's real tokens while charging
        the production arch's time — same trick as the dry-run)."""
        n_active = cfg.active_params()
        dt_bytes = jnp.dtype(cfg.dtype).itemsize
        weight_bytes = n_active * dt_bytes
        if cfg.w_bits in (1, 2) and cfg.family in PACKING_FAMILIES:
            # FCMP packing shrinks the dense-FFN re-read traffic (hybrid
            # has one shared FFN copy, encdec packs both stacks, the rest
            # one per layer)
            if cfg.family == "hybrid":
                copies = 1
            elif cfg.family == "encdec":
                copies = cfg.n_layers + cfg.n_enc_layers
            else:
                copies = cfg.n_layers
            ffn = 3 * cfg.d_model * cfg.d_ff * copies * dt_bytes
            weight_bytes = weight_bytes - ffn + ffn * cfg.w_bits // (
                8 * dt_bytes
            )
        flops_per_token = 2.0 * n_active
        kv_bytes_per_token = (
            cfg.n_kv_cache_layers * 2 * cfg.n_kv * cfg.hd * dt_bytes
        )
        return cls(
            prefill_s_per_token=flops_per_token / hw.peak_flops,
            prefill_s_per_step=weight_bytes / hw.hbm_bw,
            decode_s_per_step=max(
                weight_bytes / hw.hbm_bw,
                flops_per_token * slots / hw.peak_flops,
            ),
            handoff_s_per_token=kv_bytes_per_token / hw.ici_bw,
        )

    def prefill_rate(self, mean_prompt: float) -> float:
        """Sustained prefill tokens/s at the given mean prompt length."""
        per_req = (
            mean_prompt * self.prefill_s_per_token + self.prefill_s_per_step
        )
        return mean_prompt / per_req

    def decode_rate(self, slots: int) -> float:
        """Sustained decode tokens/s with every lane busy."""
        return slots / self.decode_s_per_step


class Engine:
    """A scheduler replica with a virtual clock and handoff plumbing.

    Roles: ``both`` (a full serve engine), ``prefill`` (admission +
    prefill only; finished prompts leave through the scheduler's handoff
    hook as ``PrefillHandoff`` payloads in ``outbox``), ``decode``
    (adopts payloads from ``offer_import`` and runs their decode lanes).
    """

    def __init__(
        self,
        engine_id: int,
        cfg: ModelConfig,
        params,
        *,
        slots: int,
        max_len: int,
        block_tokens: int,
        cost: StepCostModel,
        role: str = "both",
        token_budget: int | None = None,
        sampling: SamplingParams | None = None,
        prefix_cache: bool = False,
        speculative=None,
        tracker=None,
        trace_spans: bool = True,
        slo=None,
        mem_policy=None,
    ):
        assert role in ("both", "prefill", "decode"), role
        self.engine_id = engine_id
        self.cfg = cfg
        self.role = role
        self.cost = cost
        # the engine, its span recorder and the scheduler's charge hook
        # all share one clock object, so mid-round work is stamped at
        # the instant it is charged (not at round granularity)
        self._vclock = VirtualClock()
        self.drained = False
        self.tracker = tracker
        self.spans = SpanRecorder(
            self._vclock.now,
            tracker=tracker if trace_spans else None,
            engine=engine_id,
            role=role,
        )
        # streaming TTFT/TPOT/queue-wait histograms + burn rates against
        # ``slo`` (``traffic.SloPolicy``; None = histograms only)
        self.slo_monitor = SLOMonitor(slo)
        self._marks: dict[int, dict[str, float]] = {}
        pool = KVPool.for_slots(
            cfg, slots=slots, max_len=max_len, block_tokens=block_tokens
        )
        cache = None
        if prefix_cache:
            from repro.runtime.prefix_cache import PrefixCache

            cache = PrefixCache(pool)
        # memory counterpart of the span recorder: the ledger emits
        # kind="mem" pool-mutation deltas on the same virtual clock and
        # tracker stream; the pressure monitor turns the per-round gauges
        # into the elastic-fleet admission/scale signal
        from repro.runtime.memledger import MemLedger, MemPressureMonitor

        self.ledger = MemLedger(
            self._vclock.now,
            tracker=tracker,
            engine=engine_id,
            role=role,
        )
        self.mem_monitor = MemPressureMonitor(mem_policy)
        # speculative decoding (runtime.speculative.ResolvedSpec): each
        # engine builds its own drafter (private lane KV), and the
        # drafter's work is charged at its *own* roofline — a packed twin
        # pays its FCMP-discounted weight sweep, ngram pays nothing —
        # while a verify step pays one target weight sweep plus the
        # chain's extra compute tokens
        self.draft_cost: StepCostModel | None = None
        spec = None
        if speculative is not None and role != "prefill":
            spec = speculative.build(
                cfg,
                params,
                slots=slots,
                max_len=max_len,
            )
            if speculative.draft_full_cfg is not None:
                self.draft_cost = StepCostModel.for_config(
                    speculative.draft_full_cfg, slots=slots
                )
        self.scheduler = Scheduler(
            cfg,
            params,
            pool,
            slots=slots,
            max_len=max_len,
            token_budget=token_budget,
            sampling=sampling,
            handoff=self._on_handoff if role == "prefill" else None,
            prefix_cache=cache,
            speculative=spec,
            spans=self.spans,
            ledger=self.ledger,
            mem_monitor=self.mem_monitor,
        )
        # incremental virtual-time charging: every prefill/decode step
        # advances the clock as it runs, so span boundaries and the
        # round record's clock_s come from the same accounting
        self.scheduler.charge = self._charge_work
        # unified observability: intercept the scheduler's per-round
        # record so it is logged with the *post-round* virtual clock and
        # this engine's identity merged in (one record per round still)
        self._pending_records: list[dict] = []
        if tracker is not None:
            self.scheduler.on_round = self._pending_records.append
            tracker.log_hyperparameters(
                {
                    "surface": "engine",
                    "engine": engine_id,
                    "role": role,
                    "arch": cfg.name,
                    "family": cfg.family,
                    "slots": slots,
                    "max_len": max_len,
                    "block_tokens": block_tokens,
                    "token_budget": self.scheduler.token_budget,
                    "prefix_cache": prefix_cache,
                    "decode_s_per_step": cost.decode_s_per_step,
                    "prefill_s_per_token": cost.prefill_s_per_token,
                }
            )
        self.outbox: list[tuple[float, PrefillHandoff]] = []
        self._imports: list[tuple[float, int]] = []  # (ready_at, rid)
        self._import_payloads: dict[int, PrefillHandoff] = {}
        self._import_tokens = 0
        # (kind, rid, t) with kind in {"admit", "first", "done",
        # "handoff"}; stamped by the span recorder, drained by the cluster
        self.events: list[tuple[str, int, float]] = []

    # ---------------- virtual clock ----------------

    @property
    def clock(self) -> float:
        return self._vclock.t

    @clock.setter
    def clock(self, t: float) -> None:
        # external writes (router arrival alignment, import waits) keep
        # working; the shared VirtualClock makes them visible to the
        # recorder and charge hook too
        self._vclock.t = t

    def _charge_work(self, op: str, *, tokens: int = 0, steps: int = 0):
        if op == "prefill":
            self._vclock.advance(
                tokens * self.cost.prefill_s_per_token
                + steps * self.cost.prefill_s_per_step
            )
        elif op == "decode":
            self._vclock.advance(steps * self.cost.decode_s_per_step)
        elif op == "draft":
            # the drafter's own roofline: a prefill call carries tokens
            # (prompt warm-up), a rollout carries only steps; an ngram
            # drafter has no cost model and is free
            dc = self.draft_cost
            if dc is not None:
                if tokens:
                    self._vclock.advance(
                        tokens * dc.prefill_s_per_token
                        + steps * dc.prefill_s_per_step
                    )
                else:
                    self._vclock.advance(steps * dc.decode_s_per_step)
        elif op == "verify":
            # one target weight sweep scores the whole chain (the win);
            # ``tokens`` are the chain positions beyond one-per-lane,
            # charged at the compute-bound prefill rate
            self._vclock.advance(
                steps * self.cost.decode_s_per_step
                + tokens * self.cost.prefill_s_per_token
            )
        else:  # pragma: no cover - scheduler charges only these ops
            raise ValueError(f"unknown charge op {op!r}")

    # ---------------- load / admission ----------------

    @property
    def queued_tokens(self) -> int:
        return sum(r.total_tokens for r in self.scheduler.queue) + (
            self._import_tokens
        )

    @property
    def load_tokens(self) -> int:
        """Committed + queued + pending-import tokens: the router's
        least-loaded metric."""
        return self.scheduler.committed_tokens + self.queued_tokens

    def can_accept(self, total_tokens: int) -> bool:
        if self.drained:
            return False
        sched = self.scheduler
        usable = sched.pool.usable_blocks * sched.pool.block_tokens
        if total_tokens > min(usable, sched.max_len):
            return False
        if self.load_tokens + total_tokens <= sched.token_budget:
            return True
        # fleet-level chunked admission: an over-budget prompt lands on
        # an *idle* engine of a chunkable family — the scheduler admits
        # it solo (mirroring its committed_tokens == 0 rule) and its
        # chunk cursor amortizes the prefill across rounds
        return (
            self.cfg.family in CHUNKABLE_FAMILIES and self.load_tokens == 0
        )

    def prefix_match_tokens(self, prompt) -> int:
        """Longest cached-prefix match for a prompt on this engine (0
        without a cache) — the router's prefix-aware scoring signal."""
        cache = self.scheduler.prefix_cache
        if cache is None:
            return 0
        return cache.match_tokens(
            prompt, anchor=(self.cfg.family == "hybrid")
        )

    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        rid: int,
        t_submit: float | None = None,
    ):
        t_sub = self.clock if t_submit is None else t_submit
        self._marks[rid] = {"submit": t_sub}
        self.scheduler.submit(prompt, max_new_tokens, rid=rid, t_submit=t_sub)

    def offer_import(self, ready_at: float, payload: PrefillHandoff) -> None:
        bisect.insort(self._imports, (ready_at, payload.rid))
        self._import_payloads[payload.rid] = payload
        self._import_tokens += payload.total_tokens

    def has_work(self) -> bool:
        return bool(
            self.scheduler.queue
            or any(r is not None for r in self.scheduler.active)
            or self._imports
        )

    # ---------------- handoff (prefill role) ----------------

    def _on_handoff(self, payload: PrefillHandoff) -> None:
        """Scheduler hook: stamp the payload's interconnect-ready time
        (prefill itself was already charged incrementally) and record
        the transit as this request's ``handoff`` span — the decode-side
        timeline resumes exactly at ``ready``."""
        t0 = self.spans.now()
        ready = self.clock + payload.n_tokens * self.cost.handoff_s_per_token
        self.outbox.append((ready, payload))
        self.spans.mark(
            payload.rid,
            "handoff",
            t0,
            ready,
            tokens=payload.n_tokens,
            kv_bytes=payload.kv_bytes,
        )
        self.spans.event("handoff", payload.rid, t0)
        self.spans.forget(payload.rid)

    # ---------------- the engine round ----------------

    def _try_imports(self) -> None:
        while self._imports:
            ready_at, rid = self._imports[0]
            if ready_at > self.clock:
                if not (
                    self.scheduler.queue
                    or any(r is not None for r in self.scheduler.active)
                ):
                    # nothing else to run: wait for the payload
                    self.clock = ready_at
                else:
                    break
            payload = self._import_payloads[rid]
            if not self.scheduler.import_prefilled(payload, ready_at=ready_at):
                break  # no lane/budget yet; decode below frees one
            self._imports.pop(0)
            del self._import_payloads[rid]
            self._import_tokens -= payload.total_tokens

    def step_round(self) -> None:
        """One scheduler round on the virtual clock.

        Work is charged *incrementally* by the scheduler's charge hook
        (each prefill/decode step advances the shared clock the instant
        it runs), so the only cost added here is the per-round host
        overhead — and the milestone events / spans the scheduler
        recorded already carry exact mid-round timestamps."""
        self._try_imports()
        self.scheduler.round()
        self._vclock.advance(self.cost.round_overhead_s)
        new_events = self.spans.drain_events()
        self._note_events(new_events)
        self.events.extend(new_events)
        # the scheduler's round record, stamped with the post-round
        # clock and this round's virtual-time milestone events
        for rec in self._pending_records:
            rec["engine"] = self.engine_id
            rec["role"] = self.role
            rec["clock_s"] = round(self.clock, 9)
            rec["events"] = list(new_events)
            self.tracker.log_metrics(rec, step=rec["round"])
        self._pending_records.clear()
        self.spans.flush()

    def _note_events(self, events) -> None:
        """Fold milestone events into the per-request marks and, at
        completion, feed the streaming SLO monitor."""
        for kind, rid, t in events:
            marks = self._marks.setdefault(rid, {})
            if kind == "handoff":
                # finishes elsewhere; the decode engine observes it
                self._marks.pop(rid, None)
                continue
            marks[kind] = t
            if kind != "done":
                continue
            req = self.scheduler.requests.get(rid)
            n = len(req.output) if req is not None else 0
            first = marks.get("first", t)
            sub = marks.get("submit", math.nan)
            adm = marks.get("admit", math.nan)
            self.slo_monitor.observe(
                t=t,
                ttft=first - sub,
                ttft_admit=first - adm,
                tpot=(t - first) / (n - 1) if n > 1 else 0.0,
                queue_wait=adm - sub,
            )
            self._marks.pop(rid, None)

    # ---------------- drain ----------------

    def drain(self):
        """Stop intake and hand queued (and mid-chunked-prefill)
        requests back to the router."""
        self.drained = True
        moved = self.scheduler.drain()
        for req in moved:
            self._marks.pop(req.rid, None)
        return moved

    def undrain(self) -> None:
        """Reopen intake after a drain — soak churn cycles an engine out
        (drain, requeue elsewhere) and back in without rebuilding it."""
        self.drained = False

    def summary(self) -> dict:
        s = self.scheduler.stats
        return {
            "engine": self.engine_id,
            "role": self.role,
            "clock_s": round(self.clock, 6),
            "completed": s.completed,
            "handoffs": s.handoffs,
            "prefill_steps": s.prefill_steps,
            "prefill_tokens": s.prefill_tokens,
            "prefix_hits": s.prefix_hits,
            "prefix_hit_tokens": s.prefix_hit_tokens,
            "prefix_hit_rate": round(s.prefix_hit_rate, 4),
            "shared_blocks_peak": s.shared_blocks_peak,
            "cached_blocks": self.scheduler.pool.cached_blocks,
            "decode_steps": s.decode_steps,
            "generated_tokens": s.generated_tokens,
            "expert_tokens": s.expert_tokens,
            "accepted_tokens": s.accepted_tokens,
            "draft_tokens": s.draft_tokens,
            "verify_steps": s.verify_steps,
            "accepted_per_step": round(s.accepted_per_step, 4),
            "pool_utilization": round(s.steady_state_utilization, 4),
            "spans": self.spans.n_spans,
            "slo": self.slo_monitor.summary(now=self.clock),
            "mem": self.mem_monitor.summary(now=self.clock),
            "fragmentation": self.scheduler.pool.fragmentation_report(),
        }
