"""Multi-engine fleet serving (ISSUE 4).

The single-engine reproduction (``runtime.scheduler`` over
``runtime.kv_pool``) scales out here: N engine replicas behind a router
(``cluster.router``), optionally split into prefill and decode roles
with KV-block handoff and GALS-ratio provisioning (``cluster.disagg``),
driven by a seed-deterministic synthetic trace with TTFT/TPOT/goodput
SLO accounting (``cluster.traffic``). Engines run the real model on a
roofline-calibrated virtual clock (``cluster.engine``), so fleet
speedups gate in CI as deterministically as token equivalence does.
"""

from repro.runtime.cluster.disagg import (
    DisaggCluster,
    RoleRates,
    measured_role_rates,
    provision_split,
)
from repro.runtime.cluster.engine import Engine, StepCostModel
from repro.runtime.cluster.router import FleetCluster, FleetRunResult, Router
from repro.runtime.memledger import MemLedger, MemPolicy, MemPressureMonitor
from repro.runtime.spans import SLOMonitor, SpanRecorder, VirtualClock
from repro.runtime.cluster.traffic import (
    ClientRequest,
    RequestTiming,
    SloPolicy,
    SloReport,
    TrafficSpec,
    slo_report,
    synthesize,
)

__all__ = [
    "ClientRequest",
    "DisaggCluster",
    "Engine",
    "FleetCluster",
    "FleetRunResult",
    "MemLedger",
    "MemPolicy",
    "MemPressureMonitor",
    "RequestTiming",
    "RoleRates",
    "Router",
    "SLOMonitor",
    "SloPolicy",
    "SloReport",
    "SpanRecorder",
    "StepCostModel",
    "TrafficSpec",
    "VirtualClock",
    "measured_role_rates",
    "provision_split",
    "slo_report",
    "synthesize",
]
