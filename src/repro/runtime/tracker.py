"""Unified serve observability: one tracker, one record per serve round.

The paper's argument is only as good as its measurements — FCMP (§IV-V)
is sold entirely on measured utilization/throughput bands, and the
serving reproduction had grown four ad-hoc stats surfaces to mirror
that: ``SchedulerStats``, ``KVPool.stats()``, ``PrefixCache.stats()``
and ``Engine.summary()``. This module replaces their ad-hoc consumption
with a single append-only stream: every scheduler round emits exactly
one structured record that merges the scheduler's counter *deltas* since
the previous record with the pool/cache *gauges* at emission time (and,
under a fleet engine, the engine id and post-round virtual clock).

The interface is levanter's tracker shape: ``log_hyperparameters`` once
per run, step-keyed ``log_metrics`` per round, ``finish`` at shutdown.
Backends: ``JsonlTracker`` (one JSON object per line — greppable,
mergeable by ``benchmarks/merge_runs.py``), ``MemoryTracker`` (tests and
in-process replay checks), ``NullTracker`` (explicit no-op), and
``CompositeTracker`` (fan-out, e.g. JSONL to disk + memory for asserts).

Because per-round counters are emitted as deltas, the stream is
*replayable*: summing a run's records (``replay_summary``) reproduces
the scheduler/engine totals exactly — the soak harness's acceptance
check, and the property that makes a trace a complete account of the
run rather than a lossy sample of it.

Record schema (``kind="metrics"``, one per round):

    round                 scheduler round index (the step key)
    queued/queued_tokens  intake backlog at end of round   [gauge]
    active                busy decode lanes                [gauge]
    committed_tokens      admitted token commitment        [gauge]
    prefill_steps/_tokens, decode_steps, generated_tokens,
    completed, handoffs, prefix_hits, prefix_hit_tokens,
    expert_tokens (moe routed token-expert slots)          [deltas]
    moe_expert_entropy    normalized expert-load entropy   [gauge, moe]
    moe_hot_expert_fraction  routed tokens hitting a
                          residency-pinned expert          [gauge, moe]
    ttfts                 wall-clock TTFTs recorded this round
    pool_*                KVPool gauges (utilization, free/held/shared/
                          cached/evictable blocks) + cumulative
                          alloc/freed/cow counters
    cache_*               radix-cache gauges when a cache is attached
    engine/role/clock_s   added by ``cluster.Engine`` (virtual clock
                          *after* the round's cost is charged)
    events                engine-level (kind, rid, t_virtual) milestone
                          events collected this round (admit/first/
                          done/handoff)

A second record kind, ``kind="span"`` (emitted via ``log_spans`` by
``runtime.spans.SpanRecorder``), interleaves per-request lifecycle
spans — {rid, phase, t0, t1, engine?, role?, attrs...} — in the same
stream; ``replay_summary`` ignores them and ``runtime.spans``'s
``validate_trace`` checks their exact-decomposition contract.

A third kind, ``kind="mem"`` (emitted via ``log_mem`` by
``runtime.memledger.MemLedger``), interleaves event-sourced KV-pool
mutation deltas — {op, owner, t, d_held_blocks, d_bytes, ...} — plus
``op="attach"`` absolute baselines and ``op="reserve"`` static byte
reservations (weight-resident VMEM, the expert stream ring).
``replay_summary`` ignores them; ``runtime.memledger.validate_ledger``
checks their integration contract against the per-round pool gauges.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import numpy as np


def jsonable(obj: Any) -> Any:
    """Recursively coerce numpy scalars/arrays and tuples for json."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, (bool, int, float, str)) or obj is None:
        return obj
    return str(obj)


class Tracker:
    """Interface: ``log_hyperparameters`` once, ``log_metrics`` per step."""

    def log_hyperparameters(self, hparams: dict) -> None:
        raise NotImplementedError

    def log_metrics(self, metrics: dict, *, step: int) -> None:
        raise NotImplementedError

    def log_spans(self, spans: list[dict]) -> None:
        # optional: per-request lifecycle spans (runtime.spans). Default
        # no-op so pre-span backends keep working unchanged.
        pass

    def log_mem(self, records: list[dict]) -> None:
        # optional: memory-ledger deltas (runtime.memledger). Default
        # no-op so pre-ledger backends keep working unchanged.
        pass

    def finish(self) -> None:  # optional flush/close
        pass


class NullTracker(Tracker):
    """Discards everything (the default for tests and bare schedulers)."""

    def log_hyperparameters(self, hparams: dict) -> None:
        pass

    def log_metrics(self, metrics: dict, *, step: int) -> None:
        pass


class MemoryTracker(Tracker):
    """Keeps records in-process: replay checks without file round-trips."""

    def __init__(self):
        self.hparams: list[dict] = []
        self.records: list[dict] = []
        self.spans: list[dict] = []
        self.mems: list[dict] = []
        # every record in arrival order, kind-tagged — in-process tests
        # validate cross-kind interleaving (mem-before-metrics ordering,
        # full-stream ledger integration) without a file round-trip
        self.stream: list[dict] = []

    def log_hyperparameters(self, hparams: dict) -> None:
        self.hparams.append(dict(hparams))
        self.stream.append({"kind": "hparams", **hparams})

    def log_metrics(self, metrics: dict, *, step: int) -> None:
        rec = {**metrics, "step": step}
        self.records.append(rec)
        self.stream.append({"kind": "metrics", **rec})

    def log_spans(self, spans: list[dict]) -> None:
        tagged = [{"kind": "span", **s} for s in spans]
        self.spans.extend(tagged)
        self.stream.extend(tagged)

    def log_mem(self, records: list[dict]) -> None:
        tagged = [{"kind": "mem", **m} for m in records]
        self.mems.extend(tagged)
        self.stream.extend(tagged)


class JsonlTracker(Tracker):
    """Appends one JSON object per line to ``path``.

    Lines carry ``kind`` ("hparams" or "metrics") so a mixed stream from
    several engines sharing one tracker stays self-describing.
    """

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "a")
        self.n_records = 0

    def log_hyperparameters(self, hparams: dict) -> None:
        self._write({"kind": "hparams", **jsonable(hparams)})

    def log_metrics(self, metrics: dict, *, step: int) -> None:
        self._write({"kind": "metrics", "step": step, **jsonable(metrics)})
        self.n_records += 1

    def log_spans(self, spans: list[dict]) -> None:
        for s in spans:
            self._write({"kind": "span", **jsonable(s)})

    def log_mem(self, records: list[dict]) -> None:
        for m in records:
            self._write({"kind": "mem", **jsonable(m)})

    def _write(self, obj: dict) -> None:
        self._fh.write(json.dumps(obj) + "\n")
        self._fh.flush()

    def finish(self) -> None:
        self._fh.close()


class CompositeTracker(Tracker):
    """Fans every call out to several backends."""

    def __init__(self, *trackers: Tracker):
        self.trackers = trackers

    def log_hyperparameters(self, hparams: dict) -> None:
        for t in self.trackers:
            t.log_hyperparameters(hparams)

    def log_metrics(self, metrics: dict, *, step: int) -> None:
        for t in self.trackers:
            t.log_metrics(metrics, step=step)

    def log_spans(self, spans: list[dict]) -> None:
        for t in self.trackers:
            t.log_spans(spans)

    def log_mem(self, records: list[dict]) -> None:
        for t in self.trackers:
            t.log_mem(records)

    def finish(self) -> None:
        for t in self.trackers:
            t.finish()


def read_jsonl(path) -> list[dict]:
    """Load a ``JsonlTracker`` stream back into records."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# counter keys whose per-round values are deltas (summable on replay)
DELTA_KEYS = (
    "prefill_steps",
    "prefill_tokens",
    "decode_steps",
    "generated_tokens",
    "completed",
    "handoffs",
    "prefix_hits",
    "prefix_hit_tokens",
    "expert_tokens",
    "accepted_tokens",
    "draft_tokens",
    "verify_steps",
)

# SchedulerStats fields that are deliberately NOT replayed as deltas:
# round counts are the record count itself, ttfts ride their own list,
# util samples / peaks / wall decode time are gauges or derived values.
# Everything else on SchedulerStats MUST be in DELTA_KEYS — see
# ``delta_coverage_gaps`` (the drift guard that makes a new counter
# field a named test failure instead of a silent replay mismatch, the
# way ``expert_tokens`` nearly slipped through in PR 7).
NON_DELTA_STATS_FIELDS = frozenset(
    {
        "rounds",
        "ttfts",
        "util_samples",
        "util_samples_any",
        "shared_blocks_peak",
        "decode_time",
    }
)


def delta_coverage_gaps(stats_cls=None) -> list[str]:
    """Names of ``SchedulerStats`` fields covered by neither DELTA_KEYS
    nor the declared non-delta exemptions. Non-empty means a stats field
    was added without extending the replay contract."""
    import dataclasses

    if stats_cls is None:
        from repro.runtime.scheduler import SchedulerStats as stats_cls
    return [
        f.name
        for f in dataclasses.fields(stats_cls)
        if f.name not in DELTA_KEYS and f.name not in NON_DELTA_STATS_FIELDS
    ]


def replay_summary(records: list[dict], engine: int | None = None) -> dict:
    """Reconstruct run totals from a metrics stream.

    Sums the delta counters (and concatenates TTFT events) across the
    selected records; the result must equal the live
    ``SchedulerStats``/``Engine.summary()`` totals — the tracker's
    conservation property. ``engine`` filters a multi-engine stream.
    """
    rows = [
        r
        for r in records
        if r.get("kind", "metrics") == "metrics"
        and (engine is None or r.get("engine") == engine)
    ]
    out: dict = {k: 0 for k in DELTA_KEYS}
    ttfts: list[float] = []
    for r in rows:
        for k in DELTA_KEYS:
            out[k] += r.get(k, 0)
        ttfts.extend(r.get("ttfts", ()))
    out["rounds"] = len(rows)
    out["ttfts"] = ttfts
    out["mean_ttft"] = sum(ttfts) / len(ttfts) if ttfts else 0.0
    if rows:
        last = rows[-1]
        for k in (
            "clock_s",
            "pool_utilization",
            "pool_cached_blocks",
            "moe_expert_entropy",
            "moe_hot_expert_fraction",
        ):
            if k in last:
                out[k] = last[k]
    return out
