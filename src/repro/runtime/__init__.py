from repro.runtime.kv_pool import KVPool  # noqa: F401
from repro.runtime.memledger import (  # noqa: F401
    MemLedger,
    MemPolicy,
    MemPressureMonitor,
    summarize_ledger,
    validate_ledger,
)
from repro.runtime.scheduler import (  # noqa: F401
    PrefillHandoff,
    Request,
    RequestState,
    Scheduler,
)
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.tracker import (  # noqa: F401
    CompositeTracker,
    JsonlTracker,
    MemoryTracker,
    NullTracker,
    Tracker,
    read_jsonl,
    replay_summary,
)
from repro.runtime.train import TrainLoop, TrainLoopConfig  # noqa: F401
