from repro.runtime.kv_pool import KVPool  # noqa: F401
from repro.runtime.scheduler import Request, RequestState, Scheduler  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.train import TrainLoop, TrainLoopConfig  # noqa: F401
