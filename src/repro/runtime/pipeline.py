"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Optional PP stage for very deep archs (DESIGN.md §5): the layer stack is
split into S contiguous stages along the mesh 'stage' axis; microbatches
stream through with the standard (S + M - 1)-slot schedule. Activations
move stage-to-stage with ``jax.lax.ppermute`` — the JAX-native rendering of
the paper's producer/consumer stream decoupling, one level up the stack
(GALS islands -> pipeline stages, async FIFOs -> permute buffers).

The implementation processes the classic skewed schedule: at slot t, stage
s computes microbatch (t - s). We run S + M - 1 slots of compute on every
stage (idle slots compute on zeros — the pipeline bubble, visible in the
roofline as the (S-1)/(M+S-1) utilisation factor).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(
    layer_stack_fn: Callable,
    stage_params,
    x_microbatches: jnp.ndarray,
    *,
    mesh,
    axis: str = "stage",
):
    """Run microbatches through pipeline stages.

    layer_stack_fn(stage_params_slice, x) -> x : one stage's compute.
    stage_params: pytree with leading axis = n_stages (sharded over axis).
    x_microbatches: (M, mb, ...) microbatched input, replicated.
    Returns (M, mb, ...) outputs from the last stage.
    """
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]
    n_slots = m + n_stages - 1

    def stage_prog(params_slice, xs):
        stage = jax.lax.axis_index(axis)
        params_local = jax.tree.map(lambda v: v[0], params_slice)
        buf = jnp.zeros_like(xs[0])  # incoming activation register
        outs = jnp.zeros_like(xs)

        def slot(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t; others take the permuted input
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(stage == 0, xs[mb_idx], buf)
            y = layer_stack_fn(params_local, x_in)
            # forward the result to the next stage
            buf_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)]
            )
            # last stage records its finished microbatch (t - (S-1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            valid = (t >= n_stages - 1) & (stage == n_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0
                ),
                lambda o: o,
                outs,
            )
            return (buf_next, outs), None

        (buf, outs), _ = jax.lax.scan(
            slot, (buf, outs), jnp.arange(n_slots)
        )
        # broadcast the last stage's outputs to every stage replica
        # (ppermute is a partial permutation; broadcast = masked psum)
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    from repro import compat

    fn = compat.shard_map(
        stage_prog,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(stage_params, x_microbatches)
