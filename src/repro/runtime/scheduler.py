"""Continuous-batching request scheduler over a shared KV pool.

Request lifecycle: QUEUED -> PREFILL -> DECODE -> DONE. Admission is
token-budget bound (the sum of committed prompt+generation tokens across
in-flight requests never exceeds ``token_budget``) and pool-bound (the
``KVPool`` must hold the request's full block commitment). Prefill is one
batched full-sequence step per request (time-to-first-token is a single
step, not prompt_len serve steps); decode lanes run the pool-indexed
paged step, each lane at its own depth — no lockstep shared cache length.

The frequency-compensation knob: ``decode_per_round`` (R_F) is how many
decode steps run per admission/prefill round. It is the serving Eq. 2 of
``core.gals``: a pool serving H_B co-resident requests through one
physical memory sustains decode throughput iff the decode domain gets
R_F >= H_B / N_ports rounds for every round the admission/prefill domain
steals — so the default is ``ceil(required_rf(slots))``. R_F = 1 is a
prefill-heavy schedule (fast admission, decode throughput dips); large
R_F starves admission (TTFT grows) the way an under-clocked memory
domain starves the paper's compute pipeline.
"""

from __future__ import annotations

import dataclasses
import enum
import functools
import math
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gals import required_rf
from repro.runtime.tracker import DELTA_KEYS
from repro.models.config import (
    CHUNKABLE_FAMILIES,
    PREFIX_CACHE_FAMILIES,
    ModelConfig,
)
from repro.models.lm import (
    SamplingParams,
    init_ssm_lane_state,
    sample_logits,
)
from repro.runtime.kv_pool import KVPool
from repro.runtime.speculative import SPEC_FAMILIES, LaneDraft
from repro.runtime.steps import (
    make_chunk_prefill_step,
    make_hybrid_suffix_prefill_step,
    make_paged_serve_step,
    make_pool_prefill_step,
    make_verify_step,
)


# jit wrappers cached per config so schedulers (and benchmark A/B runs)
# share compilations instead of retracing per instance
@functools.lru_cache(maxsize=None)
def _jitted_prefill(cfg: ModelConfig):
    return jax.jit(make_pool_prefill_step(cfg))


@functools.lru_cache(maxsize=None)
def _jitted_decode(cfg: ModelConfig):
    if cfg.family == "hybrid":
        # hybrid signature carries the per-lane SSM state (argnum 6) in
        # addition to the two pool halves
        return jax.jit(make_paged_serve_step(cfg), donate_argnums=(2, 3, 6))
    return jax.jit(make_paged_serve_step(cfg), donate_argnums=(2, 3))


@functools.lru_cache(maxsize=None)
def _jitted_chunk_prefill(cfg: ModelConfig):
    return jax.jit(make_chunk_prefill_step(cfg), donate_argnums=(2, 3))


@functools.lru_cache(maxsize=None)
def _jitted_verify(cfg: ModelConfig):
    return jax.jit(make_verify_step(cfg), donate_argnums=(2, 3))


@functools.lru_cache(maxsize=None)
def _jitted_hybrid_suffix(cfg: ModelConfig):
    return jax.jit(
        make_hybrid_suffix_prefill_step(cfg), donate_argnums=(2, 3, 8)
    )


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    HANDOFF = "handoff"  # prefilled here, decoded on another engine
    DONE = "done"


@dataclasses.dataclass
class PrefillHandoff:
    """A prefilled request leaving a prefill-role engine.

    The KV payload is serialized through the source pool's block ids:
    ``k``/``v`` hold the request's rows gathered in block order (shape
    (L, n_tokens, n_kv, hd)), and ``block_ids`` records which physical
    blocks produced them — the wire format is block-granular, mirroring
    the allocator, so a zero-copy transport could ship whole blocks.
    Hybrid requests additionally ship ``lane_state`` — the per-request
    SSM decode state (leaves (L, 1, ...) as in ``init_ssm_lane_state``)
    at the prompt end — so zamba2 disaggregates prefill/decode too.
    """

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    first_token: int
    n_tokens: int
    block_ids: tuple[int, ...]
    block_tokens: int
    k: np.ndarray
    v: np.ndarray
    lane_state: dict | None = None

    @property
    def kv_bytes(self) -> int:
        lane = (
            sum(leaf.nbytes for leaf in jax.tree.leaves(self.lane_state))
            if self.lane_state is not None
            else 0
        )
        return self.k.nbytes + self.v.nbytes + lane

    @property
    def total_tokens(self) -> int:
        return self.n_tokens + self.max_new_tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int
    state: RequestState = RequestState.QUEUED
    output: list[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    states_seen: list[RequestState] = dataclasses.field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new_tokens

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    def _enter(self, state: RequestState) -> None:
        self.state = state
        self.states_seen.append(state)


@dataclasses.dataclass
class SchedulerStats:
    completed: int = 0
    generated_tokens: int = 0
    prefill_steps: int = 0
    prefill_tokens: int = 0  # charged for the *unmatched* suffix only
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0  # prompt tokens served from cached blocks
    decode_steps: int = 0
    handoffs: int = 0
    expert_tokens: int = 0  # moe: routed (token, expert) slots, all layers
    # speculative decode: tokens emitted by verify steps (1..k each),
    # drafter proposals offered, and batched verify calls run
    accepted_tokens: int = 0
    draft_tokens: int = 0
    verify_steps: int = 0
    rounds: int = 0
    ttfts: list[float] = dataclasses.field(default_factory=list)
    util_samples: list[float] = dataclasses.field(default_factory=list)
    util_samples_any: list[float] = dataclasses.field(default_factory=list)
    shared_blocks_peak: int = 0
    decode_time: float = 0.0

    @property
    def mean_ttft(self) -> float:
        return sum(self.ttfts) / len(self.ttfts) if self.ttfts else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of submitted prompt tokens served from the cache
        (hit tokens / (hit tokens + prefilled tokens))."""
        total = self.prefix_hit_tokens + self.prefill_tokens
        return self.prefix_hit_tokens / total if total else 0.0

    @property
    def accepted_per_step(self) -> float:
        """Mean tokens emitted per verify step (1.0 = no draft ever
        accepted — speculative decode's whole win is this number)."""
        if not self.verify_steps:
            return 0.0
        return self.accepted_tokens / self.verify_steps

    @property
    def steady_state_utilization(self) -> float:
        """Mean pool utilization over decode steps with all lanes busy;
        if the trace never fills every lane (requests < slots), fall back
        to steps with any lane busy rather than reporting 0."""
        samples = self.util_samples or self.util_samples_any
        if not samples:
            return 0.0
        return sum(samples) / len(samples)

class Scheduler:
    """Drives requests through a fixed set of decode lanes over a KVPool."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        pool: KVPool,
        *,
        slots: int,
        max_len: int,
        token_budget: int | None = None,
        decode_per_round: int | None = None,
        sample: Callable[[np.ndarray], np.ndarray] | None = None,
        sampling: SamplingParams | None = None,
        prefill_chunk: int | None = None,
        residency=None,
        handoff: Callable[[PrefillHandoff], None] | None = None,
        prefix_cache=None,
        speculative=None,
        tracker=None,
        spans=None,
        ledger=None,
        mem_monitor=None,
    ):
        self.cfg = cfg
        self.params = params
        self.pool = pool
        self.slots = slots
        self.max_len = max_len
        self.s_max = pool.max_rows(max_len)
        usable_tokens = pool.usable_blocks * pool.block_tokens
        self.token_budget = min(token_budget or usable_tokens, usable_tokens)
        # serving Eq. 2: R_F rounds of decode per admission round
        self.decode_per_round = decode_per_round or max(
            1, math.ceil(required_rf(slots))
        )
        # ``sample`` (a batched (B, V) -> (B,) callable) overrides the
        # seed-deterministic per-request sampler; default greedy either way
        self.sample = sample
        self.sampling = sampling or SamplingParams()
        # admission compute budget per prefill chunk: prompts longer than
        # this are split across rounds instead of monopolizing one round
        self.prefill_chunk = min(
            prefill_chunk or self.token_budget, self.token_budget
        )
        self.residency = residency
        # prefill-role engines export prefilled KV instead of decoding;
        # hybrid payloads additionally carry the SSM lane-state snapshot
        self.handoff = handoff
        # radix prefix cache (runtime.prefix_cache) over this pool: new
        # requests adopt their longest cached prefix's blocks and prefill
        # only the unmatched suffix
        if prefix_cache is not None:
            if cfg.family not in PREFIX_CACHE_FAMILIES:
                raise ValueError(
                    f"prefix caching covers {PREFIX_CACHE_FAMILIES}; "
                    f"family {cfg.family!r} cannot prefill a bare suffix"
                )
            if prefix_cache.pool is not pool:
                raise ValueError("prefix cache must index this pool")
        self.prefix_cache = prefix_cache
        # speculative decode (runtime.speculative.Speculator): a drafter
        # proposes depth-k chains per decode lane; one batched verify
        # call scores them against the pool and the longest accepted
        # prefix lands — token-identical to plain decode because the
        # verifier samples with the same (seed, rid, position) rng
        if speculative is not None and cfg.family not in SPEC_FAMILIES:
            raise ValueError(
                f"speculative decoding covers {SPEC_FAMILIES}; family "
                f"{cfg.family!r} has no draft-chain rollback path"
            )
        self.speculative = speculative
        self._verify = _jitted_verify(cfg) if speculative is not None else None
        self._prefill = _jitted_prefill(cfg)
        # hybrid chunks through the carried-state suffix step below, not
        # the stateless attention chunk step
        self._chunk_prefill = (
            _jitted_chunk_prefill(cfg)
            if cfg.family in CHUNKABLE_FAMILIES and cfg.family != "hybrid"
            else None
        )
        self._hybrid_suffix = (
            _jitted_hybrid_suffix(cfg) if cfg.family == "hybrid" else None
        )
        if residency is not None:
            from repro.runtime.residency.executor import cached_budgeted_step

            self._decode = cached_budgeted_step(cfg, residency)
        else:
            self._decode = _jitted_decode(cfg)
        self._chunk_cursor: dict[int, int] = {}
        # hybrid chunked prefill: the carried SSD/conv state between a
        # long prompt's chunks (leaves (L, 1, ...)), keyed like the
        # cursor; installed into the lane slot on the final chunk
        self._chunk_lane: dict[int, dict] = {}
        # hybrid: fixed-size per-lane SSM decode state, resident next to
        # the pool (the pool pages only the shared attention blocks' KV)
        self._lane_state = (
            init_ssm_lane_state(cfg, slots) if cfg.family == "hybrid" else None
        )
        self.queue: deque[Request] = deque()
        self.requests: dict[int, Request] = {}
        self.active: list[int | None] = [None] * slots
        self._token = np.zeros((slots, 1), np.int32)
        self._lengths = np.zeros((slots,), np.int32)
        # per-lane physical row tables, updated on admission / block
        # growth / completion only (not rebuilt every decode step); the
        # device copy is re-uploaded only when an event dirties the table
        self._row_table = np.tile(pool.scratch_rows(self.s_max), (slots, 1))
        self._row_table_dev = jnp.asarray(self._row_table)
        self._table_dirty = False
        self._next_rid = 0
        self.stats = SchedulerStats()
        # moe expert-load observability: cumulative per-(layer, expert)
        # routed-token tally fed by every serve step's counts output;
        # ``_emit_round`` derives the load-entropy / hot-expert gauges
        # from it. ``_expert_resident`` is the residency plan's pinned
        # (L, E) set — with no plan every expert is resident.
        self._expert_counts = (
            np.zeros((cfg.n_layers, cfg.n_experts), np.float64)
            if cfg.family == "moe"
            else None
        )
        self._expert_resident = None
        if cfg.family == "moe" and residency is not None:
            self._expert_resident = ~np.asarray(
                residency.expert_stream_mask(cfg), bool
            )
        # unified observability (runtime.tracker): one record per round,
        # emitted either straight to ``tracker`` or through ``on_round``
        # (a fleet Engine installs the hook so the record also carries
        # the post-round virtual clock). Counters are emitted as deltas
        # against ``_emit_base`` so replaying a stream reproduces the
        # totals exactly, wherever the counters were advanced.
        self.tracker = tracker
        self.on_round: Callable[[dict], None] | None = None
        self._emit_base: dict[str, int] = {}
        self._emit_ttft_base = 0
        # request-lifecycle spans (runtime.spans.SpanRecorder): queue /
        # prefix_lookup / prefill chunk / decode slice per request, with
        # exact-decomposition tiling. A fleet Engine passes a recorder on
        # its virtual clock; bare schedulers may pass a wall-clock one.
        self.spans = spans
        # virtual-time charge hook: a fleet Engine installs this so each
        # unit of work advances the virtual clock at the instant it
        # happens (charge("prefill", tokens=, steps=) / ("decode",
        # steps=)) — per-request spans then carry true phase boundaries
        # instead of round-granular ones.
        self.charge: Callable[..., None] | None = None
        # open decode slices: rid -> [t_slice_start, steps] for the
        # contiguous decode steps a lane ran this round (one span each)
        self._decode_open: dict[int, list] = {}
        # event-sourced memory ledger (runtime.memledger.MemLedger):
        # every pool mutation emits a kind="mem" delta record; the round
        # emission syncs + flushes it *before* the gauge record so
        # integrated deltas equal the gauges at every round boundary
        self.ledger = ledger
        if ledger is not None and ledger.pool is None:
            ledger.attach(pool)
        # streaming pressure signal (runtime.memledger.MemPressureMonitor)
        # fed once per round — the elastic-fleet admission/scale input
        self.mem_monitor = mem_monitor
        if ledger is not None and residency is not None:
            # static owners: the weight-resident VMEM set and the expert
            # stream ring buffer, so byte attribution covers the whole
            # accelerator budget rather than just the KV pool
            ledger.reserve(
                "weight-resident",
                residency.resident_bytes,
                blocks=residency.resident_block_count,
            )
            ledger.reserve(
                "ring-slot", residency.ring_bytes, depth=residency.stream_ahead
            )
        if tracker is not None:
            hp = {
                "surface": "scheduler",
                "arch": cfg.name,
                "family": cfg.family,
                "slots": slots,
                "max_len": max_len,
                "token_budget": self.token_budget,
                "decode_per_round": self.decode_per_round,
                "prefill_chunk": self.prefill_chunk,
                "block_tokens": pool.block_tokens,
                "pool_blocks": pool.usable_blocks,
                "prefix_cache": prefix_cache is not None,
            }
            if speculative is not None:
                hp["speculate"] = speculative.name
                hp["spec_depth"] = speculative.depth
            if residency is not None:
                hp["residency"] = residency.summary()
            tracker.log_hyperparameters(hp)

    # ---------------- submission ----------------

    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        *,
        rid: int | None = None,
        t_submit: float | None = None,
    ) -> int:
        """Queue a request. ``rid`` lets a fleet router assign globally
        unique ids across engines — the sampler is keyed on (seed, rid,
        position), so a request keeps its exact token stream wherever it
        lands (and across a drain/requeue). ``t_submit`` anchors the
        request's queue span on the caller's clock (a router passes the
        client arrival time, so queue wait is measured from submission,
        not admission)."""
        total = len(prompt) + max_new_tokens
        if len(prompt) < 1 or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens >= 1")
        if total > self.max_len:
            raise ValueError(
                f"request needs {total} tokens > max_len {self.max_len}"
            )
        usable = self.pool.usable_blocks * self.pool.block_tokens
        if total > usable:
            raise ValueError(
                f"request needs {total} tokens > pool capacity {usable}"
            )
        # prompts over the admission token budget are legal for chunkable
        # families: they admit solo and prefill in budget-sized chunks
        # across rounds (hybrid carries the SSD/conv state between
        # chunks; moe routes dropless, so a chunk boundary is invisible
        # to the expert dispatch).
        if (
            total > self.token_budget
            and self.cfg.family not in CHUNKABLE_FAMILIES
        ):
            raise ValueError(
                f"request needs {total} tokens > token budget "
                f"{self.token_budget} ({self.cfg.family} prompts cannot "
                "chunk)"
            )
        if rid is None:
            rid = self._next_rid
        elif rid in self.requests:
            raise ValueError(f"request id {rid} already known")
        self._next_rid = max(self._next_rid, rid + 1)
        req = Request(rid, np.asarray(prompt, np.int32), max_new_tokens)
        req.t_submit = time.monotonic()
        req._enter(RequestState.QUEUED)
        self.queue.append(req)
        self.requests[rid] = req
        if self.spans is not None:
            self.spans.open(rid, "queue", t0=t_submit)
        return rid

    def drain(self) -> list[Request]:
        """Stop intake: pop and return every request this engine can
        still give up, so a router can requeue it elsewhere (sampling is
        rid-keyed, so the token stream survives the move).

        That covers the queue *and* any mid-flight chunked prefill: a
        request whose ``_chunk_cursor`` is live has a lane reserved and
        pool blocks partially written, but no token sampled yet — its
        blocks are released (refcounts make adopted prefix blocks safe),
        its cursor and carried hybrid chunk state dropped, and its lane
        returned, so the requeued request restarts cold with nothing
        leaked here. Decoding requests finish here normally (their
        sampled tokens exist only on this engine)."""
        out: list[Request] = []
        # aborted chunked prefills first: they are older than anything
        # still queued, and requeue order preserves FIFO fairness
        for slot, rid in enumerate(self.active):
            if rid is None or rid not in self._chunk_cursor:
                continue
            req = self.requests.pop(rid)
            del self._chunk_cursor[rid]
            self._chunk_lane.pop(rid, None)
            self.pool.release(rid)
            self.active[slot] = None
            self._token[slot, 0] = 0
            self._lengths[slot] = 0
            self._row_table[slot] = self.pool.scratch_rows(self.s_max)
            self._table_dirty = True
            req.output.clear()
            req._enter(RequestState.QUEUED)
            if self.spans is not None:
                self.spans.abort(rid, reason="drain")
            out.append(req)
        while self.queue:
            req = self.queue.popleft()
            del self.requests[req.rid]
            if self.spans is not None:
                self.spans.abort(req.rid, reason="drain")
            out.append(req)
        return out

    # ---------------- internals ----------------

    @property
    def committed_tokens(self) -> int:
        return sum(
            self.requests[r].total_tokens
            for r in self.active
            if r is not None
        )

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    # ---------------- sampling ----------------

    def _sample_one(self, req: Request, row: np.ndarray) -> int:
        """Next token for one request from its (V,) logits row.

        Seed-deterministic: the rng is keyed on (seed, rid, position), so
        a request's output never depends on lane placement or co-resident
        requests (the staggered-lane invariant extends to sampling).
        """
        if self.sample is not None:  # legacy batched override
            return int(self.sample(row[None, :])[0])
        sp = self.sampling
        rng = np.random.default_rng(
            np.random.SeedSequence([sp.seed, req.rid, len(req.output)])
        )
        return sample_logits(row, sp, rng)

    def _note_expert_counts(self, counts) -> None:
        """Fold one serve step's (L, E) routed-token tally into the run
        totals. Padded prompt rows and idle decode lanes route too (the
        dropless dispatch is per-token, so their routing is inert for
        outputs but still visible here) — the gauges are a load signal,
        not an exact busy-token count."""
        c = np.asarray(counts, np.float64)
        self._expert_counts += c
        self.stats.expert_tokens += int(c.sum())

    # ---------------- admission / prefill ----------------

    def _lane_snapshot(self, slot: int) -> dict:
        """Host copy of one lane's SSM state (leaves (L, 1, ...))."""
        return jax.tree.map(
            lambda v: np.asarray(v[:, slot : slot + 1]), self._lane_state
        )

    def _restore_lane(self, slot: int, snapshot: dict) -> None:
        self._lane_state = jax.tree.map(
            lambda dst, src: dst.at[:, slot].set(jnp.asarray(src)[:, 0]),
            self._lane_state,
            snapshot,
        )

    def _commit_prefix(self, slot: int, req: Request) -> None:
        """Index the freshly-prefilled prompt in the radix cache: full
        blocks become shared nodes; hybrids also anchor the SSM state at
        the exact prompt end (snapshot taken *before* decode advances
        it)."""
        if self.prefix_cache is None:
            return
        lane = (
            self._lane_snapshot(slot) if self.cfg.family == "hybrid" else None
        )
        self.prefix_cache.commit(
            req.prompt, self.pool.blocks_of(req.rid), lane_state=lane
        )

    def _start_decode(self, slot: int, req: Request, first: int) -> None:
        """Move a fully-prefilled request onto its decode lane — or, on a
        prefill-role engine, export it through the handoff hook instead."""
        req.t_first_token = time.monotonic()
        self.stats.ttfts.append(req.ttft)
        req.output.append(first)
        self._commit_prefix(slot, req)
        if self.handoff is not None:
            self._export_handoff(slot, req)
            return
        if self.spans is not None:
            # the first token exists the instant its prefill step ends
            self.spans.event("first", req.rid)
        req._enter(RequestState.DECODE)
        p = len(req.prompt)
        self._token[slot, 0] = first
        self._lengths[slot] = p
        self._row_table[slot] = self.pool.rows_of(req.rid, pad_to=self.s_max)
        self._table_dirty = True
        if self.speculative is not None:
            self._start_drafter(slot, req)
        if len(req.output) >= req.max_new_tokens:
            self._complete(slot)

    def _start_drafter(self, slot: int, req: Request) -> None:
        """Warm the drafter's lane for a request entering decode. A model
        drafter prefills the prompt through its own weights (the target's
        prefix-cache hits don't transfer), charged at the drafter's
        roofline and attributed to a ``draft`` span."""
        t0 = self.spans.now() if self.spans is not None else 0.0
        tokens, steps = self.speculative.start_lane(slot, req.prompt)
        if tokens or steps:
            if self.charge is not None:
                self.charge("draft", tokens=tokens, steps=steps)
            if self.spans is not None:
                self.spans.mark(
                    req.rid, "draft", t0, self.spans.now(), tokens=tokens
                )

    def _export_handoff(self, slot: int, req: Request) -> None:
        """Ship a prefilled request's KV (in block-id order) off-engine
        and reclaim its lane and blocks immediately."""
        rid = req.rid
        p = len(req.prompt)
        block_ids, ks, vs = self.pool.export_blocks(rid, n_tokens=p)
        payload = PrefillHandoff(
            rid=rid,
            prompt=req.prompt,
            max_new_tokens=req.max_new_tokens,
            first_token=req.output[0],
            n_tokens=p,
            block_ids=block_ids,
            block_tokens=self.pool.block_tokens,
            k=ks,
            v=vs,
            lane_state=(
                self._lane_snapshot(slot)
                if self.cfg.family == "hybrid"
                else None
            ),
        )
        req._enter(RequestState.HANDOFF)
        self.pool.release(rid)
        self.active[slot] = None
        self.stats.handoffs += 1
        self.handoff(payload)

    def import_prefilled(
        self, payload: PrefillHandoff, *, ready_at: float | None = None
    ) -> bool:
        """Adopt a request prefilled on another engine: admit its full
        token commitment, scatter the handed-off KV rows into the pool,
        and start its decode lane at the next position. Returns False
        (without side effects) when no lane / budget / pool room is free.
        ``ready_at`` is the payload's interconnect-ready time — the span
        timeline resumes there, so any import backlog shows as ``wait``.
        """
        if payload.rid in self.requests:
            raise ValueError(f"request {payload.rid} already on this engine")
        slot = self._free_slot()
        if slot is None:
            return False
        total = payload.total_tokens
        if self.committed_tokens + total > self.token_budget:
            return False
        if not self.pool.can_admit(total):
            return False
        if self.cfg.family == "hybrid" and payload.lane_state is None:
            raise ValueError(
                f"hybrid handoff of request {payload.rid} lacks the SSM "
                "lane state; decode cannot resume from KV rows alone"
            )
        req = Request(
            payload.rid,
            np.asarray(payload.prompt, np.int32),
            payload.max_new_tokens,
        )
        req.t_submit = time.monotonic()
        req.t_first_token = req.t_submit  # first token arrived with the KV
        req.output.append(payload.first_token)
        req._enter(RequestState.DECODE)
        self.requests[payload.rid] = req
        self.pool.admit(payload.rid, total)
        self.pool.write_prefill(
            payload.rid, payload.k, payload.v, n_tokens=payload.n_tokens
        )
        if self.cfg.family == "hybrid":
            self._restore_lane(slot, payload.lane_state)
        if self.prefix_cache is not None:
            # the imported KV warms this engine's cache too
            self.prefix_cache.commit(
                req.prompt,
                self.pool.blocks_of(payload.rid),
                lane_state=payload.lane_state,
            )
        self._next_rid = max(self._next_rid, payload.rid + 1)
        self.active[slot] = payload.rid
        self._token[slot, 0] = payload.first_token
        self._lengths[slot] = payload.n_tokens
        self._row_table[slot] = self.pool.rows_of(
            payload.rid, pad_to=self.s_max
        )
        self._table_dirty = True
        if self.spans is not None:
            now = self.spans.now()
            t_ready = now if ready_at is None else min(ready_at, now)
            self.spans.seed(payload.rid, t_ready)
            if now > t_ready:
                self.spans.mark(
                    payload.rid, "wait", t_ready, now, reason="import"
                )
            # the first token arrived with the payload: it becomes
            # client-visible the instant this engine adopts it
            self.spans.event("first", payload.rid, now)
        if self.speculative is not None:
            self._start_drafter(slot, req)
        if len(req.output) >= req.max_new_tokens:
            self._complete(slot)
        return True

    def _admit_one(self) -> bool:
        """Admit the head-of-queue request if resources allow.

        Prompts within the admission budget prefill in one batched step;
        longer (chunkable-family) prompts are admitted only when no other
        request holds budget, then stream through ``prefill_chunk``-sized
        rounds so admission never stalls decode for a whole long prompt.
        """
        if not self.queue:
            return False
        slot = self._free_slot()
        if slot is None:
            return False
        req = self.queue[0]
        over_budget = (
            self.committed_tokens + req.total_tokens > self.token_budget
        )
        if over_budget and self.committed_tokens > 0:
            return False
        if not self.pool.can_admit(req.total_tokens):
            return False
        self.queue.popleft()
        req._enter(RequestState.PREFILL)
        t_admit = 0.0
        if self.spans is not None:
            t_admit = self.spans.close(req.rid)  # ends the queue span
            self.spans.event("admit", req.rid, t_admit)
        self.pool.admit(req.rid, req.total_tokens)
        p = len(req.prompt)

        # radix-cache lookup: adopt the longest cached prefix's blocks
        # (refcount bump; COW for a partially-matched block) and charge
        # prefill only for the unmatched suffix
        match = None
        if self.prefix_cache is not None:
            match = self.prefix_cache.lookup(
                req.prompt, anchor=(self.cfg.family == "hybrid")
            )
        if match is not None:
            self.pool.adopt_prefix(
                req.rid, match.shared, match.tail_block, match.matched
            )
            self.stats.prefix_hits += 1
            self.stats.prefix_hit_tokens += match.matched
        if self.spans is not None and self.prefix_cache is not None:
            # zero-width on the virtual clock: the lookup is bookkeeping,
            # but its matched-prefix length is the tuning signal
            self.spans.mark(
                req.rid,
                "prefix_lookup",
                t_admit,
                t_admit,
                matched=match.matched if match is not None else 0,
                hit=match is not None,
            )

        if self.cfg.family in CHUNKABLE_FAMILIES and (
            match is not None or p > self.prefill_chunk
        ):
            # chunked prefill: reserve the lane now, feed chunks per
            # round, starting past the matched prefix (0 on a miss).
            # Hybrid chunks resume the SSD/conv recurrence from the
            # carried state: the anchor's snapshot on a warm hit, the
            # zero state cold — a warm suffix within one chunk is
            # exactly the old single-shot suffix prefill.
            self.active[slot] = req.rid
            self._chunk_cursor[req.rid] = match.matched if match else 0
            if self.cfg.family == "hybrid":
                self._chunk_lane[req.rid] = (
                    jax.tree.map(jnp.asarray, match.lane_state)
                    if match is not None
                    else init_ssm_lane_state(self.cfg, 1)
                )
            self._prefill_one_chunk(slot)
            return True

        if self.cfg.family == "hybrid":
            # the hybrid SSD state integrates every position (a padded
            # tail would pollute the handed-over state), so hybrid
            # prefills unpadded — one trace per length. MoE buckets like
            # dense: dropless routing makes padded rows inert.
            bucket = p
        else:
            bucket = max(
                self.pool.block_tokens,
                -(-p // self.pool.block_tokens) * self.pool.block_tokens,
            )
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :p] = req.prompt
        t0 = self.spans.now() if self.spans is not None else 0.0
        if self.cfg.family == "hybrid":
            logits, ks, vs, lane = self._prefill(
                self.params, jnp.asarray(padded), p - 1
            )
            # the request's post-prompt SSM state moves into its lane slot
            self._lane_state = jax.tree.map(
                lambda dst, src: dst.at[:, slot].set(src[:, 0]),
                self._lane_state,
                lane,
            )
        elif self.cfg.family == "moe":
            logits, ks, vs, counts = self._prefill(
                self.params, jnp.asarray(padded), p - 1
            )
            self._note_expert_counts(counts)
        else:
            logits, ks, vs = self._prefill(
                self.params, jnp.asarray(padded), p - 1
            )
        self.pool.write_prefill(req.rid, ks[:, 0], vs[:, 0], n_tokens=p)
        self.stats.prefill_steps += 1
        self.stats.prefill_tokens += p
        if self.charge is not None:
            self.charge("prefill", tokens=p, steps=1)
        if self.spans is not None:
            self.spans.mark(
                req.rid, "prefill", t0, self.spans.now(), tokens=p
            )

        first = self._sample_one(req, np.asarray(logits[0, 0, :]))
        self.active[slot] = req.rid
        self._start_decode(slot, req, first)
        return True

    def _prefill_one_chunk(self, slot: int) -> None:
        """Run one ``prefill_chunk``-sized piece of a long prompt.

        Attention families pad the chunk to the fixed chunk width with
        scratch rows (one trace total). Hybrid chunks run *unpadded* —
        the SSD state integrates every fed position, so a padded tail
        would pollute the carried state — and thread ``_chunk_lane``
        through ``lm.prefill_suffix_paged_hybrid``: each chunk resumes
        the recurrence exactly where the previous one stopped, which is
        why chunked hybrid prefill is token-identical to single-shot.
        """
        rid = self.active[slot]
        req = self.requests[rid]
        c0 = self._chunk_cursor[rid]
        p = len(req.prompt)
        c = self.prefill_chunk
        n = min(c, p - c0)
        t0 = self.spans.now() if self.spans is not None else 0.0
        self.pool.note_tokens(rid, c0 + n)
        rows = self.pool.rows_of(rid)[c0 : c0 + n]
        row_table = self.pool.rows_of(rid, pad_to=self.s_max)[None]
        if self.cfg.family == "hybrid":
            logits, self.pool.k, self.pool.v, new_lane = self._hybrid_suffix(
                self.params,
                jnp.asarray(req.prompt[c0 : c0 + n][None]),
                self.pool.k,
                self.pool.v,
                jnp.asarray(row_table),
                jnp.asarray(rows[None]),
                jnp.asarray(c0, jnp.int32),
                jnp.asarray(n - 1, jnp.int32),
                self._chunk_lane[rid],
            )
            self._chunk_lane[rid] = new_lane
        else:
            scratch = int(self.pool.scratch_rows(1)[0])
            write_rows = np.full((1, c), scratch, np.int32)
            write_rows[0, :n] = rows
            tokens = np.zeros((1, c), np.int32)
            tokens[0, :n] = req.prompt[c0 : c0 + n]
            out = self._chunk_prefill(
                self.params,
                jnp.asarray(tokens),
                self.pool.k,
                self.pool.v,
                jnp.asarray(row_table),
                jnp.asarray(write_rows),
                jnp.asarray(c0, jnp.int32),
                jnp.asarray(n - 1, jnp.int32),
            )
            if self.cfg.family == "moe":
                logits, self.pool.k, self.pool.v, counts = out
                self._note_expert_counts(counts)
            else:
                logits, self.pool.k, self.pool.v = out
        self.stats.prefill_steps += 1
        self.stats.prefill_tokens += n
        if self.charge is not None:
            self.charge("prefill", tokens=n, steps=1)
        if self.spans is not None:
            self.spans.mark(
                rid, "prefill", t0, self.spans.now(), tokens=n, chunk_start=c0
            )
        self._chunk_cursor[rid] = c0 + n
        if c0 + n >= p:
            del self._chunk_cursor[rid]
            if self.cfg.family == "hybrid":
                # the post-prompt state moves into the decode lane slot
                lane = self._chunk_lane.pop(rid)
                self._lane_state = jax.tree.map(
                    lambda dst, src: dst.at[:, slot].set(src[:, 0]),
                    self._lane_state,
                    lane,
                )
            first = self._sample_one(req, np.asarray(logits[0, 0, :]))
            self._start_decode(slot, req, first)

    def _commit_generated(self, slot: int, req: Request) -> None:
        """Re-index the finished conversation — prompt *plus* generated
        tokens — so a multi-turn follow-up (prompt = this prompt + this
        response + new text) adopts the whole transcript's blocks, not
        just the original prompt's. The last sampled token was never fed
        back through the model and has no KV row, so the committed
        sequence stops one short of the full output. Must run before
        ``pool.release``: the cache pins blocks of a live request."""
        if self.prefix_cache is None:
            return
        seq = np.concatenate(
            [req.prompt, np.asarray(req.output[:-1], np.int32)]
        )
        if len(seq) == len(req.prompt):
            return  # 1-token request: the prompt commit already covers it
        lane = (
            self._lane_snapshot(slot) if self.cfg.family == "hybrid" else None
        )
        self.prefix_cache.commit(
            seq, self.pool.blocks_of(req.rid), lane_state=lane
        )

    def _complete(self, slot: int) -> None:
        rid = self.active[slot]
        req = self.requests[rid]
        req._enter(RequestState.DONE)
        self._commit_generated(slot, req)
        if self.speculative is not None:
            self.speculative.release_lane(slot)
        self.pool.release(rid)
        self.active[slot] = None
        self._token[slot, 0] = 0
        self._lengths[slot] = 0
        self._row_table[slot] = self.pool.scratch_rows(self.s_max)
        self._table_dirty = True
        self.stats.completed += 1
        self.stats.generated_tokens += len(req.output)
        if self.spans is not None:
            t = self.spans.now()
            sl = self._decode_open.pop(rid, None)
            if sl is not None:
                # completion lands exactly on this decode slice's end
                self.spans.mark(rid, "decode", sl[0], t, steps=sl[1])
            self.spans.event("done", rid, t)
            self.spans.forget(rid)

    def _decoding(self, rid: int | None) -> bool:
        return (
            rid is not None
            and self.requests[rid].state is RequestState.DECODE
        )

    def _decode_step(self) -> None:
        t0_step = self.spans.now() if self.spans is not None else 0.0
        for i, rid in enumerate(self.active):
            if not self._decoding(rid):
                continue  # empty lane, or a mid-chunked-prefill reservation
            # room for the incoming token's KV row
            before = self.pool.blocks_held(rid)
            self.pool.note_tokens(rid, int(self._lengths[i]) + 1)
            if self.pool.blocks_held(rid) != before:
                self._row_table[i] = self.pool.rows_of(rid, pad_to=self.s_max)
                self._table_dirty = True
        if self._table_dirty:
            self._row_table_dev = jnp.asarray(self._row_table)
            self._table_dirty = False
        if self.cfg.family == "hybrid":
            logits, self.pool.k, self.pool.v, self._lane_state = self._decode(
                self.params,
                jnp.asarray(self._token),
                self.pool.k,
                self.pool.v,
                self._row_table_dev,
                jnp.asarray(self._lengths),
                self._lane_state,
            )
        elif self.cfg.family == "moe":
            logits, self.pool.k, self.pool.v, counts = self._decode(
                self.params,
                jnp.asarray(self._token),
                self.pool.k,
                self.pool.v,
                self._row_table_dev,
                jnp.asarray(self._lengths),
            )
            self._note_expert_counts(counts)
        else:
            logits, self.pool.k, self.pool.v = self._decode(
                self.params,
                jnp.asarray(self._token),
                self.pool.k,
                self.pool.v,
                self._row_table_dev,
                jnp.asarray(self._lengths),
            )
        self.stats.decode_steps += 1
        if self.charge is not None:
            self.charge("decode", steps=1)
        if self.spans is not None:
            # extend (or open) each participating lane's decode slice;
            # a lane's contiguous steps this round become one span
            for rid in self.active:
                if self._decoding(rid):
                    sl = self._decode_open.get(rid)
                    if sl is None:
                        self._decode_open[rid] = [t0_step, 1]
                    else:
                        sl[1] += 1
        rows = np.asarray(logits[:, 0, :])
        pool_st = self.pool.stats()
        util = pool_st.utilization
        self.stats.shared_blocks_peak = max(
            self.stats.shared_blocks_peak, pool_st.shared_blocks
        )
        self.stats.util_samples_any.append(util)
        if all(r is not None for r in self.active):
            self.stats.util_samples.append(util)
        for i, rid in enumerate(self.active):
            if not self._decoding(rid):
                continue
            req = self.requests[rid]
            nxt = self._sample_one(req, rows[i])
            req.output.append(nxt)
            self._token[i, 0] = nxt
            self._lengths[i] += 1
            if len(req.output) >= req.max_new_tokens:
                self._complete(i)

    def _spec_step(self) -> None:
        """One speculate-and-verify cycle over every decoding lane.

        The drafter proposes up to ``depth - 1`` tokens per lane; ONE
        batched ``verify_chunk_paged`` call then feeds each lane's
        pending token plus its proposals at the lane's own offsets,
        writing their KV rows and returning the target's logits at every
        chain position. Sampling position ``m`` with the non-speculative
        rng key (seed, rid, m) makes longest-accepted-prefix selection
        deterministic — and the output token-identical to plain decode,
        since each position's logits depend only on accepted tokens.
        Rejected rows cost nothing: ``end_draft`` pops the surplus
        blocks (owner="draft" in the ledger) and the stale rows are
        overwritten by the next chain before any unmasked gather.
        """
        lanes = [
            (i, rid)
            for i, rid in enumerate(self.active)
            if self._decoding(rid)
        ]
        if not lanes:
            return
        t0 = self.spans.now() if self.spans is not None else 0.0
        views: list[LaneDraft] = []
        k_eff: dict[int, int] = {}
        for i, rid in lanes:
            req = self.requests[rid]
            # never draft past the request's commitment: the chain ends
            # at row p + max_new - 1 at most, so begin_draft stays
            # within the admitted block budget
            k_eff[rid] = min(
                self.speculative.depth,
                req.max_new_tokens - len(req.output),
            )
            views.append(
                LaneDraft(
                    slot=i,
                    rid=rid,
                    pending=int(self._token[i, 0]),
                    out_len=len(req.output),
                    n_rows=int(self._lengths[i]),
                    history=np.concatenate(
                        [req.prompt, np.asarray(req.output, np.int32)]
                    ),
                )
            )
        kmax = max(k_eff.values())
        props: dict[int, np.ndarray] = {}
        if kmax > 1:
            proposed, draft_steps = self.speculative.propose(
                views, kmax, self.sampling
            )
            for v, row in zip(views, proposed):
                props[v.rid] = row
            self.stats.draft_tokens += sum(
                k_eff[rid] - 1 for _, rid in lanes
            )
            if self.charge is not None and draft_steps:
                self.charge("draft", steps=draft_steps)
        t1 = self.spans.now() if self.spans is not None else t0
        # room for every lane's chain rows: draft-class blocks, settled
        # (or fully returned) by end_draft after acceptance
        for i, rid in lanes:
            before = self.pool.blocks_held(rid)
            self.pool.begin_draft(rid, int(self._lengths[i]) + k_eff[rid])
            if self.pool.blocks_held(rid) != before:
                self._row_table[i] = self.pool.rows_of(
                    rid, pad_to=self.s_max
                )
                self._table_dirty = True
        if self._table_dirty:
            self._row_table_dev = jnp.asarray(self._row_table)
            self._table_dirty = False
        scratch = int(self.pool.scratch_rows(1)[0])
        tokens = np.zeros((self.slots, kmax), np.int32)
        write_rows = np.full((self.slots, kmax), scratch, np.int32)
        starts = np.zeros((self.slots,), np.int32)
        for i, rid in lanes:
            ke = k_eff[rid]
            n = int(self._lengths[i])
            tokens[i, 0] = self._token[i, 0]
            if ke > 1:
                tokens[i, 1:ke] = props[rid][: ke - 1]
            write_rows[i, :ke] = self.pool.rows_of(rid)[n : n + ke]
            starts[i] = n
        out = self._verify(
            self.params,
            jnp.asarray(tokens),
            self.pool.k,
            self.pool.v,
            self._row_table_dev,
            jnp.asarray(write_rows),
            jnp.asarray(starts),
        )
        if self.cfg.family == "moe":
            logits, self.pool.k, self.pool.v, counts = out
            self._note_expert_counts(counts)
        else:
            logits, self.pool.k, self.pool.v = out
        self.stats.verify_steps += 1
        if self.charge is not None:
            # one weight sweep plus the chain's extra compute tokens
            self.charge(
                "verify",
                steps=1,
                tokens=sum(k_eff.values()) - len(lanes),
            )
        t2 = self.spans.now() if self.spans is not None else t0
        if self.spans is not None:
            for i, rid in lanes:
                if kmax > 1:
                    self.spans.mark(
                        rid, "draft", t0, t1, tokens=k_eff[rid] - 1
                    )
                self.spans.mark(rid, "verify", t1, t2, depth=k_eff[rid])
        rows = np.asarray(logits)
        done_slots: list[int] = []
        for i, rid in lanes:
            req = self.requests[rid]
            ke = k_eff[rid]
            n0 = int(self._lengths[i])
            accepted = 0
            for j in range(ke):
                nxt = self._sample_one(req, rows[i, j])
                req.output.append(nxt)
                accepted += 1
                self._token[i, 0] = nxt
                if j < ke - 1 and nxt != int(props[rid][j]):
                    break  # correction token accepted, chain tail rejected
            self.stats.accepted_tokens += accepted
            self._lengths[i] = n0 + accepted
            before = self.pool.blocks_held(rid)
            self.pool.end_draft(rid, n0 + accepted)
            if self.pool.blocks_held(rid) != before:
                self._row_table[i] = self.pool.rows_of(
                    rid, pad_to=self.s_max
                )
                self._table_dirty = True
            self.speculative.accept(i, n0 + accepted)
            if len(req.output) >= req.max_new_tokens:
                done_slots.append(i)
        # sample pool pressure with every accept settled but finished
        # requests still resident (the decode-step analog)
        pool_st = self.pool.stats()
        self.stats.shared_blocks_peak = max(
            self.stats.shared_blocks_peak, pool_st.shared_blocks
        )
        self.stats.util_samples_any.append(pool_st.utilization)
        if all(r is not None for r in self.active):
            self.stats.util_samples.append(pool_st.utilization)
        for i in done_slots:
            self._complete(i)

    # ---------------- main loop ----------------

    def round(self) -> None:
        """One scheduler round: drain admissions, advance one chunk of any
        mid-prefill long prompt, then R_F decode steps (speculate-and-
        verify cycles when a drafter is installed)."""
        while self._admit_one():
            pass
        for i, rid in enumerate(self.active):
            if rid is not None and rid in self._chunk_cursor:
                self._prefill_one_chunk(i)
        step = (
            self._spec_step if self.speculative is not None
            else self._decode_step
        )
        t0 = time.monotonic()
        for _ in range(self.decode_per_round):
            if not any(self._decoding(r) for r in self.active):
                break
            step()
        self.stats.decode_time += time.monotonic() - t0
        if self.spans is not None and self._decode_open:
            # close still-running lanes' slices at the round's decode end
            t = self.spans.now()
            for rid, (ts, steps) in self._decode_open.items():
                self.spans.mark(rid, "decode", ts, t, steps=steps)
            self._decode_open.clear()
        self.stats.rounds += 1
        if self.mem_monitor is not None:
            self.mem_monitor.observe(
                t=(
                    self.spans.now()
                    if self.spans is not None
                    else float(self.stats.rounds)
                ),
                pool=self.pool,
                evicted_blocks=(
                    self.prefix_cache.evicted_blocks
                    if self.prefix_cache is not None
                    else 0
                ),
            )
        if self.tracker is not None or self.on_round is not None:
            self._emit_round()
        if self.spans is not None:
            self.spans.flush()

    # ---------------- observability ----------------

    def _emit_round(self) -> None:
        """One structured record per round (see ``runtime.tracker``).

        Counters are deltas against the previous emission — not against
        the round's start — so work done outside ``round()`` (a decode
        engine's ``import_prefilled``, a drain) is still accounted to
        the next record and replaying the stream reproduces the totals
        exactly."""
        s = self.stats
        # mem-ledger barrier: fold un-evented note_tokens drift into one
        # sync record and flush the buffer *now*, before the gauge record
        # below is built (and possibly deferred through on_round) — every
        # mem record therefore precedes, on the stream, the metrics
        # record its deltas must integrate to (validate_ledger's exactness
        # contract at round granularity).
        if self.ledger is not None:
            self.ledger.sync()
            self.ledger.flush()
        rec: dict = {"round": s.rounds}
        # the delta set is the tracker's replay contract (DELTA_KEYS):
        # one source of truth, drift-guarded by delta_coverage_gaps
        for k in DELTA_KEYS:
            cur = getattr(s, k)
            rec[k] = cur - self._emit_base.get(k, 0)
            self._emit_base[k] = cur
        rec["ttfts"] = [
            round(t, 6) for t in s.ttfts[self._emit_ttft_base :]
        ]
        self._emit_ttft_base = len(s.ttfts)
        rec["queued"] = len(self.queue)
        rec["queued_tokens"] = sum(r.total_tokens for r in self.queue)
        rec["active"] = sum(r is not None for r in self.active)
        rec["committed_tokens"] = self.committed_tokens
        rec["chunked_prefills"] = len(self._chunk_cursor)
        p = self.pool.stats()
        rec.update(
            pool_utilization=round(p.utilization, 4),
            pool_occupancy=round(p.occupancy, 4),
            pool_free_blocks=p.free_blocks,
            pool_held_blocks=p.held_blocks,
            pool_held_tokens=p.held_tokens,
            pool_committed_blocks=p.committed_blocks,
            pool_shared_blocks=p.shared_blocks,
            pool_cached_blocks=p.cached_blocks,
            pool_evictable_blocks=p.evictable_blocks,
            pool_alloc_blocks=self.pool.alloc_blocks,
            pool_freed_blocks=self.pool.freed_blocks,
            pool_cow_copies=self.pool.cow_copies,
        )
        if self.residency is not None:
            # live residency gauges (satellite of ISSUE 9): what the
            # startup print used to say once, per round — plus the
            # cumulative streamed-traffic integral the Perfetto export
            # differentiates into an HBM MiB/s counter track
            rp = self.residency
            rec.update(
                residency_resident_bytes=int(rp.resident_bytes),
                residency_streamed_bytes_per_step=round(
                    rp.streamed_bytes_per_step, 3
                ),
                residency_hbm_traffic_reduction=round(
                    rp.hbm_traffic_reduction, 4
                ),
                residency_streamed_mib=round(
                    s.decode_steps * rp.streamed_bytes_per_step / 2**20, 6
                ),
            )
        if self.prefix_cache is not None:
            c = self.prefix_cache.stats()
            rec.update(
                cache_nodes=c["nodes"],
                cache_anchors=c["anchors"],
                cache_evicted_blocks=c["evicted_blocks"],
            )
        if self._expert_counts is not None:
            tot = float(self._expert_counts.sum())
            if tot > 0:
                # gauges over the cumulative (L, E) tally: normalized
                # load entropy (1.0 = perfectly balanced) and the
                # fraction of routed tokens that hit a resident expert
                # (1.0 with no residency plan — everything is pinned)
                pe = self._expert_counts.sum(axis=0) / tot
                ent = float(-(pe * np.log(np.maximum(pe, 1e-12))).sum())
                rec["moe_expert_entropy"] = round(
                    ent / math.log(max(2, self.cfg.n_experts)), 4
                )
                hot = (
                    self._expert_resident
                    if self._expert_resident is not None
                    else np.ones(self._expert_counts.shape, bool)
                )
                rec["moe_hot_expert_fraction"] = round(
                    float(self._expert_counts[hot].sum()) / tot, 4
                )
            if self._expert_resident is not None:
                # live (L, E) stream-mask occupancy: which streamed slots
                # the routing actually touched so far — a dead streamed
                # expert is a candidate to swap into the resident set
                streamed = ~self._expert_resident
                n_streamed = int(streamed.sum())
                rec["moe_streamed_experts"] = n_streamed
                rec["moe_stream_mask_occupancy"] = round(
                    float((self._expert_counts[streamed] > 0).sum())
                    / max(1, n_streamed),
                    4,
                )
        if self.on_round is not None:
            self.on_round(rec)
        else:
            self.tracker.log_metrics(rec, step=s.rounds)

    def run(self, max_rounds: int | None = None) -> SchedulerStats:
        """Drain the queue to empty and finish every in-flight request."""
        limit = max_rounds or 64 + sum(
            r.total_tokens for r in self.requests.values()
        )
        while self.queue or any(r is not None for r in self.active):
            if self.stats.rounds >= limit:
                raise RuntimeError(
                    f"scheduler failed to drain: {len(self.queue)} queued, "
                    f"{sum(r is not None for r in self.active)} active after "
                    f"{self.stats.rounds} rounds"
                )
            self.round()
        self.pool.validate()
        if self.ledger is not None:
            # releases after the last emitted round would otherwise sit
            # in the buffer; a trailing sync keeps the stream complete
            self.ledger.sync()
            self.ledger.flush()
        return self.stats

    def outputs(self) -> dict[int, list[int]]:
        return {rid: req.output for rid, req in self.requests.items()}
