"""Model-layer correctness: flash attention vs dense oracle, SSD vs naive
recurrence, prefill/decode consistency, MoE dispatch, packed-weight paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.models import attention as attn
from repro.models import lm, ssm
from repro.models.config import ModelConfig
from repro.configs import get_smoke_config


# --------------------------------------------------------------------------
# flash attention vs dense oracle
# --------------------------------------------------------------------------


def dense_attention(q, k, v, causal=True, window=0, q_offset=0):
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(d)
    qp = q_offset + jnp.arange(sq)
    kp = jnp.arange(sk)
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window > 0:
        m &= qp[:, None] - kp[None, :] < window
    s = jnp.where(m[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(b, sq, hq, d)


@pytest.mark.parametrize("sq,sk,hq,hkv,window,causal", [
    (64, 64, 4, 4, 0, True),
    (64, 64, 4, 2, 0, True),
    (128, 128, 6, 2, 32, True),
    (60, 60, 3, 1, 0, True),     # non-pow2 seq (whisper-style)
    (64, 64, 4, 4, 0, False),    # bidirectional (encoder)
    (32, 96, 4, 2, 0, True),     # cross-chunk (q_offset)
])
def test_flash_attention_vs_dense(sq, sk, hq, hkv, window, causal):
    rng = np.random.default_rng(sq + sk + hq)
    d = 16
    q = jnp.asarray(rng.normal(size=(2, sq, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, sk, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, sk, hkv, d)), jnp.float32)
    q_offset = sk - sq if sq != sk else 0
    out = attn.flash_attention(
        q, k, v, causal=causal, window=window,
        q_block=16, kv_block=32, q_offset=q_offset,
    )
    want = dense_attention(q, k, v, causal, window, q_offset)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_flash():
    """One-token decode against a cache == last row of full attention."""
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 2, 32, 4, 2, 16
    q_all = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k_all = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v_all = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    full = attn.flash_attention(q_all, k_all, v_all, causal=True, q_block=8)
    out = attn.decode_attention(
        q_all[:, -1:], k_all, v_all, jnp.asarray(s, jnp.int32)
    )
    assert_allclose(
        np.asarray(out[:, 0]), np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5
    )


def test_decode_attention_sliding_window():
    rng = np.random.default_rng(1)
    b, s, h, d, w = 1, 16, 2, 8, 4
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out = attn.decode_attention(q, k, v, jnp.asarray(s), window=w)
    # manual: only the last w positions attend
    s_ = jnp.einsum("bhd,bshd->bhs", q[:, 0].reshape(b, h, d), k) / np.sqrt(d)
    mask = jnp.arange(s) >= s - w
    s_ = jnp.where(mask[None, None], s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    want = jnp.einsum("bhs,bshd->bhd", p, v)
    assert_allclose(
        np.asarray(out[:, 0]), np.asarray(want), rtol=2e-5, atol=2e-5
    )


# --------------------------------------------------------------------------
# SSD (Mamba2) vs naive recurrence
# --------------------------------------------------------------------------


def naive_ssd(x, dt, a_log, b, c, d_skip):
    """Direct recurrence h_t = exp(dt*a) h_{t-1} + dt*B x ; y = C h + D x."""
    bt, s, h, p = x.shape
    n = b.shape[-1]
    a = -np.exp(np.asarray(a_log, np.float64))
    hstate = np.zeros((bt, h, p, n))
    ys = np.zeros((bt, s, h, p))
    x64 = np.asarray(x, np.float64)
    dt64 = np.asarray(dt, np.float64)
    b64, c64 = np.asarray(b, np.float64), np.asarray(c, np.float64)
    for t in range(s):
        dec = np.exp(dt64[:, t, :, None, None] * a[None, :, None, None])
        inc = (
            dt64[:, t, :, None, None]
            * x64[:, t, :, :, None]
            * b64[:, t, None, None, :]
        )
        hstate = hstate * dec + inc
        ys[:, t] = np.einsum("bhpn,bn->bhp", hstate, c64[:, t])
    ys += x64 * np.asarray(d_skip)[None, None, :, None]
    return ys, hstate


def test_ssd_chunked_matches_naive():
    rng = np.random.default_rng(0)
    bt, s, h, p, n, chunk = 2, 32, 3, 4, 8, 8
    x = jnp.asarray(rng.normal(size=(bt, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bt, s, h)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 0.5, size=(h,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bt, s, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bt, s, n)), jnp.float32)
    d_skip = jnp.asarray(rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    y, hf = ssm.ssd_chunked(x, dt, a_log, b, c, d_skip, chunk)
    want_y, want_h = naive_ssd(x, dt, a_log, b, c, d_skip)
    assert_allclose(np.asarray(y), want_y, rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(hf), want_h, rtol=2e-4, atol=2e-4)


def test_ssd_decode_continues_chunked():
    """Prefill with ssd_chunked then decode step == longer chunked run."""
    rng = np.random.default_rng(1)
    bt, s, h, p, n = 1, 16, 2, 4, 4
    x = jnp.asarray(rng.normal(size=(bt, s + 1, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bt, s + 1, h)), jnp.float32)
    a_log = jnp.zeros((h,), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bt, s + 1, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bt, s + 1, n)), jnp.float32)
    d_skip = jnp.ones((h,), jnp.float32)
    y_full, _ = ssm.ssd_chunked(
        x, dt, a_log, b, c, d_skip, chunk=s + 1
    )
    _, h_pre = ssm.ssd_chunked(
        x[:, :s], dt[:, :s], a_log, b[:, :s], c[:, :s], d_skip, chunk=s
    )
    y1, _ = ssm.ssd_decode_step(
        h_pre, x[:, s], dt[:, s], a_log, b[:, s], c[:, s], d_skip
    )
    assert_allclose(
        np.asarray(y1), np.asarray(y_full[:, s]), rtol=1e-4, atol=1e-4
    )


def test_causal_conv_decode_matches_train():
    rng = np.random.default_rng(2)
    bt, s, ch, k = 2, 10, 6, 4
    x = jnp.asarray(rng.normal(size=(bt, s, ch)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, ch)), jnp.float32)
    y_train = ssm.causal_conv(x, w)
    buf = jnp.zeros((bt, k - 1, ch), jnp.float32)
    outs = []
    for t in range(s):
        yt, buf = ssm.conv_decode_step(buf, x[:, t], w)
        outs.append(yt)
    y_dec = jnp.stack(outs, axis=1)
    assert_allclose(np.asarray(y_dec), np.asarray(y_train), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# prefill -> decode consistency (the serving contract), per family
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["llama3p2_1b", "mamba2_1p3b", "zamba2_2p7b", "olmoe_1b_7b"]
)
def test_decode_matches_forward(arch):
    """Feeding tokens one-by-one through decode_step reproduces the
    teacher-forced forward logits."""
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        # capacity dropping depends on the dispatch group (S tokens at
        # prefill vs 1 at decode); give every expert full capacity so the
        # consistency contract is exact.
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.experts_per_token
        )
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    b, s = 1, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    full_logits, _ = lm.forward(params, cfg, toks)
    cache = lm.init_cache(cfg, b, s)
    step = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    outs = []
    for t in range(s):
        lg, cache = step(params, toks[:, t : t + 1], cache)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    want = np.asarray(full_logits, np.float32)
    assert_allclose(dec, want, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# MoE dispatch
# --------------------------------------------------------------------------


def test_moe_capacity_drops_gracefully():
    from repro.models.moe import moe_capacity, moe_ffn

    cfg = get_smoke_config("olmoe_1b_7b")
    assert moe_capacity(cfg, 1024) >= 8
    rng = np.random.default_rng(0)
    params = lm.init_params(cfg, jax.random.key(0))
    lp = params["layers"]
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y, aux = moe_ffn(
        x, lp["router"][0], lp["w1"][0], lp["w3"][0], lp["w2"][0], cfg
    )
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    # aux loss is ~1 for a balanced uniform router (Switch normalisation)
    assert 0.5 < float(aux) < float(cfg.n_experts)


def test_moe_capacity_boundaries():
    """Rounding boundaries of the train-path capacity: tiny groups keep
    their exact capacity (no degeneration to the 8-sublane grain), the
    round-up kicks in only at cap >= 8, and — the clamp-after-round
    regression — the rounded capacity never exceeds the group size (an
    over-group capacity would gather out-of-range rows)."""
    import dataclasses

    from repro.models.moe import moe_capacity

    cfg = get_smoke_config("olmoe_1b_7b")  # E=8, k=2, cf=1.25
    assert moe_capacity(cfg, 1) == 1  # floor: at least one slot
    assert moe_capacity(cfg, 4) == 1  # raw 1.25 -> exact, not grain 8
    assert moe_capacity(cfg, 24) == 7  # raw 7.5: below 8 stays exact
    assert moe_capacity(cfg, 26) == 8  # raw 8.125: first rounded value
    assert moe_capacity(cfg, 32) == 16  # 10 -> next 8-sublane boundary
    assert moe_capacity(cfg, 1024) == 320
    # clamp-after-round: with cf=4, group 9 -> raw 9 -> rounds to 16,
    # which must clamp back to the 9 gatherable rows
    fat = dataclasses.replace(cfg, capacity_factor=4.0)
    assert moe_capacity(fat, 9) == 9
    for g in range(1, 64):
        assert 1 <= moe_capacity(fat, g) <= g


def test_packed_lm_close_to_dense_ffn():
    """w_bits=1 FFN: the packed path must equal explicit unpack-matmul."""
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config("llama3p2_1b"), w_bits=1)
    params = lm.init_params(cfg, jax.random.key(0))
    w1 = params["layers"]["w1"]
    assert isinstance(w1, dict) and w1["packed"].dtype == jnp.uint8
    toks = jnp.zeros((1, 8), jnp.int32)
    lg, _ = lm.forward(params, cfg, toks)
    assert bool(jnp.isfinite(lg).all())
