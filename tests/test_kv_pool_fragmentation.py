"""KV-pool fragmentation under churn: freed blocks must be *reused*
(no monotonic high-water growth across request generations), commitment
accounting must stay exact through mixed alloc/free interleavings, and
the allocator invariants must hold at every step.

Property-style via hypothesis (the deterministic ``repro.testing`` stub
in hermetic environments): each example drives a random admit/grow/
release schedule against a small pool and checks the allocator after
every operation.
"""

from hypothesis import given, settings
from hypothesis import strategies as st
import pytest

from repro.configs import get_smoke_config
from repro.runtime.kv_pool import KVPool

BLOCK = 4
N_BLOCKS = 17  # 16 usable


def _pool():
    return KVPool(
        get_smoke_config("smollm_360m"), n_blocks=N_BLOCKS, block_tokens=BLOCK
    )


def test_freed_blocks_are_reused_not_grown():
    """Generations of admit/fill/release must cycle the same physical
    blocks: the union of blocks ever handed out stays bounded by the
    pool size (no high-water creep), and later generations actually
    reuse earlier generations' freed blocks."""
    pool = _pool()
    seen: set[int] = set()
    generations = []
    for gen in range(6):
        rids = [gen * 10 + i for i in range(4)]
        held: set[int] = set()
        for rid in rids:
            pool.admit(rid, 16)
            pool.note_tokens(rid, 16)
            held.update(pool._held[rid])
        pool.validate()
        assert len(held) == 16  # the whole pool, every generation
        generations.append(held)
        seen |= held
        for rid in rids:
            pool.release(rid)
        pool.validate()
        assert pool.free_blocks == pool.usable_blocks
    assert len(seen) <= pool.usable_blocks, "allocator leaked new blocks"
    for later in generations[1:]:
        assert later & generations[0], "freed blocks never reused"


def test_interleaved_churn_keeps_commitment_exact():
    """Alternating short/long requests with out-of-order releases: the
    uncommitted-free invariant (sum of committed-not-held <= free) must
    hold exactly, and admission must be refused precisely when the
    commitment arithmetic says so."""
    pool = _pool()
    pool.admit(0, 32)  # 8 blocks committed
    pool.admit(1, 8)  # 2 blocks
    pool.note_tokens(0, 5)  # holds 2
    pool.note_tokens(1, 8)  # holds 2
    assert pool.outstanding_commitment == (8 - 2) + 0
    # free = 12, uncommitted = 12 - 6 = 6 blocks = 24 tokens
    assert pool.can_admit(24)
    assert not pool.can_admit(25)
    pool.release(1)
    assert pool.can_admit(32)
    pool.admit(2, 32)
    pool.note_tokens(2, 32)
    pool.note_tokens(0, 32)
    pool.validate()
    assert pool.free_blocks == 0
    assert pool.outstanding_commitment == 0
    pool.release(0)
    pool.release(2)
    assert pool.free_blocks == pool.usable_blocks


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_random_churn_invariants(data):
    """Random admit/grow/release schedule: validate() after every op,
    released blocks return to the free list, and the pool always drains
    back to empty."""
    pool = _pool()
    live: dict[int, int] = {}  # rid -> total committed tokens
    next_rid = 0
    for _ in range(30):
        ops = ["admit", "grow", "release"]
        op = data.draw(st.sampled_from(ops), label="op")
        if op == "admit":
            total = data.draw(st.integers(1, 24), label="total")
            if pool.can_admit(total):
                pool.admit(next_rid, total)
                live[next_rid] = total
                next_rid += 1
        elif op == "grow" and live:
            rid = data.draw(
                st.sampled_from(sorted(live)), label="rid"
            )
            tokens = data.draw(
                st.integers(1, live[rid]), label="tokens"
            )
            # note_tokens must accept any count within the commitment,
            # non-monotone calls included (it only ever grows the hold)
            pool.note_tokens(rid, max(tokens, pool.tokens_held(rid)))
        elif op == "release" and live:
            rid = data.draw(
                st.sampled_from(sorted(live)), label="rid"
            )
            pool.release(rid)
            del live[rid]
        pool.validate()
        held = sum(pool.blocks_held(r) for r in live)
        assert held + pool.free_blocks == pool.usable_blocks
        assert pool.outstanding_commitment <= pool.free_blocks
    for rid in list(live):
        pool.release(rid)
    pool.validate()
    assert pool.free_blocks == pool.usable_blocks
    assert pool.stats().held_tokens == 0


def test_over_commitment_growth_is_refused():
    pool = _pool()
    pool.admit(0, 8)
    pool.note_tokens(0, 8)
    with pytest.raises(RuntimeError):
        pool.note_tokens(0, 9)
    # the failed growth must not corrupt accounting
    pool.validate()
    assert pool.blocks_held(0) == 2
    pool.release(0)
    assert pool.free_blocks == pool.usable_blocks


# ---------------- refcounts, COW, cache pins (ISSUE 5) ----------------


def test_double_free_raises_actionable_error():
    """Releasing a request twice must raise an error naming the rid, not
    silently re-append its blocks to the free list."""
    pool = _pool()
    pool.admit(7, 8)
    pool.note_tokens(7, 8)
    pool.release(7)
    free_before = pool.free_blocks
    with pytest.raises(ValueError, match="7.*double free"):
        pool.release(7)
    assert pool.free_blocks == free_before  # nothing re-appended
    pool.validate()
    with pytest.raises(ValueError, match="99"):
        pool.release(99)  # never admitted


def test_validate_asserts_free_list_uniqueness():
    pool = _pool()
    pool.validate()
    pool._free.append(pool._free[-1])  # corrupt: duplicate free entry
    with pytest.raises(AssertionError, match="duplicate"):
        pool.validate()


def test_adopt_prefix_refcounts_and_cow():
    """Aliasing bumps refcounts; a partial tail is duplicated (COW) so
    the adopter's writes can never touch the shared rows; release of
    either holder leaves the other intact."""
    pool = _pool()
    pool.admit(0, 12)
    pool.note_tokens(0, 12)  # blocks b0 b1 b2
    b = pool.blocks_of(0)
    pool.admit(1, 16)
    # request 1 matched 10 tokens of request 0's prompt: 2 full blocks
    # shared + a mid-block divergence in b[2]
    pool.adopt_prefix(1, b[:2], b[2], 10)
    pool.validate()
    assert pool.ref_count(b[0]) == pool.ref_count(b[1]) == 2
    assert pool.ref_count(b[2]) == 1  # tail was copied, not aliased
    cow = pool.blocks_of(1)[2]
    assert cow not in b
    st = pool.stats()
    assert st.shared_blocks == 2
    # shared physical rows counted once: 12 + 16 logical tokens over
    # 12 + (16 - 8 shared) physical rows... held_tokens is per-block max
    assert st.held_tokens == 12 + (10 - 8) + 0  # b0..b2 (12) + cow (2)
    pool.release(0)
    pool.validate()
    assert pool.ref_count(b[0]) == 1  # request 1 still holds the aliases
    pool.note_tokens(1, 16)
    pool.release(1)
    pool.validate()
    assert pool.free_blocks == pool.usable_blocks


def test_cache_pin_and_eviction_never_reclaims_held_blocks():
    """uncache() frees a block only at refcount zero: eviction can never
    reclaim a block a live request holds."""
    pool = _pool()
    pool.admit(0, 8)
    pool.note_tokens(0, 8)
    b0, b1 = pool.blocks_of(0)
    pool.retain_cached(b0)
    pool.retain_cached(b1)
    pool.validate()
    assert pool.cached_blocks == 2 and pool.evictable_blocks == 0
    assert pool.uncache(b0) == 0  # request 0 still holds it
    assert b0 not in pool._free
    pool.release(0)
    pool.validate()
    assert b0 in pool._free  # freed at release: last holder let go
    assert pool.evictable_blocks == 1  # b1: cache-only now
    assert pool.uncache(b1) == 1
    pool.validate()
    assert pool.free_blocks == pool.usable_blocks


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_refcount_cow_churn_invariants(data):
    """Random admit/grow/adopt/pin/unpin/release schedule: after every
    op no block is simultaneously free and referenced, refcounts hit
    zero iff no live block-table entry or cache pin remains, and
    eviction (unpin) never frees a block a live request holds — all
    enforced by validate() plus explicit free-list checks."""
    pool = _pool()
    live: dict[int, int] = {}  # rid -> committed tokens
    pinned: list[int] = []
    next_rid = 0
    for _ in range(40):
        op = data.draw(
            st.sampled_from(["admit", "grow", "adopt", "pin", "unpin",
                             "release"]),
            label="op",
        )
        if op == "admit":
            total = data.draw(st.integers(1, 16), label="total")
            if pool.can_admit(total):
                pool.admit(next_rid, total)
                live[next_rid] = total
                next_rid += 1
        elif op == "grow" and live:
            rid = data.draw(st.sampled_from(sorted(live)), label="rid")
            tokens = data.draw(st.integers(1, live[rid]), label="tokens")
            pool.note_tokens(rid, max(tokens, pool.tokens_held(rid)))
        elif op == "adopt" and live:
            donor = data.draw(st.sampled_from(sorted(live)), label="donor")
            held = pool.tokens_held(donor)
            if held >= 2 and pool.can_admit(16):
                matched = data.draw(
                    st.integers(1, held - 1), label="matched"
                )
                pool.admit(next_rid, 16)
                blocks = pool.blocks_of(donor)
                full = matched // BLOCK
                tail = blocks[full] if matched % BLOCK else None
                pool.adopt_prefix(next_rid, blocks[:full], tail, matched)
                live[next_rid] = 16
                next_rid += 1
        elif op == "pin" and live:
            rid = data.draw(st.sampled_from(sorted(live)), label="prid")
            cands = [
                b for b in pool.blocks_of(rid) if b not in pool._cached
            ]
            if cands:
                pool.retain_cached(cands[0])
                pinned.append(cands[0])
        elif op == "unpin" and pinned:
            b = pinned.pop(data.draw(
                st.integers(0, len(pinned) - 1), label="unpin_i"
            ))
            holders = sum(b in pool.blocks_of(r) for r in live)
            freed = pool.uncache(b)
            # eviction never reclaims a block a live request holds
            assert freed == (0 if holders else 1)
            assert (b in pool._free) == (holders == 0)
        elif op == "release" and live:
            rid = data.draw(st.sampled_from(sorted(live)), label="rrid")
            pool.release(rid)
            del live[rid]
        pool.validate()
        # refcount == 0 (absent) iff free; shared counted once in stats
        st_ = pool.stats()
        assert st_.held_blocks + pool.free_blocks + sum(
            1 for b in pool._cached
            if all(b not in pool.blocks_of(r) for r in live)
        ) == pool.usable_blocks
        assert st_.utilization <= 1.0 + 1e-9
    for b in list(pinned):
        pool.uncache(b)
    for rid in list(live):
        pool.release(rid)
    pool.validate()
    assert pool.free_blocks == pool.usable_blocks
