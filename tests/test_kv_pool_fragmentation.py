"""KV-pool fragmentation under churn: freed blocks must be *reused*
(no monotonic high-water growth across request generations), commitment
accounting must stay exact through mixed alloc/free interleavings, and
the allocator invariants must hold at every step.

Property-style via hypothesis (the deterministic ``repro.testing`` stub
in hermetic environments): each example drives a random admit/grow/
release schedule against a small pool and checks the allocator after
every operation.
"""

from hypothesis import given, settings
from hypothesis import strategies as st
import pytest

from repro.configs import get_smoke_config
from repro.runtime.kv_pool import KVPool

BLOCK = 4
N_BLOCKS = 17  # 16 usable


def _pool():
    return KVPool(
        get_smoke_config("smollm_360m"), n_blocks=N_BLOCKS, block_tokens=BLOCK
    )


def test_freed_blocks_are_reused_not_grown():
    """Generations of admit/fill/release must cycle the same physical
    blocks: the union of blocks ever handed out stays bounded by the
    pool size (no high-water creep), and later generations actually
    reuse earlier generations' freed blocks."""
    pool = _pool()
    seen: set[int] = set()
    generations = []
    for gen in range(6):
        rids = [gen * 10 + i for i in range(4)]
        held: set[int] = set()
        for rid in rids:
            pool.admit(rid, 16)
            pool.note_tokens(rid, 16)
            held.update(pool._held[rid])
        pool.validate()
        assert len(held) == 16  # the whole pool, every generation
        generations.append(held)
        seen |= held
        for rid in rids:
            pool.release(rid)
        pool.validate()
        assert pool.free_blocks == pool.usable_blocks
    assert len(seen) <= pool.usable_blocks, "allocator leaked new blocks"
    for later in generations[1:]:
        assert later & generations[0], "freed blocks never reused"


def test_interleaved_churn_keeps_commitment_exact():
    """Alternating short/long requests with out-of-order releases: the
    uncommitted-free invariant (sum of committed-not-held <= free) must
    hold exactly, and admission must be refused precisely when the
    commitment arithmetic says so."""
    pool = _pool()
    pool.admit(0, 32)  # 8 blocks committed
    pool.admit(1, 8)  # 2 blocks
    pool.note_tokens(0, 5)  # holds 2
    pool.note_tokens(1, 8)  # holds 2
    assert pool.outstanding_commitment == (8 - 2) + 0
    # free = 12, uncommitted = 12 - 6 = 6 blocks = 24 tokens
    assert pool.can_admit(24)
    assert not pool.can_admit(25)
    pool.release(1)
    assert pool.can_admit(32)
    pool.admit(2, 32)
    pool.note_tokens(2, 32)
    pool.note_tokens(0, 32)
    pool.validate()
    assert pool.free_blocks == 0
    assert pool.outstanding_commitment == 0
    pool.release(0)
    pool.release(2)
    assert pool.free_blocks == pool.usable_blocks


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_random_churn_invariants(data):
    """Random admit/grow/release schedule: validate() after every op,
    released blocks return to the free list, and the pool always drains
    back to empty."""
    pool = _pool()
    live: dict[int, int] = {}  # rid -> total committed tokens
    next_rid = 0
    for _ in range(30):
        ops = ["admit", "grow", "release"]
        op = data.draw(st.sampled_from(ops), label="op")
        if op == "admit":
            total = data.draw(st.integers(1, 24), label="total")
            if pool.can_admit(total):
                pool.admit(next_rid, total)
                live[next_rid] = total
                next_rid += 1
        elif op == "grow" and live:
            rid = data.draw(
                st.sampled_from(sorted(live)), label="rid"
            )
            tokens = data.draw(
                st.integers(1, live[rid]), label="tokens"
            )
            # note_tokens must accept any count within the commitment,
            # non-monotone calls included (it only ever grows the hold)
            pool.note_tokens(rid, max(tokens, pool.tokens_held(rid)))
        elif op == "release" and live:
            rid = data.draw(
                st.sampled_from(sorted(live)), label="rid"
            )
            pool.release(rid)
            del live[rid]
        pool.validate()
        held = sum(pool.blocks_held(r) for r in live)
        assert held + pool.free_blocks == pool.usable_blocks
        assert pool.outstanding_commitment <= pool.free_blocks
    for rid in list(live):
        pool.release(rid)
    pool.validate()
    assert pool.free_blocks == pool.usable_blocks
    assert pool.stats().held_tokens == 0


def test_over_commitment_growth_is_refused():
    pool = _pool()
    pool.admit(0, 8)
    pool.note_tokens(0, 8)
    with pytest.raises(RuntimeError):
        pool.note_tokens(0, 9)
    # the failed growth must not corrupt accounting
    pool.validate()
    assert pool.blocks_held(0) == 2
    pool.release(0)
    assert pool.free_blocks == pool.usable_blocks
