"""Fault-tolerance substrate: checkpoint atomicity/async/elastic restore,
train-loop preemption recovery, straggler detection, data determinism."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.data.pipeline import CifarPipeline, TokenPipeline
from repro.optim.adamw import AdamW
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.train import TrainLoop, TrainLoopConfig


def _tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32), "c": jnp.float32(2.5)},
    }


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(7, tree, extra={"data_step": 7})
    out, extra = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for step in (1, 2, 3, 4):
        mgr.save(step, tree, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]  # retention keeps the newest 2


def test_ckpt_atomic_no_partial_visible(tmp_path):
    """A tmp dir mid-write is never listed as a valid checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    os.makedirs(tmp_path / ".tmp-step_00000009")
    assert mgr.all_steps() == []
    mgr.save(9, _tree())
    assert mgr.all_steps() == [9]


def test_ckpt_elastic_restore_different_device_layout(tmp_path):
    """Restore places leaves with new shardings (mesh-shape change)."""
    from repro.ckpt.manager import restore_resharded

    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    shardings = jax.tree.map(lambda _: None, tree)
    out, _ = restore_resharded(mgr, jax.tree.map(jnp.zeros_like, tree), shardings)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


# --------------------------------------------------------------------------
# train loop: preemption + deterministic resume
# --------------------------------------------------------------------------


def _toy_problem():
    """y = Wx regression; step_fn follows the TrainLoop contract."""
    opt = AdamW(lr=1e-2, warmup_steps=1, weight_decay=0.0)

    def loss(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    @jax.jit
    def step_fn(params, opt_state, batch):
        l, g = jax.value_and_grad(loss)(params, batch)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, {"loss": l}

    params = {"w": jnp.zeros((4, 2))}
    return step_fn, opt, params


class _ToyPipeline:
    def __init__(self, seed=0):
        from repro.data.pipeline import PipelineState

        self.state = PipelineState()
        self.seed = seed
        self.w_true = np.random.default_rng(99).normal(size=(4, 2))

    def batch_at(self, step):
        rng = np.random.default_rng((self.seed, step))
        x = rng.normal(size=(8, 4)).astype(np.float32)
        return {"x": x, "y": (x @ self.w_true).astype(np.float32)}


def test_trainloop_preemption_resume_bitwise(tmp_path):
    """Kill at step 12, resume from checkpoint: final params identical to an
    uninterrupted run."""
    cfgloop = TrainLoopConfig(n_steps=20, ckpt_every=5, ckpt_async=False)

    # uninterrupted reference
    step_fn, opt, params0 = _toy_problem()
    loop = TrainLoop(step_fn, _ToyPipeline(), None, cfgloop)
    ref_params, _, _ = loop.run(params0, opt.init(params0))

    # interrupted run
    step_fn, opt, params0 = _toy_problem()
    ckpt = CheckpointManager(str(tmp_path), keep=3)

    class Preempt(RuntimeError):
        pass

    def bomb(step):
        if step == 12:
            raise Preempt()

    loop = TrainLoop(step_fn, _ToyPipeline(), ckpt, cfgloop, pre_step_hook=bomb)
    with pytest.raises(Preempt):
        loop.run(params0, opt.init(params0))

    # "new process": restore and finish
    step_fn, opt, params0 = _toy_problem()
    loop = TrainLoop(step_fn, _ToyPipeline(), ckpt, cfgloop)
    params, opt_state, start = loop.restore_or_init(params0, opt.init(params0))
    assert start == 10  # last checkpoint before the kill
    out_params, _, _ = loop.run(params, opt_state, start)

    np.testing.assert_array_equal(
        np.asarray(ref_params["w"]), np.asarray(out_params["w"])
    )


def test_straggler_monitor_flags_and_recovers():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, min_steps=2)
    for _ in range(3):
        mon.record_step([1.0, 1.0, 1.0, 1.0])
    assert mon.flagged == set()
    newly = []
    for _ in range(6):
        newly += mon.record_step([1.0, 1.0, 1.0, 3.0])
    assert newly == [3]
    assert mon.healthy_hosts == [0, 1, 2]
    for _ in range(12):
        mon.record_step([1.0, 1.0, 1.0, 1.0])
    assert mon.flagged == set()  # recovered


def test_pipeline_determinism():
    p1 = TokenPipeline(vocab=100, batch=4, seq_len=16, seed=3)
    p2 = TokenPipeline(vocab=100, batch=4, seq_len=16, seed=3)
    b1, b2 = p1.batch_at(5), p2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 100
    c = CifarPipeline(batch=4, seed=1)
    np.testing.assert_array_equal(
        c.batch_at(0)["labels"], CifarPipeline(batch=4, seed=1).batch_at(0)["labels"]
    )


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------


def test_topk_error_feedback_converges():
    from repro.optim.compression import topk_error_feedback_update

    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    rounds = 96
    for _ in range(rounds):
        _, transmitted, err = topk_error_feedback_update(g_true, err, k=8)
        acc += transmitted
    # error feedback is unbiased over time: cumulative transmitted +
    # residual error == cumulative true gradient EXACTLY (telescoping sum)
    np.testing.assert_allclose(
        np.asarray(acc + err), np.asarray(g_true) * rounds, rtol=1e-4
    )
    # and the residual stays bounded (each coord transmits every ~n/k rounds)
    assert float(jnp.max(jnp.abs(err))) < 64 / 8 * float(
        jnp.max(jnp.abs(g_true))
    ) * 1.5


def test_int8_quantize_roundtrip():
    from repro.optim.compression import int8_dequantize, int8_quantize

    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    q, s = int8_quantize(g)
    back = int8_dequantize(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(
        np.asarray(back), np.asarray(g), atol=float(s) * 0.51
    )
