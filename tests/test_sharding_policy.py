"""Sharding-policy invariants: every assigned arch gets a legal spec for
every parameter leaf / batch / cache (divisibility fallbacks must never
produce an unshardable spec), and big leaves actually get sharded."""

import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.config import SHAPES, shape_applicable

# spec construction must not require real devices: build a fake "mesh"
# exposing only what the policy reads (axis_names + shape).


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}
    size = 256


class FakeMeshMP:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}
    size = 512


import jax

from repro.dist import sharding as shd
from repro.models import lm


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [FakeMesh(), FakeMeshMP()], ids=["1pod", "2pod"])
def test_param_specs_legal_and_effective(arch, mesh):
    cfg = get_config(arch)
    specs = shd.param_specs(cfg, mesh)
    abstract = lm.abstract_params(cfg)
    flat_s = jax.tree_util.tree_flatten_with_path(specs)[0]
    flat_a = {tuple(str(k) for k in p): l
              for p, l in jax.tree_util.tree_flatten_with_path(abstract)[0]}
    n_sharded_bytes = 0
    n_total_bytes = 0
    for path, spec in flat_s:
        leaf = flat_a[tuple(str(k) for k in path)]
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        n_total_bytes += nbytes
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else tuple(axes)
            div = int(np.prod([mesh.shape[a] for a in axes]))
            # legality: the sharded dim must divide
            assert leaf.shape[dim] % div == 0, (path, leaf.shape, spec)
            n_sharded_bytes += nbytes
            break
    # effectiveness: most parameter bytes are TP-sharded for every arch
    assert n_sharded_bytes / n_total_bytes > 0.85, (
        arch, n_sharded_bytes / n_total_bytes,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_and_cache_specs_legal(arch):
    cfg = get_config(arch)
    mesh = FakeMesh()
    for shape in SHAPES.values():
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        bspecs = shd.batch_specs(cfg, mesh, shape.global_batch)
        for name, spec in bspecs.items():
            if spec and spec[0] is not None:
                axes = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
                div = int(np.prod([mesh.shape[a] for a in axes]))
                assert shape.global_batch % div == 0, (arch, shape.name, name)
        if shape.kind == "decode":
            cspecs = shd.cache_specs(
                cfg, mesh, shape.global_batch, shape.seq_len
            )
            assert "len" in cspecs
            # every family provides specs for every cache leaf it creates
            cache = jax.eval_shape(
                lambda: lm.init_cache(cfg, shape.global_batch, 64)
            )
            for k in cache:
                assert k in cspecs, (arch, k)


def test_vocab_padding_always_divides():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 16 == 0
        assert cfg.padded_vocab >= cfg.vocab
