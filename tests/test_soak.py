"""Soak harness (`benchmarks/soak_bench`, ISSUE 6): the churn loop at
test scale, the trajectory file contract, and a hypothesis-swept churn
property — random drain points and follow-up mixes must never break the
pool/cursor/tracker conservation invariants."""

import json
import sys
from pathlib import Path

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import trajectory
from benchmarks.soak_bench import run_soak
from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.runtime.cluster import FleetCluster, StepCostModel
from repro.runtime.cluster.traffic import ClientRequest
from repro.runtime.tracker import MemoryTracker, replay_summary

SLOTS, MAX_LEN, BLOCK = 2, 48, 4


# ---------------- trajectory file ----------------


def test_trajectory_append_and_load(tmp_path):
    path = tmp_path / "BENCH_trajectory.json"
    assert trajectory.load_runs(path) == []
    e0 = trajectory.append_run({"ok": True, "x": 1}, bench="soak", path=path)
    e1 = trajectory.append_run({"ok": True, "x": 2}, bench="soak", path=path)
    assert (e0["run_index"], e1["run_index"]) == (0, 1)
    runs = trajectory.load_runs(path)
    assert [r["x"] for r in runs] == [1, 2]
    assert all(r["bench"] == "soak" for r in runs)
    # the file is a plain JSON list (merge/report tooling reads it raw)
    assert isinstance(json.loads(path.read_text()), list)


# ---------------- the soak loop at test scale ----------------


def test_soak_smoke_invariants_green(tmp_path):
    """A small soak must exercise every churn dimension (drain, restore,
    follow-ups, handoffs, invariant probes) and finish with zero
    invariant violations and an exactly-replaying trace."""
    trace = tmp_path / "soak.jsonl"
    summary = run_soak(
        virtual_hours=0.1, n_segments=2, requests_per_segment=5,
        check_every=4, trace_out=str(trace),
    )
    assert summary["errors"] == []
    assert summary["ok"]
    assert summary["completed"] == summary["requests"]
    assert summary["drains"] >= 1
    assert summary["handoffs"] > 0
    assert summary["invariant_checks"] > 0
    assert summary["virtual_hours"] >= 0.095
    assert trace.exists() and summary["trace_records"] > 0


# ---------------- hypothesis churn property ----------------


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm_360m")
    params = lm.init_params(cfg, jax.random.key(0))
    cost = StepCostModel.for_config(get_config("smollm_360m"), slots=SLOTS)
    return cfg, params, cost


@settings(max_examples=5, deadline=None)
@given(data=st.data())
def test_churn_conserves_pool_and_stream(setup, data):
    """Property: for random two-burst traces (random follow-up choice,
    random drain time, random lengths) the fleet conserves blocks
    (lifetime alloc - freed == live), leaks no cursors/lanes, completes
    everything exactly once, and its tracker stream replays to the live
    totals."""
    cfg, params, cost = setup
    seed = data.draw(st.integers(0, 2**16), label="seed")
    drain_frac = data.draw(
        st.sampled_from((0.0, 0.3, 0.7)), label="drain_frac"
    )
    rng = np.random.default_rng(seed)
    mem = MemoryTracker()
    cl = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, policy="prefix-aware",
        prefix_cache=True, tracker=mem,
    )
    fresh = lambda k: rng.integers(0, cfg.vocab, size=(k,)).astype(np.int32)
    burst1 = [
        ClientRequest(i, 0.001 * i, fresh(int(rng.integers(6, 15))),
                      int(rng.choice((4, 8))), i)
        for i in range(4)
    ]
    res1 = cl.run(burst1)
    # burst 2: half follow-ups over burst 1's conversations
    burst2 = []
    for j in range(4):
        rid = 4 + j
        if j % 2 == 0:
            parent = burst1[int(rng.integers(len(burst1)))]
            prompt = np.concatenate(
                [parent.prompt,
                 np.asarray(res1.outputs[parent.rid], np.int32), fresh(5)]
            )
            session = parent.session
        else:
            prompt, session = fresh(int(rng.integers(6, 15))), rid
        burst2.append(
            ClientRequest(rid, 10.0 + 0.001 * j, prompt,
                          int(rng.choice((4, 8))), session)
        )
    drain_at = (int(rng.integers(2)), 10.0 + drain_frac * 0.004)
    res2 = cl.run(burst2, drain_at=drain_at)
    cl.restore_engine(drain_at[0])

    done = set(res1.outputs) | set(res2.outputs)
    assert done >= {r.rid for r in burst1 + burst2}
    for e in cl.engines:
        sch = e.scheduler
        sch.pool.validate()  # includes alloc - freed == live conservation
        assert not sch._chunk_cursor and not sch._chunk_lane
        assert sch.pool.alloc_blocks - sch.pool.freed_blocks == (
            len(sch.pool._refs)
        )
        rep = replay_summary(mem.records, engine=e.engine_id)
        summ = e.summary()
        for k in ("completed", "prefill_tokens", "decode_steps",
                  "generated_tokens", "prefix_hit_tokens"):
            assert rep[k] == summ[k], (seed, e.engine_id, k)
    total_out = sum(
        len(v) for v in {**res1.outputs, **res2.outputs}.values()
    )
    assert total_out == sum(
        e.scheduler.stats.generated_tokens for e in cl.engines
    )
