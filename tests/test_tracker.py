"""Unified serve observability (`runtime.tracker`, ISSUE 6): backend
round-trips, and the conservation property that makes the stream an
*account* of a run rather than a sample — replaying the emitted records
reproduces the scheduler/engine totals exactly."""

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.runtime.cluster import FleetCluster, StepCostModel, TrafficSpec
from repro.runtime.cluster.traffic import synthesize
from repro.runtime.kv_pool import KVPool
from repro.runtime.prefix_cache import PrefixCache
from repro.runtime.scheduler import Scheduler
from repro.runtime.tracker import (
    DELTA_KEYS,
    CompositeTracker,
    JsonlTracker,
    MemoryTracker,
    NullTracker,
    delta_coverage_gaps,
    read_jsonl,
    replay_summary,
)

SLOTS, MAX_LEN, BLOCK, GEN = 2, 32, 4, 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm_360m")
    params = lm.init_params(cfg, jax.random.key(0))
    return cfg, params


def _sched(cfg, params, tracker, **kw):
    pool = KVPool.for_slots(
        cfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    kw.setdefault("prefix_cache", PrefixCache(pool))
    return Scheduler(
        cfg, params, pool, slots=SLOTS, max_len=MAX_LEN,
        tracker=tracker, **kw,
    )


# ---------------- backends ----------------


def test_jsonl_tracker_roundtrip(tmp_path):
    path = tmp_path / "run" / "trace.jsonl"
    t = JsonlTracker(path)
    t.log_hyperparameters({"arch": "x", "slots": np.int64(2)})
    t.log_metrics(
        {"round": 1, "ttfts": [np.float32(0.5)], "blocks": (1, 2)}, step=1
    )
    t.log_metrics({"round": 2, "util": np.float64(0.25)}, step=2)
    assert t.n_records == 2
    t.finish()
    recs = read_jsonl(path)
    assert [r["kind"] for r in recs] == ["hparams", "metrics", "metrics"]
    assert recs[0]["slots"] == 2  # numpy coerced to plain json types
    assert recs[1]["step"] == 1 and recs[1]["blocks"] == [1, 2]
    assert recs[2]["util"] == 0.25
    # append mode: a reopened tracker extends the same stream
    t2 = JsonlTracker(path)
    t2.log_metrics({"round": 3}, step=3)
    t2.finish()
    assert len(read_jsonl(path)) == 4


def test_composite_finish_and_spans_fan_out(tmp_path):
    """``finish`` must reach every backend (a composite that leaves a
    JSONL file open loses its tail on interpreter exit), and span
    batches fan out like metrics do."""
    a = JsonlTracker(tmp_path / "a.jsonl")
    b = JsonlTracker(tmp_path / "b.jsonl")
    mem = MemoryTracker()
    comp = CompositeTracker(a, mem, b)
    comp.log_spans([{"rid": 1, "phase": "queue", "t0": 0.0, "t1": 1.0}])
    comp.finish()
    assert a._fh.closed and b._fh.closed
    for path in (a.path, b.path):
        recs = read_jsonl(path)
        assert [r["kind"] for r in recs] == ["span"]
        assert recs[0]["phase"] == "queue"
    assert mem.spans[0]["kind"] == "span"


# ---------------- replay-contract drift guard ----------------


def test_delta_keys_cover_scheduler_stats():
    """Every ``SchedulerStats`` counter must be in DELTA_KEYS or the
    declared non-delta set — a new stats field fails here *by name*
    instead of silently breaking replay conservation."""
    assert delta_coverage_gaps() == []


def test_delta_coverage_gap_names_the_new_field():
    import dataclasses

    from repro.runtime.scheduler import SchedulerStats

    @dataclasses.dataclass
    class Grown(SchedulerStats):
        brand_new_counter: int = 0

    assert delta_coverage_gaps(Grown) == ["brand_new_counter"]


def test_replay_summary_filters_interleaved_engines():
    """Engine filtering over a stream whose engine ids interleave round
    by round (the shared-tracker fleet shape), with span records mixed
    in — spans must not perturb the metrics replay."""
    recs = []
    for rnd in range(3):
        for eng in (0, 1):
            recs.append({
                "kind": "metrics", "engine": eng, "round": rnd,
                "generated_tokens": eng + 1, "ttfts": [float(rnd)],
            })
        recs.append({
            "kind": "span", "rid": rnd, "phase": "queue",
            "t0": 0.0, "t1": 1.0, "engine": rnd % 2,
        })
    r0 = replay_summary(recs, engine=0)
    r1 = replay_summary(recs, engine=1)
    assert (r0["rounds"], r1["rounds"]) == (3, 3)
    assert (r0["generated_tokens"], r1["generated_tokens"]) == (3, 6)
    assert r0["ttfts"] == r1["ttfts"] == [0.0, 1.0, 2.0]
    unfiltered = replay_summary(recs)
    assert unfiltered["rounds"] == 6
    assert unfiltered["generated_tokens"] == 9


def test_composite_fans_out_and_null_discards():
    mem_a, mem_b = MemoryTracker(), MemoryTracker()
    t = CompositeTracker(mem_a, NullTracker(), mem_b)
    t.log_hyperparameters({"k": 1})
    t.log_metrics({"v": 2}, step=7)
    t.finish()
    for mem in (mem_a, mem_b):
        assert mem.hparams == [{"k": 1}]
        assert mem.records == [{"v": 2, "step": 7}]


# ---------------- scheduler stream conservation ----------------


def test_scheduler_stream_replays_to_totals(setup):
    """Summing the per-round deltas of the emitted stream must equal the
    live ``SchedulerStats`` totals — across warm prefix hits, chunked
    prefill, and multi-wave serving."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    mem = MemoryTracker()
    sched = _sched(cfg, params, mem, token_budget=16)
    base = rng.integers(0, cfg.vocab, size=(10,)).astype(np.int32)
    long_p = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
    for wave in ([base], [np.concatenate([base, base[:4]]), long_p]):
        for p in wave:
            sched.submit(p, GEN)
        sched.run()

    st = sched.stats
    assert st.prefix_hit_tokens > 0  # warm wave actually hit
    assert len(mem.records) == st.rounds
    assert [h["surface"] for h in mem.hparams] == ["scheduler"]
    rep = replay_summary(mem.records)
    for k in DELTA_KEYS:
        assert rep[k] == getattr(st, k), k
    assert rep["rounds"] == st.rounds
    assert len(rep["ttfts"]) == len(st.ttfts)
    assert rep["mean_ttft"] == pytest.approx(st.mean_ttft, abs=1e-5)
    # gauges come from the last record and reflect the pool right now
    last = mem.records[-1]
    assert last["pool_cached_blocks"] == sched.pool.cached_blocks
    assert last["queued"] == 0 and last["active"] == 0
    # lifetime alloc/free conservation is visible in the stream
    assert last["pool_alloc_blocks"] - last["pool_freed_blocks"] == (
        sched.pool.cached_blocks
    )


def test_drained_work_lands_in_next_record(setup):
    """Counters mutated *outside* ``round()`` (a drain's released
    blocks) must still be accounted by the following emission — deltas
    are against the previous record, not the round start."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    mem = MemoryTracker()
    sched = _sched(cfg, params, mem, token_budget=8)
    long_p = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
    sched.submit(long_p, GEN)
    sched._admit_one()  # first chunk prefilled, no record emitted yet
    moved = sched.drain()
    assert [r.rid for r in moved] == [0]
    sched.submit(long_p, GEN, rid=0)
    sched.run()
    rep = replay_summary(mem.records)
    st = sched.stats
    for k in DELTA_KEYS:
        assert rep[k] == getattr(st, k), k  # pre-drain chunk included
    assert mem.records[-1]["pool_free_blocks"] == sched.pool.free_blocks


def test_jsonl_append_survives_drain_restore_cycles(setup, tmp_path):
    """A JSONL stream reopened mid-life (process restart between a
    drain and the requeue) appends rather than truncates, and the
    stitched stream still replays to the live totals."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    path = tmp_path / "serve.jsonl"
    sched = _sched(cfg, params, JsonlTracker(path), token_budget=8)
    long_p = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
    sched.submit(long_p, GEN)
    sched._admit_one()  # first chunk prefilled mid-flight
    moved = sched.drain()
    assert [r.rid for r in moved] == [0]
    sched.tracker.finish()
    sched.tracker = JsonlTracker(path)  # reopened: append mode
    sched.submit(long_p, GEN, rid=0)
    sched.run()
    sched.tracker.finish()
    recs = read_jsonl(path)
    rep = replay_summary(recs)
    st = sched.stats
    for k in DELTA_KEYS:
        assert rep[k] == getattr(st, k), k
    assert rep["rounds"] == st.rounds


# ---------------- fleet stream ----------------


def test_fleet_stream_replays_per_engine(setup, tmp_path):
    """A two-engine fleet sharing one JSONL tracker produces a stream
    that splits by engine id and replays to each engine's summary."""
    cfg, params = setup
    cost = StepCostModel.for_config(get_config("smollm_360m"), slots=SLOTS)
    spec = TrafficSpec(
        vocab=cfg.vocab,
        n_requests=8,
        arrival_rate=2000.0,
        prompt_lens=((6, 0.5), (10, 0.5)),
        gen_lens=((4, 1.0),),
        seed=5,
    )
    path = tmp_path / "fleet.jsonl"
    tracker = JsonlTracker(path)
    cl = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, tracker=tracker,
    )
    res = cl.run(synthesize(spec))
    tracker.finish()
    recs = read_jsonl(path)
    assert sum(r["kind"] == "hparams" for r in recs) == 2  # one per engine
    for e in cl.engines:
        rep = replay_summary(recs, engine=e.engine_id)
        summ = e.summary()
        for k in (
            "completed", "handoffs", "prefill_steps", "prefill_tokens",
            "decode_steps", "generated_tokens",
        ):
            assert rep[k] == summ[k], (e.engine_id, k)
        assert rep["clock_s"] == pytest.approx(summ["clock_s"], abs=1e-5)
    # every completion shows up as a virtual-time "done" event
    done = {
        rid
        for r in recs
        if r["kind"] == "metrics"
        for kind, rid, _ in r.get("events", ())
        if kind == "done"
    }
    assert done == set(res.outputs)
