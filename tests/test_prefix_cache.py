"""Radix prefix cache (`runtime.prefix_cache`) + refcounted COW pool
(ISSUE 5): longest-prefix matching at block granularity, partial-block
divergence via copy-on-write, hybrid SSM-state anchors, LRU eviction
under admission pressure, and — the acceptance gate — prefix-cached
decode being *exactly* token-identical to cold-start serving for dense,
packed, and hybrid archs, greedy and seeded sampling alike."""

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.runtime.kv_pool import KVPool
from repro.runtime.prefix_cache import PrefixCache
from repro.runtime.scheduler import Scheduler

BLOCK, MAX_LEN, SLOTS, GEN = 4, 48, 3, 4


def _cfg():
    return get_smoke_config("smollm_360m")


def _sched(cfg, params, cached=True, slots=SLOTS, n_blocks=None, **kw):
    if n_blocks is None:
        pool = KVPool.for_slots(
            cfg, slots=slots, max_len=MAX_LEN, block_tokens=BLOCK
        )
    else:
        pool = KVPool(cfg, n_blocks=n_blocks, block_tokens=BLOCK)
    cache = PrefixCache(pool) if cached else None
    return Scheduler(
        cfg, params, pool, slots=slots, max_len=MAX_LEN,
        prefix_cache=cache, **kw,
    )


def _serve_waves(sched, waves, gen=GEN):
    for wave in waves:
        for p in wave:
            sched.submit(p, gen)
        sched.run()
    sched.pool.validate()
    return sched.outputs()


def _prompt(rng, n, vocab):
    return rng.integers(0, vocab, size=(n,)).astype(np.int32)


# ---------------- radix tree unit behaviour ----------------


def test_radix_match_insert_and_cap():
    """Full blocks match through the tree; the match is capped at p-1
    (something must prefill); a mid-block divergence returns the partial
    block for COW; unrelated prompts miss."""
    cfg = _cfg()
    pool = KVPool(cfg, n_blocks=33, block_tokens=BLOCK)
    cache = PrefixCache(pool)
    prompt = np.arange(100, 112, dtype=np.int32)  # 12 tokens, 3 blocks
    pool.admit(0, 12)
    pool.note_tokens(0, 12)
    blocks = pool.blocks_of(0)
    cache.commit(prompt, blocks)
    assert cache.stats()["nodes"] == 3
    pool.release(0)
    pool.validate()

    # identical prompt: cap at p-1 = 11 -> 2 full blocks + COW tail
    m = cache.lookup(prompt)
    assert (m.matched, m.shared, m.tail_block) == (11, blocks[:2], blocks[2])
    # an extension matches the whole committed prefix, block-aligned
    ext = np.concatenate([prompt, [7, 8]]).astype(np.int32)
    m = cache.lookup(ext)
    assert (m.matched, m.shared, m.tail_block) == (12, blocks, None)
    # divergence mid-block 2: partial match -> COW that block
    div = prompt.copy()
    div[9] = 999
    m = cache.lookup(div)
    assert (m.matched, m.shared, m.tail_block) == (9, blocks[:2], blocks[2])
    # divergence in block 0: 3 shared tokens, all COW
    div0 = prompt.copy()
    div0[3] = 999
    m = cache.lookup(div0)
    assert (m.matched, m.shared, m.tail_block) == (3, (), blocks[0])
    # a 1-token prompt can never hit (cap 0), nor can a miss
    assert cache.lookup(prompt[:1]) is None
    assert cache.lookup(np.array([1, 2, 3, 4, 5], np.int32)) is None
    # peek scoring does not bump hit counters
    hits = cache.hits
    assert cache.match_tokens(prompt) == 11
    assert cache.hits == hits


def test_radix_eviction_is_lru_and_bottom_up():
    """Eviction removes leaf nodes LRU-first, freeing exactly the blocks
    nothing else holds; a fresher chain survives an older one."""
    cfg = _cfg()
    pool = KVPool(cfg, n_blocks=33, block_tokens=BLOCK)
    cache = PrefixCache(pool)
    old = np.arange(0, 8, dtype=np.int32)
    new = np.arange(50, 58, dtype=np.int32)
    for rid, p in ((0, old), (1, new)):
        pool.admit(rid, 8)
        pool.note_tokens(rid, 8)
        cache.commit(p, pool.blocks_of(rid))
        pool.release(rid)
    cache.lookup(np.concatenate([new, [1]]).astype(np.int32))  # touch new
    assert pool.cached_blocks == 4
    freed = cache.evict(2)
    assert freed == 2
    # the untouched chain went first, deepest leaf upward
    assert cache.lookup(np.concatenate([old, [1]]).astype(np.int32)) is None
    assert cache.lookup(np.concatenate([new, [1]]).astype(np.int32)) is not None
    pool.validate()
    assert pool.free_blocks + pool.cached_blocks == pool.usable_blocks


def test_eviction_spares_zero_gain_anchors():
    """A block-aligned anchor (tail None) frees nothing when evicted;
    under pressure the evictor must reclaim real blocks (LRU leaves)
    and keep such anchors — hybrid resume points — alive."""
    cfg = get_smoke_config("zamba2_2p7b")
    pool = KVPool(cfg, n_blocks=33, block_tokens=BLOCK)
    cache = PrefixCache(pool)
    lane = {"ssm": np.zeros((2, 1, 1), np.float32)}
    anchored = np.arange(0, 8, dtype=np.int32)  # aligned: tail None
    pool.admit(0, 8)
    pool.note_tokens(0, 8)
    cache.commit(anchored, pool.blocks_of(0), lane_state=lane)
    pool.release(0)
    plain = np.arange(50, 58, dtype=np.int32)
    pool.admit(1, 8)
    pool.note_tokens(1, 8)
    cache.commit(plain, pool.blocks_of(1))
    pool.release(1)
    cache.lookup(np.concatenate([anchored, [1]]).astype(np.int32),
                 anchor=True)  # the anchor chain is *fresher* than plain
    assert cache.evict(1) == 1
    # the plain chain's leaf went; the anchor (and its chain) survived
    m = cache.lookup(
        np.concatenate([anchored, [1]]).astype(np.int32), anchor=True
    )
    assert m is not None and m.matched == 8
    # with nothing else left, anchors do yield so their nodes can free
    freed = cache.evict(8)
    assert freed >= 3  # plain's other block + the anchor chain's two
    assert pool.cached_blocks == 0
    pool.validate()


# ---------------- scheduler-level token identity ----------------


def test_warm_serving_token_identical_and_charges_suffix_only():
    """Prefix-cached serving must reproduce cold serving exactly while
    charging prefill only for unmatched suffixes — including a sibling
    that diverges mid-block (COW) and co-resident aliasing."""
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(5)
    base = _prompt(rng, 10, cfg.vocab)  # 10 % BLOCK != 0
    ext = np.concatenate([base, _prompt(rng, 6, cfg.vocab)])
    sib = np.concatenate([base[:-1], _prompt(rng, 7, cfg.vocab)])
    waves = [[base], [ext, sib]]

    cold = _serve_waves(_sched(cfg, params, cached=False), waves)
    warm_s = _sched(cfg, params, cached=True)
    warm = _serve_waves(warm_s, waves)
    assert warm == cold
    st = warm_s.stats
    # base misses; at completion it commits prompt + generated tokens
    # (13 of 14 — the last sampled token never entered the KV), so ext
    # matches 10: its 2 full prompt blocks plus 2 tokens into base's
    # generated block (mid-block COW). Once ext commits, sib matches 9 —
    # one token into the third block.
    assert st.prefix_hit_tokens == 10 + 9
    assert st.prefill_tokens == 10 + (16 - 10) + (16 - 9)
    assert st.prefix_hits == 2
    assert st.shared_blocks_peak >= 2  # ext and sib alias base's blocks


def test_warm_serving_matches_seeded_sampling():
    """The identity gate holds under non-greedy sampling too: the rng is
    keyed on (seed, rid, position), which cached prefill does not move."""
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(6)
    base = _prompt(rng, 12, cfg.vocab)
    ext = np.concatenate([base, _prompt(rng, 5, cfg.vocab)])
    sp = lm.SamplingParams(temperature=0.8, top_k=16, top_p=0.9, seed=11)
    waves = [[base], [ext]]
    cold = _serve_waves(_sched(cfg, params, cached=False, sampling=sp), waves)
    warm = _serve_waves(_sched(cfg, params, cached=True, sampling=sp), waves)
    assert warm == cold


def test_warm_serving_packed_arch():
    """FCMP-packed weights (w_bits=1) hold the same identity gate."""
    cfg = dataclasses.replace(_cfg(), w_bits=1)
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    base = _prompt(rng, 8, cfg.vocab)
    ext = np.concatenate([base, _prompt(rng, 8, cfg.vocab)])
    waves = [[base], [ext]]
    cold = _serve_waves(_sched(cfg, params, cached=False), waves)
    warm_s = _sched(cfg, params, cached=True)
    warm = _serve_waves(warm_s, waves)
    assert warm == cold
    assert warm_s.stats.prefix_hits == 1


def test_hybrid_warm_serving_resumes_ssm_state():
    """Zamba2 prefix hits resume from the anchor's SSM snapshot: nested
    multi-turn prompts reproduce cold serving exactly, with anchors at
    non-block-aligned positions exercising the COW tail."""
    cfg = get_smoke_config("zamba2_2p7b")
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(8)
    t1 = _prompt(rng, 9, cfg.vocab)  # 9 % BLOCK != 0: partial-tail anchor
    t2 = np.concatenate([t1, _prompt(rng, 7, cfg.vocab)])
    t3 = np.concatenate([t2, _prompt(rng, 6, cfg.vocab)])
    waves = [[t1], [t2], [t3]]
    cold = _serve_waves(_sched(cfg, params, cached=False), waves)
    warm_s = _sched(cfg, params, cached=True)
    warm = _serve_waves(warm_s, waves)
    assert warm == cold
    st = warm_s.stats
    assert st.prefix_hits == 2
    assert st.prefix_hit_tokens == 9 + 16  # t2 resumes at 9, t3 at 16
    assert st.prefill_tokens == 9 + 7 + 6


def test_hybrid_divergent_prompt_misses_anchor():
    """A prompt sharing tokens but not a committed *prompt end* has no
    SSM state to resume from — hybrids must miss, not corrupt."""
    cfg = get_smoke_config("zamba2_2p7b")
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(9)
    t1 = _prompt(rng, 8, cfg.vocab)
    div = np.concatenate([t1[:6], _prompt(rng, 6, cfg.vocab)])
    waves = [[t1], [div]]
    cold = _serve_waves(_sched(cfg, params, cached=False), waves)
    warm_s = _sched(cfg, params, cached=True)
    warm = _serve_waves(warm_s, waves)
    assert warm == cold
    assert warm_s.stats.prefix_hits == 0  # 6 matched tokens but no anchor


def test_eviction_under_admission_pressure():
    """A pool too small to keep every finished prompt cached must evict
    LRU prefixes to admit new work — and still serve correctly."""
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(10)
    # 8 usable blocks; each 8+GEN request commits 3 blocks -> pressure
    prompts = [_prompt(rng, 8, cfg.vocab) for _ in range(6)]
    sched = _sched(cfg, params, cached=True, slots=2, n_blocks=9)
    outs = _serve_waves(sched, [[p] for p in prompts])
    assert sorted(outs) == list(range(6))
    assert sched.prefix_cache.evicted_blocks > 0
    cold = _serve_waves(
        _sched(cfg, params, cached=False, slots=2, n_blocks=9),
        [[p] for p in prompts],
    )
    assert outs == cold


def test_shared_blocks_counted_once_in_utilization():
    """Eq.-1-style accounting: co-resident requests aliasing one prefix
    contribute its physical rows (and tokens) once."""
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(11)
    base = _prompt(rng, 8, cfg.vocab)
    ext_a = np.concatenate([base, _prompt(rng, 4, cfg.vocab)])
    ext_b = np.concatenate([base, _prompt(rng, 4, cfg.vocab)])
    sched = _sched(cfg, params, cached=True)
    sched.submit(base, GEN)
    sched.run()
    for p in (ext_a, ext_b):
        sched.submit(p, GEN)
    while sched.queue or any(r is not None for r in sched.active):
        sched.round()
        st = sched.pool.stats()
        assert st.utilization <= 1.0 + 1e-9
        assert st.held_blocks <= st.n_blocks
    assert sched.stats.shared_blocks_peak >= 2
    sched.pool.validate()


def test_moe_warm_serving_token_identical():
    """MoE holds the identity gate (the carve-out is gone): dropless
    routing makes a cached prefix's KV exactly what a cold prefill would
    recompute, so warm serving reproduces cold serving token-for-token
    while charging prefill only for the unmatched suffix."""
    cfg = get_smoke_config("olmoe_1b_7b")
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(12)
    base = _prompt(rng, 10, cfg.vocab)  # 10 % BLOCK != 0
    ext = np.concatenate([base, _prompt(rng, 6, cfg.vocab)])
    waves = [[base], [ext]]
    cold = _serve_waves(_sched(cfg, params, cached=False), waves)
    warm_s = _sched(cfg, params, cached=True)
    warm = _serve_waves(warm_s, waves)
    assert warm == cold
    st = warm_s.stats
    assert st.prefix_hits == 1
    assert st.prefix_hit_tokens == 10  # 2 full blocks + 2-token COW tail
    assert st.expert_tokens > 0  # routed through the dropless dispatch


def test_moe_followup_adopts_generated_tokens():
    """The generated-token adoption path works for moe too: a follow-up
    over a finished moe conversation matches into the generated region
    and replays the cold stream exactly."""
    cfg = get_smoke_config("olmoe_1b_7b")
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(15)
    base = _prompt(rng, 10, cfg.vocab)

    warm_s = _sched(cfg, params, cached=True)
    warm_s.submit(base, GEN)
    warm_s.run()
    reply = warm_s.outputs()[0]
    assert len(reply) == GEN
    followup = np.concatenate(
        [base, np.asarray(reply, np.int32), _prompt(rng, 5, cfg.vocab)]
    )
    # committed seq = 10 prompt + 3 generated = 13 -> 3 full blocks
    assert warm_s.prefix_cache.match_tokens(followup) == 12

    warm_s.submit(followup, GEN)
    warm_s.run()
    warm = warm_s.outputs()

    cold_s = _sched(cfg, params, cached=False)
    for p in (base, followup):
        cold_s.submit(p, GEN)
        cold_s.run()
    assert warm == cold_s.outputs()
    assert warm_s.stats.prefix_hit_tokens == 12


# ---------------- generated-token re-indexing (ISSUE 6) ----------------


def test_followup_adopts_generated_tokens():
    """A finished request re-commits prompt + generated tokens, so a
    multi-turn follow-up (prior prompt + prior response + new text)
    matches past the original prompt into the *generated* region."""
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(13)
    base = _prompt(rng, 10, cfg.vocab)

    warm_s = _sched(cfg, params, cached=True)
    warm_s.submit(base, GEN)
    warm_s.run()
    reply = warm_s.outputs()[0]
    assert len(reply) == GEN
    followup = np.concatenate(
        [base, np.asarray(reply, np.int32), _prompt(rng, 5, cfg.vocab)]
    )
    # committed seq = 10 prompt + 3 generated (the last sampled token
    # never entered the KV) = 13 -> 3 full blocks; the follow-up matches
    # all 12 block-aligned tokens, 2 of them generated
    assert warm_s.prefix_cache.match_tokens(followup) == 12
    assert len(base) < 12

    warm_s.submit(followup, GEN)
    warm_s.run()
    warm = warm_s.outputs()

    cold_s = _sched(cfg, params, cached=False)
    for p in (base, followup):
        cold_s.submit(p, GEN)
        cold_s.run()
    assert warm == cold_s.outputs()
    st = warm_s.stats
    assert st.prefix_hit_tokens == 12
    assert st.prefill_tokens == 10 + (len(followup) - 12)


def test_hybrid_followup_resumes_at_conversation_end():
    """Hybrid completion commits an anchor at the *conversation* end
    (prompt + generated), so the canonical multi-turn follow-up resumes
    the SSM state there and prefills only the new turn — and the block
    that was the prompt anchor's partial tail (now a full node: the pin
    multiset case) evicts exactly once with nothing leaked."""
    cfg = get_smoke_config("zamba2_2p7b")
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(14)
    base = _prompt(rng, 9, cfg.vocab)  # unaligned: anchor pins a tail

    warm_s = _sched(cfg, params, cached=True)
    warm_s.submit(base, GEN)
    warm_s.run()
    reply = warm_s.outputs()[0]
    followup = np.concatenate(
        [base, np.asarray(reply, np.int32), _prompt(rng, 6, cfg.vocab)]
    )
    # completion anchor sits at 9 + 3 = 12 consumed tokens
    assert warm_s.prefix_cache.match_tokens(followup, anchor=True) == 12

    warm_s.submit(followup, GEN)
    warm_s.run()
    warm = warm_s.outputs()
    cold_s = _sched(cfg, params, cached=False)
    for p in (base, followup):
        cold_s.submit(p, GEN)
        cold_s.run()
    assert warm == cold_s.outputs()
    st = warm_s.stats
    assert st.prefix_hit_tokens >= 12
    assert st.prefill_tokens == 9 + (len(followup) - st.prefix_hit_tokens)

    # full eviction releases the double-pinned block exactly once
    cache = warm_s.prefix_cache
    cache.evict(warm_s.pool.usable_blocks)
    assert warm_s.pool.cached_blocks == 0
    warm_s.pool.validate()
    assert warm_s.pool.free_blocks == warm_s.pool.usable_blocks
