"""Speculative decoding: draft-tree verification over the paged pool.

The hard invariant is *structural token identity*: whatever the drafter
proposes, the verifier samples each position from the target's own
logits with the non-speculative rng key (seed, rid, position), so the
served stream is byte-identical to plain decode — drafter quality moves
the acceptance rate, never the output. The property sweep drives random
(seed, depth, acceptance-pattern) draft trees through a protocol-level
drafter that mixes oracle and deliberately-wrong proposals, checking
identity, pool refcount/ledger exactness after every rollback, and the
accepted-token conservation law.
"""

import dataclasses
import functools
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.configs import get_smoke_config
from repro.models import lm
from repro.runtime.kv_pool import KVPool
from repro.runtime.memledger import GAUGES, MemLedger, _snapshot
from repro.runtime.scheduler import Scheduler
from repro.runtime.speculative import (
    MODEL_DRAFT_FAMILIES,
    NgramDrafter,
    SpecConfig,
    Speculator,
    build_speculator,
    compatible_drafters,
    dequantize_ffn_params,
    pack_ffn_params,
    resolve,
)
from repro.runtime.tracker import DELTA_KEYS, MemoryTracker, delta_coverage_gaps

BLOCK, MAX_LEN, SLOTS, P, GEN = 4, 32, 2, 6, 8
N_REQ = 3  # > SLOTS so one request staggers in behind the others


@functools.lru_cache(maxsize=None)
def _ctx(arch="smollm_360m"):
    cfg = get_smoke_config(arch)
    return cfg, lm.init_params(cfg, jax.random.key(0))


def _pool(cfg):
    return KVPool(
        cfg, n_blocks=1 + SLOTS * MAX_LEN // BLOCK, block_tokens=BLOCK
    )


def _sched(cfg, params, **kw):
    kw.setdefault("slots", SLOTS)
    kw.setdefault("max_len", MAX_LEN)
    return Scheduler(cfg, params, _pool(cfg), **kw)


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, vocab, size=(P,)).astype(np.int32) for _ in range(n)
    ]


def _baseline(cfg, params, prompts, sampling):
    sched = _sched(cfg, params, sampling=sampling)
    for p in prompts:
        sched.submit(p, GEN)
    sched.run()
    return sched.outputs()


class PatternDrafter:
    """Protocol-level drafter for the property sweep: proposes the token
    the non-speculative oracle stream holds at each position with
    probability ``q``, else a token guaranteed wrong — so a random
    acceptance pattern exercises every accept length from 1 (pending
    only) to the full chain, without any model cost."""

    is_model = False

    def __init__(self, oracle, vocab, q, seed):
        self.oracle = oracle  # rid -> the full non-speculative output
        self.vocab = vocab
        self.q = q
        self.rng = np.random.default_rng(seed)

    def start_lane(self, slot, prompt):
        return 0, 0

    def release_lane(self, slot):
        pass

    def accept(self, slot, n_rows):
        pass

    def propose(self, lanes, k, sampling):
        props = np.zeros((len(lanes), k - 1), np.int32)
        for j, ln in enumerate(lanes):
            out = self.oracle[ln.rid]
            for m in range(k - 1):
                pos = ln.out_len + m
                right = int(out[pos]) if pos < len(out) else 0
                if self.rng.random() < self.q:
                    props[j, m] = right
                else:  # anything in the vocab except the oracle token
                    wrong = int(self.rng.integers(self.vocab - 1))
                    props[j, m] = (right + 1 + wrong) % self.vocab
        return props, 0


def _integrated_ledger_state(records):
    """Fold the attach baseline + every d_ delta, as validate_ledger
    does, returning the integrated gauge dict."""
    assert records and records[0]["op"] == "attach"
    state = {k: records[0][k] for k in GAUGES}
    for r in records[1:]:
        if r.get("op") == "reserve":
            continue
        for k in GAUGES:
            state[k] += r.get("d_" + k, 0)
    return state


# ---------------- the property sweep ----------------


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 5),
    depth=st.sampled_from((2, 3, 5)),
    q=st.sampled_from((0.0, 0.35, 0.75, 1.0)),
    greedy=st.booleans(),
)
def test_random_draft_trees_are_token_identical(seed, depth, q, greedy):
    cfg, params = _ctx()
    sampling = (
        lm.SamplingParams()
        if greedy
        else lm.SamplingParams(temperature=0.9, top_k=32, seed=seed)
    )
    prompts = _prompts(N_REQ, cfg.vocab, seed=seed)
    oracle = _baseline(cfg, params, prompts, sampling)

    tracker = MemoryTracker()
    ledger = MemLedger(lambda: 0.0, tracker=tracker)
    sched = _sched(
        cfg,
        params,
        sampling=sampling,
        speculative=Speculator(
            PatternDrafter(oracle, cfg.vocab, q, seed), depth=depth
        ),
        ledger=ledger,
    )
    for p in prompts:
        sched.submit(p, GEN)
    while sched.queue or any(r is not None for r in sched.active):
        sched.round()
        # rollback exactness, probed after every round: refcounts audit
        # clean and no draft-class block outlives its verify cycle
        sched.pool.validate()
        assert not sched.pool.draft_rids()

    assert sched.outputs() == oracle, (
        f"speculative stream diverged (depth={depth}, q={q}, "
        f"greedy={greedy})"
    )

    # accepted-token conservation: every decode token flowed through a
    # verify step (the first token of each request comes from prefill)
    stats = sched.stats
    assert stats.accepted_tokens == N_REQ * (GEN - 1)
    # a verify step is ONE batched cycle across every decoding lane, so
    # the bounds are per-cycle: a request needs at least ceil((GEN-1)/
    # depth) cycles of its own, and the worst case is one token per
    # cycle with no lane overlap at all
    per_req = math.ceil((GEN - 1) / depth)
    assert per_req <= stats.verify_steps <= N_REQ * (GEN - 1)
    if q == 1.0:  # every chain accepted whole
        assert stats.verify_steps <= N_REQ * per_req
    if q == 0.0:  # every proposal rejected: one token per lane-cycle
        assert stats.verify_steps >= GEN - 1
    assert stats.draft_tokens > 0

    # ledger exactness: integrating the draft_grow/draft_end deltas (and
    # everything else) lands int-exactly on the live pool snapshot
    ledger.sync()
    ledger.flush()
    recs = tracker.mems
    assert _integrated_ledger_state(recs) == _snapshot(sched.pool)
    # decode-time block growth goes through the draft owner class
    assert any(r.get("op") == "draft_grow" for r in recs)


# ---------------- drafter units ----------------


def test_ngram_drafter_continuation():
    d = NgramDrafter()
    ctx = np.array([7, 1, 2, 3, 9, 1, 2], np.int32)
    # suffix [1, 2] last occurred at index 1 -> continuation 3, 9
    np.testing.assert_array_equal(d._continuation(ctx, 2), [3, 9])
    # no earlier occurrence of anything: repeat-last fallback
    np.testing.assert_array_equal(
        d._continuation(np.array([4, 5, 6], np.int32), 3), [6, 6, 6]
    )
    # match runs to end of context: continuation crosses into the suffix
    ctx2 = np.array([1, 2, 8, 1, 2], np.int32)
    np.testing.assert_array_equal(d._continuation(ctx2, 3), [8, 1, 2])
    # continuation shorter than n: padded with its own last token
    ctx3 = np.array([3, 7, 3], np.int32)
    np.testing.assert_array_equal(d._continuation(ctx3, 3), [7, 3, 3])


def test_ngram_speculation_token_identical_seeded():
    cfg, params = _ctx()
    sampling = lm.SamplingParams(temperature=0.8, top_k=40, seed=3)
    prompts = _prompts(N_REQ, cfg.vocab, seed=21)
    oracle = _baseline(cfg, params, prompts, sampling)
    spec = build_speculator(
        cfg,
        params,
        SpecConfig(drafter="ngram", depth=4),
        slots=SLOTS,
        max_len=MAX_LEN,
        smoke=True,
    )
    sched = _sched(cfg, params, sampling=sampling, speculative=spec)
    for p in prompts:
        sched.submit(p, GEN)
    sched.run()
    assert sched.outputs() == oracle
    assert sched.stats.accepted_tokens == N_REQ * (GEN - 1)


def test_model_drafter_twin_token_identical():
    cfg, params = _ctx()
    # the lossless pairing: a dequantized target and its re-packed twin
    params = dequantize_ffn_params(params, 2)
    prompts = _prompts(N_REQ, cfg.vocab, seed=8)
    oracle = _baseline(cfg, params, prompts, None)
    spec = build_speculator(
        cfg,
        params,
        SpecConfig(drafter="smollm_360m", depth=4, quant=2),
        slots=SLOTS,
        max_len=MAX_LEN,
        smoke=True,
    )
    assert spec.is_model and spec.name.endswith("@w2")
    sched = _sched(cfg, params, speculative=spec)
    for p in prompts:
        sched.submit(p, GEN)
    sched.run()
    assert sched.outputs() == oracle
    # the twin's logits equal the target's, so every chain is accepted
    # whole: no request ever needs more than ceil((GEN-1)/depth) cycles
    assert sched.stats.verify_steps <= N_REQ * math.ceil((GEN - 1) / 4)


def test_twin_packing_round_trips_on_its_own_codebook():
    cfg, params = _ctx()
    dense = dequantize_ffn_params(params, 2)
    first = pack_ffn_params(params, 2)
    again = pack_ffn_params(dense, 2)
    for k in ("w1", "w3", "w2"):
        # re-quantizing the dequantized twin reproduces the codes exactly
        # (the codebook is a fixed point); the recomputed scale only
        # drifts by float-sum epsilon
        np.testing.assert_array_equal(
            np.asarray(first["layers"][k]["packed"]),
            np.asarray(again["layers"][k]["packed"]),
        )
        np.testing.assert_allclose(
            np.asarray(first["layers"][k]["scale"]),
            np.asarray(again["layers"][k]["scale"]),
            rtol=1e-5,
        )


# ---------------- pool draft bracket ----------------


def test_pool_draft_bracket_grow_and_rollback():
    cfg, _ = _ctx()
    pool = _pool(cfg)
    tracker = MemoryTracker()
    ledger = MemLedger(lambda: 0.0, tracker=tracker)
    ledger.attach(pool)
    pool.admit(0, P + GEN)
    pool.note_tokens(0, P)
    held = pool.blocks_held(0)
    free = pool.free_blocks

    pool.begin_draft(0, P + 5)  # grows across a block boundary
    assert set(pool.draft_rids()) == {0}
    assert pool.blocks_held(0) > held
    pool.validate()  # draft growth keeps the refcount audit clean

    pool.end_draft(0, P + 1)  # chain rejected: keep only the pending row
    assert not pool.draft_rids()
    assert pool.free_blocks == free  # surplus blocks all returned
    pool.validate()

    # ledger integrates to the live snapshot across the bracket
    ledger.sync()
    ledger.flush()
    mems = tracker.mems
    assert any(r["op"] == "draft_grow" for r in mems)
    assert any(r["op"] == "draft_end" for r in mems)
    assert _integrated_ledger_state(mems) == _snapshot(pool)

    pool.release(0)
    pool.validate()
    assert pool.free_blocks == pool.usable_blocks


def test_release_clears_open_draft_bracket():
    cfg, _ = _ctx()
    pool = _pool(cfg)
    pool.admit(0, P + GEN)
    pool.note_tokens(0, P)
    pool.begin_draft(0, P + 4)
    pool.release(0)  # drain/abort path: bracket still open
    assert not pool.draft_rids()
    pool.validate()
    assert pool.free_blocks == pool.usable_blocks


# ---------------- resolution ----------------


def test_resolve_rejects_unknown_drafter_listing_options():
    cfg, _ = _ctx()
    with pytest.raises(ValueError, match="ngram"):
        resolve(cfg, SpecConfig(drafter="no_such_arch"), smoke=True)


def test_resolve_rejects_unpackable_drafter_family():
    cfg, _ = _ctx()
    with pytest.raises(ValueError, match="packed twin"):
        resolve(cfg, SpecConfig(drafter="olmoe_1b_7b"), smoke=True)


def test_resolve_rejects_vocab_mismatch():
    cfg, _ = _ctx()
    target = dataclasses.replace(cfg, vocab=cfg.vocab + 1)
    with pytest.raises(ValueError, match="vocab"):
        resolve(target, SpecConfig(drafter="smollm_360m"), smoke=True)


def test_resolve_rejects_hybrid_target():
    hybrid = get_smoke_config("zamba2_2p7b")
    with pytest.raises(ValueError, match="roll back"):
        resolve(hybrid, SpecConfig(drafter="ngram"), smoke=True)


def test_resolve_rejects_bad_depth_and_quant():
    cfg, _ = _ctx()
    with pytest.raises(ValueError, match="depth"):
        resolve(cfg, SpecConfig(drafter="ngram", depth=1), smoke=True)
    with pytest.raises(ValueError, match="carrier"):
        resolve(cfg, SpecConfig(drafter="ngram", quant=4), smoke=True)


def test_compatible_drafters_cover_packable_families():
    cfg, _ = _ctx()
    opts = compatible_drafters(cfg, smoke=True)
    assert opts[0] == "ngram"
    assert "smollm_360m" in opts  # the twin itself
    for arch in opts[1:]:
        assert get_smoke_config(arch).family in MODEL_DRAFT_FAMILIES


def test_moe_target_has_no_twin_drafter():
    mcfg = get_smoke_config("olmoe_1b_7b")
    opts = compatible_drafters(mcfg, smoke=True)
    # ngram and *foreign* packable archs, never the moe arch itself
    # (expert FFNs do not pack into FCMP carriers)
    assert "ngram" in opts and "olmoe_1b_7b" not in opts
    rs = resolve(mcfg, SpecConfig(drafter="ngram"), smoke=True)
    assert rs.draft_cfg is None and not rs.twin


# ---------------- telemetry coverage ----------------


def test_spec_counters_are_replayable_deltas():
    for key in ("accepted_tokens", "draft_tokens", "verify_steps"):
        assert key in DELTA_KEYS
    assert delta_coverage_gaps() == []
