"""Unit + property tests for the FCMP core (packing, GALS, buffers)."""

import math
from fractions import Fraction

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BRAM18,
    DEVICES,
    Folding,
    GaParams,
    GalsOperatingPoint,
    LayerSpec,
    PackItem,
    WeightBuffer,
    baseline_packing,
    bin_cost,
    buffer_set,
    cnv_layers,
    folding_delta_fps,
    max_bin_height,
    mvau_buffer,
    mvau_cycles,
    needs_odd_even_split,
    pack_anneal,
    pack_ffd,
    pack_genetic,
    required_rf,
    resnet50_layers,
    resblock_slr_map,
    search_folding,
    virtual_ports,
)
from repro.core.buffers import kernel_efficiency_bound
from repro.core.gals import reads_per_compute_cycle, split_buffer_rate


# ---------------------------------------------------------------- buffers


def test_mvau_buffer_shapes():
    layer = LayerSpec("l", c_in=64, c_out=128, k=3, out_pixels=100, w_bits=1)
    buf = mvau_buffer(layer, Folding(pe=4, simd=8))
    assert buf.width_bits == 4 * 8 * 1
    assert buf.depth_words == (9 * 64 // 8) * (128 // 4)
    assert buf.bits == layer.param_bits  # folding never changes total bits


def test_folding_validation():
    layer = LayerSpec("l", c_in=64, c_out=128, k=3)
    with pytest.raises(ValueError):
        mvau_buffer(layer, Folding(pe=3, simd=8))  # 3 does not divide 128
    with pytest.raises(ValueError):
        mvau_buffer(layer, Folding(pe=4, simd=7))  # 7 does not divide 576


@given(
    c_in=st.sampled_from([16, 32, 64, 128]),
    c_out=st.sampled_from([16, 32, 64, 128]),
    k=st.sampled_from([1, 3, 5]),
    pe_log=st.integers(0, 4),
    simd_log=st.integers(0, 4),
    w=st.sampled_from([1, 2, 4, 8]),
)
def test_folding_preserves_bits_and_work(c_in, c_out, k, pe_log, simd_log, w):
    """Invariant: folding trades width for depth; total bits and total
    cycles*parallelism are conserved (Fig. 2's premise)."""
    layer = LayerSpec("l", c_in, c_out, k, out_pixels=49, w_bits=w)
    pe, simd = 2**pe_log, 2**simd_log
    if c_out % pe or (k * k * c_in) % simd:
        return
    buf = mvau_buffer(layer, Folding(pe, simd))
    assert buf.bits == layer.param_bits
    assert mvau_cycles(layer, Folding(pe, simd)) * pe * simd == layer.macs


def test_more_parallelism_never_fewer_brams():
    """Fig. 2: doubling parallelism keeps params constant but BRAMs
    monotonically non-decreasing."""
    layer = LayerSpec("l", 256, 256, 3, out_pixels=1, w_bits=1)
    prev = 0
    for p in [1, 2, 4, 8, 16]:
        buf = mvau_buffer(layer, Folding(p, p))
        blocks = buf.blocks(BRAM18)
        assert blocks >= prev
        prev = blocks


def test_kernel_efficiency_bound():
    # 3x3 kernels cap efficiency at 9/16; 1x1 at 1.0 (paper §II-B(b))
    assert kernel_efficiency_bound(3) == pytest.approx(9 / 16)
    assert kernel_efficiency_bound(1) == 1.0
    assert kernel_efficiency_bound(5) == pytest.approx(25 / 32)


# ---------------------------------------------------------------- packing


def _items(widths_depths, region=""):
    return [
        PackItem(WeightBuffer(f"b{i}", w, d, 1), region)
        for i, (w, d) in enumerate(widths_depths)
    ]


def test_bin_cost_single_matches_primitive():
    it = _items([(18, 1024)])[0]
    assert bin_cost([it])[0] == 1
    it = _items([(19, 1024)])[0]
    assert bin_cost([it])[0] == 2


def test_bin_cost_vertical_and_horizontal():
    # two 9-wide 1024-deep buffers: vertical concat = 18x1024 = 1 BRAM
    items = _items([(9, 1024), (9, 1024)])
    cost, _ = bin_cost(items)
    assert cost == 1
    # two 18-wide 512-deep buffers: horizontal stack = 18x1024 = 1 BRAM
    items = _items([(18, 512), (18, 512)])
    cost, layout = bin_cost(items)
    assert cost == 1


def test_packing_beats_baseline_on_shallow_buffers():
    # 8 buffers of 18x128: baseline 8 BRAMs, packed (H_B=4) -> 2 BRAMs
    items = _items([(18, 128)] * 8)
    base = baseline_packing(items)
    packed = pack_ffd(items, max_height=4)
    assert base.total_blocks == 8
    assert packed.total_blocks <= 2 * math.ceil(8 / 4)
    assert packed.efficiency > base.efficiency


def test_region_constraint_respected():
    items = _items([(18, 128)] * 4, region="slr0") + _items(
        [(18, 128)] * 4, region="slr1"
    )
    packed = pack_ffd(items, max_height=4)
    packed.validate(4)  # raises if a bin mixes regions
    for b in packed.bins:
        assert len({items[i].region for i in b}) == 1


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 24),
    seed=st.integers(0, 5),
    h=st.sampled_from([2, 3, 4]),
    data=st.data(),
)
def test_packing_properties(n, seed, h, data):
    """Properties for any packing solver output:
    - it is a partition (validate),
    - no bin exceeds H_B,
    - efficiency in (0, 1],
    - never worse than baseline (solvers only merge when it saves)."""
    wd = [
        (
            data.draw(st.sampled_from([1, 2, 4, 9, 18, 32, 64])),
            data.draw(st.sampled_from([16, 100, 512, 1024, 3000])),
        )
        for _ in range(n)
    ]
    items = _items(wd)
    base = baseline_packing(items)
    for solver in (
        lambda: pack_ffd(items, h),
        lambda: pack_anneal(items, h, steps=300, seed=seed),
    ):
        p = solver()
        p.validate(h)
        assert max(p.heights, default=0) <= h
        assert 0 < p.efficiency <= 1.0 + 1e-9
        assert p.total_blocks <= base.total_blocks


def test_genetic_at_least_matches_ffd_cnv():
    layers = cnv_layers(1)
    sol = search_folding(layers, DEVICES["zynq7020"], 0.5, 0.9)
    items = [PackItem(b) for b in buffer_set(layers, sol.foldings)]
    ffd = pack_ffd(items, 4)
    ga = pack_genetic(items, GaParams(max_height=4, generations=15, seed=1))
    assert ga.total_blocks <= ffd.total_blocks
    assert ga.efficiency >= ffd.efficiency


def test_rn50_packing_reaches_paper_band():
    """Paper Table IV: RN50 baseline ~53% -> P4 75-93%. Our model-derived
    folding must show the same qualitative jump (>= 15 points)."""
    layers = resnet50_layers(1)
    sol = search_folding(layers, DEVICES["u250"], 0.55, 0.85)
    bufs = buffer_set(layers, sol.foldings)
    regions = resblock_slr_map(layers, 4)
    items = [PackItem(b, r) for b, r in zip(bufs, regions)]
    base = baseline_packing(items)
    packed = pack_ffd(items, 4)
    assert packed.efficiency - base.efficiency >= 0.10
    assert packed.total_blocks < base.total_blocks


# ---------------------------------------------------------------- GALS


def test_eq2_bin_height():
    assert max_bin_height(1.0) == 2
    assert max_bin_height(1.5) == 3
    assert max_bin_height(2.0) == 4
    assert virtual_ports(2.0) == 4


def test_required_rf():
    assert required_rf(4) == Fraction(2)
    assert required_rf(3) == Fraction(3, 2)
    assert required_rf(2) == Fraction(1)


def test_odd_even_split_flag():
    assert needs_odd_even_split(3)
    assert not needs_odd_even_split(4)
    assert not needs_odd_even_split(2)
    assert not needs_odd_even_split(1)


def test_split_buffer_rate_exceeds_one():
    # Fig. 7b: the split buffer gets 2Nb/(Nb+1) > 1 -> backpressure kicks in
    assert split_buffer_rate(3) == Fraction(6, 4)
    assert float(split_buffer_rate(3)) > 1.0


@given(h=st.integers(1, 8))
def test_rf_h_roundtrip(h):
    rf = required_rf(h)
    assert max_bin_height(float(rf)) >= h
    assert reads_per_compute_cycle(h, float(rf)) >= 1.0 - 1e-9


def test_delta_fps_table5_rn50_u250():
    """Table V row RN50-W1A2-U250-P4: F_c=183, F_m=363, baseline 195 MHz.
    min(183, 363/2)=181.5 -> ~7% raw; paper reports 12% (incl. their
    baseline's 'approximately 12%' target miss). Accept the 5-15% band."""
    op = GalsOperatingPoint(183.0, 363.0, 4, 195.0)
    assert 0.05 <= op.delta_fps <= 0.15
    assert not op.throughput_preserved  # R_F=1.98 < 2 (barely misses)


def test_delta_fps_cnv_zero_loss():
    # Table V: CNV meets 100/200 MHz -> no throughput loss
    op = GalsOperatingPoint(100.0, 200.0, 4, 100.0)
    assert op.delta_fps == pytest.approx(0.0)
    assert op.throughput_preserved


def test_fcmp_beats_folding():
    """Paper §V: FCMP port to U280 loses 32%, folding loses 51% -> FCMP is
    ~38% faster. Check the models reproduce that ordering."""
    fcmp = GalsOperatingPoint(138.0, 373.0, 4, 195.0)  # U280-P4 row
    fold = folding_delta_fps(2)  # F2: half parallelism
    # paper's F2 ran at 191 MHz vs 195 baseline -> delta ~ 1-191/(2*195)=51%
    fold_measured = 1.0 - 191.0 / (2 * 195.0)
    assert fcmp.delta_fps < fold_measured
    speedup = (1 - fcmp.delta_fps) / (1 - fold_measured)
    assert 1.25 <= speedup <= 1.55  # paper: 38% faster


# ---------------------------------------------------------------- folding


def test_search_folding_fits_device():
    layers = cnv_layers(1)
    dev = DEVICES["zynq7020"]
    sol = search_folding(layers, dev, 0.5, 0.9)
    assert sol.luts <= 0.5 * dev.luts
    assert sol.brams <= 0.9 * dev.bram18
    m = sol.model(100.0)
    assert m.fps > 100  # must reach a usable operating point


def test_pipeline_model_identities():
    layers = cnv_layers(1)
    sol = search_folding(layers, DEVICES["zynq7020"], 0.5, 0.9)
    m = sol.model(100.0)
    assert m.latency_s >= m.max_ii / (100e6)
    f2 = m.folded(2)
    assert f2.fps <= m.fps
