"""Distribution-layer tests on forced multi-device CPU (subprocess-based:
the parent pytest process has already locked jax to 1 device, so every
multi-device check runs in a child with XLA_FLAGS set before jax init)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced(script: str, n_dev: int = 8, timeout: int = 500) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_int8_allreduce_multidevice():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.optim.compression import int8_allreduce
        mesh = jax.make_mesh((8,), ("data",))
        g = jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 64.0
        f = shard_map(
            lambda x: int8_allreduce(x[0], "data"),
            mesh=mesh, in_specs=P("data"), out_specs=P(),
        )
        got = f(g)
        want = jnp.mean(g, axis=0)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-2, err
        print("OK", err)
    """)
    assert "OK" in out


def test_pipeline_parallel_forward():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_forward
        mesh = jax.make_mesh((4,), ("stage",))
        # 4 stages, each multiplies by its own matrix
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(size=(4, 1, 8, 8)) * 0.3, jnp.float32)
        xs = jnp.asarray(rng.normal(size=(6, 2, 8)), jnp.float32)  # 6 ubatches
        def stage(w, x):
            return x @ w[0]
        out = pipeline_forward(stage, ws, xs, mesh=mesh, axis="stage")
        want = xs
        for s in range(4):
            want = jnp.einsum("mbi,ij->mbj", want, ws[s, 0])
        err = float(jnp.max(jnp.abs(out - want)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_small_mesh_train_step_shards():
    """A reduced config train step lowers + runs on a real 2x4 mesh, with
    the policy shardings, and matches the single-device result."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_smoke_config
        from repro.dist import sharding as shd
        from repro.models import lm
        from repro.optim.adamw import AdamW
        from repro.runtime.steps import make_train_step
        cfg = get_smoke_config("llama3p2_1b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = lm.init_params(cfg, jax.random.key(0))
        opt = AdamW(warmup_steps=1)
        step = make_train_step(cfg, opt, remat="none", ce_chunk=16)
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32) + 3,
                 "labels": jnp.ones((4, 32), jnp.int32)}
        # sharded
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.param_specs(cfg, mesh))
        with mesh:
            p = jax.device_put(params, p_sh)
            st = opt.init(p)
            p2, st2, m = jax.jit(step)(p, st, batch)
            sharded_loss = float(m["loss"])
        # single-device reference
        p2r, st2r, mr = jax.jit(step)(params, opt.init(params), batch)
        ref_loss = float(mr["loss"])
        assert abs(sharded_loss - ref_loss) < 1e-4, (sharded_loss, ref_loss)
        print("OK", sharded_loss, ref_loss)
    """)
    assert "OK" in out


def test_moe_expert_parallel_consistency():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_smoke_config
        from repro.dist import sharding as shd
        from repro.models import lm
        cfg = get_smoke_config("olmoe_1b_7b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = lm.init_params(cfg, jax.random.key(1))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab, (2, 16)), jnp.int32)
        ref, _ = jax.jit(lambda p, t: lm.forward(p, cfg, t))(params, toks)
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.param_specs(cfg, mesh))
        with mesh:
            p = jax.device_put(params, p_sh)
            got, _ = jax.jit(lambda p, t: lm.forward(p, cfg, t))(p, toks)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-3, err
        print("OK", err)
    """)
    assert "OK" in out


def test_dryrun_cell_on_small_mesh():
    """The dry-run path itself (lower+compile+roofline) on an 8-device
    toy mesh with a reduced config — exercises the exact production code."""
    out = run_forced("""
        import jax, dataclasses
        from repro.configs import get_smoke_config
        from repro.launch.dryrun import lower_cell
        from repro.models.config import SHAPES, ShapeConfig
        from repro.perf.roofline import roofline
        cfg = get_smoke_config("h2o_danube_1p8b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        SHAPES["toy"] = ShapeConfig("toy", 64, 8, "train")
        lowered, _ = lower_cell(cfg, "toy", mesh, remat="none", ce_chunk=16)
        compiled = lowered.compile()
        rl = roofline("toy", compiled, cfg, SHAPES["toy"], mesh.size)
        assert rl.flops > 0 and rl.hbm_bytes > 0
        assert rl.coll_bytes > 0  # TP all-reduces must be present
        print("OK", rl.bottleneck, rl.flops)
    """)
    assert "OK" in out


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint written under a (2,4) mesh restores onto (4,2) and (1,1)."""
    out = run_forced(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.ckpt import CheckpointManager
        from repro.ckpt.manager import restore_resharded
        from repro.configs import get_smoke_config
        from repro.dist import sharding as shd
        from repro.models import lm
        cfg = get_smoke_config("llama3p2_1b")
        params = lm.init_params(cfg, jax.random.key(0))
        mesh1 = jax.make_mesh((2, 4), ("data", "model"))
        p_sh1 = jax.tree.map(lambda s: NamedSharding(mesh1, s),
                             shd.param_specs(cfg, mesh1))
        p1 = jax.device_put(params, p_sh1)
        mgr = CheckpointManager({str(tmp_path)!r}, keep=2)
        mgr.save(5, p1)
        mesh2 = jax.make_mesh((4, 2), ("data", "model"))
        p_sh2 = jax.tree.map(lambda s: NamedSharding(mesh2, s),
                             shd.param_specs(cfg, mesh2))
        restored, _ = restore_resharded(mgr, params, p_sh2)
        a = np.asarray(jax.device_get(restored["embed"]))
        b = np.asarray(jax.device_get(params["embed"]))
        np.testing.assert_array_equal(a, b)
        print("OK")
    """)
    assert "OK" in out


def test_split_d_decode_attention_matches_dense():
    """The shard_map split-d decode path (Perf iter. 7) is numerically
    identical to the dense decode attention on a real multi-device mesh."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import attention as A
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        b, s, hq, hkv, d = 4, 32, 6, 3, 8   # hkv=3 doesn't divide 4
        q = jnp.asarray(rng.normal(size=(b, 1, hq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        cl = jnp.asarray(s, jnp.int32)
        want = A.decode_attention(q, k, v, cl)
        with mesh:
            got = jax.jit(lambda q, k, v: A.decode_attention_split_d(
                q, k, v, cl, mesh=mesh, batch_axes=("data",)))(q, k, v)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 2e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_seq_sharded_prefill_attention_matches_dense():
    """The shard_map sequence-sharded prefill path (Perf iter. 8) matches
    the reference flash attention on a real mesh, incl. the causal mask
    across shard boundaries."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import attention as A
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(1)
        b, s, hq, hkv, d = 4, 64, 6, 2, 8
        q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        want = A.flash_attention_scan(q, k, v, causal=True, q_block=16,
                                      kv_block=16)
        with mesh:
            got = jax.jit(lambda q, k, v: A.flash_attention_seq_sharded(
                q, k, v, causal=True, mesh=mesh,
                batch_axes=("data",)))(q, k, v)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 2e-5, err
        # windowed variant too
        want_w = A.flash_attention_scan(q, k, v, causal=True, window=24,
                                        q_block=16, kv_block=16)
        with mesh:
            got_w = jax.jit(lambda q, k, v: A.flash_attention_seq_sharded(
                q, k, v, causal=True, window=24, mesh=mesh,
                batch_axes=("data",)))(q, k, v)
        err_w = float(jnp.max(jnp.abs(got_w - want_w)))
        assert err_w < 2e-5, err_w
        print("OK", err, err_w)
    """)
    assert "OK" in out
