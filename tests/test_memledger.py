"""Memory ledger (`runtime.memledger`, ISSUE 9): event-sourced
``kind="mem"`` pool-mutation records whose integrated deltas reproduce
the per-round pool gauges exactly — across drain/requeue mid-chunked
prefill, engine drain + restore churn, prefix-cache evict-to-empty and
a hypothesis refcount/COW churn sweep — plus the streaming pressure
monitor and the owner-attribution summary built on top."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.runtime.cluster import FleetCluster, StepCostModel, TrafficSpec
from repro.runtime.cluster.traffic import synthesize
from repro.runtime.kv_pool import KVPool
from repro.runtime.memledger import (
    GAUGES,
    MemLedger,
    MemPolicy,
    MemPressureMonitor,
    _snapshot,
    kv_block_bytes,
    summarize_ledger,
    validate_ledger,
)
from repro.runtime.prefix_cache import PrefixCache
from repro.runtime.scheduler import Scheduler
from repro.runtime.tracker import MemoryTracker, replay_summary

BLOCK, MAX_LEN, SLOTS = 4, 32, 2


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm_360m")
    params = lm.init_params(cfg, jax.random.key(0))
    cost = StepCostModel.for_config(get_config("smollm_360m"), slots=SLOTS)
    return cfg, params, cost


def _cfg():
    return get_smoke_config("smollm_360m")


def _ledgered_pool(cfg, n_blocks=17):
    """A raw pool with an attached ledger feeding a MemoryTracker."""
    pool = KVPool(cfg, n_blocks=n_blocks, block_tokens=BLOCK)
    trk = MemoryTracker()
    clock = iter(range(10**9))
    led = MemLedger(lambda: float(next(clock)), tracker=trk)
    led.attach(pool)
    return pool, led, trk


def _integrate(mems):
    """Fold a mem-record list into absolute gauges (attach + deltas)."""
    state = None
    for r in mems:
        if r.get("op") == "attach":
            state = {k: r[k] for k in GAUGES}
        elif r.get("op") == "reserve":
            continue
        else:
            for k in GAUGES:
                state[k] += r.get("d_" + k, 0)
    return state


# ---------------- ledger unit behavior ----------------


def test_block_bytes_matches_array_footprint():
    cfg = _cfg()
    pool = KVPool(cfg, n_blocks=9, block_tokens=BLOCK)
    bb = kv_block_bytes(pool)
    rows = pool.k.shape[1]
    assert bb * (rows // BLOCK) == pool.k.nbytes + pool.v.nbytes
    assert bb > 0


def test_attach_emits_absolute_baseline_and_binds_pool():
    cfg = _cfg()
    pool, led, trk = _ledgered_pool(cfg)
    led.flush()
    assert pool.ledger is led
    (att,) = trk.mems
    assert att["op"] == "attach" and att["owner"] == "pool"
    assert att["n_blocks"] == pool.usable_blocks
    assert att["block_tokens"] == BLOCK
    assert att["block_bytes"] == kv_block_bytes(pool)
    for k in GAUGES:
        assert att[k] == _snapshot(pool)[k]


def test_ops_emit_sparse_deltas_with_exact_bytes():
    cfg = _cfg()
    pool, led, trk = _ledgered_pool(cfg)
    bb = kv_block_bytes(pool)
    pool.admit(0, 12)
    pool.note_tokens(0, 6)
    led.sync()  # fold the note_tokens held_tokens drift
    pool.release(0)
    led.flush()
    by_op = {r["op"]: r for r in trk.mems}
    # admit: pure commitment, no blocks move
    assert by_op["admit"]["d_committed_blocks"] == 3
    assert "d_held_blocks" not in by_op["admit"]
    assert by_op["admit"]["rid"] == 0
    # grow: 6 tokens -> 2 blocks off the free list, bytes = 2 blocks
    g = by_op["grow"]
    assert g["owner"] == "request" and g["grown"] == 2
    assert g["d_held_blocks"] == 2 and g["d_free_blocks"] == -2
    assert g["d_alloc_blocks"] == 2 and g["d_bytes"] == 2 * bb
    # sync carries the un-evented held_tokens drift
    assert by_op["sync"]["d_held_tokens"] == 6
    # release returns everything
    r = by_op["release"]
    assert r["d_held_blocks"] == -2 and r["d_freed_blocks"] == 2
    assert r["d_bytes"] == -2 * bb
    # integration lands back on the live snapshot
    assert _integrate(trk.mems) == _snapshot(pool)


def test_cow_adopt_emits_shared_and_cow_deltas():
    cfg = _cfg()
    pool, led, trk = _ledgered_pool(cfg)
    pool.admit(0, 6)
    pool.note_tokens(0, 6)  # blocks [full, partial-tail]
    b_full, b_tail = pool.blocks_of(0)
    pool.admit(1, 6)
    pool.adopt_prefix(1, (b_full,), b_tail, 6)
    led.sync()
    led.flush()
    adopt = next(r for r in trk.mems if r["op"] == "adopt_prefix")
    assert adopt["shared"] == 1 and adopt["cow"] == 1
    assert adopt["d_cow_copies"] == 1
    assert adopt["d_shared_blocks"] == 1  # the full block now has 2 users
    assert adopt["d_alloc_blocks"] == 1  # the private COW duplicate
    assert _integrate(trk.mems) == _snapshot(pool)
    assert pool.cow_copies == 1


def test_reserve_records_carry_bytes_not_deltas():
    cfg = _cfg()
    pool, led, trk = _ledgered_pool(cfg)
    led.reserve("weight-resident", 1 << 20, blocks=3)
    led.reserve("ring-slot", 1 << 16, depth=2)
    led.flush()
    res = [r for r in trk.mems if r["op"] == "reserve"]
    assert [r["owner"] for r in res] == ["weight-resident", "ring-slot"]
    assert res[0]["nbytes"] == 1 << 20 and res[0]["blocks"] == 3
    assert all(not any(k.startswith("d_") for k in r) for r in res)
    # reserve records are invisible to gauge integration
    assert _integrate(trk.mems) == _snapshot(pool)
    s = summarize_ledger(trk.mems)["engines"][0]
    assert s["reserved_bytes"] == {
        "weight-resident": 1 << 20,
        "ring-slot": 1 << 16,
    }


def test_ledger_without_tracker_counts_and_drops():
    cfg = _cfg()
    pool = KVPool(cfg, n_blocks=9, block_tokens=BLOCK)
    led = MemLedger(lambda: 0.0)
    led.attach(pool)
    pool.admit(0, 8)
    pool.note_tokens(0, 8)
    pool.release(0)
    assert led.n_records == led.n_dropped >= 4
    assert led._buf == []
    # diffing kept running: a fresh sync has nothing left to fold
    n = led.n_records
    led.sync()
    assert led.n_records == n


# ---------------- bare scheduler: interleaving + exactness ----------------


def _run_sched(cfg, params, *, n=5, trk=None, **kw):
    pool = KVPool.for_slots(
        cfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    trk = trk if trk is not None else MemoryTracker()
    clock = iter(range(10**9))
    led = MemLedger(lambda: float(next(clock)), tracker=trk)
    sched = Scheduler(
        cfg, params, pool, slots=SLOTS, max_len=MAX_LEN,
        prefix_cache=PrefixCache(pool), tracker=trk, ledger=led,
        mem_monitor=MemPressureMonitor(), **kw,
    )
    rng = np.random.default_rng(0)
    for _ in range(n):
        sched.submit(
            rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32), 4
        )
    stats = sched.run()
    return sched, stats, trk


def test_scheduler_stream_validates_and_replays(setup):
    cfg, params, _ = setup
    sched, stats, trk = _run_sched(cfg, params)
    assert validate_ledger(trk.stream) == []
    rep = replay_summary(trk.stream)
    assert rep["completed"] == stats.completed == 5
    assert rep["generated_tokens"] == stats.generated_tokens
    # the ledger's own integration lands on the live pool
    assert _integrate(trk.mems) == _snapshot(sched.pool)
    assert sched.ledger.n_records == len(trk.mems)


def test_mem_records_flush_before_their_round_record(setup):
    """The barrier that makes the stream self-validating: every round's
    mem records land in the stream *before* the metrics record whose
    gauges they must integrate to."""
    cfg, params, _ = setup
    _, _, trk = _run_sched(cfg, params, n=3)
    seen_metrics = 0
    for r in trk.stream:
        if r["kind"] == "metrics":
            seen_metrics += 1
        elif r["kind"] == "mem" and r["op"] != "attach":
            # block motion happens inside a round: its record must not
            # trail the round's own metrics record
            pass
    # stronger: walking the stream, the integrated state at each metrics
    # record already matches — which is validate_ledger, plus the attach
    # must be the very first mem record
    mems = [r for r in trk.stream if r["kind"] == "mem"]
    assert mems[0]["op"] == "attach"
    first_metrics = next(
        i for i, r in enumerate(trk.stream) if r["kind"] == "metrics"
    )
    first_mem = next(
        i for i, r in enumerate(trk.stream) if r["kind"] == "mem"
    )
    assert first_mem < first_metrics
    assert seen_metrics > 0


def test_drain_requeue_mid_chunked_prefill_stays_exact(setup):
    """The hard seam: a drain aborts a chunked prefill mid-flight —
    partially written blocks release, the cursor drops — and the ledger
    must account for every block the abort path returns."""
    cfg, params, _ = setup
    pool = KVPool.for_slots(
        cfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    trk = MemoryTracker()
    clock = iter(range(10**9))
    led = MemLedger(lambda: float(next(clock)), tracker=trk)
    sched = Scheduler(
        cfg, params, pool, slots=SLOTS, max_len=MAX_LEN,
        token_budget=16, prefill_chunk=8, tracker=trk, ledger=led,
        mem_monitor=MemPressureMonitor(),
    )
    rng = np.random.default_rng(1)
    long_prompt = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
    sched.submit(long_prompt, 4)
    sched.round()  # first chunk prefilled; cursor live, blocks held
    assert sched._chunk_cursor, "prompt must still be mid-chunk"
    assert pool.stats().held_blocks > 0
    moved = sched.drain()
    assert [r.rid for r in moved] == [0]
    led.sync()
    led.flush()
    assert validate_ledger(trk.stream) == []
    assert _integrate(trk.mems) == _snapshot(pool)
    assert pool.free_blocks == pool.usable_blocks  # nothing leaked
    # the abort's release is an attributed event, not silent sync drift
    assert any(
        r["op"] == "release" and r.get("rid") == 0 for r in trk.mems
    )


def test_prefix_cache_evict_to_empty_stays_exact():
    """Evicting the cache down to nothing walks uncache/evict through
    the ledger; integration must land on the all-free pool."""
    cfg = _cfg()
    pool, led, trk = _ledgered_pool(cfg, n_blocks=9)
    cache = PrefixCache(pool)
    prompt = np.arange(8, dtype=np.int32)
    pool.admit(0, 8)
    pool.note_tokens(0, 8)
    cache.commit(prompt, pool.blocks_of(0))
    led.sync()
    pool.release(0)
    st = pool.stats()
    assert st.cached_blocks == 2 and st.evictable_blocks == 2
    freed = cache.evict(100)  # far more than cached: drain to empty
    led.sync()
    led.flush()
    assert freed == 2
    st = pool.stats()
    assert st.cached_blocks == 0 and st.evictable_blocks == 0
    assert pool.free_blocks == pool.usable_blocks
    assert _integrate(trk.mems) == _snapshot(pool)
    evict = next(r for r in trk.mems if r["op"] == "evict")
    assert evict["owner"] == "prefix-cache" and evict["freed"] == 2
    # per-block frees already rode the uncache records: the evict
    # summary record itself carries no net gauge delta
    assert not any(k.startswith("d_") for k in evict)
    uncached = [r for r in trk.mems if r["op"] == "uncache"]
    assert len(uncached) == 2
    assert sum(r.get("d_freed_blocks", 0) for r in uncached) == 2


# ---------------- fleet: restore seam + surfaced summaries ----------------


def test_fleet_drain_restore_stream_stays_exact(setup):
    """Engine drain + restore churn over one shared stream: the ledger
    stays exact through the requeue storm, and the mem summaries
    surface per engine and fleet-wide."""
    cfg, params, cost = setup
    trk = MemoryTracker()
    cl = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, policy="prefix-aware",
        prefix_cache=True, tracker=trk,
    )
    spec = TrafficSpec(
        vocab=cfg.vocab, n_requests=8, arrival_rate=2000.0,
        prompt_lens=((6, 0.5), (10, 0.5)), gen_lens=((4, 1.0),), seed=3,
    )
    res1 = cl.run(synthesize(spec), drain_at=(0, 0.0005))
    cl.restore_engine(0)
    spec2 = TrafficSpec(
        vocab=cfg.vocab, n_requests=6, arrival_rate=2000.0,
        prompt_lens=((6, 1.0),), gen_lens=((4, 1.0),), seed=4,
    )
    import dataclasses

    trace2 = [
        dataclasses.replace(r, rid=r.rid + 8) for r in synthesize(spec2)
    ]
    res2 = cl.run(trace2)
    assert len(res1.outputs) == 8 and len(res2.outputs) == 14
    assert validate_ledger(trk.stream) == []
    for e in cl.engines:
        rep = replay_summary(trk.stream, engine=e.engine_id)
        assert rep["completed"] == e.summary()["completed"]
        mem = e.summary()["mem"]
        assert mem["observed"] > 0
        assert 0.0 < mem["peak_occupancy"] <= 1.0
        assert e.summary()["fragmentation"].keys() == {
            "baseline_blocks", "ffd_blocks",
            "baseline_efficiency", "ffd_efficiency",
        }
    ms = res2.mem_summary
    assert ms["signal"] in ("ok", "pressure", "storm")
    assert ms["peak_occupancy"] > 0.0
    assert ms["headroom_blocks"] >= 0
    # both engines attached once each: exactly two attach records
    attaches = [m for m in trk.mems if m["op"] == "attach"]
    assert sorted(a["engine"] for a in attaches) == [0, 1]


def test_summarize_ledger_attributes_peaks_per_engine(setup):
    cfg, params, cost = setup
    trk = MemoryTracker()
    cl = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, prefix_cache=True, tracker=trk,
    )
    spec = TrafficSpec(
        vocab=cfg.vocab, n_requests=6, arrival_rate=2000.0,
        prompt_lens=((8, 1.0),), gen_lens=((4, 1.0),), seed=5,
    )
    cl.run(synthesize(spec))
    s = summarize_ledger(trk.stream)
    assert [e["engine"] for e in s["engines"]] == [0, 1]
    for e in s["engines"]:
        assert e["peak_held_blocks"] > 0
        assert 0.0 < e["peak_occupancy"] <= 1.0
        assert e["alloc_blocks"] >= e["freed_blocks"] >= 0
        assert e["alloc_mib"] > 0.0
        assert e["n_records"] > 0


# ---------------- hypothesis: refcount/COW churn ----------------


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_churn_integration_exact_every_step(data):
    """The property behind validate_ledger: after EVERY pool mutation
    (+ a sync for token drift), integrating the emitted deltas equals
    the live snapshot — admit/grow/adopt(COW)/release/cache/evict in
    random interleavings included."""
    cfg = _cfg()
    pool, led, trk = _ledgered_pool(cfg, n_blocks=17)
    cache = PrefixCache(pool)
    rng_rid = iter(range(10**6))
    live: list[int] = []
    for _ in range(data.draw(st.integers(4, 14), label="n_ops")):
        ops = ["admit"]
        if live:
            ops += ["grow", "release", "adopt"]
        if pool.cached_blocks:
            ops.append("evict")
        op = data.draw(st.sampled_from(ops), label="op")
        if op == "admit":
            total = data.draw(st.integers(2, 12), label="total")
            if pool.can_admit(total):
                rid = next(rng_rid)
                pool.admit(rid, total)
                pool.note_tokens(
                    rid, data.draw(st.integers(1, total), label="tok")
                )
                live.append(rid)
        elif op == "grow":
            rid = data.draw(st.sampled_from(live), label="rid")
            cap = pool._committed[rid] * BLOCK
            pool.note_tokens(
                rid, data.draw(st.integers(1, cap), label="grow_to")
            )
        elif op == "adopt":
            donor = data.draw(st.sampled_from(live), label="donor")
            m = pool.tokens_held(donor)
            held = pool.blocks_of(donor)
            tail = None if m % BLOCK == 0 else held[m // BLOCK]
            if pool.can_admit(m + 1):
                rid = next(rng_rid)
                pool.admit(rid, m + 1)
                pool.adopt_prefix(rid, held[: m // BLOCK], tail, m)
                live.append(rid)
        elif op == "release":
            rid = data.draw(st.sampled_from(live), label="rid")
            if data.draw(st.booleans(), label="cache_first"):
                toks = np.arange(pool.tokens_held(rid), dtype=np.int32)
                cache.commit(toks, pool.blocks_of(rid))
            live.remove(rid)
            pool.release(rid)
        elif op == "evict":
            cache.evict(data.draw(st.integers(1, 4), label="n_evict"))
        led.sync()
        led.flush()
        assert _integrate(trk.mems) == _snapshot(pool)
        pool.validate()
    led.flush()
    assert validate_ledger(trk.stream) in ([],)


# ---------------- pressure monitor ----------------


def _occupied_pool(cfg, frac):
    pool = KVPool(cfg, n_blocks=17, block_tokens=BLOCK)
    n = int(pool.usable_blocks * frac)
    if n:
        pool.admit(0, n * BLOCK)
        pool.note_tokens(0, n * BLOCK)
    return pool


def test_monitor_burn_and_pressure_signal():
    cfg = _cfg()
    mon = MemPressureMonitor(MemPolicy(max_occupancy=0.5, target=0.9))
    hot = _occupied_pool(cfg, 0.75)
    for i in range(10):
        mon.observe(t=float(i), pool=hot, evicted_blocks=0)
    # every round violated the 0.5 ceiling: burn = 1/0.1 = 10x budget
    assert mon.violations == mon.observed == 10
    assert mon.burn_rates(10.0)["60s"] == pytest.approx(10.0)
    assert mon.signal(10.0) == "pressure"
    s = mon.summary(now=10.0)
    assert s["signal"] == "pressure"
    assert s["peak_held_blocks"] == 12
    assert s["frag_at_peak"]["baseline_blocks"] == 12
    assert s["occupancy"]["n"] == 10


def test_monitor_eviction_storm_and_ok():
    cfg = _cfg()
    cool = _occupied_pool(cfg, 0.25)
    mon = MemPressureMonitor()
    for i in range(5):
        mon.observe(t=float(i), pool=cool, evicted_blocks=0)
    assert mon.signal(5.0) == "ok"
    # a cumulative eviction spike past half the pool inside the short
    # window flips the signal to storm even at low occupancy
    mon.observe(t=6.0, pool=cool, evicted_blocks=12)
    assert mon.eviction_rates(6.0)["60s"] == 12
    assert mon.signal(6.0) == "storm"
    assert mon.summary(now=6.0)["signal"] == "storm"


def test_monitor_frag_trend_flags_degradation():
    cfg = _cfg()
    mon = MemPressureMonitor(windows=(10.0, 50.0, 100.0))
    full = _occupied_pool(cfg, 0.5)  # block-aligned: utilization 1.0
    ragged = KVPool(cfg, n_blocks=17, block_tokens=BLOCK)
    for rid in range(6):
        ragged.admit(rid, 1)  # 1 token per block: utilization 1/4
        ragged.note_tokens(rid, 1)
    for i in range(40):
        mon.observe(t=float(i), pool=full)
    for i in range(40, 100):
        mon.observe(t=float(i), pool=ragged)
    trend = mon.frag_trend(100.0)
    assert trend["short_utilization"] < trend["long_utilization"]
    assert trend["degrading"]


# ---------------- validator guard rails ----------------


def test_validate_ledger_flags_missing_attach_and_drift():
    bad = [
        {"kind": "mem", "op": "grow", "owner": "request", "d_held_blocks": 1}
    ]
    errs = validate_ledger(bad)
    assert any("before attach" in e for e in errs)
    assert validate_ledger([]) == [
        "stream has no kind='mem' records (ledger never attached?)"
    ]
    # a tampered gauge is a named mismatch, not a silent pass
    cfg = _cfg()
    pool, led, trk = _ledgered_pool(cfg, n_blocks=9)
    pool.admit(0, 8)
    pool.note_tokens(0, 8)
    led.sync()
    led.flush()
    good = list(trk.stream) + [
        {
            "kind": "metrics",
            "pool_held_blocks": 99,
            "pool_utilization": 1.0,
        }
    ]
    errs = validate_ledger(good)
    assert any("pool_held_blocks=99" in e for e in errs)
