"""Fleet serving subsystem (`runtime.cluster`): traffic determinism,
GALS-ratio provisioning, disaggregated prefill/decode token-identity,
and router invariants (no request lost, duplicated, or perturbed by an
engine drain).

The KV-handoff property test drives the scheduler-level hooks directly
(prefill on engine A through the handoff hook, import on engine B) under
a hypothesis-swept seed, for both greedy and seeded-sampling decode.
Cluster-level runs use short traces: every engine executes the real
model, so trace size is wall-clock."""

import dataclasses
import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, get_smoke_config
from repro.dist.mesh_axes import MeshView
from repro.dist.placement import plan_engine_placement
from repro.models import lm
from repro.runtime.cluster import (
    DisaggCluster,
    FleetCluster,
    RoleRates,
    SloPolicy,
    StepCostModel,
    TrafficSpec,
    provision_split,
    synthesize,
)
from repro.runtime.cluster.traffic import ClientRequest
from repro.runtime.kv_pool import KVPool
from repro.runtime.scheduler import RequestState, Scheduler

SLOTS, MAX_LEN, BLOCK = 2, 32, 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm_360m")
    params = lm.init_params(cfg, jax.random.key(0))
    cost = StepCostModel.for_config(get_config("smollm_360m"), slots=SLOTS)
    return cfg, params, cost


def _spec(cfg, **kw):
    kw.setdefault("n_requests", 10)
    kw.setdefault("arrival_rate", 2000.0)
    kw.setdefault("prompt_lens", ((6, 0.5), (10, 0.5)))
    kw.setdefault("gen_lens", ((4, 0.5), (8, 0.5)))
    kw.setdefault("seed", 2)
    return TrafficSpec(vocab=cfg.vocab, **kw)


def _cluster(kind, cfg, params, cost, spec, **kw):
    common = dict(
        slots=SLOTS,
        max_len=MAX_LEN,
        block_tokens=BLOCK,
        cost=cost,
    )
    common.update(kw)
    if kind == "disagg":
        return DisaggCluster(cfg, params, spec=spec, **common)
    return FleetCluster(cfg, params, **common)


# ---------------- traffic generator ----------------


def test_traffic_is_seed_deterministic(setup):
    cfg, _, _ = setup
    spec = _spec(cfg)
    a, b = synthesize(spec), synthesize(spec)
    assert [r.t_arrival for r in a] == [r.t_arrival for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    assert [r.session for r in a] == [r.session for r in b]
    c = synthesize(dataclasses.replace(spec, seed=3))
    assert [r.t_arrival for r in a] != [r.t_arrival for r in c]
    # arrivals are ordered, lengths come from the declared mixes
    assert all(
        x.t_arrival <= y.t_arrival for x, y in zip(a, a[1:])
    )
    assert {len(r.prompt) for r in a} <= {6, 10}
    assert {r.max_new_tokens for r in a} <= {4, 8}


# ---------------- GALS provisioning ----------------


def test_provision_split_follows_eq2_ratio():
    """The split maximises min(producer, consumer) throughput under the
    Eq. 2 feasibility ordering: a fast prefill tier concentrates engines
    on decode, and vice versa."""
    fast_prefill = RoleRates(prefill_req_rate=300.0, decode_req_rate=100.0)
    assert provision_split(4, fast_prefill) == (1, 3)  # R_F = 3 feeds 3
    balanced = RoleRates(prefill_req_rate=100.0, decode_req_rate=100.0)
    assert provision_split(4, balanced) == (2, 2)
    fast_decode = RoleRates(prefill_req_rate=100.0, decode_req_rate=300.0)
    assert provision_split(4, fast_decode) == (3, 1)
    with pytest.raises(ValueError):
        provision_split(1, balanced)


def test_cost_model_is_roofline_shaped(setup):
    _, _, cost = setup
    assert cost.prefill_s_per_token > 0
    assert cost.decode_s_per_step >= cost.prefill_s_per_step > 0
    # FCMP packing must shrink the decode step's weight re-read term
    packed = StepCostModel.for_config(
        dataclasses.replace(get_config("smollm_360m"), w_bits=1),
        slots=SLOTS,
    )
    assert packed.decode_s_per_step < cost.decode_s_per_step


# ---------------- KV handoff property (scheduler-level) ----------------


def _mk_sched(cfg, params, **kw):
    pool = KVPool.for_slots(
        cfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    return Scheduler(
        cfg, params, pool, slots=SLOTS, max_len=MAX_LEN, **kw
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_handoff_reproduces_single_engine_stream(setup, seed):
    """A request prefilled on engine A and decoded on engine B must emit
    exactly the single-engine token stream — greedy and seeded-sampling."""
    cfg, params, _ = setup
    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab, size=(int(rng.integers(3, 9)),)).astype(
            np.int32
        )
        for _ in range(3)
    ]
    gen = int(rng.integers(2, 6))
    for sampling in (
        None,
        lm.SamplingParams(temperature=0.8, top_k=16, top_p=0.9, seed=seed),
    ):
        kw = {"sampling": sampling} if sampling else {}
        single = _mk_sched(cfg, params, **kw)
        for i, p in enumerate(prompts):
            single.submit(p, gen, rid=i)
        single.run()

        payloads = []
        a = _mk_sched(cfg, params, handoff=payloads.append, **kw)
        b = _mk_sched(cfg, params, **kw)
        for i, p in enumerate(prompts):
            a.submit(p, gen, rid=i)
        while a.queue or any(r is not None for r in a.active):
            a.round()
        assert a.stats.handoffs == len(prompts)
        assert all(
            r.state is RequestState.HANDOFF for r in a.requests.values()
        )
        a.pool.validate()
        assert a.pool.free_blocks == a.pool.usable_blocks
        for pl in payloads:
            # block-id serialization is complete: ids cover the payload
            assert len(pl.block_ids) * pl.block_tokens >= pl.n_tokens
            while not b.import_prefilled(pl):
                b.round()
        while any(r is not None for r in b.active):
            b.round()
        assert b.outputs() == single.outputs()


# ---------------- cluster-level equivalence + scaling ----------------


def test_fleet_and_disagg_match_single_engine(setup):
    cfg, params, cost = setup
    spec = _spec(cfg)
    trace = synthesize(spec)
    single = _cluster("fleet", cfg, params, cost, spec, n_engines=1).run(
        trace
    )
    assert all(
        len(single.outputs[r.rid]) == r.max_new_tokens for r in trace
    )
    fleet = _cluster("fleet", cfg, params, cost, spec, n_engines=2).run(
        trace
    )
    disagg = _cluster(
        "disagg", cfg, params, cost, spec, n_engines=3
    ).run(trace)
    assert fleet.outputs == single.outputs
    assert disagg.outputs == single.outputs
    # two engines must finish the saturating trace sooner in virtual time
    mk = lambda r: max(t.t_done for t in r.timings.values())
    assert mk(fleet) < mk(single)
    # every request got timed
    rep = fleet.report(SloPolicy(ttft=1.0, tpot=1.0))
    assert rep.completed == spec.n_requests == rep.slo_met


def test_disagg_packed_arch_token_identity(setup):
    """The FCMP-packed (w_bits=1) variant holds the same gate."""
    cfg, _, _ = setup
    pcfg = dataclasses.replace(cfg, w_bits=1)
    pparams = lm.init_params(pcfg, jax.random.key(0))
    cost = StepCostModel.for_config(
        dataclasses.replace(get_config("smollm_360m"), w_bits=1),
        slots=SLOTS,
    )
    spec = _spec(pcfg, n_requests=6)
    trace = synthesize(spec)
    single = _cluster("fleet", pcfg, pparams, cost, spec, n_engines=1).run(
        trace
    )
    disagg = _cluster(
        "disagg", pcfg, pparams, cost, spec, n_engines=2
    ).run(trace)
    assert disagg.outputs == single.outputs


def test_disagg_one_token_requests_complete(setup):
    """Regression: a request whose single token arrives with the handoff
    (max_new_tokens == 1) finishes at the moment of import and must be
    timed as completed, not left with t_done unset."""
    cfg, params, cost = setup
    spec = _spec(cfg, n_requests=4, gen_lens=((1, 1.0),))
    trace = synthesize(spec)
    res = _cluster("disagg", cfg, params, cost, spec, n_engines=2).run(
        trace
    )
    rep = res.report(SloPolicy(ttft=1.0, tpot=1.0))
    assert rep.completed == 4
    assert rep.goodput_tokens_per_s > 0
    assert all(not math.isnan(t.t_done) for t in res.timings.values())


def test_disagg_rejects_non_paged_families(setup):
    """Pure SSM still has no block wire format; hybrid now disaggregates
    (the payload carries its SSM lane state)."""
    _, _, cost = setup
    scfg = get_smoke_config("mamba2_1p3b")
    sparams = lm.init_params(scfg, jax.random.key(0))
    with pytest.raises(ValueError, match="wire format"):
        DisaggCluster(
            scfg, sparams, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
            block_tokens=BLOCK, cost=cost, split=(1, 1),
        )


def test_hybrid_disagg_token_identity():
    """ISSUE 5 satellite: zamba2 requests prefill on engine A and decode
    on engine B — the handoff ships the SSM lane state next to the KV
    blocks, and the streams equal single-engine serving exactly."""
    hcfg = get_smoke_config("zamba2_2p7b")
    hparams = lm.init_params(hcfg, jax.random.key(0))
    cost = StepCostModel.for_config(get_config("zamba2_2p7b"), slots=SLOTS)
    spec = _spec(hcfg, n_requests=6)
    trace = synthesize(spec)
    single = _cluster("fleet", hcfg, hparams, cost, spec, n_engines=1).run(
        trace
    )
    disagg = _cluster("disagg", hcfg, hparams, cost, spec, n_engines=2).run(
        trace
    )
    assert disagg.outputs == single.outputs
    assert sum(
        s["handoffs"] for s in disagg.engine_summaries
    ) == spec.n_requests


def test_moe_disagg_token_identity():
    """Dropless moe disaggregates like dense: the KV blocks are the whole
    handoff (expert choices are recomputed per token on the decode
    engine from the same gates), so prefill-on-A / decode-on-B equals
    single-engine serving token for token."""
    mcfg = get_smoke_config("olmoe_1b_7b")
    mparams = lm.init_params(mcfg, jax.random.key(0))
    cost = StepCostModel.for_config(get_config("olmoe_1b_7b"), slots=SLOTS)
    spec = _spec(mcfg, n_requests=6)
    trace = synthesize(spec)
    single = _cluster("fleet", mcfg, mparams, cost, spec, n_engines=1).run(
        trace
    )
    disagg = _cluster("disagg", mcfg, mparams, cost, spec, n_engines=2).run(
        trace
    )
    assert disagg.outputs == single.outputs
    assert sum(
        s["handoffs"] for s in disagg.engine_summaries
    ) == spec.n_requests
    # both sides of the split routed tokens through the dispatch
    assert all(s["expert_tokens"] > 0 for s in disagg.engine_summaries)


def test_router_chunked_admission_takes_over_budget_prompt():
    """Fleet-level chunked admission (the Router analog of the
    scheduler's solo admission): a prompt larger than every engine's
    token budget is no longer bounced at offer() for chunkable families —
    an idle engine accepts it and streams it through budget-sized
    chunks, emitting the exact stream of an unbudgeted single engine."""
    cfg = get_smoke_config("smollm_360m")
    params = lm.init_params(cfg, jax.random.key(0))
    cost = StepCostModel.for_config(get_config("smollm_360m"), slots=SLOTS)
    rng = np.random.default_rng(41)
    long_p = rng.integers(0, cfg.vocab, size=(20,)).astype(np.int32)
    trace = [ClientRequest(0, 0.0, long_p, 4, 0)]

    big = FleetCluster(
        cfg, params, n_engines=1, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost,
    ).run(trace)
    budgeted = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, token_budget=16,
    )
    assert all(
        e.scheduler.token_budget < len(long_p) + 4
        for e in budgeted.engines
    )
    res = budgeted.run(trace)
    assert res.outputs == big.outputs
    # the prompt really went through the chunked path, not one big step
    assert sum(s["prefill_steps"] for s in res.engine_summaries) == 2


def test_hybrid_handoff_payload_carries_lane_state(setup):
    """Scheduler-level: the hybrid PrefillHandoff must carry the SSM
    snapshot, and importing without one is an error, not silent drift."""
    hcfg = get_smoke_config("zamba2_2p7b")
    hparams = lm.init_params(hcfg, jax.random.key(0))
    payloads = []
    pool = KVPool.for_slots(
        hcfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    a = Scheduler(
        hcfg, hparams, pool, slots=SLOTS, max_len=MAX_LEN,
        handoff=payloads.append,
    )
    a.submit(np.arange(5, dtype=np.int32) % hcfg.vocab, 3)
    while a.queue or any(r is not None for r in a.active):
        a.round()
    (pl,) = payloads
    assert pl.lane_state is not None
    assert pl.kv_bytes > pl.k.nbytes + pl.v.nbytes  # lane rides the wire
    bpool = KVPool.for_slots(
        hcfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    b = Scheduler(hcfg, hparams, bpool, slots=SLOTS, max_len=MAX_LEN)
    with pytest.raises(ValueError, match="lane state"):
        b.import_prefilled(dataclasses.replace(pl, lane_state=None))
    assert b.import_prefilled(pl)


# ---------------- router invariants ----------------


def test_drain_loses_and_duplicates_nothing(setup):
    """Draining an engine mid-run requeues its queued requests onto the
    survivors; every request completes exactly once with its exact
    single-engine token stream."""
    cfg, params, cost = setup
    spec = _spec(cfg, n_requests=12)
    trace = synthesize(spec)
    single = _cluster("fleet", cfg, params, cost, spec, n_engines=1).run(
        trace
    )
    # a small token budget keeps queues non-empty at drain time, so the
    # drain actually moves requests
    total = spec.max_total_tokens
    cl = _cluster(
        "fleet", cfg, params, cost, spec, n_engines=2,
        token_budget=2 * total,
    )
    drained = cl.run(trace, drain_at=(0, 0.004))
    assert cl.engines[0].drained
    moved = [
        rid for rid, eids in cl.router.assignments.items() if len(eids) > 1
    ]
    assert moved, "drain happened while nothing was queued (test is inert)"
    assert all(
        eids[-1] == 1
        for rid, eids in cl.router.assignments.items()
        if len(eids) > 1
    )
    # exactly-once completion, bit-identical streams (rid-keyed sampling)
    assert drained.outputs == single.outputs
    assert sorted(drained.outputs) == [r.rid for r in trace]


def test_prefix_aware_routing_reuses_cached_blocks(setup):
    """The prefix-aware policy lands repeat prompts on the engine whose
    radix cache holds their prefix: hit tokens accrue, and the streams
    stay identical to least-loaded routing (the identity invariant is
    placement-independent)."""
    cfg, params, cost = setup
    rng = np.random.default_rng(17)
    base = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    trace = []
    t = 0.0
    for rid in range(8):
        t += 0.05  # light load: engines go idle between arrivals, so
        # only the cache score (not load) can keep a session together
        ext = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)
        prompt = base if rid % 2 == 0 else np.concatenate([base, ext])
        trace.append(
            ClientRequest(rid, t, prompt, 4, session=rid % 2)
        )
    ll = _cluster("fleet", cfg, params, cost, None, n_engines=2).run(trace)
    pa_cluster = _cluster(
        "fleet", cfg, params, cost, None, n_engines=2,
        policy="prefix-aware", prefix_cache=True,
    )
    pa = pa_cluster.run(trace)
    assert pa.outputs == ll.outputs
    hits = sum(s["prefix_hits"] for s in pa.engine_summaries)
    assert hits >= 6  # every repeat after the two cold prompts hits
    # the shared-prefix requests were co-located, not spread by load
    eng_of = {rid: eids[-1] for rid, eids in pa.assignments.items()}
    assert len({eng_of[r.rid] for r in trace[2:]}) <= 2


def test_affinity_keeps_sessions_on_one_engine(setup):
    """Under light load (no capacity fallback) every request of a session
    lands on the session's pinned engine."""
    cfg, params, cost = setup
    spec = _spec(
        cfg, n_requests=10, arrival_rate=20.0, session_reuse=0.6, seed=5
    )
    trace = synthesize(spec)
    cl = _cluster(
        "fleet", cfg, params, cost, spec, n_engines=3, policy="affinity"
    )
    res = cl.run(trace)
    by_session: dict[int, int] = {}
    for r in trace:
        eid = cl.router.assignments[r.rid][-1]
        assert by_session.setdefault(r.session, eid) == eid, (
            f"session {r.session} split across engines"
        )
    # and the streams still match least-loaded routing
    ll = _cluster("fleet", cfg, params, cost, spec, n_engines=3).run(trace)
    assert res.outputs == ll.outputs


def test_router_rejects_impossible_requests(setup):
    cfg, params, cost = setup
    spec = _spec(cfg, n_requests=2)
    cl = _cluster("fleet", cfg, params, cost, spec, n_engines=2)
    big = synthesize(spec)[0]
    big = dataclasses.replace(
        big, prompt=np.zeros((MAX_LEN,), np.int32), max_new_tokens=8
    )
    with pytest.raises(ValueError, match="no undrained engine"):
        cl.router.offer(big)


# ---------------- engine placement over the mesh ----------------


def test_engine_placement_slices_batch_axes_only():
    view = MeshView(("pod", "data", "model"), (2, 16, 16))
    pls = plan_engine_placement(view, 4)
    assert [p.axis for p in pls] == ["data"] * 4
    assert [(p.lo, p.hi) for p in pls] == [(0, 4), (4, 8), (8, 12), (12, 16)]
    assert all(p.view.shape == {"pod": 2, "data": 4, "model": 16} for p in pls)
    assert all(p.devices == 128 for p in pls)
    # 2 engines prefer the largest divisible batch axis
    assert plan_engine_placement(view, 2)[0].axis == "data"
    # never split the tensor axis: 32 divides no batch axis here
    with pytest.raises(ValueError, match="batch axis"):
        plan_engine_placement(view, 32)
    with pytest.raises(ValueError):
        plan_engine_placement(view, 0)
