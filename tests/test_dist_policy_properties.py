"""Property suite for the ``repro.dist`` sharding policy.

Random mesh shapes x every ARCH_IDS family, asserting the policy's four
guarantees (mirroring the style of ``tests/test_core_packing.py``'s
packing properties):

* every emitted spec is *legal* (sharded dims divide their axis product)
  and *region-pure* (no dim entry mixes tensor and batch axes) — checked
  via ``legalize.validate_spec``, the analogue of ``Packing.validate``;
* parameter sharding is *effective*: >= 85% of parameter bytes carry at
  least one sharded dim for every power-of-two TP degree up to 16 (the
  production mesh);
* batch/token specs never produce an unshardable batch dim;
* cache specs are *complete*: every leaf ``lm.init_cache`` creates gets a
  spec, for every family.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.configs import ARCH_IDS, get_config
from repro.dist import sharding as shd
from repro.dist.legalize import validate_spec
from repro.dist.mesh_axes import MeshView


class FakeMesh:
    """Only what the policy is allowed to read: axis_names + shape."""

    def __init__(self, **shape: int):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)
        self.size = int(np.prod(list(shape.values())))


def mesh_strategy():
    """Random production-plausible meshes (TP a power of two <= 16)."""
    return st.sampled_from(
        [
            FakeMesh(data=d, model=m)
            for d in (1, 2, 4, 8, 16, 32)
            for m in (1, 2, 4, 8, 16)
        ]
        + [
            FakeMesh(pod=p, data=d, model=m)
            for p in (2, 4)
            for d in (4, 16)
            for m in (4, 16)
        ]
    )


def _leaf_map(tree):
    return {
        tuple(str(k) for k in path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
@settings(max_examples=8, deadline=None)
@given(mesh=mesh_strategy())
def test_param_specs_legal_pure_and_effective(arch, mesh):
    from repro.models import lm

    cfg = get_config(arch)
    mv = MeshView.of(mesh)
    specs = _leaf_map(shd.param_specs(cfg, mesh))
    leaves = _leaf_map(lm.abstract_params(cfg))
    assert set(specs) == set(leaves)  # structure mirrors the params
    for path, spec in specs.items():
        validate_spec(tuple(leaves[path].shape), spec, mv)  # legal + pure
    frac = shd.sharded_byte_fraction(cfg, mesh)
    assert frac > 0.85, (arch, dict(mesh.shape), frac)


@pytest.mark.parametrize("arch", ARCH_IDS)
@settings(max_examples=8, deadline=None)
@given(
    mesh=mesh_strategy(),
    global_batch=st.sampled_from([1, 2, 8, 32, 128, 256, 1024]),
)
def test_batch_and_token_specs_legal(arch, mesh, global_batch):
    cfg = get_config(arch)
    mv = MeshView.of(mesh)
    for name, spec in shd.batch_specs(cfg, mesh, global_batch).items():
        validate_spec((global_batch,) + (1,) * (len(spec) - 1), spec, mv)
    tok = shd.token_spec(cfg, mesh, global_batch)
    validate_spec((global_batch, 1), tok, mv)


@pytest.mark.parametrize("arch", ARCH_IDS)
@settings(max_examples=6, deadline=None)
@given(
    mesh=mesh_strategy(),
    batch=st.sampled_from([1, 4, 32, 128]),
    seq_len=st.sampled_from([64, 4096, 32768]),
)
def test_cache_specs_complete_and_legal(arch, mesh, batch, seq_len):
    from repro.models import lm

    cfg = get_config(arch)
    mv = MeshView.of(mesh)
    specs = shd.cache_specs(cfg, mesh, batch, seq_len)
    assert "len" in specs
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, batch, seq_len))
    if cfg.family == "encdec":
        # the launch layer appends cross-attention caches; the policy must
        # cover them too (cache_shardings indexes specs by cache key)
        from repro.models.encdec import cross_cache_struct

        cache = dict(cache)
        cache["cross_k"] = cache["cross_v"] = cross_cache_struct(cfg, batch)
    for name, leaf in cache.items():
        assert name in specs, (arch, name)
        validate_spec(tuple(leaf.shape), specs[name], mv)


def test_packed_carrier_specs_mirror_weights():
    """FCMP-packed configs (w_bits=2): carriers shard like their parent
    weight, per-channel scales replicate, tree structure still mirrors."""
    from repro.models import lm

    cfg = dataclasses.replace(get_config("llama3p2_1b"), w_bits=2)
    mesh = FakeMesh(data=16, model=16)
    mv = MeshView.of(mesh)
    specs = _leaf_map(shd.param_specs(cfg, mesh))
    leaves = _leaf_map(lm.abstract_params(cfg))
    assert set(specs) == set(leaves)
    for path, spec in specs.items():
        validate_spec(tuple(leaves[path].shape), spec, mv)
        if path[-1] == "packed":
            assert any(e is not None for e in spec), path
        if path[-1] == "scale":
            assert all(e is None for e in spec), path
