"""Request-lifecycle span tracing (`runtime.spans`, ISSUE 8): exact
latency decomposition (every completed request's phase spans tile
[submit, done] with float-equal chaining), the Perfetto trace_event
export, and streaming SLO burn-rate monitoring — plus a hypothesis
sweep asserting the decomposition invariant over random fleets."""

import json
import math
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.perf.trace_export import to_trace_events, validate_trace_events
from repro.runtime.cluster import (
    DisaggCluster,
    FleetCluster,
    SloPolicy,
    StepCostModel,
    TrafficSpec,
)
from repro.runtime.cluster.traffic import ClientRequest, synthesize
from repro.runtime.kv_pool import KVPool
from repro.runtime.scheduler import Scheduler
from repro.runtime.spans import (
    SLOMonitor,
    SpanRecorder,
    StreamingHist,
    VirtualClock,
    decompose,
    request_events,
    request_spans,
    validate_trace,
)
from repro.runtime.tracker import JsonlTracker, MemoryTracker, read_jsonl

SLOTS, MAX_LEN, BLOCK = 2, 48, 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm_360m")
    params = lm.init_params(cfg, jax.random.key(0))
    cost = StepCostModel.for_config(get_config("smollm_360m"), slots=SLOTS)
    return cfg, params, cost


def _stream(mem: MemoryTracker) -> list[dict]:
    """One mixed record list, the shape a JSONL file replays to."""
    return mem.records + mem.spans


def _run_fleet(cfg, params, cost, *, n_requests=10, seed=3, slo=None,
               arrival_rate=2000.0, drain_at=None, tracker=None):
    mem = tracker if tracker is not None else MemoryTracker()
    cl = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, policy="prefix-aware",
        prefix_cache=True, tracker=mem, slo=slo,
    )
    spec = TrafficSpec(
        vocab=cfg.vocab, n_requests=n_requests, arrival_rate=arrival_rate,
        prompt_lens=((6, 0.5), (10, 0.5)), gen_lens=((4, 1.0),), seed=seed,
    )
    res = cl.run(synthesize(spec), drain_at=drain_at)
    return cl, res, mem


# ---------------- recorder unit behavior ----------------


def test_recorder_tiles_gaps_and_chains_exactly():
    clock = VirtualClock()
    mem = MemoryTracker()
    rec = SpanRecorder(clock.now, tracker=mem, engine=0, role="both")
    rec.open(7, "queue", t0=0.0)
    clock.advance(0.1 + 1.23e-13)  # sub-ns dust must round away
    t_admit = rec.close(7)
    assert t_admit == round(t_admit, 9)
    # a gap before the next phase is tiled with an explicit wait span
    rec.mark(7, "prefill", t_admit + 0.05, t_admit + 0.06, tokens=8)
    rec.flush()
    spans = mem.spans
    assert [s["phase"] for s in spans] == ["queue", "wait", "prefill"]
    for a, b in zip(spans, spans[1:]):
        assert b["t0"] == a["t1"]  # float-equal chaining, no tolerance
    assert spans[0]["engine"] == 0 and spans[0]["role"] == "both"
    assert spans[2]["tokens"] == 8
    assert rec.n_spans == 3 and rec._buf == []


def test_recorder_abort_marks_and_request_spans_drops_the_visit():
    clock = VirtualClock()
    mem = MemoryTracker()
    rec = SpanRecorder(clock.now, tracker=mem, engine=0)
    rec.open(1, "queue", t0=0.0)
    clock.advance(0.5)
    rec.abort(1, reason="drain")
    rec2 = SpanRecorder(clock.now, tracker=mem, engine=1)
    rec2.open(1, "queue", t0=0.0)  # requeued: clock restarts at arrival
    clock.advance(0.1)
    rec2.close(1)
    rec.flush(), rec2.flush()
    aborted = [s for s in mem.spans if s.get("aborted")]
    assert len(aborted) == 1 and aborted[0]["reason"] == "drain"
    surv = request_spans(mem.spans)
    assert [s["engine"] for s in surv[1]] == [1]  # visit 0 excluded whole


def test_recorder_without_tracker_keeps_no_buffer():
    clock = VirtualClock()
    rec = SpanRecorder(clock.now, tracker=None)
    for i in range(100):
        rec.mark(0, "prefill", float(i), float(i) + 1.0)
    assert rec.n_spans == 100 and rec._buf == []
    rec.flush()  # no tracker: must not raise


# ---------------- standalone scheduler, wall clock ----------------


def test_standalone_scheduler_wall_clock_spans(setup):
    """A bare Scheduler with a monotonic-clock recorder emits tiled
    spans through the same tracker stream (the `launch.serve
    --trace-out` path)."""
    cfg, params, _ = setup
    rng = np.random.default_rng(0)
    mem = MemoryTracker()
    pool = KVPool.for_slots(
        cfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    rec = SpanRecorder(time.monotonic, tracker=mem)
    sched = Scheduler(
        cfg, params, pool, slots=SLOTS, max_len=MAX_LEN,
        tracker=mem, spans=rec,
    )
    for _ in range(3):
        sched.submit(
            rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32), 4
        )
    sched.run()
    assert rec.n_spans > 0 and mem.spans
    assert {"queue", "prefill", "decode"} <= {s["phase"] for s in mem.spans}
    groups = request_spans(mem.spans)
    assert set(groups) == {0, 1, 2}
    for spans in groups.values():  # contiguity holds on the wall clock too
        for a, b in zip(spans, spans[1:]):
            assert b["t0"] == a["t1"]


def test_scheduler_drain_aborts_open_timelines(setup):
    cfg, params, _ = setup
    rng = np.random.default_rng(4)
    clock = VirtualClock()
    mem = MemoryTracker()
    rec = SpanRecorder(clock.now, tracker=mem)
    pool = KVPool.for_slots(
        cfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    sched = Scheduler(
        cfg, params, pool, slots=SLOTS, max_len=MAX_LEN,
        token_budget=8, tracker=mem, spans=rec,
    )
    long_p = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
    sched.submit(long_p, 4)
    sched._admit_one()  # first chunk in, request mid-flight
    moved = sched.drain()
    rec.flush()
    assert [r.rid for r in moved] == [0]
    assert any(s.get("aborted") for s in mem.spans)
    assert request_spans(mem.spans) == {}  # the whole visit is excluded


# ---------------- fleet decomposition exactness ----------------


def test_fleet_trace_decomposes_exactly(setup):
    """The tentpole invariant: every completed request's spans tile
    [submit, done] with float-equal chaining, milestone stamps land on
    span boundaries, and pre-first phase durations telescope to exactly
    the submit-relative TTFT."""
    cfg, params, cost = setup
    cl, res, mem = _run_fleet(cfg, params, cost, n_requests=10, seed=3)
    recs = _stream(mem)
    assert validate_trace(recs) == []
    events = request_events(recs)
    spans_by = request_spans(recs)
    assert set(events) == set(res.outputs) == set(spans_by)
    for rid, timing in res.timings.items():
        ev = events[rid]
        assert ev["first"] == pytest.approx(timing.t_first, abs=1e-9)
        assert ev["done"] == pytest.approx(timing.t_done, abs=1e-9)
        assert ev["admit"] == pytest.approx(timing.t_admit, abs=1e-9)
        first_span = spans_by[rid][0]
        assert first_span["phase"] == "queue"
        assert first_span["t0"] == pytest.approx(
            timing.t_arrival, abs=1e-9
        )
        # TTFT decomposition: pre-first phases sum to the client TTFT
        pre = math.fsum(
            s["t1"] - s["t0"]
            for s in spans_by[rid]
            if s["t1"] <= ev["first"]
        )
        assert pre == pytest.approx(timing.ttft, abs=1e-9)
    # phase totals cover [submit, done] for every request
    for rid, agg in decompose(recs).items():
        total = math.fsum(agg.values())
        t0 = spans_by[rid][0]["t0"]
        assert abs(total - (events[rid]["done"] - t0)) < 1e-9


def test_ttft_submit_vs_admit_split(setup):
    """Satellite 1: TTFT is measured from submission; the spread to the
    admission-relative reading is exactly the queue wait."""
    cfg, params, cost = setup
    _, res, _ = _run_fleet(
        cfg, params, cost, n_requests=12, seed=9, arrival_rate=5000.0
    )
    rep = res.report(SloPolicy(ttft=10.0, tpot=10.0))
    for t in res.timings.values():
        assert not math.isnan(t.t_admit)
        assert t.queue_wait >= -1e-12  # admission never precedes arrival
        assert t.ttft == pytest.approx(
            t.queue_wait + t.ttft_admit, abs=1e-9
        )
    assert rep.ttft_p95 >= rep.ttft_admit_p95 - 1e-12
    assert rep.queue_wait_p95 >= 0.0
    assert rep.ttft_admit_p95 > 0.0


def test_fleet_drain_requeue_timeline_still_tiles(setup):
    """Requests drained mid-flight restart elsewhere; their aborted
    engine-visits are excluded and the surviving timeline still tiles
    [submit, done] exactly."""
    cfg, params, cost = setup
    rng = np.random.default_rng(11)
    mem = MemoryTracker()
    cl = FleetCluster(
        cfg, params, n_engines=2, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, policy="prefix-aware",
        prefix_cache=True, tracker=mem,
    )
    fresh = lambda k: rng.integers(0, cfg.vocab, size=(k,)).astype(np.int32)
    burst = [
        ClientRequest(i, 0.001 * i, fresh(int(rng.integers(8, 15))),
                      int(rng.choice((4, 8))), i)
        for i in range(8)
    ]
    res = cl.run(burst, drain_at=(0, 0.0035))
    cl.restore_engine(0)
    assert len(res.outputs) == len(burst)
    recs = _stream(mem)
    assert validate_trace(recs) == []
    aborted = [s for s in mem.spans if s.get("aborted")]
    if aborted:  # the drain actually moved someone
        surv = request_spans(recs)
        for s in aborted:
            assert all(
                x["engine"] != s["engine"] for x in surv.get(s["rid"], [])
            )


def test_disagg_handoff_span_and_transit(setup):
    """Disagg: the handoff span carries the virtual interconnect transit
    (tokens * handoff_s_per_token), the decode-side timeline continues
    at the payload's ready time, and the whole trace still decomposes."""
    cfg, params, cost = setup
    mem = MemoryTracker()
    spec = TrafficSpec(
        vocab=cfg.vocab, n_requests=6, arrival_rate=2000.0,
        prompt_lens=((8, 1.0),), gen_lens=((4, 1.0),), seed=7,
    )
    cl = DisaggCluster(
        cfg, params, n_engines=3, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, spec=spec, tracker=mem,
    )
    res = cl.run(synthesize(spec))
    recs = _stream(mem)
    assert validate_trace(recs) == []
    hand = [s for s in mem.spans if s["phase"] == "handoff"]
    assert len(hand) == len(res.outputs)
    for s in hand:
        assert s["role"] == "prefill"
        assert s["t1"] - s["t0"] == pytest.approx(
            s["tokens"] * cost.handoff_s_per_token, abs=1e-9
        )
    for rid, spans in request_spans(recs).items():
        roles = [s["role"] for s in spans]
        assert roles[0] == "prefill" and "decode" in roles


@settings(max_examples=5, deadline=None)
@given(data=st.data())
def test_random_fleets_decompose_exactly(setup, data):
    """Property: random seeds, loads, and fleet shapes never break the
    exact-decomposition invariant (the span analogue of the tracker's
    replay-conservation sweep)."""
    cfg, params, cost = setup
    seed = data.draw(st.integers(0, 2**16), label="seed")
    n_req = data.draw(st.sampled_from((4, 8, 12)), label="n_req")
    rate = data.draw(st.sampled_from((100.0, 2000.0)), label="rate")
    cl, res, mem = _run_fleet(
        cfg, params, cost, n_requests=n_req, seed=seed, arrival_rate=rate
    )
    assert len(res.outputs) == n_req
    assert validate_trace(_stream(mem)) == [], seed


# ---------------- SLO monitoring ----------------


def test_slo_monitor_burn_rates():
    mon = SLOMonitor(
        SloPolicy(ttft=0.1, tpot=0.01, target=0.9), windows=(10.0, 100.0)
    )
    for i in range(20):
        mon.observe(t=float(i), ttft=0.05, tpot=0.005, queue_wait=0.01)
    for i in range(5):
        mon.observe(t=20.0 + i, ttft=1.0, tpot=0.005)  # TTFT violations
    s = mon.summary(now=25.0)
    assert s["observed"] == 25 and s["violations"] == 5
    # last 10s: 5 ok + 5 bad -> rate .5 / budget .1; 100s: 5/25 / .1
    assert s["burn_10s"] == pytest.approx(5.0)
    assert s["burn_100s"] == pytest.approx(2.0)
    assert s["queue_wait"]["n"] == 20  # nan milestones don't count
    assert s["ttft"]["max"] == 1.0
    assert s["ttft"]["p50"] <= s["ttft"]["p99"] <= s["ttft"]["max"]


def test_slo_monitor_without_policy_streams_hists_only():
    mon = SLOMonitor()
    mon.observe(t=0.0, ttft=0.2, tpot=0.001)
    s = mon.summary(now=1.0)
    assert s["observed"] == 1 and "violations" not in s
    assert mon.burn_rates(1.0) == {}


def test_streaming_hist_percentiles_bracket_exact():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-3.0, sigma=1.0, size=2000)
    h = StreamingHist()
    for x in xs:
        h.add(float(x))
    for q in (50, 95, 99):
        exact = float(np.percentile(xs, q))
        est = h.percentile(q)
        # log buckets at 8/decade: within one bucket ratio (10^(1/8))
        assert exact * 0.9 <= est <= exact * 1.4, (q, exact, est)
    assert h.percentile(100) == float(xs.max())


def test_fleet_surfaces_slo_and_burn_rates(setup):
    cfg, params, cost = setup
    slo = SloPolicy(ttft=10.0, tpot=10.0, target=0.99)
    cl, res, _ = _run_fleet(cfg, params, cost, n_requests=8, slo=slo)
    assert res.slo_summary["observed"] == len(res.outputs)
    assert res.slo_summary["violations"] == 0
    assert any(k.startswith("burn_") for k in res.slo_summary)
    per_engine = [e.summary() for e in cl.engines]
    assert sum(s["slo"]["observed"] for s in per_engine) == len(res.outputs)
    assert all(s["spans"] > 0 for s in per_engine)
    assert all(s["slo"]["queue_wait"]["n"] == s["completed"]
               for s in per_engine)


# ---------------- Perfetto export ----------------


def test_trace_export_roundtrip_and_flows(setup, tmp_path):
    """A real disagg trace exports to valid trace_event JSON with one
    named track per engine and paired handoff flow arrows."""
    cfg, params, cost = setup
    path = tmp_path / "trace.jsonl"
    tracker = JsonlTracker(path)
    spec = TrafficSpec(
        vocab=cfg.vocab, n_requests=6, arrival_rate=2000.0,
        prompt_lens=((8, 1.0),), gen_lens=((4, 1.0),), seed=7,
    )
    cl = DisaggCluster(
        cfg, params, n_engines=3, slots=SLOTS, max_len=MAX_LEN,
        block_tokens=BLOCK, cost=cost, spec=spec, tracker=tracker,
    )
    res = cl.run(synthesize(spec))
    tracker.finish()

    from repro.perf import trace_export

    out = tmp_path / "trace.perfetto.json"
    assert trace_export.main([str(path), "--check", "-o", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert validate_trace_events(doc) == []
    evs = doc["traceEvents"]
    track_names = {
        e["args"]["name"] for e in evs if e["ph"] == "M"
    }
    assert any("prefill" in n for n in track_names)
    assert any("decode" in n for n in track_names)
    starts = [e for e in evs if e["ph"] == "s"]
    # every request crossed prefill -> decode exactly once
    assert len(starts) == len(res.outputs)
    assert all(e["cat"] == "handoff" for e in starts)
    assert any(e["ph"] == "C" for e in evs)  # gauges became counters


def test_validate_trace_events_catches_malformed():
    assert validate_trace_events({}) != []
    assert validate_trace_events({"traceEvents": {}}) != []
    bad_dur = {"traceEvents": [{"ph": "X", "name": "x", "ts": 0.0}]}
    assert any("dur" in e for e in validate_trace_events(bad_dur))
    bad_ts = {"traceEvents": [{"ph": "C", "name": "c"}]}
    assert any("ts" in e for e in validate_trace_events(bad_ts))
    unpaired = {
        "traceEvents": [{"ph": "s", "name": "f", "ts": 0.0, "id": 1}]
    }
    assert any("unpaired" in e for e in validate_trace_events(unpaired))
    ok = to_trace_events([])
    assert validate_trace_events(ok) == []
