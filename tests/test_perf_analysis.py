"""Unit tests for the loop-aware HLO cost analysis (perf/hlo_analysis).

The roofline numbers in EXPERIMENTS.md are only as good as this parser:
validate trip-count multiplication, dot-flop math, collective accounting
and the in-place dynamic-update-slice special cases on hand-written HLO,
then cross-check against a real compiled module where XLA's own cost
analysis is exact (loop-free graph).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.perf.hlo_analysis import (
    analyze,
    computation_multipliers,
    parse_module,
    shape_bytes,
    xla_cost_analysis,
)


SYNTHETIC = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %w = f32[16,16] constant({...})
  %y = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%y), replica_groups={}, to_apply=%sum
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> (s32[], f32[8,16]) {
  %arg = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %arg)
  ROOT %w2 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert shape_bytes("bf16[4]") == 8
    assert shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert shape_bytes("pred[10,10]") == 100


def test_synthetic_trip_count_multiplies():
    comps = parse_module(SYNTHETIC)
    assert set(comps) == {"body", "cond", "sum", "main"}
    mult, kind = computation_multipliers(comps)
    assert mult["main"] == 1.0
    assert mult["body"] == 5.0
    assert mult["cond"] == 5.0
    cost = analyze(SYNTHETIC)
    # dot: 2 * 8*16 * 16 flops, executed 5 times
    assert cost.dot_flops == 5 * 2 * 8 * 16 * 16
    # all-reduce operand: 8*16*4 bytes, 5 times
    assert cost.collective_bytes["all-reduce"] == 5 * 8 * 16 * 4


DUS_HLO = """
HloModule dus

%fused_dus (a: f32[64,16], u: f32[1,16], i: s32[]) -> f32[64,16] {
  %a = f32[64,16] parameter(0)
  %u = f32[1,16] parameter(1)
  %i = s32[] parameter(2)
  %z = s32[] constant(0)
  ROOT %d = f32[64,16] dynamic-update-slice(%a, %u, %i, %z)
}

ENTRY %main (buf: f32[64,16], upd: f32[1,16], idx: s32[]) -> f32[64,16] {
  %buf = f32[64,16] parameter(0)
  %upd = f32[1,16] parameter(1)
  %idx = s32[] parameter(2)
  ROOT %f = f32[64,16] fusion(%buf, %upd, %idx), kind=kLoop, calls=%fused_dus
}
"""


def test_dus_fusion_counts_update_not_buffer():
    cost = analyze(DUS_HLO)
    # 3 x update bytes (1*16*4), NOT the 64*16*4 buffer
    assert cost.traffic_bytes == 3 * 1 * 16 * 4


def test_against_xla_cost_analysis_loop_free():
    """On a loop-free jit, our dot flops match XLA's cost analysis."""

    @jax.jit
    def f(a, b):
        return a @ b

    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 32), jnp.float32)
    compiled = f.lower(a, b).compile()
    ours = analyze(compiled.as_text()).dot_flops
    theirs = xla_cost_analysis(compiled).get("flops", 0.0)
    assert ours == 2 * 64 * 128 * 32
    # XLA counts the same matmul (modulo fusion bookkeeping)
    assert abs(ours - theirs) / ours < 0.05


def test_scan_undercount_demonstrated():
    """The reason this module exists: XLA's cost analysis does NOT
    multiply scan bodies by trip count; ours does."""
    n = 10

    @jax.jit
    def f(x, w):
        def body(c, _):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, None, length=n)
        return out

    x = jnp.ones((32, 64), jnp.float32)
    w = jnp.ones((64, 64), jnp.float32)
    compiled = f.lower(x, w).compile()
    per_iter = 2 * 32 * 64 * 64
    ours = analyze(compiled.as_text()).dot_flops
    theirs = float(xla_cost_analysis(compiled).get("flops", 0.0))
    assert ours == n * per_iter, (ours, n * per_iter)
    assert theirs <= per_iter * 2  # XLA counts the body ~once
