"""Budgeted weight-residency subsystem: planner invariants, the
weight-streaming kernel vs its oracle, budgeted-vs-full serve
token-identity (the acceptance gate), and the launch.port §V ordering."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.resource_model import TPU_TIERS, TPU_V5E
from repro.core.vmem_plan import WeightBlock, pack_blocks, vmem_tile_ram
from repro.models import lm
from repro.runtime.kv_pool import KVPool
from repro.runtime.residency import (
    TrafficProfile,
    compile_residency_plan,
    stream_ahead_depth,
    weight_blocks,
)
from repro.runtime.scheduler import Scheduler

BLOCK, MAX_LEN, SLOTS, P, GEN = 4, 16, 2, 4, 4


def _cfg(w_bits=0):
    cfg = get_smoke_config("smollm_360m")
    return dataclasses.replace(cfg, w_bits=w_bits) if w_bits else cfg


def _total_block_bytes(cfg):
    return sum(b.padded_bytes() for b in weight_blocks(cfg))


# ---------------- vmem_plan packing bridge ----------------


def test_vmem_tile_ram_matches_chip_geometry():
    """blocks_for on the tile primitive == chip.tile_blocks_for exactly."""
    ram = vmem_tile_ram(TPU_V5E)
    for rows, cols, bits in [(128, 256, 1), (96, 130, 2), (7, 7, 16)]:
        blk = WeightBlock("b", rows, cols, bits)
        carrier_rows = -(-rows * bits // 8)
        assert (
            ram.blocks_for(cols * 8, carrier_rows)
            == TPU_V5E.tile_blocks_for(carrier_rows, cols)
        )
        # padded_bytes is the tile count times the tile byte size
        assert blk.padded_bytes() == TPU_V5E.tile_blocks_for(
            carrier_rows, cols
        ) * TPU_V5E.sublane * TPU_V5E.lane


@pytest.mark.parametrize("solver", ["ffd", "anneal"])
def test_pack_blocks_is_valid_packing(solver):
    blocks = weight_blocks(_cfg(w_bits=1))
    packing = pack_blocks(blocks, solver=solver, max_height=4)
    packing.validate(max_height=4)
    # packing can only improve on one-block-per-bin tile counts
    solo = sum(
        vmem_tile_ram().blocks_for(it.width, it.depth)
        for it in packing.items
    )
    assert packing.total_blocks <= solo


# ---------------- planner ----------------


def test_plan_budget_monotonicity_and_accounting():
    cfg = _cfg()
    total = _total_block_bytes(cfg)
    fracs = [0.0, 0.4, 1.1]
    plans = [
        compile_residency_plan(
            cfg, vmem_budget_bytes=int(total * f),
            traffic=TrafficProfile(lanes=2),
        )
        for f in fracs
    ]
    res = [p.resident_fraction for p in plans]
    assert res == sorted(res), "resident set must grow with the budget"
    assert plans[0].resident_fraction == 0.0
    assert plans[-1].resident_fraction == 1.0
    assert plans[-1].streamed_bytes_per_step == 0
    assert plans[-1].hbm_traffic_reduction == 1.0
    for p in plans:
        assert p.resident_bytes <= p.vmem_budget_bytes
        mask = p.layer_stream_mask(cfg)
        assert len(mask) == cfg.n_layers


def test_plan_packed_blocks_shrink_with_bits():
    """1-bit carriers need ~1/32 the tiles of f32 — the FCMP packing win
    that makes the whole model resident where dense was not."""
    dense, packed = _total_block_bytes(_cfg()), _total_block_bytes(
        _cfg(w_bits=1)
    )
    assert packed * 8 <= dense


def test_stream_ahead_depth_maps_rf():
    """R_F mapping: the packing bandwidth surplus funds the ring depth."""
    assert stream_ahead_depth(_cfg()) == 2  # no surplus -> minimum ring
    assert stream_ahead_depth(_cfg(w_bits=1)) == 8  # 32x surplus, clamped
    assert stream_ahead_depth(_cfg(w_bits=2)) == 8
    bf16 = dataclasses.replace(_cfg(w_bits=2), dtype="bfloat16")
    assert stream_ahead_depth(bf16) == 4  # 2 ports * 8x surplus / H_B=4


def test_plan_residency_is_layer_granular():
    """No stranded VMEM: residency is all-or-nothing per layer, so the
    plan's reported streamed bytes equal exactly what the layer-granular
    executor streams."""
    cfg = _cfg(w_bits=1)
    total = _total_block_bytes(cfg)
    for frac in (0.2, 0.5, 0.8):
        plan = compile_residency_plan(
            cfg, vmem_budget_bytes=int(total * frac),
            traffic=TrafficProfile(lanes=2),
        )
        res = plan.block_resident()
        mask = plan.layer_stream_mask(cfg)
        for l in range(cfg.n_layers):
            states = {
                r for n, r in res.items() if n.startswith(f"L{l:03d}.")
            }
            assert len(states) == 1, f"layer {l} partially resident"
            assert mask[l] == (not states.pop())
        executor_streams = sum(
            b.padded_bytes()
            for b in plan.blocks
            if mask[int(b.name[1:4])]
        )
        assert plan.streamed_bytes_per_step == executor_streams


def test_moe_read_weights_scale_expert_value():
    from repro.runtime.residency.plan import read_weight

    moe = get_smoke_config("olmoe_1b_7b")
    w = read_weight("L000.e0.w1", moe)
    assert w == moe.experts_per_token / moe.n_experts
    assert read_weight("L000.w1", _cfg()) == 1.0


# ---------------- weight-streaming kernel vs oracle ----------------


@pytest.mark.parametrize("bits,depth", [(0, 2), (1, 2), (2, 4), (0, 3)])
def test_weight_stream_kernel_matches_ref(bits, depth):
    from repro.kernels import weight_stream as ws
    from repro.kernels.ops import pack_weights
    from repro.kernels.ref import stream_matmul_ref

    rng = np.random.default_rng(bits * 10 + depth)
    m, k, n = 8, 512, 256
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    scale = jnp.asarray(rng.uniform(0.5, 2.0, size=(n,)).astype(np.float32))
    if bits == 0:
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    else:
        vals = rng.integers(-1, 2, size=(k, n)).astype(np.float32)
        if bits == 1:
            vals = np.sign(vals + 0.5)
        w = pack_weights(jnp.asarray(vals), bits)
    out = ws.stream_matmul(
        x, w, scale, bits=bits, k=k, bn=128, ck=128, stream_depth=depth,
        interpret=True,
    )
    ref = stream_matmul_ref(x, w, scale, bits, k)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
    )


def test_ops_stream_matmul_pads_uneven_shapes():
    from repro.kernels.ops import stream_matmul

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 3, 100)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(100, 70)).astype(np.float32))
    out = stream_matmul(x, w, None, bits=0, k=100)
    assert out.shape == (2, 3, 70)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(jnp.einsum("...k,kn->...n", x, w)),
        rtol=1e-4, atol=1e-4,
    )


# ---------------- budgeted serve equivalence (acceptance gate) ----------------


def _serve_outputs(cfg, params, prompts, plan):
    pool = KVPool.for_slots(
        cfg, slots=SLOTS, max_len=MAX_LEN, block_tokens=BLOCK
    )
    sched = Scheduler(
        cfg, params, pool, slots=SLOTS, max_len=MAX_LEN, residency=plan
    )
    for p in prompts:
        sched.submit(p, GEN)
    sched.run()
    return sched.outputs()


@pytest.mark.parametrize("w_bits", [0, 1])
def test_budgeted_serve_token_identical(w_bits):
    """`--vmem-budget` decode == unbudgeted decode, token for token, on
    the dense LM family (w_bits=0) and the FCMP-packed 1-bit variant
    (the paper's CNN precision), with the plan forced to stream."""
    cfg = _cfg(w_bits)
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(0, cfg.vocab, size=(P,)).astype(np.int32)
        for _ in range(3)
    ]
    plan = compile_residency_plan(
        cfg,
        vmem_budget_bytes=_total_block_bytes(cfg) // 2,
        traffic=TrafficProfile(lanes=SLOTS, prompt_len=P, gen_len=GEN),
    )
    mask = plan.layer_stream_mask(cfg)
    assert any(mask), "plan must stream at least one layer"
    assert not all(mask), "half budget should pin at least one layer"
    full = _serve_outputs(cfg, params, prompts, None)
    budgeted = _serve_outputs(cfg, params, prompts, plan)
    assert full == budgeted


def test_moe_budgeted_serve_token_identical():
    """Expert streaming is the moe analog of the dense layer stream:
    a half-budget plan pins some (layer, expert) regions and streams the
    rest through the weight ring, and because the dropless dispatch scans
    experts in the same order either way, the budgeted token stream is
    identical to the unbudgeted one."""
    cfg = get_smoke_config("olmoe_1b_7b")
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(6)
    prompts = [
        rng.integers(0, cfg.vocab, size=(P,)).astype(np.int32)
        for _ in range(3)
    ]
    plan = compile_residency_plan(
        cfg,
        vmem_budget_bytes=_total_block_bytes(cfg) // 2,
        traffic=TrafficProfile(lanes=SLOTS, prompt_len=P, gen_len=GEN),
    )
    mask = np.asarray(plan.expert_stream_mask(cfg), bool)
    assert mask.shape == (cfg.n_layers, cfg.n_experts)
    assert mask.any(), "plan must stream at least one expert"
    assert not mask.all(), "half budget should pin at least one expert"
    full = _serve_outputs(cfg, params, prompts, None)
    budgeted = _serve_outputs(cfg, params, prompts, plan)
    assert full == budgeted


def test_budgeted_serve_still_rejects_stateful_families():
    """The residency executor streams FFN weights; ssm/hybrid recurrent
    state is out of its scope and must fail loudly, not silently."""
    hyb = get_smoke_config("zamba2_2p7b")
    plan = compile_residency_plan(
        hyb, vmem_budget_bytes=0, traffic=TrafficProfile(lanes=2)
    )
    from repro.runtime.residency import make_budgeted_paged_serve_step

    with pytest.raises(ValueError, match="streamable-FFN"):
        make_budgeted_paged_serve_step(hyb, plan)


# ---------------- launch.port (§V ordering) ----------------


@pytest.mark.parametrize(
    "arch,target", [("cnv_w1a1", "zynq7012s"), ("rn50_w2a2", "u280")]
)
def test_port_reproduces_section_v_ordering(arch, target):
    from repro.launch.port import accel_port_rows

    rows = {r["device"]: r for r in accel_port_rows(arch)}
    r = rows[target]
    assert not r["baseline_fits"], "port target must be the smaller part"
    assert r["packed_fits"], "FCMP packing must make the design fit"
    assert r["fcmp_delta_fps_pct"] < r["fold2_delta_fps_pct"]
    assert r["recommended"] == "fcmp"


def test_port_lm_ladder_prefers_packing():
    from repro.launch.port import lm_port_rows

    rows = lm_port_rows("smollm_360m", quant=1, lanes=8)
    tiers = {r["device"] for r in rows}
    assert tiers == set(TPU_TIERS)
    by = {(r["device"], r["variant"]): r for r in rows}
    for tier in TPU_TIERS:
        packed = by[(tier, "fcmp_packed")]
        dense = by[(tier, "dense")]
        assert packed["tokens_per_s"] >= dense["tokens_per_s"]
        assert (
            packed["streamed_mib_per_step"] <= dense["streamed_mib_per_step"]
        )
