"""End-to-end system tests: train->checkpoint->kill->resume on a real
(reduced) model, packed-weight serving, and the streamlined CNN datapath."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.models import lm
from repro.optim.adamw import AdamW
from repro.runtime.steps import make_serve_step, make_train_step
from repro.runtime.train import TrainLoop, TrainLoopConfig


def _setup(arch="smollm_360m"):
    cfg = get_smoke_config(arch)
    opt = AdamW(lr=1e-3, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, opt, remat="none", ce_chunk=16))
    params = lm.init_params(cfg, jax.random.key(0))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=2, seq_len=32, seed=1)
    return cfg, opt, step, params, pipe


def test_train_ckpt_kill_resume_equals_uninterrupted(tmp_path):
    loop_cfg = TrainLoopConfig(n_steps=12, ckpt_every=4, ckpt_async=False)

    # reference: uninterrupted
    cfg, opt, step, params, pipe = _setup()
    ref, _, _ = TrainLoop(step, pipe, None, loop_cfg).run(
        params, opt.init(params)
    )

    # interrupted at step 7 -> restart from the step-4 checkpoint
    cfg, opt, step, params, pipe = _setup()
    ckpt = CheckpointManager(str(tmp_path))

    class Boom(RuntimeError):
        pass

    def bomb(s):
        if s == 7:
            raise Boom()

    with pytest.raises(Boom):
        TrainLoop(step, pipe, ckpt, loop_cfg, pre_step_hook=bomb).run(
            params, opt.init(params)
        )

    cfg, opt, step, params, pipe = _setup()
    loop = TrainLoop(step, pipe, ckpt, loop_cfg)
    p, s, start = loop.restore_or_init(params, opt.init(params))
    assert start == 4
    out, _, _ = loop.run(p, s, start)

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6,
        )


def test_loss_descends_on_learnable_data():
    cfg, opt, step, params, pipe = _setup()
    state = opt.init(params)
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses[::6]


def test_packed_weights_serve_loop():
    """FCMP-packed (1-bit) model generates greedily without NaNs and the
    packed leaves are genuinely uint8 carriers (16x smaller)."""
    cfg = dataclasses.replace(get_smoke_config("llama3p2_1b"), w_bits=1)
    params = lm.init_params(cfg, jax.random.key(0))
    w1 = params["layers"]["w1"]
    dense_bytes = cfg.n_layers * cfg.d_model * cfg.d_ff * 2
    packed_bytes = w1["packed"].size + w1["scale"].size * 4
    assert packed_bytes < dense_bytes / 8
    serve = jax.jit(make_serve_step(cfg))
    cache = lm.init_cache(cfg, 2, 12)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(8):
        logits, cache = serve(params, tok, cache)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_cnn_streamlined_matches_float_path():
    """Paper §III-B: BN+act folded to thresholds is bit-exact vs the QAT
    graph in eval mode — on the full CNV topology."""
    from repro.models.cnn import (
        cnn_forward,
        cnn_forward_streamlined,
        cnv_topology,
        init_cnn_params,
        streamline_params,
    )

    specs = cnv_topology(w_bits=1, a_bits=2)
    params = init_cnn_params(specs, jax.random.key(0))
    # randomise BN stats so the fold is non-trivial
    k = jax.random.key(1)
    for sp in specs:
        k, k1, k2 = jax.random.split(k, 3)
        params[sp.name]["bn_mu"] = (
            jax.random.normal(k1, (sp.c_out,)) * 0.2
        )
        params[sp.name]["bn_var"] = (
            jax.random.uniform(k2, (sp.c_out,)) * 2.0 + 0.1
        )
    x = jax.random.normal(jax.random.key(2), (2, 32, 32, 3))
    ref = cnn_forward(params, specs, x, train=False)
    sparams = streamline_params(params, specs)
    got = cnn_forward_streamlined(sparams, specs, x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4
    )


def test_conv_as_mvau_kernel_path():
    """The im2col + fused Pallas MVAU path equals the conv+threshold path."""
    from repro.models.cnn import (
        cnn_forward,
        conv_as_mvau,
        cnv_topology,
        init_cnn_params,
        streamline_params,
    )

    specs = cnv_topology(w_bits=1, a_bits=2)[1:2]  # conv1 template
    sp = dataclasses.replace(specs[0], c_in=8, c_out=16, pool=False)
    params = init_cnn_params([sp], jax.random.key(0))
    sparams = streamline_params(params, [sp])
    x = jax.random.normal(jax.random.key(1), (1, 8, 8, 8))
    want = cnn_forward(params, [sp], x, train=False)
    got = conv_as_mvau(
        x, np.asarray(sparams[sp.name]["w"]),
        sparams[sp.name]["thresholds"], sp.w_bits,
    )
    np.testing.assert_allclose(
        np.asarray(got).reshape(want.shape), np.asarray(want),
        rtol=1e-4, atol=1e-4,
    )


def test_packed_arch_train_step_excludes_carriers():
    """ROADMAP bugfix: jax.grad over a packed (w_bits=1) arch must not
    crash — uint8 carriers get float0 tangents (allow_int) and AdamW
    passes them through untouched while float leaves keep training."""
    cfg = dataclasses.replace(get_smoke_config("llama3p2_1b"), w_bits=1)
    opt = AdamW(lr=1e-3, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, opt, remat="none", ce_chunk=16))
    params = lm.init_params(cfg, jax.random.key(0))
    state = opt.init(params)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=2, seq_len=32, seed=1)
    carriers_before = np.asarray(params["layers"]["w1"]["packed"])
    embed_before = np.asarray(params["embed"])
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    new_params, state, m = step(params, state, batch)
    assert np.isfinite(float(m["loss"]))
    np.testing.assert_array_equal(
        np.asarray(new_params["layers"]["w1"]["packed"]), carriers_before
    )
    assert new_params["layers"]["w1"]["packed"].dtype == jnp.uint8
    assert not np.array_equal(np.asarray(new_params["embed"]), embed_before)


def test_train_driver_rejects_quant_on_packed_arch(capsys):
    """`train.py --quant 1` on a packing arch exits with an actionable
    message instead of a jax.grad traceback; unknown --arch likewise."""
    from repro.launch import train as train_launch

    rc = train_launch.main(
        ["--arch", "llama3p2_1b", "--smoke", "--quant", "1", "--steps", "1"]
    )
    out = capsys.readouterr().out
    assert rc == 2
    assert "inference-only" in out and "quantize" in out

    rc = train_launch.main(["--arch", "not_a_real_arch", "--smoke"])
    out = capsys.readouterr().out
    assert rc == 2
    assert "valid archs" in out
