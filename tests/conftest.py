"""Tier-1 test configuration.

Makes the suite hermetic: ``src`` is put on ``sys.path`` (so plain
``python -m pytest`` works without exporting PYTHONPATH), and when the
real ``hypothesis`` library is not installed (the pinned container image
cannot pip-install; CI installs it via the ``test`` extra in
pyproject.toml) the deterministic stub from ``repro.testing`` is
registered so the property suites still collect and run.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.testing.hypothesis_stub import install_if_missing

install_if_missing()
